"""Figure 1 — error-per-iteration for the optimization primitives, plus the
fused-gradient hot-path comparison.

Reproduces the paper's four runs (linear, linear+L1, logistic,
logistic+L2) with all six methods at the same initial step size, reporting
log10(f_k − f*) at fixed iteration budgets.  Problem sizes are scaled to
this container (the paper's 10000×1024 runs in minutes on one core; we use
the same generator at 1000×128 so the whole figure reproduces in seconds —
pass --full for paper-size).

The fused section benchmarks the single-pass fused gradient
(kernels/fusedgrad) against the apply+adjoint baseline on the gra/lbfgs hot
loops and emits one ``BENCH {json}`` line per config with wall time,
iterations/sec, the *counted* A-passes per attempt/evaluation (structural:
via a CountingLinop trace — 2 unfused → 1 fused), and the roofline-modeled
per-pass times.  Wired into ``run.py --only optim``.
"""
from __future__ import annotations

import json
import time
from dataclasses import replace as _dc_replace

import numpy as np

from repro.core.optim import (make_problem, minimize, composite_value,
                              METHODS)
from repro.core.tfocs import CountingLinop
from repro.launch import telemetry

# Trace-time A-pass call sites per method (see CountingLinop: while-loop
# bodies trace once, so counts are structural).  gra traces its attempt
# body once plus one init evaluation; lbfgs traces value_and_grad at init,
# at the first probe, and in the line-search body — 3 sites, no extra init.
_SITES = {"gra": ("init+attempts", 1, 1), "lbfgs": ("evals", 0, 3)}


def fused_pass_counts(pname: str, method: str, fused: bool, *,
                      m: int = 200, n: int = 32) -> dict:
    """Structural A-pass counts for one solver config on a tiny problem.

    Returns the raw trace counts plus `per_attempt`, the A-passes each
    backtracking attempt / line-search evaluation performs (the number the
    fused kernel halves: 2 → 1).  Deterministic — used by the perf-smoke
    test as well as the BENCH emission below."""
    p = make_problem(pname, m=m, n=n)
    wrapped = CountingLinop(p.linop)
    pw = _dc_replace(p, linop=wrapped)
    minimize(pw, method, max_iters=2, fused=fused)
    counts = dict(wrapped.counts)
    _, init_passes, sites = _SITES[method]
    total = sum(counts.values())
    per_attempt = (total - init_passes) / sites
    return {"counts": counts, "total": total, "per_attempt": per_attempt}


def _timed(p, method, fused, iters, reps=3):
    """Warm jitted-loop wall time: the whole solver is jitted once (tol=0 so
    it runs exactly `iters` iterations) and timed over warm repeats, so the
    numbers are pure loop runtime — no trace/compile noise."""
    import jax
    import jax.numpy as jnp
    from repro.core.tfocs.solver import tfocs, TfocsOptions
    from repro.core.optim.lbfgs import lbfgs
    from repro.core.optim.problems import lbfgs_value_and_grad
    n = p.linop.in_shape[0]
    if method == "lbfgs":
        vg = lbfgs_value_and_grad(p, fused=fused)
        fn = jax.jit(lambda x0: lbfgs(vg, x0, max_iters=iters, tol=0.0)[0])
    else:
        opts = TfocsOptions(max_iters=iters, tol=0.0, L0=p.L, Lexact=p.L,
                            accel=False, backtracking=False, fused=fused)
        fn = jax.jit(
            lambda x0: tfocs(p.smooth, p.linop, p.prox, x0, opts)[0])
    x0 = jnp.zeros(n, jnp.float32)
    x = jax.block_until_ready(fn(x0))              # compile + warm-up
    dt = telemetry.timeit(lambda: fn(x0), reps=reps, warmup=0).mean_s
    return x, {"wall_s": round(dt, 4), "iters_run": iters,
               "per_iter_ms": round(dt / iters * 1e3, 4),
               "iters_per_s": round(iters / dt, 2)}


def run(full: bool = False) -> list[tuple[str, float, str]]:
    m, n = (10000, 1024) if full else (1000, 128)
    iters = 150
    rows = []
    for pname in ["linear", "linear_l1", "logistic", "logistic_l2"]:
        p = make_problem(pname, m=m, n=n)
        results = {}
        for method in METHODS:
            t0 = time.perf_counter()
            x, info = minimize(p, method, max_iters=iters)
            dt = time.perf_counter() - t0
            results[method] = (float(composite_value(p, x)), dt,
                               np.asarray(info["history"]))
        fstar = min(v[0] for v in results.values())
        for method, (f, dt, hist) in results.items():
            err = max(f - fstar, 1e-12)
            # error at 1/3 of budget, for the convergence-curve shape
            mid = hist[iters // 3]
            mid_err = max(float(mid) - fstar, 1e-12) if np.isfinite(mid) \
                else float("nan")
            rows.append((
                f"fig1_{pname}_{method}",
                dt / iters * 1e6,
                f"log10_err_final={np.log10(err):.2f};"
                f"log10_err_mid={np.log10(mid_err):.2f}"))

    # -- fused vs unfused hot-path section (BENCH json per config) -----------
    from repro.launch import planner
    fiters = 50
    for pname in ("linear", "logistic"):
        p = make_problem(pname, m=m, n=n)
        nd = p.linop.in_shape[0]
        modeled = dict(planner.plan(
            "grad", {"m": p.linop.out_shape[0], "n": nd}).alternatives)
        for method in ("gra", "lbfgs"):
            rec = {"suite": "optim_fused", "problem": pname,
                   "method": method, "m": m, "n": nd, "iters": fiters,
                   "modeled": {
                       "fused_s": modeled["fused"],
                       "unfused_s": modeled["unfused"],
                       "modeled_speedup": modeled["unfused"]
                       / max(modeled["fused"], 1e-30)}}
            for fused in (False, True):
                passes = fused_pass_counts(pname, method, fused)
                x, timing = _timed(p, method, fused, fiters)
                rec["fused" if fused else "unfused"] = dict(
                    timing,
                    a_passes_per_attempt=passes["per_attempt"],
                    trace_counts=passes["counts"],
                    objective=float(composite_value(p, x)))
            rec["a_pass_ratio"] = (
                rec["unfused"]["a_passes_per_attempt"]
                / max(rec["fused"]["a_passes_per_attempt"], 1e-30))
            rec["wall_speedup"] = (rec["unfused"]["per_iter_ms"]
                                   / max(rec["fused"]["per_iter_ms"], 1e-9))
            print("BENCH " + json.dumps(rec))
            rows.append((
                f"fused_{pname}_{method}",
                rec["fused"]["per_iter_ms"] * 1e3,
                f"a_passes_fused={rec['fused']['a_passes_per_attempt']:.0f};"
                f"a_passes_unfused="
                f"{rec['unfused']['a_passes_per_attempt']:.0f};"
                f"wall_speedup={rec['wall_speedup']:.2f}"))
    return rows
