"""Figure 1 — error-per-iteration for the optimization primitives.

Reproduces the paper's four runs (linear, linear+L1, logistic,
logistic+L2) with all six methods at the same initial step size, reporting
log10(f_k − f*) at fixed iteration budgets.  Problem sizes are scaled to
this container (the paper's 10000×1024 runs in minutes on one core; we use
the same generator at 1000×128 so the whole figure reproduces in seconds —
pass --full for paper-size).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.optim import (make_problem, minimize, composite_value,
                              METHODS)


def run(full: bool = False) -> list[tuple[str, float, str]]:
    m, n = (10000, 1024) if full else (1000, 128)
    iters = 150
    rows = []
    for pname in ["linear", "linear_l1", "logistic", "logistic_l2"]:
        p = make_problem(pname, m=m, n=n)
        results = {}
        for method in METHODS:
            t0 = time.perf_counter()
            x, info = minimize(p, method, max_iters=iters)
            dt = time.perf_counter() - t0
            results[method] = (float(composite_value(p, x)), dt,
                               np.asarray(info["history"]))
        fstar = min(v[0] for v in results.values())
        for method, (f, dt, hist) in results.items():
            err = max(f - fstar, 1e-12)
            # error at 1/3 of budget, for the convergence-curve shape
            mid = hist[iters // 3]
            mid_err = max(float(mid) - fstar, 1e-12) if np.isfinite(mid) \
                else float("nan")
            rows.append((
                f"fig1_{pname}_{method}",
                dt / iters * 1e6,
                f"log10_err_final={np.log10(err):.2f};"
                f"log10_err_mid={np.log10(mid_err):.2f}"))
    return rows
