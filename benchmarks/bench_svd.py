"""Table 1 — ARPACK-analogue SVD runtimes, plus the 3-way mode shoot-out.

The paper factorizes (23M×38k, 51M nnz) … (94M×4k, 1.6B nnz) matrices on a
68-executor cluster, reporting seconds-per-Lanczos-iteration and totals.
This container is one CPU core, so the benchmark runs ~1000× scaled-down
replicas with the same aspect ratios/sparsity structure and reports:
  * measured time per matrix-free Lanczos iteration (the paper's metric),
  * the projected per-iteration time on the 256-chip v5e pod from the
    roofline (matvec bytes / aggregate HBM bandwidth), which is the
    apples-to-apples "what the production mesh would do" number.

The second half races compute_svd's three modes (gram / lanczos /
randomized) on the same moderately-rectangular dense matrix — the regime
the randomized path was added for — and emits one ``BENCH {json}`` line per
mode with wall time and relative singular-value error vs the dense oracle.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import CoordinateMatrix, RowMatrix
from repro.core.linalg import compute_svd, lanczos_eigsh
from repro.launch import telemetry
from repro.launch.machine import V5E

# (rows, cols, nnz) ~ paper Table 1 ÷ 1000
CASES = [
    ("tbl1_23Mx38K", 23_000, 380, 51_000),
    ("tbl1_63Mx49K", 63_000, 490, 440_000),
    ("tbl1_94Mx4K", 94_000, 40, 1_600_000),
]

POD_HBM_BW = 256 * V5E.hbm_bw     # aggregate bytes/s, 256-chip pod
SCALE = 1000                      # size scale-down factor


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, m, n, nnz in CASES:
        rng = np.random.default_rng(0)
        ri = rng.integers(0, m, nnz).astype(np.int32)
        ci = rng.integers(0, n, nnz).astype(np.int32)
        va = rng.normal(size=nnz).astype(np.float32)
        A = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                    jnp.asarray(va), (m, n))
        op = jax.jit(A.normal_op())
        v = jnp.ones((n,), jnp.float32) / np.sqrt(n)
        op(v).block_until_ready()            # compile
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            v = op(v)
            v = v / jnp.linalg.norm(v)
        v.block_until_ready()
        per_iter = (time.perf_counter() - t0) / iters

        # full solve (k=5 like the paper)
        t0 = time.perf_counter()
        k = min(5, n - 2)
        vals, vecs, info = lanczos_eigsh(op, n, k, tol=1e-4,
                                         max_restarts=20)
        jax.block_until_ready(vals)
        total = time.perf_counter() - t0

        # roofline projection to the pod at FULL paper size:
        # per matvec pass, move nnz·(val+2 idx) + dense vectors
        full_nnz = nnz * SCALE
        bytes_per_iter = 2 * (full_nnz * 12) + 8 * (m * SCALE + n * 10)
        projected = bytes_per_iter / POD_HBM_BW
        rows.append((f"svd_{name}_periter", per_iter * 1e6,
                     f"pod_projected_s={projected:.4f}"))
        rows.append((f"svd_{name}_total", total * 1e6,
                     f"restarts={int(info['restarts'])}"))
    rows.extend(run_mode_comparison())
    return rows


def run_mode_comparison(m: int = 20_000, n: int = 1024, k: int = 8
                        ) -> list[tuple[str, float, str]]:
    """Race gram / lanczos / randomized on one moderately-rectangular dense
    matrix (rank-structured + noise).  Emits a ``BENCH {json}`` line per
    mode; returns the CSV rows for the harness."""
    rng = np.random.default_rng(0)
    rank = 2 * k
    U = np.linalg.qr(rng.normal(size=(m, rank)))[0]
    V = np.linalg.qr(rng.normal(size=(n, rank)))[0]
    A = ((U * np.linspace(100.0, 10.0, rank)) @ V.T
         + 0.02 * rng.normal(size=(m, n))).astype(np.float32)
    s_ref = np.linalg.svd(A, compute_uv=False)[:k]
    rm = RowMatrix.create(A)

    modes = {
        "gram": {},
        "lanczos": {"tol": 1e-5, "max_restarts": 60},
        "randomized": {"oversampling": 10, "power_iters": 2},
    }
    rows = []
    for mode, kw in modes.items():
        # Warm-up run eats the jit trace+compile; the timed run is the
        # steady-state number the modes are actually compared on.
        res = None

        def go():
            nonlocal res
            res = compute_svd(rm, k, mode=mode, compute_u=False, **kw)
            return res.s

        dt = telemetry.timeit(go, reps=1, warmup=1).times[0]
        rel = float(np.max(np.abs(np.asarray(res.s) - s_ref) / s_ref))
        record = {"bench": "svd_mode_comparison", "mode": mode,
                  "m": m, "n": n, "k": k, "wall_s": round(dt, 4),
                  "rel_sigma_err": rel}
        if mode == "randomized":
            record["passes_over_A"] = int(res.info["passes_over_A"])
            record["tail_ratio"] = float(res.info["tail_ratio"])
        if mode == "lanczos":
            record["restarts"] = int(res.info["restarts"])
        print("BENCH", json.dumps(record))
        rows.append((f"svd_mode_{mode}", dt * 1e6,
                     f"rel_sigma_err={rel:.2e}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
