"""Table 1 — ARPACK-analogue SVD runtimes.

The paper factorizes (23M×38k, 51M nnz) … (94M×4k, 1.6B nnz) matrices on a
68-executor cluster, reporting seconds-per-Lanczos-iteration and totals.
This container is one CPU core, so the benchmark runs ~1000× scaled-down
replicas with the same aspect ratios/sparsity structure and reports:
  * measured time per matrix-free Lanczos iteration (the paper's metric),
  * the projected per-iteration time on the 256-chip v5e pod from the
    roofline (matvec bytes / aggregate HBM bandwidth), which is the
    apples-to-apples "what the production mesh would do" number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import CoordinateMatrix
from repro.core.linalg import lanczos_eigsh

# (rows, cols, nnz) ~ paper Table 1 ÷ 1000
CASES = [
    ("tbl1_23Mx38K", 23_000, 380, 51_000),
    ("tbl1_63Mx49K", 63_000, 490, 440_000),
    ("tbl1_94Mx4K", 94_000, 40, 1_600_000),
]

POD_HBM_BW = 256 * 819e9          # aggregate bytes/s
SCALE = 1000                      # size scale-down factor


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, m, n, nnz in CASES:
        rng = np.random.default_rng(0)
        ri = rng.integers(0, m, nnz).astype(np.int32)
        ci = rng.integers(0, n, nnz).astype(np.int32)
        va = rng.normal(size=nnz).astype(np.float32)
        A = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                    jnp.asarray(va), (m, n))
        op = jax.jit(A.normal_op())
        v = jnp.ones((n,), jnp.float32) / np.sqrt(n)
        op(v).block_until_ready()            # compile
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            v = op(v)
            v = v / jnp.linalg.norm(v)
        v.block_until_ready()
        per_iter = (time.perf_counter() - t0) / iters

        # full solve (k=5 like the paper)
        t0 = time.perf_counter()
        k = min(5, n - 2)
        vals, vecs, info = lanczos_eigsh(op, n, k, tol=1e-4,
                                         max_restarts=20)
        jax.block_until_ready(vals)
        total = time.perf_counter() - t0

        # roofline projection to the pod at FULL paper size:
        # per matvec pass, move nnz·(val+2 idx) + dense vectors
        full_nnz = nnz * SCALE
        bytes_per_iter = 2 * (full_nnz * 12) + 8 * (m * SCALE + n * 10)
        projected = bytes_per_iter / POD_HBM_BW
        rows.append((f"svd_{name}_periter", per_iter * 1e6,
                     f"pod_projected_s={projected:.4f}"))
        rows.append((f"svd_{name}_total", total * 1e6,
                     f"restarts={int(info['restarts'])}"))
    return rows
