"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (paper artifacts:
Table 1 = bench_svd, Figure 1 = bench_optim, Figure 2 = bench_gemm,
§4.2 = bench_sparse; autotune = the kernel block-size sweep, which also
emits ``BENCH {json}`` lines and refreshes the persistent config cache;
planner = execution-planner golden decisions + machine-model calibration
from measured timings, persisted next to the autotune cache;
collectives = modeled-vs-measured psum time by payload size and device
count plus the link_eff fit demo, BENCH json only — never persisted;
precision = bytes/wall-clock/solution-error by storage and wire format —
f32 vs bf16 storage, int8 BlockELL, compressed int8 psums — across the
Figure-1 family, BENCH json only).
bench_optim additionally emits ``BENCH {json}`` lines for the fused-vs-
unfused gradient hot path (wall time, iterations/sec, counted A-passes
per attempt: 2 unfused → 1 fused); serve = the solver serving frontend
(bench_serve: requests/sec + p50/p99 latency under a shared-matrix trace,
batched-vs-serial throughput ratio, grouped-vs-serial A-pass counts).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-size problems (slow on one core)")
    ap.add_argument("--only", default=None,
                    help="run a single suite: "
                         "svd|optim|gemm|sparse|autotune|planner|serve|"
                         "collectives|precision")
    args = ap.parse_args()

    from benchmarks import (bench_svd, bench_optim, bench_gemm, bench_sparse,
                            bench_autotune, bench_planner, bench_serve,
                            bench_collectives, bench_precision)
    suites = {
        "svd": lambda: bench_svd.run(),
        "optim": lambda: bench_optim.run(full=args.full),
        "gemm": lambda: bench_gemm.run(),
        "sparse": lambda: bench_sparse.run(),
        "autotune": lambda: bench_autotune.run(),
        "planner": lambda: bench_planner.run(),
        "serve": lambda: bench_serve.run(full=args.full),
        "collectives": lambda: bench_collectives.run(),
        "precision": lambda: bench_precision.run(),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for sname, fn in suites.items():
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{sname}_SUITE_ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
