"""Execution-planner benchmark: golden-shape decisions + machine calibration.

    PYTHONPATH=src python -m benchmarks.bench_planner [--no-write]

Two sections, each emitting ``BENCH {json}`` lines (run.py --only planner):

  1. **Golden decisions** — ``planner.plan()`` on the shape table the
     dispatch-parity tests pin, priced against the *reference* machine
     model (explicitly, so the output is host-independent).  A decision
     that drifts from the recorded expectation flips ``stable: false`` —
     the machine-readable form of the perf-smoke guard.

  2. **Calibration** — times the kernels of several shapes on THIS host
     (off-TPU the ops wrappers run the structured jnp reference paths — the
     real execution engine of this container), builds
     ``planner.calibration_record``s, fits ``MachineModel.calibrate()``
     (least squares on the roofline terms), and reports modeled-vs-measured
     mean relative error before and after — ``tightened`` must be true.
     Unless --no-write, the fit is persisted next to the autotune config
     cache ($REPRO_AUTOTUNE_CACHE redirects both) where every subsequent
     ``plan()`` on this backend prefers it; the final BENCH line re-plans a
     golden shape to prove the calibrated constants are picked up.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as at
from repro.kernels import ops
from repro.launch import machine, planner, telemetry

# The canonical decision table: (op, dims, context, expected choice on the
# reference machine).  tests/test_perf_smoke.py asserts these stay stable;
# tests/test_planner.py pins the wider table.
GOLDEN = [
    ("sparse_matmul", {"m": 4096, "n": 2048, "nx": 1, "ell": 2, "bs": 128},
     None, "bsr"),
    ("sparse_matmul", {"m": 1024, "n": 4096, "nx": 128, "ell": 32,
                       "bs": 128}, None, "dense"),
    ("grad", {"m": 10000, "n": 1024}, None, "fused"),
    ("grad", {"m": 16, "n": 1024}, None, "unfused"),
    ("svd", {"m": 100000, "n": 4096, "k": 32}, {"kind": "row"}, "gram"),
    ("svd", {"m": 100000, "n": 16384, "k": 32}, {"kind": "row"},
     "randomized"),
    ("svd", {"m": 100000, "n": 16384, "k": 256}, {"kind": "row"},
     "lanczos"),
    ("bsr_bs", {"m": 4096, "n": 2048, "nx": 128},
     {"ell_by_bs": {8: 80, 16: 44, 32: 24, 64: 14, 128: 8}}, "bs=128"),
]

# (kernel, dims) measured for calibration — tall-skinny Gram/sketch shapes
# plus square GEMMs, the regimes the distmat layer actually hits.
CALIB_SHAPES = [
    ("gemm", {"m": 512, "k": 512, "n": 512}),
    ("gemm", {"m": 1024, "k": 1024, "n": 1024}),
    ("gemm", {"m": 2048, "k": 256, "n": 256}),
    ("tsgram", {"m": 16384, "n": 256}),
    ("tsgram", {"m": 8192, "n": 512}),
    ("fusedgrad", {"m": 10000, "n": 512}),
    ("randsketch", {"m": 16384, "n": 1024, "r": 72}),
]


def golden_plans() -> list[dict]:
    """One record per GOLDEN row, priced on the reference machine (stable
    across hosts and calibration state)."""
    out = []
    for op, dims, ctx, want in GOLDEN:
        p = planner.plan(op, dims, jnp.float32, machine=machine.V5E,
                         context=ctx)
        out.append({"op": op, "dims": dims, "choice": p.choice,
                    "expected": want, "stable": p.choice == want,
                    "modeled_us": round(p.cost_s * 1e6, 3),
                    "bound": p.breakdown.get("bound"),
                    "alternatives": {k: round(v * 1e6, 3)
                                     for k, v in p.alternatives}})
    return out


def _runner(kernel: str, dims: dict):
    rng = np.random.default_rng(0)

    def arr(*shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)

    if kernel == "gemm":
        a, b = arr(dims["m"], dims["k"]), arr(dims["k"], dims["n"])
        return lambda: ops.gemm(a, b).block_until_ready()
    if kernel == "tsgram":
        a = arr(dims["m"], dims["n"])
        return lambda: ops.tsgram(a).block_until_ready()
    if kernel == "randsketch":
        a, q = arr(dims["m"], dims["n"]), arr(dims["m"], dims["r"])
        return lambda: ops.randsketch(a, q).block_until_ready()
    if kernel == "fusedgrad":
        a = arr(dims["m"], dims["n"])
        x, t = arr(dims["n"]), arr(dims["m"])
        w = jnp.ones((dims["m"],), jnp.float32)
        return lambda: jax.block_until_ready(
            ops.fused_grad(a, x, t, w, loss="quad"))
    raise ValueError(kernel)


def measure_records(reps: int = 5) -> list[dict]:
    """Time each CALIB_SHAPES kernel on this host (median of reps, after a
    compile-eating warm-up) and wrap as calibration records."""
    records = []
    for kernel, dims in CALIB_SHAPES:
        run = _runner(kernel, dims)
        measured = telemetry.timeit(run, reps=reps, warmup=1).median_s
        blocks = at.get_config(kernel, dims, jnp.float32)
        records.append(planner.calibration_record(kernel, dims, blocks,
                                                  jnp.float32, measured))
    return records


def run(*, write: bool = True, reps: int = 5) -> list[tuple[str, float, str]]:
    rows = []

    # -- 1. golden decisions (reference machine; host-independent) ----------
    stable_all = True
    for rec in golden_plans():
        stable_all = stable_all and rec["stable"]
        print("BENCH", json.dumps(dict(rec, bench="planner_decision"),
                                  sort_keys=True))
        rows.append((f"planner_{rec['op']}_"
                     + "x".join(str(v) for v in rec["dims"].values()),
                     rec["modeled_us"],
                     f"choice={rec['choice']};stable={rec['stable']}"))

    # -- 2. calibration on this host's measured timings ---------------------
    backend = jax.default_backend()
    records = measure_records(reps=reps)
    fitted, err_before, err_after = planner.calibrate(records,
                                                      backend=backend,
                                                      write=write)
    tightened = err_after <= err_before
    print("BENCH", json.dumps({
        "bench": "planner_calibration", "backend": backend,
        "n_records": len(records), "reps": reps,
        "machine": fitted.name,
        "err_before": round(err_before, 4), "err_after": round(err_after, 4),
        "tightened": tightened,
        "mxu_eff": {k: round(v, 6) for k, v in fitted.mxu_eff.items()},
        "hbm_eff": {k: round(v, 6) for k, v in fitted.hbm_eff.items()},
        "written": write,
        "calibration_path": str(machine.calibration_path()) if write
        else None}, sort_keys=True))
    rows.append(("planner_calibration", err_after * 100,
                 f"err_before={err_before:.3f};err_after={err_after:.3f};"
                 f"tightened={tightened}"))

    if write:
        # Prove plan() prefers the calibrated constants: same golden shape,
        # default machine lookup, now reports calibrated=True.
        at.reset()
        p = planner.plan("grad", {"m": 10000, "n": 1024}, jnp.float32,
                         backend=backend)
        print("BENCH", json.dumps({
            "bench": "planner_calibrated_replan", "backend": backend,
            "machine": p.machine, "calibrated": p.calibrated,
            "choice": p.choice,
            "modeled_us": round(p.cost_s * 1e6, 3)}, sort_keys=True))
        rows.append(("planner_calibrated_replan", p.cost_s * 1e6,
                     f"calibrated={p.calibrated};choice={p.choice}"))

    rows.append(("planner_decisions_stable", 0.0, f"ok={stable_all}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--no-write", action="store_true",
                    help="fit only; do not persist the calibration")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    for name, us, derived in run(write=not args.no_write, reps=args.reps):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
