"""Offline block-size sweep for the Pallas kernels (kernels/autotune.py).

    PYTHONPATH=src python -m benchmarks.bench_autotune \
        [--kernel gemm] [--dtype f32|bf16] [--topn 3] [--reps 5] \
        [--cache PATH] [--no-write]

For every (kernel, shape) in SWEEP it enumerates layout-legal candidates,
ranks them with the roofline cost model, and emits one ``BENCH {json}``
line per (kernel, shape, config) considered.  The winner is written into
the persistent JSON cache (``--cache`` / ``$REPRO_AUTOTUNE_CACHE`` /
``~/.cache/repro/autotune.json``), where every subsequent
``ops.gemm(..., tune="auto")`` with the same shape bucket picks it up
without re-ranking or re-timing.

Selection semantics match dispatch:
  * on TPU the top-N model-ranked candidates plus the legacy hand-picked
    constants are timed on device (median of ``--reps``) and the measured
    winner is cached with its wall time — re-run this CLI once per new
    hardware generation to refresh the shipped v5e defaults;
  * on CPU (this container) timing interpret-mode kernels is meaningless,
    so the cost-model rank is the selector — deterministic, and by
    construction never worse than the legacy constants by model score
    (the legacy config is always in the ranked pool).

The final ``autotune_cache_roundtrip`` BENCH line demonstrates the cache
contract: a second ``ops.gemm`` call with the same shape bucket resolves
its config from the in-memory memo (no new ranking), and after a memo
flush from the persistent file (no new ranking either).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as at
from repro.kernels import ops

# The shape regimes the distmat/serving layers actually hit: §4-style square
# GEMMs, SUMMA panels, tall-skinny Gram/sketch reductions (Table 1 aspect
# ratios), prefill attention, and Mamba train shapes.
SWEEP: dict[str, list[dict[str, int]]] = {
    "gemm": [
        {"m": 256, "k": 256, "n": 256},
        {"m": 1024, "k": 1024, "n": 1024},
        {"m": 2048, "k": 2048, "n": 2048},
        {"m": 4096, "k": 512, "n": 4096},
        {"m": 10000, "k": 1000, "n": 1000},
    ],
    "tsgram": [
        {"m": 16384, "n": 256},
        {"m": 65536, "n": 512},
        {"m": 8192, "n": 1024},
    ],
    "randsketch": [
        {"m": 16384, "n": 2048, "r": 72},
        {"m": 65536, "n": 4096, "r": 136},
    ],
    # Figure-1 composite-gradient shard shapes (the fused optimizer hot path).
    "fusedgrad": [
        {"m": 10000, "n": 1024},
        {"m": 65536, "n": 512},
    ],
    "flash_attention": [
        {"sq": 2048, "sk": 2048, "d": 128, "causal": 1},
        {"sq": 8192, "sk": 8192, "d": 128, "causal": 1},
    ],
    "selective_scan": [
        {"s": 2048, "d": 768, "n": 16},
        {"s": 4096, "d": 1024, "n": 16},
    ],
    # SparseRowMatrix shard shapes (ROADMAP: "sweep the BSR block size too").
    # nnz sets the entry density the cost model turns into an expected ELL
    # width per candidate block size.
    "bsr": [
        {"m": 4096, "n": 2048, "nnz": 4096 * 2048 // 20, "nx": 128},
        {"m": 8192, "n": 4096, "nnz": 8192 * 4096 // 100, "nx": 128},
    ],
}

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _make_runner(kernel: str, dims: dict, dtype):
    """Closure that executes the kernel once with the given blocks and
    blocks until the device is done — the timing unit for at.sweep()."""
    rng = np.random.default_rng(0)

    def arr(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype)

    if kernel == "gemm":
        a, b = arr(dims["m"], dims["k"]), arr(dims["k"], dims["n"])
        return lambda blk: ops.gemm(a, b, **blk).block_until_ready()
    if kernel == "tsgram":
        a = arr(dims["m"], dims["n"])
        return lambda blk: ops.tsgram(a, **blk).block_until_ready()
    if kernel == "randsketch":
        a, q = arr(dims["m"], dims["n"]), arr(dims["m"], dims["r"])
        return lambda blk: ops.randsketch(a, q, **blk).block_until_ready()
    if kernel == "fusedgrad":
        a = arr(dims["m"], dims["n"])
        x, t = arr(dims["n"]), arr(dims["m"])
        w = jnp.ones((dims["m"],), jnp.float32)
        return lambda blk: jax.block_until_ready(
            ops.fused_grad(a, x, t, w, loss="quad", **blk))
    if kernel == "flash_attention":
        q = arr(1, 1, dims["sq"], dims["d"])
        k = arr(1, 1, dims["sk"], dims["d"])
        v = arr(1, 1, dims["sk"], dims["d"])
        return lambda blk: ops.flash_attention(
            q, k, v, causal=bool(dims["causal"]), **blk).block_until_ready()
    if kernel == "bsr":
        # The knob is a *format* parameter: rebuild the BlockELL per block
        # size (cached across reps) and time the SpMM through the wrapper.
        from repro.kernels.bsr import BlockELL
        dense = (rng.random((dims["m"], dims["n"]))
                 < dims["nnz"] / (dims["m"] * dims["n"])
                 ) * rng.normal(size=(dims["m"], dims["n"]))
        dense = np.asarray(jnp.asarray(dense, dtype))   # swept dtype, as arr()
        x = arr(dims["n"], dims["nx"])
        cache: dict[int, BlockELL] = {}

        def run_bsr(blk):
            bs = blk["bs"]
            if bs not in cache:
                cache[bs] = BlockELL.from_dense(dense, bs)
            ops.bsr_matmul(cache[bs], x).block_until_ready()
        return run_bsr
    if kernel == "selective_scan":
        x, dt = arr(1, dims["s"], dims["d"]), arr(1, dims["s"], dims["d"])
        A = arr(dims["d"], dims["n"])
        B, C = arr(1, dims["s"], dims["n"]), arr(1, dims["s"], dims["n"])
        D = arr(dims["d"])
        return lambda blk: ops.selective_scan(
            x, jnp.abs(dt) * 0.1, -jnp.abs(A) - 0.1, B, C, D,
            **blk).block_until_ready()
    raise ValueError(kernel)


def sweep_one(kernel: str, dims: dict, dtype, *, topn: int, reps: int,
              measure: bool, write: bool,
              calib_records: list | None = None) -> tuple[str, float, str]:
    """Rank (and on TPU, time) one shape; emit BENCH lines; cache winner.
    Measured timings are additionally appended to `calib_records` as
    MachineModel calibration records (launch/planner.calibration_record) —
    the sweep is the data source the machine model learns from."""
    backend = jax.default_backend()
    ranked = at.rank(kernel, dims, dtype)
    legacy = dict(at.KERNELS[kernel].legacy)
    legacy_model_us = at.model_time(kernel, legacy, dims, dtype) * 1e6

    measured: dict[str, float] = {}
    if measure:
        timed = at.sweep(kernel, dims, dtype, _make_runner(kernel, dims, dtype),
                         top_n=topn, reps=reps)
        measured = {json.dumps(b, sort_keys=True): s * 1e6 for s, b in timed}
        selected = timed[0][1]
        selected_us = timed[0][0] * 1e6
        if calib_records is not None:
            from repro.launch import planner
            calib_records.extend(
                planner.calibration_record(kernel, dims, b, dtype, s)
                for s, b in timed)
    else:
        selected = ranked[0][1]
        selected_us = ranked[0][0] * 1e6

    shown = ranked[:topn]
    if legacy not in [b for _, b in shown]:
        shown = shown + [(legacy_model_us / 1e6, legacy)]
    for score, blocks in shown:
        key = json.dumps(blocks, sort_keys=True)
        print("BENCH", json.dumps({
            "bench": "autotune", "kernel": kernel, "dims": dims,
            "dtype": jnp.dtype(dtype).name, "backend": backend,
            "config": blocks, "model_us": round(score * 1e6, 3),
            "measured_us": (round(measured[key], 3)
                            if key in measured else None),
            "selected": blocks == selected, "legacy": blocks == legacy,
            "not_slower_than_legacy": (
                blocks != selected
                or (measured.get(key, score * 1e6)
                    <= measured.get(json.dumps(legacy, sort_keys=True),
                                    legacy_model_us) + 1e-9)),
        }))

    if write:
        at.record(kernel, dims, dtype, selected, backend=backend,
                  source="swept" if measure else "model",
                  us=selected_us if measure else None)
    shape = "x".join(str(dims[k]) for k in at.KERNELS[kernel].dims)
    return (f"autotune_{kernel}_{shape}", selected_us,
            f"legacy_model_us={legacy_model_us:.1f};"
            f"cands={len(ranked)};cache_key="
            f"{at.cache_key(kernel, backend, dtype, dims)}")


def verify_cache_roundtrip() -> tuple[str, float, str]:
    """Prove the contract: second same-bucket ops.gemm call = no re-rank."""
    at.reset()
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(96, 160)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(160, 96)), jnp.float32)
    ops.gemm(a, b, force_pallas=True).block_until_ready()
    after_first = dict(at.stats)
    ops.gemm(a, b, force_pallas=True).block_until_ready()
    after_second = dict(at.stats)
    # Same bucket again after a memo flush: must come from the persistent
    # cache file written by this sweep (or re-rank if --no-write was used).
    cfg = at.get_config("gemm", {"m": 96, "k": 160, "n": 96}, jnp.float32)
    at._memo.clear()
    at._caches.clear()
    from_disk = at.get_config("gemm", {"m": 100, "k": 150, "n": 100},
                              jnp.float32)  # same 128x256x128 bucket
    ok = (after_second["ranked"] == after_first["ranked"]
          and after_second["memo_hits"] > after_first["memo_hits"]
          and from_disk == cfg)
    print("BENCH", json.dumps({
        "bench": "autotune_cache_roundtrip",
        "first_call_stats": after_first, "second_call_stats": after_second,
        "persistent_hit_config": from_disk,
        "second_call_reranked": after_second["ranked"] != after_first["ranked"],
        "ok": ok}))
    return ("autotune_cache_roundtrip", 0.0, f"ok={ok}")


def run(*, kernels=None, dtypes=("f32",), topn: int = 3, reps: int = 5,
        measure: bool | None = None, write: bool = True
        ) -> list[tuple[str, float, str]]:
    on_tpu = jax.default_backend() == "tpu"
    measure = on_tpu if measure is None else measure
    if measure and not on_tpu:
        # Off-TPU the ops wrappers dispatch to the block-size-agnostic jnp
        # reference, so "timing" candidates would rank pure noise — and the
        # winner would be persisted as if it had been swept.
        raise SystemExit("--measure needs a TPU backend: off-TPU timings "
                         "ignore the block config; rely on the cost-model "
                         "ranking instead (the default here)")
    rows = []
    calib_records: list[dict] = []
    for kernel, shapes in SWEEP.items():
        if kernels and kernel not in kernels:
            continue
        for dims in shapes:
            for dname in dtypes:
                rows.append(sweep_one(kernel, dims, DTYPES[dname],
                                      topn=topn, reps=reps,
                                      measure=measure, write=write,
                                      calib_records=calib_records))
    if calib_records:
        # The sweep IS the calibration data (ROADMAP: learn the cost-model
        # constants from recorded sweep timings): fit the machine model's
        # effective efficiencies and persist them next to the config cache.
        from repro.launch import planner
        fitted, err_before, err_after = planner.calibrate(calib_records,
                                                          write=write)
        print("BENCH", json.dumps({
            "bench": "autotune_calibration", "machine": fitted.name,
            "n_records": len(calib_records),
            "err_before": round(err_before, 4),
            "err_after": round(err_after, 4),
            "tightened": err_after <= err_before, "written": write},
            sort_keys=True))
        rows.append(("autotune_calibration", err_after * 100,
                     f"err_before={err_before:.3f};"
                     f"err_after={err_after:.3f}"))
    if write and (not kernels or "gemm" in kernels):
        # Seed the roundtrip probe's bucket, then demonstrate the contract.
        at.record("gemm", {"m": 96, "k": 160, "n": 96}, jnp.float32,
                  at.rank("gemm", {"m": 96, "k": 160, "n": 96},
                          jnp.float32)[0][1],
                  backend=jax.default_backend(), source="model")
        rows.append(verify_cache_roundtrip())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--kernel", action="append", default=None,
                    help="restrict to one kernel (repeatable)")
    ap.add_argument("--dtype", action="append", choices=sorted(DTYPES),
                    default=None, help="dtypes to sweep (default f32)")
    ap.add_argument("--topn", type=int, default=3)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--cache", default=None,
                    help="cache file (default $REPRO_AUTOTUNE_CACHE or "
                         "~/.cache/repro/autotune.json)")
    ap.add_argument("--measure", action="store_true",
                    help="force the on-device timing sweep (TPU only; "
                         "off-TPU the reference path ignores block sizes)")
    ap.add_argument("--no-write", action="store_true",
                    help="rank/time only; do not touch the cache")
    args = ap.parse_args()
    if args.cache:
        os.environ["REPRO_AUTOTUNE_CACHE"] = args.cache
    for name, us, derived in run(kernels=args.kernel,
                                 dtypes=tuple(args.dtype or ("f32",)),
                                 topn=args.topn, reps=args.reps,
                                 measure=args.measure or None,
                                 write=not args.no_write):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
