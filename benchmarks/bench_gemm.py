"""Figure 2 — hardware-accelerated GEMM from a managed runtime.

The paper benchmarks f2jblas/OpenBLAS/MKL/cuBLAS GEMM from the JVM across
matrix sizes.  The analogues here:
  * measured: XLA:CPU wall time per GEMM (the "managed runtime" number),
  * derived: v5e MXU roofline time (2mnk / 197 TFLOP/s vs HBM bytes/819GB/s
    — whichever dominates), the number the Pallas kernel targets; the
    kernel itself is validated against the oracle in tests (interpret mode
    is not a timing proxy).

Autotuner: the Pallas GEMM no longer uses hand-picked 256×256×512 tiles.
`ops.gemm(..., tune="auto")` (the default) resolves `bm/bn/bk` per
(backend, dtype, shape-bucket) via `repro.kernels.autotune` — persistent
cache first ($REPRO_AUTOTUNE_CACHE or ~/.cache/repro/autotune.json, JSON
{"entries": {key: {"blocks": ...}}}; shipped v5e defaults in
kernels/autotune_v5e.json), roofline cost-model ranking otherwise.  On new
hardware, re-sweep offline with

    PYTHONPATH=src python -m benchmarks.bench_autotune

which times the top model-ranked candidates per shape (median-of-k) and
writes the winners into the cache; explicit `bm=`/`bn=`/`bk=` kwargs
always override, and `tune="off"` restores the legacy constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import telemetry

SIZES = [(256, 256, 256), (1024, 1024, 1024), (2048, 2048, 2048),
         (4096, 4096, 512), (10000, 1000, 1000)]


def _roofline_us(m: int, n: int, k: int, dtype_bytes: int) -> float:
    flops = 2.0 * m * n * k
    bytes_ = dtype_bytes * (m * k + k * n + m * n)
    return max(flops / 197e12, bytes_ / 819e9) * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    for dtype, dname in [(jnp.float32, "f32"), (jnp.bfloat16, "bf16")]:
        for m, n, k in SIZES:
            a = jnp.asarray(rng.normal(size=(m, k)), dtype)
            b = jnp.asarray(rng.normal(size=(k, n)), dtype)
            f = jax.jit(lambda x, y: x @ y)
            us = telemetry.timeit(lambda: f(a, b), reps=3, warmup=1).mean_us
            gflops = 2.0 * m * n * k / (us / 1e6) / 1e9
            rows.append((
                f"fig2_gemm_{dname}_{m}x{k}x{n}", us,
                f"cpu_gflops={gflops:.1f};"
                f"v5e_roofline_us={_roofline_us(m, n, k, 2 if dname == 'bf16' else 4):.1f}"))
    return rows
