"""Serving-frontend benchmark — requests/sec and tail latency under a
synthetic shared-matrix trace.

The trace is the multi-user regime the solver server exists for: K solve
requests against the SAME design matrix (distinct right-hand sides).  Two
deployments answer it:

  * ``serial``  — a 1-slot server: requests run one at a time, each paying
    its own A-passes (the no-batching baseline);
  * ``batched`` — a K-slot server: the group shares ONE fused multi-RHS
    A-pass per solver iteration (continuous batching, launch/serve).

Emits one ``BENCH {json}`` line per config with requests/sec for both,
p50/p99 submit→finish latency, the batched:serial throughput ratio, and
the counted group A-passes (grouped ≪ serial — the pass sharing is where
the throughput comes from).  Wired into ``run.py --only serve``; the
perf-smoke serving canary asserts the structural half (grouped A-passes <
serial A-passes) without timing anything.

A second ``BENCH`` line (suite ``serve_recovery``) measures the
fault-tolerance overhead: the same k-request group solved under 0, 1 and
2 injected straggler episodes (train.faults.FaultyLinop), each detected
by the ShardMonitor and healed by a mid-solve re-mesh.  It reports
requests/sec per straggler count and the recovery latency — wall seconds
from straggler onset to the completed re-mesh, re-JIT included.
"""
from __future__ import annotations

import json
import time

import numpy as np


def _trace(m: int, n: int, k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    bs = [(A @ rng.normal(size=n) + 0.01 * rng.normal(size=m))
          .astype(np.float32) for _ in range(k)]
    return A, bs


def _serve(server, A, bs, *, tol: float = 1e-6, max_iters: int = 200):
    """Run the trace through `server`; returns (wall_s, latencies,
    group_a_passes, results).  Timing a LONG-LIVED server is the point:
    the first trace through a server compiles the group step closures, so
    callers warm the same server with the same matrix before timing (a
    serving deployment answers a stream, not a cold start)."""
    from repro import api
    passes0 = server.stats["a_passes"]
    events0 = len(server._events)
    t0 = time.perf_counter()
    ids = [server.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                          method="gra", tol=tol,
                                          max_iters=max_iters))
           for b in bs]
    server.run()
    wall = time.perf_counter() - t0
    res = [server.result(i) for i in ids]
    assert all(r is not None for r in res)
    lats = sorted(t1 - t0_ for _, t0_, t1 in server._events[events0:])
    return wall, lats, server.stats["a_passes"] - passes0, res


def group_pass_counts(m: int = 200, n: int = 32, k: int = 4,
                      iters: int = 10) -> dict:
    """Structural A-pass comparison, no timing: a k-request group run to a
    fixed iteration count vs k sequential single-request runs on the same
    engine.  Deterministic — the perf-smoke serving canary asserts
    grouped < serial on these numbers."""
    import jax.numpy as jnp
    from repro import api
    from repro.core.tfocs import CountingLinop
    from repro.core.tfocs.linop import LinopMatrix
    from repro.launch.serve import GroupRunner

    A, bs = _trace(m, n, k, seed=1)

    def run(reqs_per_group):
        lin = CountingLinop(LinopMatrix(jnp.asarray(A)))
        runner = GroupRunner(lin, "quad", slots=max(reqs_per_group, 1))
        passes = 0
        for start in range(0, k, reqs_per_group):
            for b in bs[start:start + reqs_per_group]:
                runner.admit(api.SolveRequest(A=A, b=b, loss="quad",
                                              tol=0.0, max_iters=iters))
            while runner.busy():
                runner.step()
        return runner.a_passes, dict(lin.counts)

    grouped, gcounts = run(k)
    serial, scounts = run(1)
    return {"k": k, "iters": iters, "grouped_a_passes": grouped,
            "serial_a_passes": serial,
            "grouped_trace_counts": gcounts,
            "serial_trace_counts": scounts,
            "a_pass_ratio": serial / max(grouped, 1)}


def recovery_overhead(m: int = 256, n: int = 32, k: int = 4,
                      max_iters: int = 300, delay_s: float = 0.02,
                      straggler_counts: tuple[int, ...] = (0, 1, 2)) -> dict:
    """Throughput of a k-request elastic group under injected straggler
    episodes.  Each episode arms a delay on shard 0 a few iterations
    ahead; the ShardMonitor trips on the telemetry, the executor
    re-meshes mid-solve (clearing the delay with the dropped shard), and
    the next episode is armed.  Recovery latency is measured from the
    first delayed iteration to the completed re-mesh — so it prices
    detection, the matrix move AND the engine re-JIT."""
    import jax
    import jax.numpy as jnp
    from repro.core.distmat import RowMatrix
    from repro.core.distmat.types import make_mesh
    from repro.core.optim.elastic import ElasticConfig, ElasticGroup
    from repro.core.tfocs.linop import LinopMatrix
    from repro.train.faults import FaultPlan, FaultyLinop, FaultyMesh
    from repro.train.straggler import ShardMonitor, StragglerConfig

    A, bs = _trace(m, n, k, seed=7)
    out = {"suite": "serve_recovery", "m": m, "n": n, "requests": k,
           "delay_s": delay_s, "stragglers": {}}
    for count in straggler_counts:
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
        fm = FaultyMesh(mesh)
        lin = FaultyLinop(LinopMatrix(RowMatrix.create(jnp.asarray(A),
                                                       mesh)),
                          FaultPlan())
        cfg = ElasticConfig(
            monitor=ShardMonitor(lin.row_shards(),
                                 StragglerConfig(warmup_steps=2,
                                                 threshold=2.0,
                                                 trip_limit=2)),
            remesh_to=fm.drop)
        grp = ElasticGroup(lin, "quad", slots=k, elastic=cfg)
        # Warm pass: compile the group step closure at full width so the
        # timed trace prices steady-state iterations, not the cold start
        # (re-JIT after a re-mesh IS billed — that is recovery cost).
        for b in bs:
            grp.admit_slot(b, tol=0.0, x0=None)
        while grp.iteration < 2:
            grp.step_iteration()
        for i in range(k):
            grp.clear_slot(i)

        def arm(step_from):
            # Mutate the SHARED dict/plan in place: after a re-mesh the
            # live wrapper is a dataclasses.replace copy that aliases
            # them — rebinding `lin.delays` would arm a dead instance.
            lin.delays[0] = delay_s
            lin.plan.delay_from = step_from
            return step_from

        for b in bs:
            grp.admit_slot(b, tol=1e-6)
        episodes_left = count
        armed_from = arm(grp.iteration + 2) if episodes_left else None
        onset = None
        recov = []
        it_cap = grp.iteration + max_iters
        t0 = time.perf_counter()
        while grp.busy() and grp.iteration < it_cap:
            if armed_from is not None and onset is None \
                    and grp.iteration >= armed_from:
                onset = time.perf_counter()
            seen = grp.remeshes
            grp.step_iteration()
            if grp.remeshes > seen and onset is not None:
                recov.append(time.perf_counter() - onset)
                onset = None
                episodes_left -= 1
                armed_from = arm(grp.iteration + 2) if episodes_left \
                    else None
            done = np.asarray(grp.state.done)
            if bool(done[grp.active].all()):
                break
        wall = time.perf_counter() - t0
        out["stragglers"][str(count)] = {
            "wall_s": round(wall, 4),
            "requests_per_s": round(k / wall, 2),
            "iterations": grp.iteration,
            "remeshes": grp.remeshes,
            "recovery_latency_s": [round(r, 4) for r in recov],
        }
    clean = out["stragglers"].get("0")
    if clean is not None:
        for rec in out["stragglers"].values():
            rec["throughput_vs_clean"] = round(
                rec["requests_per_s"] / max(clean["requests_per_s"],
                                            1e-12), 3)
    return out


def traced_demo(out_dir: str = "bench-artifacts",
                m: int = 256, n: int = 32, k: int = 4,
                delay_s: float = 0.02) -> dict:
    """End-to-end traced episode for the CI trace artifact: a batched
    served solve plus an elastic fault episode (straggler → trip →
    checkpoint → re-mesh) recorded under one telemetry Recorder, exported
    as JSONL events and a Chrome/Perfetto trace.  Returns the summary so
    the caller (and CI log) can see the span-tree phase coverage."""
    import pathlib
    import tempfile

    import jax
    import jax.numpy as jnp
    from repro.core.distmat import RowMatrix
    from repro.core.distmat.types import make_mesh
    from repro.core.optim.elastic import (ElasticConfig, ElasticGroup,
                                          SolveCheckpoint)
    from repro.core.tfocs.linop import LinopMatrix
    from repro.launch import telemetry
    from repro.launch.serve import SolverServer
    from repro.train.faults import FaultPlan, FaultyLinop, FaultyMesh
    from repro.train.straggler import ShardMonitor, StragglerConfig

    rec = telemetry.Recorder()
    A, bs = _trace(m, n, k, seed=3)
    with telemetry.recording(rec):
        # -- served group solve: admit/queue-wait/latency/retire spans ---
        server = SolverServer(slots=k)
        _serve(server, A, bs, max_iters=60)

        # -- elastic fault episode: iterate/checkpoint/re-mesh spans -----
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
        fm = FaultyMesh(mesh)
        lin = FaultyLinop(LinopMatrix(RowMatrix.create(jnp.asarray(A),
                                                       mesh)),
                          FaultPlan())
        with tempfile.TemporaryDirectory() as ckdir:
            cfg = ElasticConfig(
                monitor=ShardMonitor(lin.row_shards(),
                                     StragglerConfig(warmup_steps=2,
                                                     threshold=2.0,
                                                     trip_limit=2)),
                remesh_to=fm.drop,
                checkpoint=SolveCheckpoint(ckdir, every=5,
                                           async_save=False))
            grp = ElasticGroup(lin, "quad", slots=k, elastic=cfg)
            for b in bs:
                grp.admit_slot(b, tol=1e-6)
            lin.delays[0] = delay_s
            lin.plan.delay_from = 4
            it_cap = 120
            while grp.busy() and grp.iteration < it_cap:
                grp.step_iteration()
                if grp.remeshes >= 1 and grp.iteration >= 20:
                    break

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    rec.export_jsonl(out / "telemetry_events.jsonl")
    rec.export_chrome_trace(out / "trace.perfetto.json")
    summary = rec.summary()
    summary["artifacts"] = [str(out / "telemetry_events.jsonl"),
                            str(out / "trace.perfetto.json")]
    return summary


def run(full: bool = False) -> list[tuple[str, float, str]]:
    configs = [(2000, 256, 8), (2000, 256, 16)] if full \
        else [(512, 64, 8)]
    rows = []
    from repro.launch.serve import SolverServer
    for m, n, k in configs:
        A, bs = _trace(m, n, k)
        batched, serial = SolverServer(slots=k), SolverServer(slots=1)
        # Warm both servers on the same matrix at a tiny iteration budget:
        # the first trace compiles each server's group step closure (one
        # per slot width), which must not be billed to the steady state.
        _serve(batched, A, bs, max_iters=2)
        _serve(serial, A, bs[:1], max_iters=2)

        wall_b, lats, passes_b, res_b = _serve(batched, A, bs)
        wall_s, _, passes_s, res_s = _serve(serial, A, bs)

        rps_b, rps_s = k / wall_b, k / wall_s
        rec = {"suite": "serve", "m": m, "n": n, "requests": k,
               "batched": {"wall_s": round(wall_b, 4),
                           "requests_per_s": round(rps_b, 2),
                           "p50_latency_ms": round(
                               lats[len(lats) // 2] * 1e3, 3),
                           "p99_latency_ms": round(
                               lats[min(int(len(lats) * 0.99),
                                        len(lats) - 1)] * 1e3, 3),
                           "group_a_passes": passes_b},
               "serial": {"wall_s": round(wall_s, 4),
                          "requests_per_s": round(rps_s, 2),
                          "total_a_passes": passes_s},
               "throughput_ratio": round(rps_b / max(rps_s, 1e-12), 3),
               "a_pass_ratio": round(passes_s / max(passes_b, 1), 3),
               "structural": group_pass_counts()}
        print("BENCH " + json.dumps(rec))
        rows.append((
            f"serve_{m}x{n}_k{k}",
            wall_b / k * 1e6,
            f"rps_batched={rps_b:.1f};rps_serial={rps_s:.1f};"
            f"throughput_ratio={rps_b / max(rps_s, 1e-12):.2f};"
            f"p99_ms={rec['batched']['p99_latency_ms']:.1f};"
            f"a_pass_ratio={rec['a_pass_ratio']:.2f}"))

    rec = recovery_overhead()
    print("BENCH " + json.dumps(rec))
    s = rec["stragglers"]
    recov = [x for r in s.values() for x in r["recovery_latency_s"]]
    rows.append((
        f"serve_recovery_{rec['m']}x{rec['n']}_k{rec['requests']}",
        (max(recov) if recov else 0.0) * 1e6,
        ";".join(f"rps_s{c}={r['requests_per_s']:.1f}"
                 for c, r in s.items())
        + f";remeshes={sum(r['remeshes'] for r in s.values())}"
        + (f";recovery_p100_ms={max(recov) * 1e3:.1f}" if recov else "")))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--traced-demo", action="store_true",
                    help="record a traced served solve + fault episode and "
                         "export JSONL + Perfetto trace artifacts")
    ap.add_argument("--out-dir", default="bench-artifacts")
    args = ap.parse_args()
    if args.traced_demo:
        summary = traced_demo(out_dir=args.out_dir)
        print("TRACE " + json.dumps(summary, sort_keys=True))
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
