"""Collective benchmark: modeled vs measured psum time by payload & topology.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m benchmarks.bench_collectives

Two sections, each emitting ``BENCH {json}`` lines (run.py --only
collectives):

  1. **psum sweep** — for each device count in {1, 2, 4, 8} (capped by what
     exists) and payload size, the wall time of a jitted ``shard_map`` psum
     over a one-axis mesh next to ``MachineModel.collective()``'s link-model
     prediction (ring vs tree by payload, the algorithm the planner prices
     overlap decisions against).  On the CI host the "links" are shared
     memory, so the absolute ratio is expected to drift — the sweep's job is
     to expose that drift as data, per payload and device count.

  2. **link_eff fit demo** — the sweep's records (raw collective terms +
     measured seconds) run through ``MachineModel.calibrate()``, which fits
     the comm column (1/link_eff) alongside the roofline terms; the BENCH
     line reports modeled-vs-measured mean relative error before and after.
     NOT persisted by default (--write opts in): the host-CPU fit would
     poison kernel plans for anyone benchmarking on this machine, and the
     planner already prefers any real calibration recorded for the backend.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.launch import machine, telemetry

# Payload sizes (f32 elements) spanning the latency- and bandwidth-bound
# regimes of the link model: 4 KiB, 256 KiB, 4 MiB.
PAYLOAD_ELEMS = (1024, 65536, 1048576)
DEVICE_COUNTS = (1, 2, 4, 8)


def _psum_fn(mesh, n_elems: int):
    """Jitted one-axis all-reduce: each shard contributes an (1, E) block,
    the psum leaves the replicated sum — the exact collective the distmat
    gram/fused_grad/rmatvec bodies issue."""
    P = jax.sharding.PartitionSpec

    def body(v):
        return jax.lax.psum(v, "data")

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=P("data", None),
                                 out_specs=P(None, None)))
    n = mesh.shape["data"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, n_elems)),
                    jnp.float32)
    return f, x


def sweep(model: machine.MachineModel, *, reps: int = 5) -> list[dict]:
    """One record per (device count, payload): measured psum wall time plus
    the link model's prediction and the raw collective terms calibrate()
    consumes."""
    devices = jax.devices()
    out = []
    for nd in DEVICE_COUNTS:
        if nd > len(devices):
            continue
        mesh = jax.sharding.Mesh(np.asarray(devices[:nd]).reshape(nd),
                                 ("data",))
        for elems in PAYLOAD_ELEMS:
            payload = float(elems) * 4.0
            f, x = _psum_fn(mesh, elems)
            measured = telemetry.timeit(
                lambda: jax.block_until_ready(f(x)),
                reps=reps, warmup=1).median_s
            coll = model.collective(payload, (nd,), "float32")
            out.append({
                "devices": nd, "payload_bytes": payload,
                "algorithm": coll["algorithm"],
                "comm_bytes": coll["comm_bytes"],
                "comm_steps": coll["comm_steps"],
                "modeled_s": coll["comm_s"],
                "measured_s": measured,
                # comm-only calibration records: the per-shard add is noise
                # next to the collective, and keeping the compute/memory
                # columns zero stops the (payload-collinear) roofline terms
                # from stealing the link coefficient in the lstsq
                "dtype": "float32", "flops": 0.0,
                "hbm_bytes": 0.0, "steps": 0.0, "mxu_util": 1.0,
            })
    return out


def run(*, write: bool = False, reps: int = 5) -> list[tuple[str, float, str]]:
    rows = []
    backend = jax.default_backend()
    model = machine.for_backend(backend)

    records = sweep(model, reps=reps)
    for r in records:
        ratio = r["measured_s"] / r["modeled_s"] if r["modeled_s"] > 0 \
            else None
        print("BENCH", json.dumps({
            "bench": "collective_psum", "backend": backend,
            "machine": model.name, "devices": r["devices"],
            "payload_bytes": r["payload_bytes"],
            "algorithm": r["algorithm"],
            "modeled_us": round(r["modeled_s"] * 1e6, 3),
            "measured_us": round(r["measured_s"] * 1e6, 3),
            "ratio": round(ratio, 4) if ratio is not None else None},
            sort_keys=True))
        rows.append((f"psum_d{r['devices']}_{int(r['payload_bytes'])}B",
                     r["measured_s"] * 1e6,
                     f"modeled_us={r['modeled_s'] * 1e6:.1f};"
                     f"algo={r['algorithm']}"))

    # -- link_eff fit demo: the comm column joins the lstsq ------------------
    comm_records = [r for r in records if r["devices"] > 1]
    if len(comm_records) >= 2:
        err_before = model.error(comm_records)
        fitted = model.calibrate(comm_records)
        err_after = fitted.error(comm_records)
        tightened = err_after <= err_before
        print("BENCH", json.dumps({
            "bench": "collective_link_fit", "backend": backend,
            "n_records": len(comm_records),
            "err_before": round(err_before, 4),
            "err_after": round(err_after, 4), "tightened": tightened,
            "link_eff": {k: round(v, 6) for k, v in fitted.link_eff.items()},
            "written": write}, sort_keys=True))
        rows.append(("collectives_link_fit", err_after * 100,
                     f"err_before={err_before:.3f};"
                     f"err_after={err_after:.3f};tightened={tightened}"))
        if write:
            machine.save_calibration(backend, fitted)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--write", action="store_true",
                    help="persist the link fit (off by default — see "
                         "module docstring)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    for name, us, derived in run(write=args.write, reps=args.reps):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
