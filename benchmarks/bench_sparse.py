"""§4.2 — sparse kernels, local and distributed.

MLlib's CCS SpMV/SpMM vs dense; the TPU-native block-sparse (BSR) layout;
and the distributed SparseRowMatrix vs dense RowMatrix sweep that reports
the *density break-even* — the number the density-aware dispatch in
launch/planner.py acts on.  Each distributed row also emits a ``BENCH``
json line with the measured speedups and the cost model's own call, so the
break-even is recorded machine-readably (run.py --only sparse).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import RowMatrix, SparseMatrixCSC, SparseRowMatrix
from repro.kernels.bsr import BlockELL
from repro.launch import planner, telemetry


def _time(f, *args, reps=5):
    return telemetry.timeit(lambda: f(*args), reps=reps, warmup=2).mean_us


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    m, n, nx = 2048, 2048, 64
    for density in (0.01, 0.1):
        S = ((rng.random((m, n)) < density)
             * rng.normal(size=(m, n))).astype(np.float32)
        x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(n, nx)), jnp.float32)
        sp = SparseMatrixCSC.from_dense(S)
        Sd = jnp.asarray(S)

        us_spmv = _time(jax.jit(sp.matvec), x)
        us_dmv = _time(jax.jit(lambda v: Sd @ v), x)
        rows.append((f"s42_csc_spmv_d{density}", us_spmv,
                     f"dense_us={us_dmv:.1f}"))
        us_spmm = _time(jax.jit(sp.matmat), X)
        us_dmm = _time(jax.jit(lambda v: Sd @ v), X)
        rows.append((f"s42_csc_spmm_d{density}", us_spmm,
                     f"dense_us={us_dmm:.1f}"))

    # block-sparse: 8x8 blocks, 12.5% block density
    mask = rng.random((32, 32)) < 0.125
    dense = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(256, 256))).astype(np.float32)
    bell = BlockELL.from_dense(dense, bs=8)
    X = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    from repro.kernels import ops
    us_bsr = _time(lambda xx: ops.bsr_matmul(bell, xx), X)
    us_dense = _time(jax.jit(lambda xx: jnp.asarray(dense) @ xx), X)
    rows.append(("s42_bsr_matmul_d0.125", us_bsr,
                 f"dense_us={us_dense:.1f};"
                 f"block_density={bell.density():.3f}"))
    rows.extend(run_distributed())
    return rows


def run_distributed() -> list[tuple[str, float, str]]:
    """SparseRowMatrix (BSR path forced) vs dense RowMatrix at several
    block densities: where does block-sparse storage stop paying?

    The matrix arrays are passed *into* the jitted functions — a zero-arg
    closure would let XLA constant-fold the whole contraction away.
    """
    rows = []
    rng = np.random.default_rng(0)
    m, n, bs = 4096, 2048, 128
    breakeven_ok = True
    for density in (0.01, 0.05, 0.10):
        mask = rng.random((m // bs, n // bs)) < density
        dense = (np.kron(mask, np.ones((bs, bs)))
                 * rng.normal(size=(m, n))).astype(np.float32)
        srm = SparseRowMatrix.from_dense(dense, bs=bs)
        rm = RowMatrix.create(dense)
        v = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

        sp_mv = jax.jit(lambda data, cols, vv, _s=srm: dataclasses.replace(
            _s, data=data, cols=cols).matvec(vv, dispatch="bsr"))
        dn_mv = jax.jit(lambda r, vv, _r=rm: dataclasses.replace(
            _r, rows=r).matvec(vv))
        sp_gram = jax.jit(lambda data, cols, _s=srm: dataclasses.replace(
            _s, data=data, cols=cols).gram(dispatch="bsr"))
        dn_gram = jax.jit(lambda r, _r=rm: dataclasses.replace(
            _r, rows=r).gram())

        us_sp_mv = _time(sp_mv, srm.data, srm.cols, v)
        us_dn_mv = _time(dn_mv, rm.rows, v)
        us_sp_g = _time(sp_gram, srm.data, srm.cols, reps=3)
        us_dn_g = _time(dn_gram, rm.rows, reps=3)

        decision = planner.plan("sparse_matmul",
                                {"m": srm.m_pad, "n": srm.n_pad, "nx": 1,
                                 "ell": srm.ell, "bs": srm.bs})
        alt = dict(decision.alternatives)
        if density <= 0.05:
            breakeven_ok = breakeven_ok and us_sp_mv < us_dn_mv
        print("BENCH", json.dumps({
            "bench": "sparse_distributed", "m": m, "n": n, "bs": bs,
            "block_density": density, "ell": srm.ell,
            "matvec_bsr_us": round(us_sp_mv, 1),
            "matvec_dense_us": round(us_dn_mv, 1),
            "matvec_speedup": round(us_dn_mv / us_sp_mv, 3),
            "gram_bsr_us": round(us_sp_g, 1),
            "gram_dense_us": round(us_dn_g, 1),
            "gram_speedup": round(us_dn_g / us_sp_g, 3),
            "model_use_bsr": decision.choice == "bsr",
            "model_bsr_s": alt["bsr"], "model_dense_s": alt["dense"],
            "bsr_wins_at_low_density": breakeven_ok,
        }))
        rows.append((f"s42_dist_spmv_bd{density}", us_sp_mv,
                     f"dense_us={us_dn_mv:.1f};ell={srm.ell};"
                     f"model_use_bsr={decision.choice == 'bsr'}"))
        rows.append((f"s42_dist_gram_bd{density}", us_sp_g,
                     f"dense_us={us_dn_g:.1f}"))
    return rows
