"""§4.2 — sparse single-core kernels.

MLlib's CCS SpMV/SpMM vs dense; plus the TPU-native block-sparse (BSR)
layout, reporting the density break-even against dense GEMM — the number
that decides when the Pallas BSR kernel pays off on the MXU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import SparseMatrixCSC
from repro.kernels.bsr import BlockELL


def _time(f, *args, reps=5):
    f(*args)
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    m, n, nx = 2048, 2048, 64
    for density in (0.01, 0.1):
        S = ((rng.random((m, n)) < density)
             * rng.normal(size=(m, n))).astype(np.float32)
        x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(n, nx)), jnp.float32)
        sp = SparseMatrixCSC.from_dense(S)
        Sd = jnp.asarray(S)

        us_spmv = _time(jax.jit(sp.matvec), x)
        us_dmv = _time(jax.jit(lambda v: Sd @ v), x)
        rows.append((f"s42_csc_spmv_d{density}", us_spmv,
                     f"dense_us={us_dmv:.1f}"))
        us_spmm = _time(jax.jit(sp.matmat), X)
        us_dmm = _time(jax.jit(lambda v: Sd @ v), X)
        rows.append((f"s42_csc_spmm_d{density}", us_spmm,
                     f"dense_us={us_dmm:.1f}"))

    # block-sparse: 8x8 blocks, 12.5% block density
    mask = rng.random((32, 32)) < 0.125
    dense = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(256, 256))).astype(np.float32)
    bell = BlockELL.from_dense(dense, bs=8)
    X = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    from repro.kernels import ops
    us_bsr = _time(lambda xx: ops.bsr_matmul(bell, xx), X)
    us_dense = _time(jax.jit(lambda xx: jnp.asarray(dense) @ xx), X)
    rows.append(("s42_bsr_matmul_d0.125", us_bsr,
                 f"dense_us={us_dense:.1f};"
                 f"block_density={bell.density():.3f}"))
    return rows
