"""Low-precision benchmark: bytes, wall-clock and solution error by format.

    PYTHONPATH=src python -m benchmarks.bench_precision

Three sections, each emitting ``BENCH {json}`` lines (run.py --only
precision):

  1. **storage sweep** — the bandwidth-bound fused-grad shape priced by the
     planner's precision sweep, f32 vs bf16 storage: modeled seconds (V5E
     roofline at each byte width), measured wall time, and the actual
     operand bytes.  The acceptance floor (bf16 ≥ 1.5× over f32) is a
     MODELED property of the reference accelerator: on the CI host XLA CPU
     upcasts bf16 tiles before computing, so the measured ratio hovers near
     1× — the sweep's job is to expose that gap as data, exactly like
     bench_collectives does for link time.

  2. **Figure-1 family** — every (method, precision) pair through
     ``api.solve`` on one shared problem: wall time, iterations, reported
     precision, per-pass wire bytes (f32 vs the int8+scale compressed
     psum), and solution error against the f32 reference — the
     speedup-vs-accuracy table the quickstart quotes.

  3. **int8 BlockELL** — a block-sparse operand stored exact vs quantized:
     actual stored bytes (data + scales), matvec wall time, and operator
     error, the storage side of the sparse_matmul precision decision.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.distmat import RowMatrix, SparseRowMatrix
from repro.core.tfocs.linop import LinopMatrix
from repro.core.tfocs.smooth import SmoothQuad, row_separable
from repro.launch import machine, planner, telemetry

# The bandwidth-bound fused-grad shape of the planner goldens: wide enough
# that the A-stream dominates and the precision sweep picks bf16 at 1e-4.
STORAGE_SHAPE = (8192, 2048)

# Figure-1 family problem (small enough for CI, ill-conditioned enough
# that precision differences are visible in the iterates).
FAMILY_SHAPE = (1024, 128)
FAMILY = [("gra", "f32"), ("gra", "bf16"), ("gra", "psum8"),
          ("acc_b", "f32"), ("acc_b", "bf16"),
          ("acc_rb", "f32"), ("acc_rb", "bf16"),
          ("lbfgs", "f32"), ("lbfgs", "bf16")]


def _fused_runner(A, store_dtype):
    rm = RowMatrix.create(A, store_dtype=store_dtype)
    lin = LinopMatrix(rm)
    sep = row_separable(SmoothQuad(lin.pad_data(
        jnp.zeros(A.shape[0], jnp.float32)), lin.row_weights()))
    f = jax.jit(lambda x: lin.fused_grad(x, sep))
    return f, rm


def storage_sweep(reps: int) -> list[tuple[str, float, str]]:
    m, n = STORAGE_SHAPE
    backend = jax.default_backend()
    rng = np.random.default_rng(0)
    A = rng.normal(size=(m, n)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)

    plan = planner.plan("grad", {"m": m, "n": n}, machine=machine.V5E,
                        context={"tol": 1e-4, "axes": (8,)})
    alt = dict(plan.alternatives)
    modeled = {"f32": alt["precision:f32"], "bf16": alt["precision:bf16"]}

    rows, meas, opbytes = [], {}, {}
    for dt, lbl in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
        f, rm = _fused_runner(A, dt)
        jax.block_until_ready(f(x))
        meas[lbl] = telemetry.timeit(
            lambda: jax.block_until_ready(f(x)), reps=reps,
            warmup=1).median_s
        opbytes[lbl] = int(rm.rows.size) * rm.rows.dtype.itemsize

    sp_model = modeled["f32"] / modeled["bf16"]
    sp_meas = meas["f32"] / meas["bf16"]
    print("BENCH", json.dumps({
        "bench": "precision_storage", "backend": backend,
        "m": m, "n": n, "planner_pick": plan.precision,
        "operand_bytes_f32": opbytes["f32"],
        "operand_bytes_bf16": opbytes["bf16"],
        "modeled_us_f32": round(modeled["f32"] * 1e6, 3),
        "modeled_us_bf16": round(modeled["bf16"] * 1e6, 3),
        "measured_us_f32": round(meas["f32"] * 1e6, 1),
        "measured_us_bf16": round(meas["bf16"] * 1e6, 1),
        "speedup_modeled": round(sp_model, 3),
        "speedup_measured": round(sp_meas, 3),
        "meets_1p5x_modeled": sp_model >= 1.5}, sort_keys=True))
    rows.append(("precision_fusedgrad_bf16", meas["bf16"] * 1e6,
                 f"speedup_modeled={sp_model:.2f};"
                 f"speedup_measured={sp_meas:.2f};"
                 f"bytes={opbytes['bf16']}/{opbytes['f32']}"))
    return rows


def family_sweep(reps: int) -> list[tuple[str, float, str]]:
    m, n = FAMILY_SHAPE
    backend = jax.default_backend()
    rng = np.random.default_rng(1)
    A = rng.normal(size=(m, n)).astype(np.float32)
    xs = rng.normal(size=n).astype(np.float32)
    b = (A @ xs + 0.01 * rng.normal(size=m)).astype(np.float32)
    M = RowMatrix.create(A)
    L = float(np.linalg.norm(A, 2) ** 2)
    kw = dict(loss="quad", tol=1e-5, max_iters=400, L0=L)

    refs = {}
    rows = []
    for method, prec in FAMILY:
        req = api.SolveRequest(A=M, b=b, method=method, precision=prec,
                               **kw)
        res = api.solve(req)       # warm the jit before timing
        t = telemetry.timeit(lambda: api.solve(req), reps=reps,
                             warmup=0).median_s
        x = np.asarray(res.x)
        if prec == "f32":
            refs[method] = x
        ref = refs[method]
        err = float(np.linalg.norm(x - ref)
                    / max(np.linalg.norm(ref), 1e-12))
        # Per-pass gradient wire bytes: f32 ships n·4; the compressed wire
        # ships n int8 + one f32 scale via pmax.
        wire = n * 1 + 4 if res.info["precision"] == "psum8" else n * 4
        print("BENCH", json.dumps({
            "bench": "precision_family", "backend": backend,
            "method": method, "requested": prec,
            "ran": res.info["precision"],
            "iterations": int(res.info["iterations"]),
            "converged": bool(res.info["converged"]),
            "wire_bytes_per_pass": wire,
            "measured_us": round(t * 1e6, 1),
            "solution_err_vs_f32": round(err, 8)}, sort_keys=True))
        rows.append((f"precision_{method}_{prec}", t * 1e6,
                     f"ran={res.info['precision']};err={err:.2e};"
                     f"iters={int(res.info['iterations'])}"))
    return rows


def bsr_sweep(reps: int) -> list[tuple[str, float, str]]:
    backend = jax.default_backend()
    m, n, bs = 2048, 512, 64
    rng = np.random.default_rng(2)
    mask = rng.random((m // bs, n // bs)) < 0.15
    dense = (np.kron(mask, np.ones((bs, bs)))
             * rng.normal(size=(m, n))).astype(np.float32)
    v = jnp.asarray(rng.normal(size=n), jnp.float32)

    rows = []
    stats = {}
    for q, lbl in (("none", "f32"), ("int8", "int8")):
        srm = SparseRowMatrix.from_dense(dense, bs=bs, quantize=q)
        nbytes = int(srm.data.size) * srm.data.dtype.itemsize
        if srm.scales is not None:
            nbytes += int(srm.scales.size) * srm.scales.dtype.itemsize
        f = jax.jit(srm.matvec)
        jax.block_until_ready(f(v))
        t = telemetry.timeit(lambda: jax.block_until_ready(f(v)),
                             reps=reps, warmup=1).median_s
        got = np.asarray(f(v))[:m]
        stats[lbl] = (t, nbytes, got)
    ref = dense @ np.asarray(v)
    err = float(np.abs(stats["int8"][2] - ref).max()
                / max(np.abs(ref).max(), 1e-12))
    print("BENCH", json.dumps({
        "bench": "precision_bsr_int8", "backend": backend,
        "m": m, "n": n, "bs": bs,
        "stored_bytes_f32": stats["f32"][1],
        "stored_bytes_int8": stats["int8"][1],
        "bytes_ratio": round(stats["f32"][1] / stats["int8"][1], 3),
        "measured_us_f32": round(stats["f32"][0] * 1e6, 1),
        "measured_us_int8": round(stats["int8"][0] * 1e6, 1),
        "matvec_rel_err": round(err, 6)}, sort_keys=True))
    rows.append(("precision_bsr_int8", stats["int8"][0] * 1e6,
                 f"bytes={stats['int8'][1]}/{stats['f32'][1]};"
                 f"err={err:.2e}"))
    return rows


def run(*, reps: int = 5) -> list[tuple[str, float, str]]:
    return (storage_sweep(reps) + family_sweep(max(reps // 2, 1))
            + bsr_sweep(reps))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    for name, us, derived in run(reps=args.reps):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
