"""Append generated tables to EXPERIMENTS.md from recorded JSONs."""
import json, pathlib, sys
sys.path.insert(0, "src")
from repro.launch.report import dryrun_table, roofline_table, load

md = open("EXPERIMENTS.md").read()
cut = md.index("# Generated results")
md = md[:cut] + "# Generated results\n\n"

v2 = load(pathlib.Path("experiments/dryrun"))
v2_keys = {(r["arch"], r["shape"], r["mesh"]) for r in v2}
v1 = [r for r in load(pathlib.Path("experiments/dryrun_v1"))
      if (r["arch"], r["shape"], r["mesh"]) not in v2_keys
      and r.get("status") != "error"]

md += "## §Dry-run (final code)\n\n" + dryrun_table(v2) + "\n\n"
if v1:
    md += ("### Cells from the pre-optimization sweep\n"
           "(identical model code except: vocab padding, q-chunked "
           "attention, slot-centric MoE — compile proof equally valid; "
           "memory upper-bounds the final code)\n\n"
           + dryrun_table(v1) + "\n\n")

md += "## §Roofline (single-pod, per-device terms)\n\n"
md += roofline_table(v2) + "\n\n"
if v1:
    md += "### Pre-optimization sweep cells\n\n" + roofline_table(v1) + "\n\n"

md += "## §Perf — measured hillclimb iterations\n\n"
md += ("| cell | variant | bound | step ms | compute s | memory s | "
       "collective s | verdict |\n|---|---|---|---|---|---|---|---|\n")
for p in sorted(pathlib.Path("experiments/perf").glob("*.json")):
    r = json.loads(p.read_text())
    if r.get("status") != "ok":
        continue
    rf = r["roofline"]
    tag = p.stem.split("pod")[-1].strip("_") or "base"
    md += (f"| {r['arch']}×{r['shape']} | {tag} | {rf['bound']} | "
           f"{rf['step_s']*1e3:.1f} | {rf['compute_s']:.3f} | "
           f"{rf['memory_s']:.3f} | {rf['collective_s']:.4f} | "
           f"see narrative |\n")
open("EXPERIMENTS.md", "w").write(md)
print("EXPERIMENTS.md finalized;",
      len(v2), "v2 cells,", len(v1), "v1-fallback cells")
