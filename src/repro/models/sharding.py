"""Logical→physical sharding resolution for model code.

Model code annotates activations with *logical* axes ("batch", "seq",
"model", None); this module resolves them against whatever mesh is active —
single-pod ('data','model'), multi-pod ('pod','data','model'), or no mesh at
all (CPU smoke tests → constraints become no-ops).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

BATCH = "batch"     # resolves to all non-model axes, e.g. ('pod','data')
MODEL = "model"
EXPERT = "expert"   # resolves to the model axis (EP shares the TP axis)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def batch_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return ("data",)
    return tuple(n for n in mesh.axis_names if n != "model")


def resolve(*logical, mesh: Mesh | None = None) -> P:
    """Map logical axis names to a PartitionSpec for the active mesh."""
    mesh = mesh or current_mesh()
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax == BATCH:
            out.append(batch_axes(mesh))
        elif ax in (MODEL, EXPERT):
            out.append("model")
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def shard(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(*logical, mesh=mesh)))
