"""Selective state-space blocks: Mamba1 (falcon-mamba-7b) and Mamba2/SSD
(zamba2).  TPU adaptation (DESIGN.md): the recurrence is evaluated in
*chunks* — parallel associative math inside a chunk (Mamba1: associative
scan; Mamba2: the SSD matmul formulation, which is MXU-native), sequential
`lax.scan` across chunks carrying the (B, …, N) state.  This bounds the
transient memory to O(B·Q·d·N / tp) per step instead of O(B·S·d·N).

TP: the channel dimension (d_inner / heads) is sharded over 'model'; the
state recurrence is elementwise across channels, so the scan needs no
collectives at all — only the in/out projections communicate (row-parallel
psum), identical to an MLP block.

Decode is a single fused recurrence step with O(1) state — this is why the
long_500k cell *runs* for the SSM architectures while quadratic-attention
archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import _dense_init, pdtype
from .sharding import shard, BATCH, MODEL

Array = jax.Array


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv via shift-and-add (width ≤ 4: cheaper than a
    conv op and trivially shardable along the channel axis)."""
    width = w.shape[0]
    out = x * w[-1] + b
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out


def _conv_step(state: Array, x_t: Array, w: Array, b: Array):
    """Single-token conv: state (B, width-1, C), x_t (B, C)."""
    full = jnp.concatenate([state, x_t[:, None]], 1)        # (B, width, C)
    y = (full * w[None]).sum(1) + b                          # w: (width, C)
    return full[:, 1:], y


# ================================================================ Mamba 1 ==
def init_mamba1(key, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    N = s.state_dim
    dt_rank = s.dt_rank or -(-D // 16)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "w_in": _dense_init(ks[0], (D, 2 * di), dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, di)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * N), dt),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), jnp.float32,
                               scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.099 + 0.001,
                     1e-4, None))).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[5], (di, D), dt),
    }
    spec = {
        "w_in": P(None, "model"), "conv_w": P(None, "model"),
        "conv_b": P("model"), "x_proj": P("model", None),
        "dt_proj": P(None, "model"), "dt_bias": P("model"),
        "A_log": P("model", None), "D": P("model"),
        "w_out": P("model", None),
    }
    return p, spec


def _mamba1_inner(p, x: Array, dt_rank: int, N: int, h0: Array,
                  chunk: int, unroll: bool = False,
                  shard_scan: bool = False, scan_dtype: str = "float32"):
    """x: (B,S,di) post-conv activations; returns (y, h_final)."""
    B, S, di = x.shape
    dtBC = x @ p["x_proj"].astype(x.dtype)
    dtr, Bm, Cm = jnp.split(dtBC.astype(jnp.float32),
                            [dt_rank, dt_rank + N], -1)
    dt = jax.nn.softplus(dtr @ p["dt_proj"] + p["dt_bias"])   # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di, N)

    nc = S // chunk
    xs = x.astype(jnp.float32).reshape(B, nc, chunk, di)
    dts = dt.reshape(B, nc, chunk, di)
    Bs = Bm.reshape(B, nc, chunk, N)
    Cs = Cm.reshape(B, nc, chunk, N)

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp                        # (B,Q,di) ... (B,Q,N)
        la = dtc[..., None] * A                      # (B,Q,di,N)
        bu = (dtc * xc)[..., None] * Bc[:, :, None, :]
        if shard_scan:
            # §Perf lever 1 (measured: no-op — GSPMD already shards di;
            # kept for the record, see EXPERIMENTS.md §Perf A)
            la = shard(la, BATCH, None, MODEL, None)
            bu = shard(bu, BATCH, None, MODEL, None)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        # §Perf lever 2: run the associative scan in bf16 (the recurrence
        # factors are exp(dt·A) ∈ (0,1]; products stay in range, relative
        # error ~1e-2 over a 256-chunk — acceptable for training forward
        # with f32 carry, validated in tests).
        sdt = jnp.dtype(scan_dtype)
        Acum, Bcum = jax.lax.associative_scan(
            combine, (jnp.exp(la).astype(sdt), bu.astype(sdt)), axis=1)
        hseq = Acum.astype(jnp.float32) * h[:, None] + \
            Bcum.astype(jnp.float32)                 # (B,Q,di,N)
        y = jnp.einsum("bqdn,bqn->bqd", hseq, Cc)
        return hseq[:, -1], y

    # scan over chunks (sequential), chunk tensors moved to leading axis
    inp = (xs.transpose(1, 0, 2, 3), dts.transpose(1, 0, 2, 3),
           Bs.transpose(1, 0, 2, 3), Cs.transpose(1, 0, 2, 3))
    if unroll:
        h_fin, ys_l = h0, []
        for c in range(nc):
            h_fin, yc = chunk_step(h_fin, jax.tree.map(lambda a: a[c], inp))
            ys_l.append(yc)
        ys = jnp.stack(ys_l)
    else:
        h_fin, ys = jax.lax.scan(chunk_step, h0, inp)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + x.astype(jnp.float32) * p["D"]
    return y, h_fin


def mamba1_block(p, x: Array, cfg: ModelConfig, *, cache=None):
    """x: (B,S,D). cache: {"conv": (B,w-1,di), "h": (B,di,N)} for decode."""
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    N = s.state_dim
    dt_rank = s.dt_rank or -(-D // 16)
    B, S, _ = x.shape

    xz = x @ p["w_in"]
    xr, z = jnp.split(xz, 2, -1)
    xr = shard(xr, BATCH, None, MODEL)

    if cache is None or S > 1:
        # train forward, or prefill-into-cache (chunked scan + final state)
        xc = jax.nn.silu(_causal_conv(xr.astype(jnp.float32), p["conv_w"],
                                      p["conv_b"])).astype(x.dtype)
        h0 = (cache["h"] if cache is not None
              else jnp.zeros((B, di, N), jnp.float32))
        chunk = s.chunk if S % s.chunk == 0 else max(
            q for q in range(1, min(s.chunk, S) + 1) if S % q == 0)
        y, h_fin = _mamba1_inner(p, xc, dt_rank, N, h0, chunk,
                                 unroll=cfg.scan_unroll,
                                 shard_scan=cfg.ssm_shard_scan,
                                 scan_dtype=cfg.ssm_scan_dtype)
        y = y[:, :S]
        if cache is None:
            new_cache = None
        else:
            w = s.conv_dim - 1
            conv_state = xr[:, S - w:].astype(jnp.float32)
            new_cache = {"conv": conv_state, "h": h_fin}
    else:
        conv_state, h = cache["conv"], cache["h"]
        conv_state, xc = _conv_step(conv_state, xr[:, 0].astype(jnp.float32),
                                    p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc)                                   # (B, di)
        dtBC = xc.astype(x.dtype) @ p["x_proj"].astype(x.dtype)
        dtr, Bm, Cm = jnp.split(dtBC.astype(jnp.float32),
                                [dt_rank, dt_rank + N], -1)
        dt = jax.nn.softplus(dtr @ p["dt_proj"] + p["dt_bias"])  # (B, di)
        A = -jnp.exp(p["A_log"])
        h = jnp.exp(dt[..., None] * A) * h + \
            (dt * xc)[..., None] * Bm[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cm) + xc * p["D"]
        y = y[:, None]
        new_cache = {"conv": conv_state, "h": h}

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["w_out"]
    return shard(out, BATCH, None, None), new_cache


def init_mamba1_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    from .sharding import batch_axes
    ba = batch_axes()
    cache = {"conv": jnp.zeros((batch, s.conv_dim - 1, di), jnp.float32),
             "h": jnp.zeros((batch, di, s.state_dim), jnp.float32)}
    spec = {"conv": P(ba, None, "model"), "h": P(ba, "model", None)}
    return cache, spec


# ================================================================ Mamba 2 ==
def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    N = s.state_dim
    H = di // s.head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 10)
    p = {
        "w_z": _dense_init(ks[0], (D, di), dt),
        "w_x": _dense_init(ks[1], (D, di), dt),
        "w_B": _dense_init(ks[2], (D, N), dt),
        "w_C": _dense_init(ks[3], (D, N), dt),
        "w_dt": _dense_init(ks[4], (D, H), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (s.conv_dim, di)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "convB_w": (jax.random.normal(ks[6], (s.conv_dim, N)) * 0.1
                    ).astype(jnp.float32),
        "convB_b": jnp.zeros((N,), jnp.float32),
        "convC_w": (jax.random.normal(ks[7], (s.conv_dim, N)) * 0.1
                    ).astype(jnp.float32),
        "convC_b": jnp.zeros((N,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[8], (di, D), dt),
    }
    spec = {
        "w_z": P(None, "model"), "w_x": P(None, "model"),
        "w_B": P(None, None), "w_C": P(None, None),
        "w_dt": P(None, "model"), "dt_bias": P("model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "convB_w": P(None, None), "convB_b": P(None),
        "convC_w": P(None, None), "convC_b": P(None),
        "A_log": P("model"), "D": P("model"),
        "norm_scale": P("model"), "w_out": P("model", None),
    }
    return p, spec


def _gated_norm(y: Array, z: Array, scale: Array, eps: float) -> Array:
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = (g * g).mean(-1, keepdims=True)
    return g * jax.lax.rsqrt(var + eps) * scale


def mamba2_block(p, x: Array, cfg: ModelConfig, *, cache=None):
    """SSD block. cache: {"conv","convB","convC","h"} for decode."""
    s = cfg.ssm
    D = cfg.d_model
    di = s.expand * D
    N, Pd = s.state_dim, s.head_dim
    H = di // Pd
    B, S, _ = x.shape

    z = x @ p["w_z"]
    xr = shard(x @ p["w_x"], BATCH, None, MODEL)
    Br = x @ p["w_B"]
    Cr = x @ p["w_C"]
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["w_dt"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                 # (H,)

    if cache is None or S > 1:
        xc = jax.nn.silu(_causal_conv(xr.astype(jnp.float32), p["conv_w"],
                                      p["conv_b"]))
        Bc = jax.nn.silu(_causal_conv(Br.astype(jnp.float32), p["convB_w"],
                                      p["convB_b"]))
        Cc = jax.nn.silu(_causal_conv(Cr.astype(jnp.float32), p["convC_w"],
                                      p["convC_b"]))
        Q = s.chunk if S % s.chunk == 0 else max(
            q for q in range(1, min(s.chunk, S) + 1) if S % q == 0)
        nc = S // Q
        xh = xc.reshape(B, nc, Q, H, Pd)
        dtc = dt.reshape(B, nc, Q, H)
        Bch = Bc.reshape(B, nc, Q, N)
        Cch = Cc.reshape(B, nc, Q, N)
        la = dtc * A                                         # (B,nc,Q,H)
        cs = jnp.cumsum(la, axis=2)                          # inclusive
        x_disc = xh * dtc[..., None]

        # intra-chunk (attention-like, MXU-native)
        csh = cs.transpose(0, 1, 3, 2)                       # (B,nc,H,Q)
        diff = csh[..., :, None] - csh[..., None, :]         # (B,nc,H,Q,Q)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri, jnp.exp(diff), 0.0)
        scores = jnp.einsum("bcqn,bckn->bcqk", Cch, Bch)
        M = scores[:, :, None] * L                           # (B,nc,H,Q,Q)
        y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, x_disc)

        # chunk states + inter-chunk scan
        last = cs[:, :, -1:, :]                              # (B,nc,1,H)
        decay_end = jnp.exp(last - cs)                       # (B,nc,Q,H)
        S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bch, decay_end, x_disc)
        chunk_decay = jnp.exp(last[:, :, 0])                 # (B,nc,H)

        def step(h, inp):
            sc, cd = inp
            h_new = cd[..., None, None] * h + sc
            return h_new, h

        h0 = (cache["h"] if cache is not None
              else jnp.zeros((B, H, N, Pd), jnp.float32))
        inp2 = (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
        if cfg.scan_unroll:
            h_fin, hs = h0, []
            for c in range(nc):
                h_fin, hp = step(h_fin, jax.tree.map(lambda a: a[c], inp2))
                hs.append(hp)
            H_prev = jnp.stack(hs)
        else:
            h_fin, H_prev = jax.lax.scan(step, h0, inp2)
        H_prev = H_prev.transpose(1, 0, 2, 3, 4)             # (B,nc,H,N,P)
        y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cch,
                             jnp.exp(cs), H_prev)
        y = (y_intra + y_inter).reshape(B, S, H, Pd)
        y = y + p["D"][None, None, :, None] * xc.reshape(B, S, H, Pd)
        y = y.reshape(B, S, di)
        z_full = z
        if cache is None:
            new_cache = None
        else:
            w = s.conv_dim - 1
            new_cache = {"conv": xr[:, S - w:].astype(jnp.float32),
                         "convB": Br[:, S - w:].astype(jnp.float32),
                         "convC": Cr[:, S - w:].astype(jnp.float32),
                         "h": h_fin}
    else:
        cs_x, xc1 = _conv_step(cache["conv"], xr[:, 0].astype(jnp.float32),
                               p["conv_w"], p["conv_b"])
        cs_B, Bc1 = _conv_step(cache["convB"], Br[:, 0].astype(jnp.float32),
                               p["convB_w"], p["convB_b"])
        cs_C, Cc1 = _conv_step(cache["convC"], Cr[:, 0].astype(jnp.float32),
                               p["convC_w"], p["convC_b"])
        xc1, Bc1, Cc1 = map(jax.nn.silu, (xc1, Bc1, Cc1))
        dt1 = dt[:, 0]                                       # (B,H)
        xh = xc1.reshape(B, H, Pd)
        h = cache["h"]
        h = jnp.exp(dt1 * A)[..., None, None] * h + \
            jnp.einsum("bn,bh,bhp->bhnp", Bc1, dt1, xh)
        y = jnp.einsum("bn,bhnp->bhp", Cc1, h) + \
            p["D"][None, :, None] * xh
        y = y.reshape(B, 1, di)
        z_full = z
        new_cache = {"conv": cs_x, "convB": cs_B, "convC": cs_C, "h": h}

    y = _gated_norm(y, z_full, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = y @ p["w_out"]
    return shard(out, BATCH, None, None), new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    from .sharding import batch_axes
    ba = batch_axes()
    cache = {
        "conv": jnp.zeros((batch, s.conv_dim - 1, di), jnp.float32),
        "convB": jnp.zeros((batch, s.conv_dim - 1, s.state_dim), jnp.float32),
        "convC": jnp.zeros((batch, s.conv_dim - 1, s.state_dim), jnp.float32),
        "h": jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
    }
    spec = {"conv": P(ba, None, "model"), "convB": P(ba, None, None),
            "convC": P(ba, None, None),
            "h": P(ba, "model", None, None)}
    return cache, spec
