"""Uniform model interface over all families."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .config import ModelConfig
from . import transformer as TF
from . import encdec as ED


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                    # key -> params
    specs: Callable                   # () -> (param_shapes, param_specs)
    train_loss: Callable              # (params, batch) -> (loss, metrics)
    init_caches: Callable             # (batch, max_len) -> (caches, specs)
    prefill: Callable                 # (params, batch, caches) -> (logits, caches)
    decode_step: Callable             # (params, tokens, caches, pos) -> ...
    has_decoder: bool = True


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        def init(key):
            return ED.init_encdec(key, cfg)[0]

        def prefill(params, batch, caches):
            return ED.prefill(params, batch["tokens"],
                              batch["frontend_embeds"], caches, cfg)

        return Model(
            cfg=cfg,
            init=init,
            specs=lambda: ED.encdec_specs(cfg),
            train_loss=lambda p, b: ED.train_loss(p, b, cfg),
            init_caches=lambda batch, max_len, enc_len=None: ED.init_caches(
                cfg, batch, max_len, enc_len or max_len),
            prefill=prefill,
            decode_step=lambda p, t, c, pos: ED.decode_step(p, t, c, pos,
                                                            cfg),
        )

    def init(key):
        return TF.init_lm(key, cfg)[0]

    def prefill(params, batch, caches):
        return TF.prefill(params, batch["tokens"], caches, cfg,
                          frontend_embeds=batch.get("frontend_embeds"))

    return Model(
        cfg=cfg,
        init=init,
        specs=lambda: TF.lm_specs(cfg),
        train_loss=lambda p, b: TF.train_loss(p, b, cfg),
        init_caches=lambda batch, max_len: TF.init_caches(cfg, batch,
                                                          max_len),
        prefill=prefill,
        decode_step=lambda p, t, c, pos: TF.decode_step(p, t, c, pos, cfg),
    )
