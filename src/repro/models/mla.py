"""Multi-head Latent Attention (DeepSeek V2/V3).

The KV path is a *low-rank factorization* — W_DKV: d_model → kv_lora_rank
(512) with per-head up-projections W_UK/W_UV — which is exactly the paper's
tall-skinny regime: the latent cache c_kv is the "small factor that fits on
the driver" (512 + 64 floats per token vs H·hd·2 = 32768 for MHA).

Two decode paths (the §Perf hillclimb pair for decode_32k):
  * materialize : reconstruct K, V for all cached positions each step —
                  faithful to the algebra, memory-bound on T·H·hd traffic.
  * absorbed    : fold W_UK into the query and W_UV into the output —
                  attention runs directly against the rank-512 latent cache,
                  traffic drops by ~H·hd/(r+r_rope) ≈ 57×.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import _dense_init, pdtype, apply_rope
from .sharding import shard, BATCH, MODEL

Array = jax.Array


def init_mla(key, cfg: ModelConfig):
    c = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_head = c.qk_nope_head_dim + c.qk_rope_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    if c.q_lora_rank:
        p |= {"w_dq": _dense_init(ks[0], (d, c.q_lora_rank), dt),
              "q_norm": jnp.ones((c.q_lora_rank,), jnp.float32),
              "w_uq": _dense_init(ks[1], (c.q_lora_rank, H * qk_head), dt)}
        s |= {"w_dq": P(None, None), "q_norm": P(None),
              "w_uq": P(None, "model")}
    else:
        p["w_q"] = _dense_init(ks[1], (d, H * qk_head), dt)
        s["w_q"] = P(None, "model")
    p |= {
        "w_dkv": _dense_init(ks[2], (d, c.kv_lora_rank), dt),
        "kv_norm": jnp.ones((c.kv_lora_rank,), jnp.float32),
        "w_kr": _dense_init(ks[3], (d, c.qk_rope_head_dim), dt),
        "w_uk": _dense_init(ks[4], (c.kv_lora_rank, H * c.qk_nope_head_dim),
                            dt),
        "w_uv": _dense_init(ks[5], (c.kv_lora_rank, H * c.v_head_dim), dt),
        "wo": _dense_init(ks[6], (H * c.v_head_dim, d), dt),
    }
    s |= {
        "w_dkv": P(None, None), "kv_norm": P(None), "w_kr": P(None, None),
        "w_uk": P(None, "model"), "w_uv": P(None, "model"),
        "wo": P("model", None),
    }
    return p, s


def _rms(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
            * scale).astype(x.dtype)


def _queries(p, x: Array, pos: Array, cfg: ModelConfig):
    c = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = c.qk_nope_head_dim + c.qk_rope_head_dim
    if c.q_lora_rank:
        q = _rms(x @ p["w_dq"], p["q_norm"], cfg.norm_eps) @ p["w_uq"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(B, S, H, qk_head)
    q_nope = q[..., : c.qk_nope_head_dim]
    q_rope = apply_rope(q[..., c.qk_nope_head_dim:], pos, cfg.rope_theta)
    return shard(q_nope, BATCH, None, MODEL, None), \
        shard(q_rope, BATCH, None, MODEL, None)


def _latents(p, x: Array, pos: Array, cfg: ModelConfig):
    """The tall-skinny KV path: (B,S,r) latent + (B,S,r_rope) shared key."""
    ckv = _rms(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    kr = apply_rope((x @ p["w_kr"])[:, :, None, :], pos,
                    cfg.rope_theta)[:, :, 0]
    return ckv, kr


def mla_attention(p, x: Array, pos: Array, cfg: ModelConfig, *,
                  cache: dict | None = None, cache_pos: Array | None = None,
                  decode_mode: str = "absorbed"):
    """Returns (out, new_cache); cache = {"ckv": (B,T,r), "kr": (B,T,r_r)}."""
    c = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    scale = 1.0 / np.sqrt(c.qk_nope_head_dim + c.qk_rope_head_dim)

    q_nope, q_rope = _queries(p, x, pos, cfg)
    ckv, kr = _latents(p, x, pos, cfg)

    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv,
                                                  cache_pos, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr,
                                                 cache_pos, 1)
        new_cache = {"ckv": ckv, "kr": kr}
        T = ckv.shape[1]
        valid = jnp.arange(T)[None, :] < (cache_pos + S)
        q_offset = cache_pos
    else:
        new_cache = None
        T = S
        valid = None
        q_offset = 0

    use_absorbed = (cache is not None) and decode_mode == "absorbed"
    w_uk = p["w_uk"].reshape(c.kv_lora_rank, H, c.qk_nope_head_dim)
    w_uv = p["w_uv"].reshape(c.kv_lora_rank, H, c.v_head_dim)
    if not use_absorbed:
        k_nope = jnp.einsum("btr,rhn->bthn", ckv, w_uk)
        v = jnp.einsum("btr,rhv->bthv", ckv, w_uv)

    def attend(qn, qr, off):
        """One query chunk against the full latent cache."""
        Sc = qn.shape[1]
        if use_absorbed:
            q_lat = jnp.einsum("bshn,rhn->bshr", qn, w_uk)
            logits = (jnp.einsum("bshr,btr->bhst", q_lat, ckv) +
                      jnp.einsum("bshn,btn->bhst", qr, kr)) * scale
        else:
            logits = (jnp.einsum("bshn,bthn->bhst", qn, k_nope) +
                      jnp.einsum("bshn,btn->bhst", qr, kr)) * scale
        logits = logits.astype(jnp.float32)
        qpos = off + jnp.arange(Sc)[:, None]
        cmask = qpos >= jnp.arange(T)[None, :]
        logits = jnp.where(cmask[None, None], logits, -1e30)
        if valid is not None:
            logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        if use_absorbed:
            # attn ∘ latent, then the per-head V up-projection on the output
            o_lat = jnp.einsum("bhst,btr->bshr", w.astype(ckv.dtype), ckv)
            return jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)
        return jnp.einsum("bhst,bthv->bshv", w.astype(v.dtype), v)

    qc = cfg.attn_q_chunk
    if qc and S > qc and S % qc == 0:
        nc = S // qc
        qns = jnp.moveaxis(q_nope.reshape(B, nc, qc, H, -1), 1, 0)
        qrs = jnp.moveaxis(q_rope.reshape(B, nc, qc, H, -1), 1, 0)
        offs = q_offset + jnp.arange(nc) * qc
        if cfg.scan_unroll:
            out = jnp.concatenate(
                [attend(qns[i], qrs[i], offs[i]) for i in range(nc)], 1)
        else:
            _, outs = jax.lax.scan(
                lambda cr, xx: (cr, attend(*xx)), None, (qns, qrs, offs))
            out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, c.v_head_dim)
    else:
        out = attend(q_nope, q_rope, q_offset)

    out = shard(out, BATCH, None, MODEL, None)
    out = out.reshape(B, S, H * c.v_head_dim) @ p["wo"]
    return shard(out, BATCH, None, None), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    c = cfg.mla
    dt = dtype or pdtype(cfg)
    from .sharding import batch_axes
    cache = {"ckv": jnp.zeros((batch, max_len, c.kv_lora_rank), dt),
             "kr": jnp.zeros((batch, max_len, c.qk_rope_head_dim), dt)}
    # sequence-sharded latent cache (see layers.init_attention_cache)
    spec = {"ckv": P(batch_axes(), "model", None),
            "kr": P(batch_axes(), "model", None)}
    return cache, spec
