"""Mixture-of-Experts FFN with expert parallelism over the 'model' axis.

Design (recorded in DESIGN.md): experts are sharded over the TP axis
(E_local = E / tp).  Each (dp, tp) device routes its *data shard's* tokens,
gathers the ones bound for its local experts into a capacity-bounded
(E_local, C, D) buffer, runs the expert GEMMs, scatters back, and psums the
partial outputs over 'model'.  Compared to an all_to_all dispatch this
trades duplicated (cheap) routing math for:
  * exactly ONE collective per MoE layer — the same (B,S,D) psum a
    row-parallel matmul would issue anyway;
  * no divisibility constraints on S (works for decode S=1);
  * capacity-dropping only at the per-expert level (standard GShard-style).
The a2a variant is a recorded §Perf candidate.

This is also the one place the paper's vocabulary genuinely maps onto MoE:
dispatch is a giant sparse matrix application (CoordinateMatrix semantics),
implemented the TPU way — sort + dense segment GEMMs instead of shuffles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from .config import ModelConfig
from .layers import _dense_init, pdtype
from .sharding import batch_axes, current_mesh

Array = jax.Array


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = pdtype(cfg)
    ks = jax.random.split(key, 7)
    p = {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, f), dt, scale=1.0 / (d ** 0.5)),
        "w_up": _dense_init(ks[2], (E, d, f), dt, scale=1.0 / (d ** 0.5)),
        "w_down": _dense_init(ks[3], (E, f, d), dt, scale=1.0 / (f ** 0.5)),
    }
    if cfg.moe_2d:
        # §Perf: experts resident 2D-sharded (E over model, F over data):
        # decode then never re-gathers weights — see apply_moe.
        ba = batch_axes()
        s = {
            "router": P(None, None),
            "w_gate": P("model", None, ba),
            "w_up": P("model", None, ba),
            "w_down": P("model", ba, None),
        }
    else:
        s = {
            "router": P(None, None),
            "w_gate": P("model", None, None),
            "w_up": P("model", None, None),
            "w_down": P("model", None, None),
        }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p |= {"ws_gate": _dense_init(ks[4], (d, fs), dt),
              "ws_up": _dense_init(ks[5], (d, fs), dt),
              "ws_down": _dense_init(ks[6], (fs, d), dt)}
        s |= {"ws_gate": P(None, "model"), "ws_up": P(None, "model"),
              "ws_down": P("model", None)}
    return p, s


def _moe_local(xt: Array, p: dict, cfg: ModelConfig, e_start: Array,
               e_local: int, capacity: int):
    """Token dispatch + expert GEMMs for this device's expert slice.
    xt: (T, D) local tokens.  Returns (partial output (T, D), aux loss)."""
    m = cfg.moe
    T, D = xt.shape
    E, k = m.num_experts, m.top_k

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                    # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style): E · Σ_e f_e · P_e
    counts = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
    f_e = counts / (T * k)
    P_e = probs.mean(0)
    aux = E * jnp.sum(f_e * P_e)

    N = T * k
    flat_e = eidx.reshape(-1)
    flat_g = gates.reshape(-1).astype(xt.dtype)
    flat_t = jnp.arange(N, dtype=jnp.int32) // k

    local = (flat_e >= e_start) & (flat_e < e_start + e_local)
    le = jnp.where(local, flat_e - e_start, e_local)         # e_local=trash
    perm = jnp.argsort(le, stable=True)
    sorted_le = le[perm]
    first = jnp.searchsorted(sorted_le, jnp.arange(e_local + 1),
                             side="left")
    pos = jnp.arange(N, dtype=jnp.int32) - first[sorted_le]
    keep = (sorted_le < e_local) & (pos < capacity)
    slot = jnp.where(keep, sorted_le * capacity + pos, e_local * capacity)

    # Slot-centric dispatch: build the small slot→token map first so the
    # only D-wide tensors are slot-sized (E_l·C, D), never (T·k, D).
    n_slots = e_local * capacity
    slot_token = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(
        flat_t[perm])[:-1]
    slot_gate = jnp.zeros((n_slots + 1,), xt.dtype).at[slot].set(
        jnp.where(keep, flat_g[perm], 0))[:-1]
    slot_valid = jnp.zeros((n_slots + 1,), jnp.bool_).at[slot].set(
        keep)[:-1]

    disp = jnp.where(slot_valid[:, None], xt[slot_token], 0)
    disp = disp.reshape(e_local, capacity, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", disp, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E_l, C, D)

    out = jnp.zeros((T, D), xt.dtype).at[slot_token].add(
        out_e.reshape(n_slots, D) * slot_gate[:, None])
    return out, aux


def apply_moe(p, x: Array, cfg: ModelConfig):
    """x: (B, S, D) sharded over batch axes.  Returns (out, aux_loss)."""
    m = cfg.moe
    mesh = current_mesh()
    B, S, D = x.shape

    if mesh is None:
        # Single-device path (smoke tests): one "shard" holding all experts.
        xt = x.reshape(B * S, D)
        cap = max(int(B * S * m.top_k * m.capacity_factor / m.num_experts), 4)
        out, aux = _moe_local(xt, p, cfg, jnp.int32(0), m.num_experts, cap)
        out = out.reshape(B, S, D)
    else:
        dp = batch_axes(mesh)
        tp = mesh.shape["model"]
        e_local = m.num_experts // tp
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        t_loc = (B // dp_size) * S
        cap = max(int(t_loc * m.top_k * m.capacity_factor / m.num_experts), 4)

        if cfg.moe_2d:
            # Token-gather + F-sharded expert compute: moves T·D activations
            # instead of E·D·F weights — the decode-side win (§Perf).
            t_all = B * S
            cap2 = max(int(t_all * m.top_k * m.capacity_factor
                           / m.num_experts), 4)

            def body2(x_loc, router, wg, wu, wd):
                e_start = jax.lax.axis_index("model") * e_local
                pl = {"router": router, "w_gate": wg, "w_up": wu,
                      "w_down": wd}
                xt = jax.lax.all_gather(x_loc.reshape(-1, D), dp, axis=0,
                                        tiled=True)          # (T_all, D)
                out_all, aux = _moe_local(xt, pl, cfg, e_start, e_local,
                                          cap2)
                out_all = jax.lax.psum(out_all, ("model", *dp))
                idx = jnp.int32(0)
                for a in dp:
                    idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
                t_loc = x_loc.shape[0] * S
                out = jax.lax.dynamic_slice_in_dim(out_all, idx * t_loc,
                                                   t_loc)
                return out.reshape(x_loc.shape), aux

            g_spec = P("model", None, dp)
            d_spec = P("model", dp, None)
            out, aux = compat.shard_map(
                body2, mesh=mesh,
                in_specs=(P(dp, None, None), P(None, None), g_spec, g_spec,
                          d_spec),
                out_specs=(P(dp, None, None), P()),
                check_vma=False,
            )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
        else:
            def body(x_loc, router, wg, wu, wd):
                e_start = jax.lax.axis_index("model") * e_local
                pl = {"router": router, "w_gate": wg, "w_up": wu,
                      "w_down": wd}
                xt = x_loc.reshape(-1, D)
                out, aux = _moe_local(xt, pl, cfg, e_start, e_local, cap)
                out = jax.lax.psum(out, "model")
                aux = jax.lax.pmean(aux, dp)
                return out.reshape(x_loc.shape), aux

            espec = P("model", None, None)
            out, aux = compat.shard_map(
                body, mesh=mesh,
                in_specs=(P(dp, None, None), P(None, None), espec, espec,
                          espec),
                out_specs=(P(dp, None, None), P()),
                check_vma=False,
            )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.num_shared_experts:
        h = jax.nn.silu(x @ p["ws_gate"]) * (x @ p["ws_up"])
        out = out + h @ p["ws_down"]
    return out, aux * m.router_aux_loss
