from .config import ModelConfig, MoEConfig, MLAConfig, SSMConfig, smoke_config
from .registry import build, Model

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "smoke_config", "build", "Model"]
