"""Decoder-only LM assembly: scan-over-layers, remat, caches, MTP.

Covers families: dense (llama/qwen + vlm backbone), moe (DeepSeek MLA+MoE
with dense prefix + MTP), ssm (falcon-mamba), hybrid (zamba2: mamba2
backbone + one *shared-weight* attention block applied every `attn_every`
layers, each application with its own KV cache).

Layers are stacked and driven by `lax.scan` so the HLO (and compile time on
the 512-device dry-run) is depth-independent.  Specs are collected by the
`eval_shape` capture trick — `lm_specs(cfg)` never allocates, which is what
lets the 671B config lower on this CPU-only container.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import ssm as SSM
from .sharding import shard

Array = jax.Array


# ---------------------------------------------------------- layer kinds ----
def _init_attn(key, cfg):
    if cfg.mla:
        return MLA.init_mla(key, cfg)
    return L.init_attention(key, cfg)


def _apply_attn(p, x, pos, cfg, cache=None, cache_pos=None):
    if cfg.mla:
        return MLA.mla_attention(p, x, pos, cfg, cache=cache,
                                 cache_pos=cache_pos,
                                 decode_mode=cfg.mla_decode_mode)
    return L.attention(p, x, pos, cfg, cache=cache, cache_pos=cache_pos)


def _attn_cache(cfg, batch, max_len):
    if cfg.mla:
        return MLA.init_mla_cache(cfg, batch, max_len)
    return L.init_attention_cache(cfg, batch, max_len)


def init_block(key, cfg: ModelConfig, kind: str):
    """kind ∈ {dense, moe_ffn, mamba1, mamba2}.  Returns (params, specs)."""
    ks = jax.random.split(key, 4)
    if kind in ("dense", "moe_ffn"):
        n1, s1 = L.init_norm(cfg)
        at, sa = _init_attn(ks[0], cfg)
        n2, s2 = L.init_norm(cfg)
        if kind == "moe_ffn":
            ff, sf = MOE.init_moe(ks[1], cfg)
        else:
            d_ff = (cfg.moe.dense_d_ff or cfg.d_ff) if cfg.moe else cfg.d_ff
            ff, sf = L.init_mlp(ks[1], cfg, d_ff=d_ff)
        return ({"norm1": n1, "attn": at, "norm2": n2, "ffn": ff},
                {"norm1": s1, "attn": sa, "norm2": s2, "ffn": sf})
    if kind == "mamba1":
        n1, s1 = L.init_norm(cfg)
        mx, sm = SSM.init_mamba1(ks[0], cfg)
        return {"norm1": n1, "mixer": mx}, {"norm1": s1, "mixer": sm}
    if kind == "mamba2":
        n1, s1 = L.init_norm(cfg)
        mx, sm = SSM.init_mamba2(ks[0], cfg)
        return {"norm1": n1, "mixer": mx}, {"norm1": s1, "mixer": sm}
    raise ValueError(kind)


def apply_block(p, x, pos, cfg: ModelConfig, kind: str, *,
                cache=None, cache_pos=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0)
    if kind in ("dense", "moe_ffn"):
        h = L.apply_norm(p["norm1"], x, cfg)
        a, new_cache = _apply_attn(p["attn"], h, pos, cfg, cache=cache,
                                   cache_pos=cache_pos)
        x = x + a
        h = L.apply_norm(p["norm2"], x, cfg)
        if kind == "moe_ffn":
            f, aux = MOE.apply_moe(p["ffn"], h, cfg)
        else:
            f = L.apply_mlp(p["ffn"], h, cfg)
        x = x + f
        return x, new_cache, aux
    if kind in ("mamba1", "mamba2"):
        h = L.apply_norm(p["norm1"], x, cfg)
        fn = SSM.mamba1_block if kind == "mamba1" else SSM.mamba2_block
        a, new_cache = fn(p["mixer"], h, cfg, cache=cache)
        return x + a, new_cache, aux
    raise ValueError(kind)


def block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("dense", "moe_ffn"):
        return _attn_cache(cfg, batch, max_len)
    if kind == "mamba1":
        return SSM.init_mamba1_cache(cfg, batch)
    if kind == "mamba2":
        return SSM.init_mamba2_cache(cfg, batch)
    raise ValueError(kind)


# ------------------------------------------------------------ structure ----
def lm_structure(cfg: ModelConfig) -> list[tuple[str, int, str]]:
    """[(stack_name, n_layers, kind)] per family."""
    if cfg.family in ("dense", "vlm"):
        return [("blocks", cfg.num_layers, "dense")]
    if cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        return [("dense_prefix", fk, "dense"),
                ("moe_blocks", cfg.num_layers - fk, "moe_ffn")]
    if cfg.family == "ssm":
        return [("blocks", cfg.num_layers, "mamba1")]
    if cfg.family == "hybrid":
        per = cfg.ssm.attn_every or cfg.num_layers
        n_groups = cfg.num_layers // per
        rem = cfg.num_layers - n_groups * per
        out = [("groups", n_groups, "hybrid_group")]
        if rem:
            out.append(("tail", rem, "mamba2"))
        return out
    raise ValueError(cfg.family)


_CAPTURE: dict = {}


def _stack_init(key, cfg, kind: str, n: int):
    """vmap-stacked per-layer init; captures specs as a tracing side effect."""
    tag = f"{cfg.name}/{kind}"

    def one(k):
        if kind == "hybrid_group":
            p, s = _init_hybrid_group(k, cfg)
        else:
            p, s = init_block(k, cfg, kind)
        _CAPTURE[tag] = s
        return p

    params = jax.vmap(one)(jax.random.split(key, n))
    specs = jax.tree.map(lambda sp: P(None, *sp), _CAPTURE[tag],
                         is_leaf=lambda v: isinstance(v, P))
    return params, specs


def _init_hybrid_group(key, cfg):
    """One zamba2 super-block: `attn_every` mamba2 layers (the shared
    attention weights live OUTSIDE the scan — see init_lm)."""
    per = cfg.ssm.attn_every

    def one(k):
        p, s = init_block(k, cfg, "mamba2")
        _CAPTURE["_hg"] = s
        return p

    params = jax.vmap(one)(jax.random.split(key, per))
    specs = jax.tree.map(lambda sp: P(None, *sp), _CAPTURE["_hg"],
                         is_leaf=lambda v: isinstance(v, P))
    return {"mamba": params}, {"mamba": specs}


def init_lm(key, cfg: ModelConfig):
    """Returns (params, specs). Traceable (use under jax.eval_shape for the
    dry-run); call `lm_specs(cfg)` for specs without allocation."""
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = L.init_embedding(ks[0], cfg)
    params["final_norm"], specs["final_norm"] = L.init_norm(cfg)
    for i, (name, n, kind) in enumerate(lm_structure(cfg)):
        params[name], specs[name] = _stack_init(ks[1 + i], cfg, kind, n)
    if cfg.family == "hybrid":
        params["shared_attn"], specs["shared_attn"] = \
            init_block(ks[5], cfg, "dense")
    if cfg.mtp_depth:
        p_m, s_m = init_block(ks[6], cfg, "moe_ffn" if cfg.moe else "dense")
        proj = L._dense_init(ks[7], (2 * cfg.d_model, cfg.d_model),
                             L.pdtype(cfg))
        nrm, snrm = L.init_norm(cfg)
        params["mtp"] = {"proj": proj, "block": p_m, "norm": nrm}
        specs["mtp"] = {"proj": P(None, None), "block": s_m, "norm": snrm}
    return params, specs


def lm_specs(cfg: ModelConfig):
    """PartitionSpec pytree without allocating parameters."""
    box = {}

    def f(key):
        p, s = init_lm(key, cfg)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["s"]


# ------------------------------------------------------------- forward -----
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _at(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _restack(items):
    if items and items[0] is None:
        return None
    return jax.tree.map(lambda *xs: jnp.stack(xs), *items)


def _scan_stack(params, x, pos, cfg, kind, *, caches=None, cache_pos=None):
    """Scan a stacked layer group.  Returns (x, new_caches, aux_sum)."""

    def body(carry, xs):
        x, aux = carry
        if caches is None:
            lp = xs
            x, _, a = apply_block(lp, x, pos, cfg, kind)
            return (x, aux + a), None
        lp, c = xs
        x, nc, a = apply_block(lp, x, pos, cfg, kind, cache=c,
                               cache_pos=cache_pos)
        return (x, aux + a), nc

    body = _remat(body, cfg)
    if cfg.scan_unroll:
        L = jax.tree.leaves(params)[0].shape[0]
        carry, ncs = (x, jnp.float32(0)), []
        for i in range(L):
            xs = _at(params, i) if caches is None else (_at(params, i),
                                                        _at(caches, i))
            carry, nc = body(carry, xs)
            ncs.append(nc)
        (x, aux) = carry
        return x, _restack(ncs), aux
    xs = params if caches is None else (params, caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, new_caches, aux


def _scan_hybrid(params, shared_p, x, pos, cfg, *, caches=None,
                 cache_pos=None):
    """Zamba2 groups: shared attention block + `per` mamba2 layers.
    caches = {"attn": stacked-per-group attn cache, "mamba": nested}."""

    def body(carry, xs):
        x, aux = carry
        if caches is None:
            gp = xs
            h, _, _ = apply_block(shared_p, x, pos, cfg, "dense")

            def inner(c2, lp):
                y, _, _ = apply_block(lp, c2, pos, cfg, "mamba2")
                return y, None

            if cfg.scan_unroll:
                for i in range(jax.tree.leaves(gp["mamba"])[0].shape[0]):
                    h, _ = inner(h, _at(gp["mamba"], i))
            else:
                h, _ = jax.lax.scan(inner, h, gp["mamba"])
            return (h, aux), None
        gp, c = xs
        h, nac, _ = apply_block(shared_p, x, pos, cfg, "dense",
                                cache=c["attn"], cache_pos=cache_pos)

        def inner(c2, xs2):
            lp, mc = xs2
            y, nmc, _ = apply_block(lp, c2, pos, cfg, "mamba2", cache=mc)
            return y, nmc

        if cfg.scan_unroll:
            nmcs = []
            for i in range(jax.tree.leaves(gp["mamba"])[0].shape[0]):
                h, nmc_i = inner(h, (_at(gp["mamba"], i), _at(c["mamba"], i)))
                nmcs.append(nmc_i)
            nmc = _restack(nmcs)
        else:
            h, nmc = jax.lax.scan(inner, h, (gp["mamba"], c["mamba"]))
        return (h, aux), {"attn": nac, "mamba": nmc}

    body = _remat(body, cfg)
    if cfg.scan_unroll:
        G = jax.tree.leaves(params)[0].shape[0]
        carry, ncs = (x, jnp.float32(0)), []
        for i in range(G):
            xs = _at(params, i) if caches is None else (_at(params, i),
                                                        _at(caches, i))
            carry, nc = body(carry, xs)
            ncs.append(nc)
        (x, aux) = carry
        return x, _restack(ncs), aux
    xs = params if caches is None else (params, caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, new_caches, aux


def forward(params, tokens: Array, cfg: ModelConfig, *,
            frontend_embeds: Array | None = None,
            caches: dict | None = None, cache_pos: Array | None = None):
    """Full forward.  Returns (hidden (B,S,D), new_caches, aux)."""
    B, S = tokens.shape
    if cache_pos is None:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    else:
        pos = cache_pos + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, S))
    x = L.embed(params["embed"], tokens, cfg, frontend_embeds)
    aux_total = jnp.float32(0)
    new_caches: dict[str, Any] = {}
    for name, n, kind in lm_structure(cfg):
        c = caches.get(name) if caches else None
        if kind == "hybrid_group":
            x, nc, aux = _scan_hybrid(params[name], params["shared_attn"],
                                      x, pos, cfg, caches=c,
                                      cache_pos=cache_pos)
        else:
            x, nc, aux = _scan_stack(params[name], x, pos, cfg, kind,
                                     caches=c, cache_pos=cache_pos)
        aux_total = aux_total + aux
        if caches is not None:
            new_caches[name] = nc
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, (new_caches if caches is not None else None), aux_total


def train_loss(params, batch: dict, cfg: ModelConfig):
    """Next-token CE (+ MoE aux + MTP aux).  batch: tokens (B,S) [+ stubs]."""
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    h, _, aux = forward(params, tokens, cfg, frontend_embeds=fe)
    logits = L.lm_logits(params["embed"], h, cfg)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], 1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    if fe is not None:
        n = fe.shape[1]
        mask = mask.at[:, :n].set(0.0)       # no loss on stub positions
    loss = L.softmax_xent(logits, labels, mask)
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp_depth:
        mp = params["mtp"]
        emb_next = L.embed(params["embed"],
                           jnp.concatenate([tokens[:, 1:], tokens[:, :1]], 1),
                           cfg)
        hm = jnp.concatenate([L.apply_norm(mp["norm"], h, cfg), emb_next],
                             -1) @ mp["proj"]
        kind = "moe_ffn" if cfg.moe else "dense"
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32),
                               tokens.shape)
        mtp_block = _remat(
            lambda hh: apply_block(mp["block"], hh, pos, cfg, kind), cfg)
        hm, _, aux2 = mtp_block(hm)
        hm = L.apply_norm(params["final_norm"], hm, cfg)
        logits2 = L.lm_logits(params["embed"], hm, cfg)
        labels2 = jnp.roll(tokens, -2, axis=1)
        mask2 = mask.at[:, -2:].set(0.0)
        mtp_loss = L.softmax_xent(logits2, labels2, mask2)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
        aux = aux + aux2
    return loss + aux, metrics


# ------------------------------------------------------------- serving -----
def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    caches, specs = {}, {}
    for name, n, kind in lm_structure(cfg):
        if kind == "hybrid_group":
            ac, acs = block_cache(cfg, "dense", batch, max_len)
            mc, mcs = block_cache(cfg, "mamba2", batch, max_len)
            per = cfg.ssm.attn_every
            caches[name] = {
                "attn": jax.tree.map(
                    lambda z: jnp.broadcast_to(z, (n, *z.shape)), ac),
                "mamba": jax.tree.map(
                    lambda z: jnp.broadcast_to(z, (n, per, *z.shape)), mc)}
            specs[name] = {
                "attn": jax.tree.map(lambda s: P(None, *s), acs,
                                     is_leaf=lambda v: isinstance(v, P)),
                "mamba": jax.tree.map(lambda s: P(None, None, *s), mcs,
                                      is_leaf=lambda v: isinstance(v, P))}
        else:
            c, cs = block_cache(cfg, kind, batch, max_len)
            caches[name] = jax.tree.map(
                lambda z: jnp.broadcast_to(z, (n, *z.shape)), c)
            specs[name] = jax.tree.map(lambda s: P(None, *s), cs,
                                       is_leaf=lambda v: isinstance(v, P))
    return caches, specs


def prefill(params, tokens: Array, caches: dict, cfg: ModelConfig, *,
            frontend_embeds: Array | None = None):
    """Fill caches from a prompt; returns (last-position logits, caches)."""
    h, caches, _ = forward(params, tokens, cfg,
                           frontend_embeds=frontend_embeds, caches=caches,
                           cache_pos=jnp.int32(0))
    logits = L.lm_logits(params["embed"], h[:, -1:], cfg)
    return logits, caches


def decode_step(params, tokens: Array, caches: dict, pos: Array,
                cfg: ModelConfig):
    """One token step: tokens (B,1), pos scalar int32 (current length)."""
    h, caches, _ = forward(params, tokens, cfg, caches=caches,
                           cache_pos=pos)
    logits = L.lm_logits(params["embed"], h, cfg)
    return logits, caches
