"""Core transformer layers: norms, RoPE, GQA attention (qk_norm / bias
options), gated MLP, embeddings, losses.

Pure-functional: every layer is an (init, apply) pair; `init` returns
(params, specs) where specs is a parallel pytree of PartitionSpec for the
TP layout (Megatron-style: QKV/up column-parallel over 'model', O/down
row-parallel, vocab-sharded embeddings).  Params are replicated over the
data axes; only 'model' appears in param specs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .sharding import shard, BATCH, MODEL

Array = jax.Array
KeyArray = jax.Array


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _dense_init(key: KeyArray, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----
def init_norm(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        p = {"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)}
        s = {"scale": P(None), "bias": P(None)}
    else:
        p = {"scale": jnp.ones((d,), jnp.float32)}
        s = {"scale": P(None)}
    return p, s


def apply_norm(p, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"] + p["bias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(scale: Array, x: Array, eps: float) -> Array:
    """Per-head RMS norm (qk_norm, Qwen3-style): x (..., hd)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x: (B, S, H, hd), pos: (B, S) int32 → rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def init_attention(key: KeyArray, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = {
        "wq": _dense_init(ks[0], (d, H * hd), dt),
        "wk": _dense_init(ks[1], (d, KV * hd), dt),
        "wv": _dense_init(ks[2], (d, KV * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, d), dt),
    }
    s: dict[str, Any] = {"wq": P(None, "model"), "wk": P(None, "model"),
                         "wv": P(None, "model"), "wo": P("model", None)}
    if cfg.qkv_bias:
        p |= {"bq": jnp.zeros((H * hd,), dt), "bk": jnp.zeros((KV * hd,), dt),
              "bv": jnp.zeros((KV * hd,), dt)}
        s |= {"bq": P("model"), "bk": P("model"), "bv": P("model")}
    if cfg.qk_norm:
        p |= {"q_norm": jnp.ones((hd,), jnp.float32),
              "k_norm": jnp.ones((hd,), jnp.float32)}
        s |= {"q_norm": P(None), "k_norm": P(None)}
    return p, s


def _qkv(p, x: Array, pos: Array, cfg: ModelConfig):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # No explicit head-dim constraints: H/KV are rarely divisible by the TP
    # width (56 heads on tp=16), and fighting GSPMD's propagation here
    # causes involuntary remat copies.  The projections' column sharding
    # propagates a consistent layout on its own.
    return q, k, v


def _mha_direct(q: Array, k: Array, v: Array, *, causal: bool,
                q_offset: Array | int = 0, kv_mask: Array | None = None,
                scale: float | None = None) -> Array:
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, S, KV, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        logits = jnp.where((qpos >= kpos)[None, None, None], logits, -1e30)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def mha(q: Array, k: Array, v: Array, *, causal: bool,
        q_offset: Array | int = 0, kv_mask: Array | None = None,
        scale: float | None = None, q_chunk: int = 0,
        unroll: bool = False) -> Array:
    """Grouped-query attention, f32 softmax.  q: (B,S,H,hd); k/v: (B,T,KV,·).
    q_offset: global position of the first query (decode into a cache).
    kv_mask: (B, T) validity (decode against a partially-filled cache).

    With q_chunk > 0 and long S, queries stream through in chunks so only a
    (B, H, q_chunk, T) score block is ever live — the XLA-level analogue of
    the Pallas flash kernel (which replaces this entirely on real TPU; see
    kernels/flash_attention.py).  Under full-remat training the backward
    recomputes per chunk, bounding memory both ways."""
    B, S, H, hd = q.shape
    if not q_chunk or S <= q_chunk or S % q_chunk:
        return _mha_direct(q, k, v, causal=causal, q_offset=q_offset,
                           kv_mask=kv_mask, scale=scale)
    nc = S // q_chunk
    qs = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, hd), 1, 0)
    offs = q_offset + jnp.arange(nc) * q_chunk

    def one(qc, off):
        return _mha_direct(qc, k, v, causal=causal, q_offset=off,
                           kv_mask=kv_mask, scale=scale)

    if unroll:
        outs = jnp.stack([one(qs[i], offs[i]) for i in range(nc)])
    else:
        _, outs = jax.lax.scan(lambda c, x: (c, one(*x)), None, (qs, offs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def attention(p, x: Array, pos: Array, cfg: ModelConfig, *,
              cache: dict | None = None, cache_pos: Array | None = None,
              xattn_kv: Array | None = None, causal: bool = True):
    """Full attention with optional KV cache (decode) and cross-attention.

    cache: {"k": (B, Smax, KV, hd), "v": ...} updated at cache_pos.
    Returns (out, new_cache)."""
    B, S, _ = x.shape
    if xattn_kv is not None:
        # Cross-attention: keys/values from encoder output (no RoPE, no cache
        # update needed after prefill — kv recomputed or cached upstream).
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = (x @ p["wq"]).reshape(B, S, H, hd)
        k = (xattn_kv @ p["wk"]).reshape(B, xattn_kv.shape[1], KV, hd)
        v = (xattn_kv @ p["wv"]).reshape(B, xattn_kv.shape[1], KV, hd)
        out = mha(q, k, v, causal=False, q_chunk=cfg.attn_q_chunk,
                  unroll=cfg.scan_unroll)
        out = out.reshape(B, S, -1) @ p["wo"]
        return shard(out, BATCH, None, None), None

    q, k, v = _qkv(p, x, pos, cfg)
    if cfg.attn_kv_pregather:
        # §Perf: materialize fully-gathered K/V ONCE before the q-chunk
        # loop (XLA cannot hoist the gather out of the scanned loop, so
        # without this every chunk re-gathers — see EXPERIMENTS.md §Perf).
        k = shard(k, BATCH, None, None, None)
        v = shard(v, BATCH, None, None, None)
    if cache is None:
        out = mha(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
                  unroll=cfg.scan_unroll)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, 1)
        T = ck.shape[1]
        kv_mask = jnp.arange(T)[None, :] < (cache_pos + S)
        out = mha(q, ck, cv, causal=True, q_offset=cache_pos,
                  kv_mask=kv_mask, q_chunk=cfg.attn_q_chunk,
                  unroll=cfg.scan_unroll)
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, -1) @ p["wo"]
    return shard(out, BATCH, None, None), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         dtype=None):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = dtype or pdtype(cfg)
    z = jnp.zeros((batch, max_len, KV, hd), dt)
    # Sequence-sharded cache (context-parallel decode): the attention
    # contraction over T then needs only O(B·H) softmax-stat psums instead
    # of gathering the cache — and it works for any KV-head count vs TP
    # width.  Prefill pays one reshard when writing the cache.
    spec = P(batch_spec(), "model", None, None)
    return {"k": z, "v": z}, {"k": spec, "v": spec}


def batch_spec():
    from .sharding import batch_axes
    return batch_axes()


# ----------------------------------------------------------------- mlp -----
def init_mlp(key: KeyArray, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        p = {"w_gate": _dense_init(ks[0], (d, f), dt),
             "w_up": _dense_init(ks[1], (d, f), dt),
             "w_down": _dense_init(ks[2], (f, d), dt)}
        s = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
             "w_down": P("model", None)}
    else:
        p = {"w_up": _dense_init(ks[0], (d, f), dt),
             "b_up": jnp.zeros((f,), dt),
             "w_down": _dense_init(ks[1], (f, d), dt),
             "b_down": jnp.zeros((d,), dt)}
        s = {"w_up": P(None, "model"), "b_up": P("model"),
             "w_down": P("model", None), "b_down": P(None)}
    return p, s


def apply_mlp(p, x: Array, cfg: ModelConfig) -> Array:
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard(h, BATCH, None, MODEL)
        out = h @ p["w_down"]
    else:
        h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
        h = shard(h, BATCH, None, MODEL)
        out = h @ p["w_down"] + p["b_down"]
    return shard(out, BATCH, None, None)


# ------------------------------------------------------------ embedding ----
VOCAB_PAD = 256   # pad vocab so the table shards on any mesh (≤256-way TP)


def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def init_embedding(key: KeyArray, cfg: ModelConfig):
    dt = pdtype(cfg)
    ks = jax.random.split(key, 2)
    vp = padded_vocab(cfg)
    p = {"table": _dense_init(ks[0], (vp, cfg.d_model), dt, 0.02)}
    s = {"table": P("model", None)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(ks[1], (cfg.d_model, vp), dt)
        s["head"] = P(None, "model")
    return p, s


def embed(p, tokens: Array, cfg: ModelConfig,
          frontend_embeds: Array | None = None) -> Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if frontend_embeds is not None:
        # [vlm]/[audio] stub: the first `frontend_len` positions are
        # precomputed modality embeddings (paper-assignment contract).
        n = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, n:]], 1)
    return shard(x, BATCH, None, None)


def lm_logits(p, x: Array, cfg: ModelConfig) -> Array:
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w.astype(x.dtype)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:
        # mask padded vocab columns out of the softmax
        valid = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return shard(logits, BATCH, None, MODEL)


# ---------------------------------------------------------------- loss -----
def softmax_xent(logits: Array, labels: Array,
                 mask: Array | None = None) -> Array:
    """Mean next-token CE over valid positions; logits may be vocab-sharded
    (reductions over V become psums under GSPMD)."""
    lf = logits.astype(jnp.float32)
    m = lf.max(-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), -1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = lse - label_logit
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
