"""Encoder–decoder LM (seamless-m4t-large-v2 backbone).

The audio frontend is a stub per the assignment contract: `input_specs`
provides precomputed frame embeddings (B, S_enc, D) which the encoder
consumes directly.  The decoder is a standard causal LM with per-layer
cross-attention; at prefill the cross K/V are projected once from the
encoder memory and cached (decode then touches only the small per-step
self-attention update + cached cross K/V).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from .sharding import shard, BATCH, batch_axes

Array = jax.Array


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    n1, s1 = L.init_norm(cfg)
    at, sa = L.init_attention(ks[0], cfg)
    n2, s2 = L.init_norm(cfg)
    ml, sm = L.init_mlp(ks[1], cfg)
    return ({"norm1": n1, "attn": at, "norm2": n2, "mlp": ml},
            {"norm1": s1, "attn": sa, "norm2": s2, "mlp": sm})


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    n1, s1 = L.init_norm(cfg)
    sa, ssa = L.init_attention(ks[0], cfg)
    nx, snx = L.init_norm(cfg)
    xa, sxa = L.init_attention(ks[1], cfg)
    n2, s2 = L.init_norm(cfg)
    ml, sm = L.init_mlp(ks[2], cfg)
    return ({"norm1": n1, "self_attn": sa, "norm_x": nx, "cross_attn": xa,
             "norm2": n2, "mlp": ml},
            {"norm1": s1, "self_attn": ssa, "norm_x": snx, "cross_attn": sxa,
             "norm2": s2, "mlp": sm})


_CAP: dict = {}


def _stack(key, n, one):
    def wrap(k):
        p, s = one(k)
        _CAP["s"] = s
        return p

    params = jax.vmap(wrap)(jax.random.split(key, n))
    specs = jax.tree.map(lambda sp: P(None, *sp), _CAP["s"],
                         is_leaf=lambda v: isinstance(v, P))
    return params, specs


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = L.init_embedding(ks[0], cfg)
    params["encoder"], specs["encoder"] = _stack(
        ks[1], cfg.encoder_layers, lambda k: _init_enc_layer(k, cfg))
    params["decoder"], specs["decoder"] = _stack(
        ks[2], cfg.num_layers, lambda k: _init_dec_layer(k, cfg))
    params["enc_norm"], specs["enc_norm"] = L.init_norm(cfg)
    params["final_norm"], specs["final_norm"] = L.init_norm(cfg)
    return params, specs


def encdec_specs(cfg: ModelConfig):
    box = {}

    def f(key):
        p, s = init_encdec(key, cfg)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["s"]


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, S_enc, D) stub embeddings → encoder memory."""
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = shard(frames.astype(L.pdtype(cfg)), BATCH, None, None)

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], x, cfg)
        a, _ = L.attention(lp["attn"], h, pos, cfg, causal=False)
        x = x + a
        h = L.apply_norm(lp["norm2"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h, cfg), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    if cfg.scan_unroll:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(lp, memory: Array, cfg: ModelConfig):
    B, S, _ = memory.shape
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (memory @ lp["cross_attn"]["wk"]).reshape(B, S, KV, hd)
    v = (memory @ lp["cross_attn"]["wv"]).reshape(B, S, KV, hd)
    return k, v


def _dec_layer(lp, x, pos, cfg, *, cross_k, cross_v, cache=None,
               cache_pos=None):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    h = L.apply_norm(lp["norm1"], x, cfg)
    a, new_cache = L.attention(lp["self_attn"], h, pos, cfg, cache=cache,
                               cache_pos=cache_pos)
    x = x + a
    h = L.apply_norm(lp["norm_x"], x, cfg)
    q = (h @ lp["cross_attn"]["wq"]).reshape(B, S, H, hd)
    o = L.mha(q, cross_k, cross_v, causal=False,
              q_chunk=cfg.attn_q_chunk, unroll=cfg.scan_unroll)
    o = o.reshape(B, S, H * hd) @ lp["cross_attn"]["wo"]
    x = x + shard(o, BATCH, None, None)
    h = L.apply_norm(lp["norm2"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg), new_cache


def decode_forward(params, tokens: Array, memory: Array | None,
                   cfg: ModelConfig, *, caches=None, cache_pos=None):
    """Decoder pass. caches = {"self": stacked kv, "cross_k/v": stacked}."""
    B, S = tokens.shape
    base = jnp.int32(0) if cache_pos is None else cache_pos
    pos = base + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = L.embed(params["embed"], tokens, cfg)

    if memory is None:
        # decode: cross K/V were projected once at prefill and cached
        cross_k, cross_v = caches["cross_k"], caches["cross_v"]
    else:
        def kv(lp):
            return _cross_kv(lp, memory, cfg)
        cross_k, cross_v = jax.vmap(kv)(params["decoder"])
        if caches is not None:
            caches = dict(caches, cross_k=cross_k, cross_v=cross_v)

    def body(carry, xs):
        x = carry
        if caches is None:
            lp, ck, cv = xs
            x, _ = _dec_layer(lp, x, pos, cfg, cross_k=ck, cross_v=cv)
            return x, None
        lp, ck, cv, sc = xs
        x, nsc = _dec_layer(lp, x, pos, cfg, cross_k=ck, cross_v=cv,
                            cache=sc, cache_pos=base)
        return x, nsc

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    if caches is None:
        xs = (params["decoder"], cross_k, cross_v)
        if cfg.scan_unroll:
            for i in range(cfg.num_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i], xs))
        else:
            x, _ = jax.lax.scan(body, x, xs)
        new_caches = None
    else:
        xs = (params["decoder"], cross_k, cross_v, caches["self"])
        if cfg.scan_unroll:
            scs = []
            for i in range(cfg.num_layers):
                x, sc_i = body(x, jax.tree.map(lambda a: a[i], xs))
                scs.append(sc_i)
            new_self = jax.tree.map(lambda *v: jnp.stack(v), *scs)
        else:
            x, new_self = jax.lax.scan(body, x, xs)
        new_caches = {"self": new_self, "cross_k": cross_k,
                      "cross_v": cross_v}
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, new_caches


def train_loss(params, batch: dict, cfg: ModelConfig):
    memory = encode(params, batch["frontend_embeds"], cfg)
    h, _ = decode_forward(params, batch["tokens"], memory, cfg)
    logits = L.lm_logits(params["embed"], h, cfg)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], 1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    loss = L.softmax_xent(logits, labels, mask)
    return loss, {"ce": loss}


def init_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    Ld = cfg.num_layers
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ba = batch_axes()
    dt = L.pdtype(cfg)
    kvshape = (Ld, batch, max_len, KV, hd)
    xshape = (Ld, batch, enc_len, KV, hd)
    caches = {"self": {"k": jnp.zeros(kvshape, dt),
                       "v": jnp.zeros(kvshape, dt)},
              "cross_k": jnp.zeros(xshape, dt),
              "cross_v": jnp.zeros(xshape, dt)}
    spec = P(None, ba, "model", None, None)   # sequence-sharded caches
    specs = {"self": {"k": spec, "v": spec}, "cross_k": spec,
             "cross_v": spec}
    return caches, specs


def prefill(params, tokens: Array, frames: Array, caches, cfg: ModelConfig):
    memory = encode(params, frames, cfg)
    h, caches = decode_forward(params, tokens, memory, cfg, caches=caches,
                               cache_pos=jnp.int32(0))
    logits = L.lm_logits(params["embed"], h[:, -1:], cfg)
    return logits, caches


def decode_step(params, tokens: Array, caches, pos: Array,
                cfg: ModelConfig):
    h, caches = decode_forward(params, tokens, None, cfg, caches=caches,
                               cache_pos=pos)
    logits = L.lm_logits(params["embed"], h, cfg)
    return logits, caches
