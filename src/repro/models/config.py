"""Model configuration — one dataclass family covers all 10 assigned
architectures (dense GQA / enc-dec / hybrid / MoE+MLA / SSM / VLM-backbone).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Literal


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_k_dense: int = 1          # leading dense layers (DeepSeek style)
    dense_d_ff: int | None = None   # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int | None = None  # None → full-rank Q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    version: Literal[1, 2] = 1
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64              # mamba2 only
    dt_rank: int | None = None      # mamba1 only; None → ceil(d_model/16)
    chunk: int = 256                # scan chunk length
    attn_every: int = 0             # hybrid: shared attn block period (0=off)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "encdec", "hybrid", "moe", "ssm", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None     # None → d_model // num_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # families
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    encoder_layers: int = 0
    mtp_depth: int = 0              # multi-token prediction heads (DeepSeek-V3)
    # frontend stubs ([audio]/[vlm]): input_specs provide embeddings directly
    frontend: Literal[None, "patches", "frames"] = None
    frontend_len: int = 576         # patches/frames consumed per example
    # layer flavors
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    # numerics / memory
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: Literal["none", "dots", "full"] = "full"
    scan_unroll: bool = False       # python-loop layers (cost-model lowers)
    attn_q_chunk: int = 1024        # query-chunked attention block (0=off)
    mla_decode_mode: Literal["absorbed", "materialize"] = "absorbed"
    # §Perf hillclimb levers (default off = faithful baseline)
    attn_kv_pregather: bool = False  # gather K/V once before the q-chunk loop
    moe_2d: bool = False             # F-sharded expert compute (no FSDP re-gather)
    ssm_shard_scan: bool = False     # constrain SSM scan intermediates to TP
    ssm_scan_dtype: str = "float32"  # bf16 halves the scan's HBM traffic
    tie_embeddings: bool = False
    # long-context attention capability (sub-quadratic): SSM/hybrid only
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return replace(self, **kw)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config: few layers, narrow width, small vocab."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family in ("hybrid",) else 2),
        d_model=128,
        num_heads=4, num_kv_heads=min(4, max(1, cfg.num_kv_heads)),
        head_dim=32,
        d_ff=256, vocab_size=512, dtype="float32", remat="none",
        frontend_len=8,
    )
    if cfg.moe:
        # capacity_factor high enough to avoid dropping: keeps the cached
        # decode path bit-identical to the full forward in tests.
        kw["moe"] = replace(cfg.moe, num_experts=8, top_k=2, d_ff_expert=64,
                            dense_d_ff=256, first_k_dense=1,
                            capacity_factor=8.0)
    if cfg.mla:
        kw["mla"] = replace(cfg.mla, kv_lora_rank=64,
                            q_lora_rank=64 if cfg.mla.q_lora_rank else None,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=16, chunk=16,
                            dt_rank=8 if cfg.ssm.version == 1 else None,
                            attn_every=2 if cfg.ssm.attn_every else 0)
        kw["num_layers"] = 4
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return cfg.scaled(**kw)
