"""Version compatibility shims for the jax API surface we depend on.

The repo targets the current jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``) but must also run on
the pinned jax 0.4.37 toolchain baked into the CI/container image, where:

  * ``jax.sharding.AxisType`` does not exist and ``jax.make_mesh`` takes no
    ``axis_types`` keyword (explicit-sharding axis types landed later);
  * ``shard_map`` lives in ``jax.experimental.shard_map`` only;
  * the Pallas TPU compiler-params dataclass is ``TPUCompilerParams``.

Everything below is a getattr-with-fallback — no version parsing — so the
same code path keeps working when either side of the fence changes.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.experimental.pallas.tpu as pltpu

# -- shard_map ---------------------------------------------------------------
if hasattr(jax, "shard_map"):
    _shard_map, _relax_kw = jax.shard_map, "check_vma"
else:  # jax <= 0.4.x: experimental module, and check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _relax_kw = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    kw = {} if check_vma is None else {_relax_kw: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)

def axis_size(name: str):
    """``jax.lax.axis_size`` inside shard_map; psum(1) on older jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


# -- mesh construction -------------------------------------------------------
# AxisType.Auto is the default behaviour on old jax, so the fallback is
# simply to drop the argument.
AxisType = getattr(jax.sharding, "AxisType", None)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape, names = tuple(shape), tuple(names)
    if AxisType is not None:
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)


def cost_analysis(compiled) -> dict:
    """Compiled-executable cost analysis as a flat dict on every jax version
    (jax <= 0.4.x returns a one-element list of per-device dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


# -- Pallas TPU compiler params ----------------------------------------------
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
