"""Shape-aware kernel autotuner: block-size search with a persistent cache.

The Pallas kernels used to answer the paper's §4 question ("how close to
hand-tuned hardware GEMM can a managed runtime get?") with hand-picked
`bm/bn/bk` constants that are only right for one shape regime.  This module
replaces those magic numbers with a three-stage, shape-aware search:

  1. **Candidate generation** — enumerate tile configs that satisfy the TPU
     layout rules (last dim a multiple of 128 lanes; second-to-last a
     multiple of the dtype sublane count: 8 for f32, 16 for bf16, 32 for
     int8/fp8) and whose double-buffered VMEM working set fits the budget
     (`VMEM_BUDGET`, a headroom fraction of the 16 MB/core VMEM).

  2. **Analytical roofline pre-ranking** — order candidates by a cost model:
     max(MXU time at the tile's utilization, HBM bytes / bandwidth) computed
     on the *padded* shape (so padding waste for the actual shape is priced
     in), plus a per-grid-step overhead that breaks ties toward larger
     tiles.  On CPU / interpret mode this ranking is the **sole selector**
     — no timing, fully deterministic, cheap enough to run at trace time.

  3. **On-device timing sweep** — `sweep()` times the top-N ranked
     candidates (median of k reps) on real hardware; winners are persisted
     via `record()`.  The sweep never runs implicitly inside an op dispatch
     (dispatch may happen mid-trace where timing is impossible); it is
     driven offline by `benchmarks/bench_autotune.py`.

Selected configs are memoized per (kernel, backend, dtype, shape-bucket)
and backed by a persistent JSON cache: the user cache (``$REPRO_AUTOTUNE_CACHE``
or ``~/.cache/repro/autotune.json``, written by the sweep CLI) takes
priority over the pre-swept v5e defaults shipped in ``autotune_v5e.json``.
A second lookup with the same shape bucket is a dict hit — no re-ranking,
no re-timing.

Shape buckets round every dimension up to the next power of two, so e.g.
(1000, 1000, 1000) and (1024, 1024, 1024) GEMMs share one cache entry.
"""
from __future__ import annotations

import json
import math
import os
import statistics
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.launch import machine as _machine
from repro.launch.machine import LANE, SUBLANE_BY_ITEMSIZE, CostTerms

# -- TPU layout constants -----------------------------------------------------
# Machine constants (HBM bandwidth, peak MXU FLOP/s, step overhead) live in
# launch/machine.py — the per-kernel functions below describe WORK
# (CostTerms: flops, bytes, steps, utilization) and the MachineModel turns
# work into seconds, with calibrated efficiencies when a sweep has recorded
# them.

VMEM_BYTES = _machine.V5E.vmem_bytes
VMEM_BUDGET = int(VMEM_BYTES * 0.85)       # headroom for semaphores/spills


def sublane(dtype) -> int:
    """Minimum second-to-last-dim multiple for this dtype's tiled layout."""
    return SUBLANE_BY_ITEMSIZE[jnp.dtype(dtype).itemsize]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _util(b: int) -> float:
    """MXU utilization factor for a tile dim feeding the 128-wide array."""
    return min(b, LANE) / LANE


def _steps(dim: int, mult: int, choices: Sequence[int]) -> list[int]:
    """Candidate block sizes for one dim: the given choices (multiples of
    `mult` only), each clamped to the dim rounded up to `mult`."""
    cap = _rup(max(dim, 1), mult)
    return sorted({min(c, cap) for c in choices if c % mult == 0})


# -- per-kernel candidate generation / VMEM / cost terms ----------------------
#
# Each kernel declares: the tunable knobs, the ordered logical dims that form
# the shape bucket, the legacy hand-picked constants (kept as a ranked
# candidate so the tuner can never regress past them), a generator of
# layout-legal + VMEM-feasible candidates, the double-buffered VMEM
# working-set estimate, and a declarative cost description — a CostTerms of
# (flops, hbm_bytes, steps, mxu_util) that the MachineModel prices.

@dataclass(frozen=True)
class KernelSpec:
    knobs: tuple[str, ...]
    dims: tuple[str, ...]
    legacy: Mapping[str, int]
    gen: Callable
    vmem: Callable
    terms: Callable                     # (blocks, dims, dtype) -> CostTerms


def _gemm_vmem(b, d, dtype):
    db = _itemsize(dtype)
    return (2 * (b["bm"] * b["bk"] + b["bk"] * b["bn"]) * db   # A, B streams
            + b["bm"] * b["bn"] * 4                            # f32 acc
            + 2 * b["bm"] * b["bn"] * db)                      # out tile


def _gemm_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for bm in _steps(d["m"], sub, (sub, 64, 128, 256, 512)):
        for bn in _steps(d["n"], LANE, (128, 256, 512)):
            for bk in _steps(d["k"], LANE, (128, 256, 512, 1024)):
                b = {"bm": bm, "bn": bn, "bk": bk}
                if _gemm_vmem(b, d, dtype) <= VMEM_BUDGET:
                    out.append(b)
    return out


def _gemm_terms(b, d, dtype):
    db = _itemsize(dtype)
    mp, kp = _rup(d["m"], b["bm"]), _rup(d["k"], b["bk"])
    np_ = _rup(d["n"], b["bn"])
    hbm = (mp * kp * db * (np_ // b["bn"])      # A re-read per output column
           + kp * np_ * db * (mp // b["bm"])    # B re-read per output row
           + mp * np_ * db)                     # C written once
    steps = (mp // b["bm"]) * (np_ // b["bn"]) * (kp // b["bk"])
    return CostTerms(flops=2.0 * mp * np_ * kp, hbm_bytes=hbm, steps=steps,
                     mxu_util=_util(b["bm"]))


def _tsgram_vmem(b, d, dtype):
    db = _itemsize(dtype)
    np_ = _rup(d["n"], LANE)
    return 2 * b["bm"] * np_ * db + np_ * np_ * 4 + np_ * np_ * db


def _tsgram_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for bm in _steps(d["m"], sub, (sub, 64, 128, 256, 512, 1024)):
        b = {"bm": bm}
        if _tsgram_vmem(b, d, dtype) <= VMEM_BUDGET:
            out.append(b)
    return out


def _tsgram_terms(b, d, dtype):
    db = _itemsize(dtype)
    mp, np_ = _rup(d["m"], b["bm"]), _rup(d["n"], LANE)
    hbm = mp * np_ * db + np_ * np_ * db        # one pass over A + G out
    return CostTerms(flops=2.0 * mp * np_ * np_, hbm_bytes=hbm,
                     steps=mp // b["bm"], mxu_util=_util(b["bm"]))


def _randsketch_vmem(b, d, dtype):
    db = _itemsize(dtype)
    rp = _rup(d["r"], LANE)
    return (2 * (b["bm"] * b["bn"] + b["bm"] * rp) * db
            + b["bn"] * rp * 4 + b["bn"] * rp * db)


def _randsketch_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for bm in _steps(d["m"], sub, (sub, 64, 128, 256, 512, 1024)):
        for bn in _steps(d["n"], LANE, (128, 256, 512, 1024)):
            b = {"bm": bm, "bn": bn}
            if _randsketch_vmem(b, d, dtype) <= VMEM_BUDGET:
                out.append(b)
    return out


def _randsketch_terms(b, d, dtype):
    db = _itemsize(dtype)
    mp, np_ = _rup(d["m"], b["bm"]), _rup(d["n"], b["bn"])
    rp = _rup(d["r"], LANE)
    hbm = (mp * np_ * db                        # one pass over A
           + mp * rp * db * (np_ // b["bn"])    # Q re-streamed per n-strip
           + np_ * rp * db)
    steps = (np_ // b["bn"]) * (mp // b["bm"])
    return CostTerms(flops=2.0 * mp * np_ * rp, hbm_bytes=hbm, steps=steps,
                     mxu_util=_util(b["bm"]))


def _fusedgrad_vmem(b, d, dtype):
    db = _itemsize(dtype)
    np_ = _rup(d["n"], LANE)
    return (2 * b["bm"] * np_ * db       # A row-block stream, double-buffered
            + np_ * db                   # resident x row
            + 4 * 2 * b["bm"] * db       # t, w, z (1 × bm) strips
            + np_ * 4 + np_ * 4)         # g accumulator + g out (f32)


def _fusedgrad_gen(d, dtype):
    # bm is both the A-block sublane count and the lane width of the t/w/z
    # vector strips, so candidates stay lane-aligned (multiples of 128).
    out = []
    for bm in _steps(d["m"], LANE, (128, 256, 512, 1024)):
        b = {"bm": bm}
        if _fusedgrad_vmem(b, d, dtype) <= VMEM_BUDGET:
            out.append(b)
    return out


def _fusedgrad_terms(b, d, dtype):
    """One streaming pass over A feeding two MXU contractions (z = x Aᵀ,
    g += r A) — the whole point vs apply+adjoint is the single A-read, so
    HBM traffic is m·n·db once plus vector noise."""
    db = _itemsize(dtype)
    mp, np_ = _rup(d["m"], b["bm"]), _rup(d["n"], LANE)
    hbm = mp * np_ * db + (2 * np_ + 3 * mp) * db   # ONE A pass + x,t,w,z,g
    return CostTerms(flops=4.0 * mp * np_, hbm_bytes=hbm,
                     steps=mp // b["bm"], mxu_util=_util(b["bm"]))


def _flash_vmem(b, d, dtype):
    db = _itemsize(dtype)
    dp = _rup(d["d"], LANE)
    return (2 * b["bq"] * dp * db + 4 * b["bk"] * dp * db     # Q + K,V streams
            + b["bq"] * dp * 4 + 2 * b["bq"] * LANE * 4       # acc + (m, l)
            + 2 * b["bq"] * dp * db)                          # out tile


def _flash_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for bq in _steps(d["sq"], sub, (sub, 64, 128, 256, 512)):
        for bk in _steps(d["sk"], LANE, (128, 256, 512)):
            b = {"bq": bq, "bk": bk}
            if _flash_vmem(b, d, dtype) <= VMEM_BUDGET:
                out.append(b)
    return out


def _flash_terms(b, d, dtype):
    db = _itemsize(dtype)
    sqp, skp = _rup(d["sq"], b["bq"]), _rup(d["sk"], b["bk"])
    dp = _rup(d["d"], LANE)
    frac = 0.5 if d.get("causal", 1) else 1.0   # live fraction of KV blocks
    hbm = (2 * sqp * dp * db                              # Q in + O out
           + 2 * skp * dp * db * (sqp // b["bq"]) * frac)  # K, V per q-row
    steps = (sqp // b["bq"]) * (skp // b["bk"])
    return CostTerms(flops=4.0 * sqp * skp * dp * frac, hbm_bytes=hbm,
                     steps=steps, mxu_util=_util(b["bq"]))


def _scan_vmem(b, d, dtype):
    db = _itemsize(dtype)
    bd = min(LANE, _rup(d["d"], LANE))
    np_ = _rup(d["n"], 8)
    return (6 * b["q"] * bd * db                # x, dt, y double-buffered
            + 4 * b["q"] * np_ * db             # B, C double-buffered
            + np_ * bd * (db + 4))              # A block + h scratch


def _scan_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for q in _steps(d["s"], sub, (sub, 64, 128, 256, 512)):
        b = {"q": q}
        if _scan_vmem(b, d, dtype) <= VMEM_BUDGET:
            out.append(b)
    return out


def _scan_terms(b, d, dtype):
    # VPU/memory-bound: one HBM pass over x/dt/y/B/C per d-block; the model
    # only has to order q choices (padding waste + grid-step overhead).
    # flops=0 — the max() roofline then reduces to the memory term.
    db = _itemsize(dtype)
    sp = _rup(d["s"], b["q"])
    bd = min(LANE, _rup(d["d"], LANE))
    dblocks = max(1, _rup(d["d"], bd) // bd)
    hbm = sp * (3 * bd + 2 * d["n"]) * db * dblocks
    steps = (sp // b["q"]) * dblocks
    return CostTerms(flops=0.0, hbm_bytes=hbm, steps=steps)


def _bsr_ell(bs: int, d) -> int:
    """Expected stored blocks per block-row.  When the caller knows the
    actual ELL width (an already-built BlockELL) it passes `ell` in the dims
    and we use it verbatim; otherwise estimate it from the entry count under
    a uniform-scatter model: P(block nonzero) = 1 - (1 - nnz/mn)^(bs²)."""
    if d.get("ell"):
        return int(d["ell"])
    m, n = max(int(d["m"]), 1), max(int(d["n"]), 1)
    nbc = max(n // bs, 1)
    density = min(1.0, float(d.get("nnz", m * n)) / (m * n))
    p_block = 1.0 - (1.0 - density) ** (bs * bs)
    return max(1, int(math.ceil(nbc * p_block)))


def _bsr_vmem(b, d, dtype):
    db = _itemsize(dtype)
    bs = b["bs"]
    nxp = _rup(max(d.get("nx", 1), 1), LANE)
    return (2 * bs * bs * db + 2 * bs * nxp * db     # A block + X block streams
            + bs * nxp * 4                           # f32 acc scratch
            + 2 * bs * nxp * db)                     # out tile


def _bsr_gen(d, dtype):
    sub = sublane(dtype)
    out = []
    for bs in _steps(min(d["m"], d["n"]), sub, (8, 16, 32, 64, 128)):
        b = {"bs": bs}
        if _bsr_vmem(b, d, dtype) <= VMEM_BUDGET:
            out.append(b)
    return out


def _bsr_terms(b, d, dtype):
    """BSR SpMM roofline terms: MXU work on *layout-padded* blocks (a
    bs < 128 block still occupies full 128-lane tiles, so small blocks pay
    up to a 16× flop inflation) vs HBM traffic ∝ stored blocks, plus the
    per-block grid-step overhead that punishes very small blocks at high
    density."""
    db = _itemsize(dtype)
    bs = b["bs"]
    nxp = _rup(max(d.get("nx", 1), 1), LANE)
    mp = _rup(max(d["m"], 1), bs)
    nbr = mp // bs
    ell = _bsr_ell(bs, d)
    bsl, bll = _rup(bs, sublane(dtype)), _rup(bs, LANE)
    hbm = (nbr * ell * (bs * bs + bs * nxp) * db    # A blocks + gathered X
           + mp * nxp * db)                         # out written once
    return CostTerms(flops=2.0 * nbr * ell * bsl * bll * nxp, hbm_bytes=hbm,
                     steps=nbr * ell)


KERNELS: dict[str, KernelSpec] = {
    "gemm": KernelSpec(("bm", "bn", "bk"), ("m", "k", "n"),
                       {"bm": 256, "bn": 256, "bk": 512},
                       _gemm_gen, _gemm_vmem, _gemm_terms),
    "tsgram": KernelSpec(("bm",), ("m", "n"), {"bm": 512},
                         _tsgram_gen, _tsgram_vmem, _tsgram_terms),
    "randsketch": KernelSpec(("bm", "bn"), ("m", "n", "r"),
                             {"bm": 512, "bn": 512},
                             _randsketch_gen, _randsketch_vmem,
                             _randsketch_terms),
    "fusedgrad": KernelSpec(("bm",), ("m", "n"), {"bm": 512},
                            _fusedgrad_gen, _fusedgrad_vmem,
                            _fusedgrad_terms),
    "flash_attention": KernelSpec(("bq", "bk"), ("sq", "sk", "d", "causal"),
                                  {"bq": 256, "bk": 256},
                                  _flash_gen, _flash_vmem, _flash_terms),
    "selective_scan": KernelSpec(("q",), ("s", "d", "n"), {"q": 256},
                                 _scan_gen, _scan_vmem, _scan_terms),
    "bsr": KernelSpec(("bs",), ("m", "n", "nnz", "nx"), {"bs": 8},
                      _bsr_gen, _bsr_vmem, _bsr_terms),
}


# -- candidate enumeration + ranking -----------------------------------------

def candidates(kernel: str, dims: Mapping[str, int], dtype) -> list[dict]:
    """Layout-legal candidates whose VMEM working set fits the budget."""
    return KERNELS[kernel].gen(dims, dtype)


def estimate_vmem(kernel: str, blocks: Mapping[str, int],
                  dims: Mapping[str, int], dtype) -> int:
    """Double-buffered VMEM working-set estimate in bytes."""
    return KERNELS[kernel].vmem(blocks, dims, dtype)


def cost_terms(kernel: str, blocks: Mapping[str, int],
               dims: Mapping[str, int], dtype) -> CostTerms:
    """Machine-independent work description (flops/bytes/steps/util)."""
    return KERNELS[kernel].terms(blocks, dims, dtype)


def model_time(kernel: str, blocks: Mapping[str, int],
               dims: Mapping[str, int], dtype, *,
               machine: "_machine.MachineModel | None" = None) -> float:
    """Modeled seconds (lower is better) on `machine` — the calibrated
    model for the current backend by default."""
    machine = machine or _machine.for_backend()
    return machine.time(cost_terms(kernel, blocks, dims, dtype), dtype)


def rank(kernel: str, dims: Mapping[str, int], dtype, *,
         machine: "_machine.MachineModel | None" = None
         ) -> list[tuple[float, dict]]:
    """(score, blocks) ascending by model time; deterministic tie-break.

    The legacy hand-picked config is always in the pool (even when the VMEM
    estimate is conservative enough to exclude it), so the selected config
    can never score worse than the old constants.
    """
    machine = machine or _machine.for_backend()
    pool = candidates(kernel, dims, dtype)
    legacy = dict(KERNELS[kernel].legacy)
    if legacy not in pool:
        pool = pool + [legacy]
    scored = [(model_time(kernel, b, dims, dtype, machine=machine), b)
              for b in pool]
    scored.sort(key=lambda t: (t[0], sorted(t[1].items())))
    return scored


# -- shape buckets + persistent cache ----------------------------------------

def bucket(x: int) -> int:
    """Next power of two (0 stays 0) — the shape-bucket granularity."""
    return 0 if x <= 0 else 1 << (x - 1).bit_length()


def cache_key(kernel: str, backend: str, dtype,
              dims: Mapping[str, int]) -> str:
    spec = KERNELS[kernel]
    shape = "x".join(str(bucket(int(dims[k]))) for k in spec.dims)
    return f"{kernel}|{backend}|{jnp.dtype(dtype).name}|{shape}"


DEFAULTS_PATH = Path(__file__).with_name("autotune_v5e.json")


def user_cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


class ConfigCache:
    """One JSON file of {key: {"blocks": ..., "source": ..., "us": ...}}."""

    def __init__(self, path: Path):
        self.path = Path(path)
        self.entries: dict[str, dict] = {}
        self._loaded = False

    def load(self) -> "ConfigCache":
        if not self._loaded:
            self._loaded = True
            try:
                data = json.loads(self.path.read_text())
                self.entries = dict(data.get("entries", {}))
            except (OSError, ValueError):
                self.entries = {}
        return self

    def lookup(self, key: str) -> dict | None:
        return self.load().entries.get(key)

    def put(self, key: str, blocks: Mapping[str, int], *,
            source: str = "swept", us: float | None = None) -> None:
        entry = {"blocks": dict(blocks), "source": source}
        if us is not None:
            entry["us"] = round(float(us), 3)
        self.load().entries[key] = entry

    def save(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"version": 1, "entries": self.entries}, indent=1, sort_keys=True))
        tmp.replace(self.path)


_memo: dict[str, dict] = {}
_caches: dict[Path, ConfigCache] = {}
stats = {"memo_hits": 0, "cache_hits": 0, "ranked": 0, "swept": 0}


def _cache_at(path: Path) -> ConfigCache:
    if path not in _caches:
        _caches[path] = ConfigCache(path)
    return _caches[path]


def reset() -> None:
    """Forget memoized configs, cache handles, and counters (tests) — and
    the planner/machine caches layered on top, so a recalibration or a
    cache-path change is picked up everywhere at once."""
    _memo.clear()
    _caches.clear()
    for k in stats:
        stats[k] = 0
    _machine.invalidate_cache()
    from repro.launch import planner as _planner
    _planner.invalidate_cache()


def get_config(kernel: str, dims: Mapping[str, int], dtype, *,
               backend: str | None = None) -> dict:
    """Resolve the block config for a shape: memo → user cache → shipped
    v5e defaults → roofline ranking.  Never times anything."""
    backend = backend or jax.default_backend()
    key = cache_key(kernel, backend, dtype, dims)
    if key in _memo:
        stats["memo_hits"] += 1
        return dict(_memo[key])
    entry = (_cache_at(user_cache_path()).lookup(key)
             or _cache_at(DEFAULTS_PATH).lookup(key))
    if entry is not None:
        stats["cache_hits"] += 1
        blocks = dict(entry["blocks"])
    else:
        stats["ranked"] += 1
        # Rank on the bucket's representative shape (each dim rounded up to
        # its power-of-two bucket), not the exact dims: the result is cached
        # under the bucket key, so it must not depend on which bucket member
        # arrived first.  Dispatch clamps blocks to the exact shape anyway.
        bdims = {k: bucket(int(v)) for k, v in dims.items()}
        blocks = rank(kernel, bdims, dtype,
                      machine=_machine.for_backend(backend))[0][1]
    _memo[key] = dict(blocks)
    return dict(blocks)


def resolve(kernel: str, dims: Mapping[str, int], dtype,
            overrides: Mapping[str, int | None] | None = None, *,
            tune: str = "auto", backend: str | None = None) -> dict:
    """Config the ops wrappers dispatch with: explicit block kwargs always
    win; missing knobs come from the execution planner (`tune="auto"` —
    launch/planner.plan, memoized/cached selection against the calibrated
    machine model) or the legacy constants (`tune="off"`)."""
    spec = KERNELS[kernel]
    ov = {k: v for k, v in (overrides or {}).items() if v is not None}
    if len(ov) == len(spec.knobs):
        return ov
    if tune == "auto":
        from repro.launch import planner as _planner
        base = dict(_planner.plan(kernel, dims, dtype,
                                  backend=backend).blocks)
    elif tune == "off":
        base = dict(spec.legacy)
    else:
        raise ValueError(f"tune must be 'auto' or 'off', got {tune!r}")
    base.update(ov)
    return base


# -- on-device timing sweep ---------------------------------------------------

def sweep(kernel: str, dims: Mapping[str, int], dtype,
          run_fn: Callable[[Mapping[str, int]], None], *,
          top_n: int = 3, reps: int = 5,
          include_legacy: bool = True) -> list[tuple[float, dict]]:
    """Time the top-N model-ranked candidates (plus the legacy constants)
    with `run_fn(blocks)` — which must block until the device is done —
    and return (median_seconds, blocks) ascending.  Offline use only
    (`benchmarks/bench_autotune.py`); dispatch never calls this."""
    ranked = rank(kernel, dims, dtype)
    pool = [blocks for _, blocks in ranked[:top_n]]
    legacy = dict(KERNELS[kernel].legacy)
    if include_legacy and legacy not in pool:
        pool.append(legacy)
    stats["swept"] += 1
    timed = []
    for blocks in pool:
        run_fn(blocks)                       # warm-up eats compile time
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            run_fn(blocks)
            times.append(time.perf_counter() - t0)
        timed.append((statistics.median(times), blocks))
    timed.sort(key=lambda t: (t[0], sorted(t[1].items())))
    return timed


def record(kernel: str, dims: Mapping[str, int], dtype,
           blocks: Mapping[str, int], *, backend: str | None = None,
           source: str = "swept", us: float | None = None) -> str:
    """Persist a winner into the user cache (and the in-memory memo)."""
    backend = backend or jax.default_backend()
    key = cache_key(kernel, backend, dtype, dims)
    cache = _cache_at(user_cache_path())
    cache.put(key, blocks, source=source, us=us)
    cache.save()
    _memo[key] = dict(blocks)
    return key
