"""Single-pass fused composite gradient — the optimizer hot-path kernel.

The paper's recipe keeps the matrix on the cluster and the vectors on the
driver (§3.2–3.3), and its optimizer loop consumes exactly (value, gradient)
pairs of f(Ax).  Computed naively that is TWO streaming passes over A per
evaluation: apply (z = A x) and adjoint (g = Aᵀ ∇f(z)).  But for the
row-separable losses of the whole Figure-1 family — f(z) = Σᵢ wᵢ ℓ(zᵢ, tᵢ)
with ℓ ∈ {quadratic, logistic, huber, poisson} — the residual of a row block
depends only on
that block's rows, so it can be evaluated *on-chip* between the two products
while the block is still in VMEM.  That is Spark's one-pass treeAggregate
gradient pattern, executed one level down the memory hierarchy:

    per (bm × n) row block of A (one HBM read):
        z_blk = A_blk x                      (MXU)
        r_blk = w_blk ∘ ℓ'(z_blk, t_blk)     (VPU, on-chip)
        g    += A_blkᵀ r_blk                 (MXU, resident accumulator)
        f    += Σ w_blk ℓ(z_blk, t_blk)      (scalar accumulator)

One pass over A instead of two — on an HBM-bound kernel that halves the
per-evaluation time.  The kernel also writes z out (it is computed anyway;
m·4 B next to m·n·db is noise), so callers that want the image A x — parity
checks, future cached-image schemes — get it for free.

Two layouts share the row-local loss math:

  * ``fused_grad``     — dense tall-skinny row shards (the RowMatrix path);
  * ``fused_grad_bsr`` — BlockELL shards (kernels/bsr.py layout): the whole
    block-row's stored blocks are staged per grid step, z accumulates over
    the ell slots, and the transpose contributions scatter-add into a
    resident (nbc × bs) accumulator — each stored block is read once.

The ``*_jnp`` forms are the structure-exploiting off-TPU dispatch targets
(kernels/ops.py); the densifying oracle lives in kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat
from .bsr import BlockELL, effective_data

Array = jax.Array

LOSSES = ("quad", "logistic", "huber", "poisson")


def row_loss_elem(z: Array, t: Array, w: Array, loss: str,
                  param: float = 1.0) -> tuple[Array, Array]:
    """Elementwise (w ∘ ℓ(z, t), w ∘ ℓ'(z, t)) in float32 — the row-local
    residual shared by the kernels and the structured jnp paths.  Keeping
    the loss un-summed lets the multi-RHS kernels accumulate a per-request
    value over any axis layout.

      quad:     ℓ(z, b) = ½ (z − b)²,            ℓ' = z − b
      logistic: ℓ(z, y) = log(1 + e^(−y z)),     ℓ' = −y σ(−y z)
      huber:    ℓ(z, b) = ½d² if |d| ≤ δ else δ(|d| − ½δ),  d = z − b,
                ℓ' = clip(d, ±δ)                (δ = `param`, static)
      poisson:  ℓ(z, y) = e^z − y z (log-link NLL, + const), ℓ' = e^z − y

    `param` is a static Python float (it reaches the Pallas kernels as a
    compile-time constant alongside the loss id)."""
    z = z.astype(jnp.float32)
    t = t.astype(jnp.float32)
    w = w.astype(jnp.float32)
    if loss == "quad":
        d = z - t
        return 0.5 * w * d * d, w * d
    if loss == "logistic":
        mz = -t * z
        return w * jnp.logaddexp(0.0, mz), w * (-t) * jax.nn.sigmoid(mz)
    if loss == "huber":
        delta = jnp.float32(param)
        d = z - t
        a = jnp.abs(d)
        le = w * jnp.where(a <= delta, 0.5 * d * d,
                           delta * (a - 0.5 * delta))
        return le, w * jnp.clip(d, -delta, delta)
    if loss == "poisson":
        ez = jnp.exp(z)
        return w * (ez - t * z), w * (ez - t)
    raise ValueError(f"loss must be one of {LOSSES}, got {loss!r}")


def row_loss_grad(z: Array, t: Array, w: Array, loss: str,
                  param: float = 1.0) -> tuple[Array, Array]:
    """(Σ wᵢ ℓ(zᵢ, tᵢ), w ∘ ℓ'(z, t)) in float32 — the fully-reduced form
    of `row_loss_elem` (the single-RHS kernels and jnp paths use this)."""
    le, r = row_loss_elem(z, t, w, loss, param)
    return jnp.sum(le), r


# -- dense tall-skinny kernel -------------------------------------------------

def _fused_grad_kernel(a_ref, x_ref, t_ref, w_ref, f_ref, g_ref, z_ref,
                       g_acc, f_acc, *, m_steps: int, loss: str,
                       param: float):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        f_acc[0, 0] = jnp.float32(0.0)

    blk = a_ref[...]                                     # (bm, n)
    # Sub-f32 storage upcasts in VMEM (no-op for f32): one narrow HBM read,
    # f32 math on-chip.
    if blk.dtype != jnp.float32:
        blk = blk.astype(jnp.float32)
    # Row-vector matmuls keep both contractions on the MXU: z = x Aᵀ and
    # g += r A are (1 × bm)·(bm × n) products over the block already in VMEM.
    z = jnp.dot(x_ref[...], blk.T, preferred_element_type=jnp.float32)
    fpart, r = row_loss_grad(z, t_ref[...], w_ref[...], loss, param)
    z_ref[...] = z
    g_acc[...] += jnp.dot(r.astype(blk.dtype), blk,
                          preferred_element_type=jnp.float32)
    f_acc[0, 0] += fpart

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _flush():
        g_ref[...] = g_acc[...]
        f_ref[0, 0] = f_acc[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("loss", "param", "bm", "interpret"))
def fused_grad(a: Array, x: Array, t: Array, w: Array, *, loss: str,
               bm: int, param: float = 1.0, interpret: bool = False
               ) -> tuple[Array, Array, Array]:
    """(f, g, z) = (Σ wᵢ ℓ((Ax)ᵢ, tᵢ), Aᵀ(w ∘ ℓ'(Ax, t)), Ax) in ONE
    streaming pass over A.  Layout: a (m × n) with m % bm == 0 and
    n % 128 == 0; x (1 × n); t, w (1 × m) — ops.fused_grad pads.
    Outputs are float32: f (1 × 1), g (1 × n), z (1 × m)."""
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    assert x.shape == (1, n) and t.shape == (1, m) and w.shape == (1, m), \
        (a.shape, x.shape, t.shape, w.shape)
    m_steps = m // bm

    return pl.pallas_call(
        functools.partial(_fused_grad_kernel, m_steps=m_steps, loss=loss,
                          param=float(param)),
        grid=(m_steps,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, n), jnp.float32),
                        pltpu.SMEM((1, 1), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_fused_grad",
    )(a, x, t, w)


# -- BlockELL (BSR) kernel ----------------------------------------------------

def fused_grad_bsr_vmem(a: BlockELL) -> int:
    """Resident VMEM working-set estimate for the BSR fused kernel: the
    staged block-row (double-buffered), the full x copy, the f32 gradient
    accumulator + output copy, and the t/w/z vector strips.  ops dispatch
    falls back to a two-pass BSR composition when this exceeds the budget
    (mirroring bsr_rmatmul's own fallback)."""
    bs, ell = a.bs, a.ell
    nbc = a.shape[1] // bs
    db = jnp.dtype(a.data.dtype).itemsize
    return (2 * ell * bs * bs * db        # block-row stream, double-buffered
            + nbc * bs * db               # resident x
            + nbc * bs * 4 + nbc * bs * 4  # g accumulator + g out (f32)
            + 6 * bs * 4)                 # t, w, z (1 × bs) strips


def _fused_grad_bsr_kernel(cols_ref, a_ref, x_ref, t_ref, w_ref,
                           f_ref, g_ref, z_ref, g_acc, f_acc, *,
                           nbr: int, ell: int, loss: str, param: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        f_acc[0, 0] = jnp.float32(0.0)

    blocks = a_ref[0]                                    # (ell, bs, bs)
    if blocks.dtype != jnp.float32:
        blocks = blocks.astype(jnp.float32)    # sub-f32 storage upcast
    bs = blocks.shape[-1]
    xall = x_ref[...]                                    # (nbc, bs)

    # z for the whole block-row: accumulate over the ell stored blocks while
    # they are staged in VMEM (padding slots are zero, so col 0 is harmless).
    def zstep(j, zacc):
        c = cols_ref[i * ell + j]
        xj = jax.lax.dynamic_index_in_dim(xall, c, 0, keepdims=True)
        bj = jax.lax.dynamic_index_in_dim(blocks, j, 0, keepdims=False)
        return zacc + jnp.dot(xj, bj.T, preferred_element_type=jnp.float32)

    z = jax.lax.fori_loop(0, ell, zstep, jnp.zeros((1, bs), jnp.float32))
    fpart, r = row_loss_grad(z, t_ref[...], w_ref[...], loss, param)
    z_ref[...] = z
    f_acc[0, 0] += fpart

    # Second sweep over the SAME staged blocks (no HBM re-read): scatter-add
    # each Aᵢⱼᵀ r into the resident block-column accumulator.
    def gstep(j, carry):
        c = cols_ref[i * ell + j]
        bj = jax.lax.dynamic_index_in_dim(blocks, j, 0, keepdims=False)
        contrib = jnp.dot(r.astype(bj.dtype), bj,
                          preferred_element_type=jnp.float32)
        cur = pl.load(g_acc, (pl.ds(c, 1), slice(None)))
        pl.store(g_acc, (pl.ds(c, 1), slice(None)), cur + contrib)
        return carry

    jax.lax.fori_loop(0, ell, gstep, 0)

    @pl.when(i == nbr - 1)
    def _flush():
        g_ref[...] = g_acc[...]
        f_ref[0, 0] = f_acc[0, 0]


@functools.partial(jax.jit, static_argnames=("loss", "param", "interpret"))
def fused_grad_bsr(a: BlockELL, x: Array, t: Array, w: Array, *, loss: str,
                   param: float = 1.0,
                   interpret: bool = False) -> tuple[Array, Array, Array]:
    """Fused (f, g, z) for a BlockELL shard: every stored block is read from
    HBM exactly once.  x (n,), t/w (m,) over the padded BlockELL dims;
    outputs f () , g (n,), z (m,) in float32."""
    m, n = a.shape
    assert x.shape == (n,) and t.shape == (m,) and w.shape == (m,), \
        (a.shape, x.shape, t.shape, w.shape)
    bs, ell = a.bs, a.ell
    nbr, nbc = m // bs, n // bs

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr,),
        in_specs=[
            pl.BlockSpec((1, ell, bs, bs), lambda i, cols: (i, 0, 0, 0)),
            pl.BlockSpec((nbc, bs), lambda i, cols: (0, 0)),
            pl.BlockSpec((1, bs), lambda i, cols: (0, i)),
            pl.BlockSpec((1, bs), lambda i, cols: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, cols: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((nbc, bs), lambda i, cols: (0, 0)),
            pl.BlockSpec((1, bs), lambda i, cols: (0, i)),
        ],
        scratch_shapes=[pltpu.VMEM((nbc, bs), jnp.float32),
                        pltpu.SMEM((1, 1), jnp.float32)],
    )
    f, g, z = pl.pallas_call(
        functools.partial(_fused_grad_bsr_kernel, nbr=nbr, ell=ell,
                          loss=loss, param=float(param)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbc, bs), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_fused_grad_bsr",
    )(a.cols.reshape(-1), a.data.reshape(nbr, ell, bs, bs),
      x.reshape(nbc, bs), t.reshape(1, m), w.reshape(1, m))
    return f[0, 0], g.reshape(n), z[0]


# -- multi-RHS (request-batched) dense kernel ---------------------------------

def _fused_grad_multi_kernel(a_ref, x_ref, t_ref, w_ref, f_ref, g_ref, z_ref,
                             g_acc, f_acc, *, m_steps: int, loss: str,
                             param: float):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    blk = a_ref[...]                                     # (bm, n)
    if blk.dtype != jnp.float32:
        blk = blk.astype(jnp.float32)          # sub-f32 storage upcast
    x = x_ref[...]                                       # (kp, n)
    # One block read serves every request: z = X Aᵀ is a (kp × n)·(n × bm)
    # product over the block already in VMEM — the whole point of grouping.
    z = jnp.dot(x, blk.T, preferred_element_type=jnp.float32)   # (kp, bm)
    le, r = row_loss_elem(z, t_ref[...], w_ref[...], loss, param)
    z_ref[...] = z
    g_acc[...] += jnp.dot(r.astype(blk.dtype), blk,
                          preferred_element_type=jnp.float32)
    # Per-request loss: fold the lane-aligned bm axis down to one 128-lane
    # strip (bm % 128 == 0 by layout contract); the host sums the strip.
    kp, bm = le.shape
    f_acc[...] += le.reshape(kp, bm // 128, 128).sum(axis=1)

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _flush():
        g_ref[...] = g_acc[...]
        f_ref[...] = f_acc[...]


@functools.partial(jax.jit,
                   static_argnames=("loss", "param", "bm", "interpret"))
def fused_grad_multi(a: Array, x: Array, t: Array, w: Array, *, loss: str,
                     bm: int, param: float = 1.0, interpret: bool = False
                     ) -> tuple[Array, Array, Array]:
    """Request-batched fused gradients: (f, g, z) for kp right-hand sides
    in ONE streaming pass over A — each A block is read from HBM once and
    amortized across every request in the group.  Layout: a (m × n) with
    m % bm == 0, bm % 128 == 0, n % 128 == 0; x (kp × n); t, w (kp × m)
    with kp a multiple of 8 (sublane) — ops.fused_grad_multi pads.
    Outputs are float32: f (kp × 128) [sum axis 1 for the per-request
    values], g (kp × n), z (kp × m)."""
    m, n = a.shape
    kp = x.shape[0]
    assert m % bm == 0 and bm % 128 == 0, (m, bm)
    assert kp % 8 == 0, kp
    assert x.shape == (kp, n) and t.shape == (kp, m) and w.shape == (kp, m), \
        (a.shape, x.shape, t.shape, w.shape)
    m_steps = m // bm

    return pl.pallas_call(
        functools.partial(_fused_grad_multi_kernel, m_steps=m_steps,
                          loss=loss, param=float(param)),
        grid=(m_steps,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((kp, n), lambda i: (0, 0)),
            pl.BlockSpec((kp, bm), lambda i: (0, i)),
            pl.BlockSpec((kp, bm), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((kp, 128), lambda i: (0, 0)),
            pl.BlockSpec((kp, n), lambda i: (0, 0)),
            pl.BlockSpec((kp, bm), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, 128), jnp.float32),
            jax.ShapeDtypeStruct((kp, n), jnp.float32),
            jax.ShapeDtypeStruct((kp, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kp, n), jnp.float32),
                        pltpu.VMEM((kp, 128), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_fused_grad_multi",
    )(a, x, t, w)


# -- multi-RHS BlockELL (BSR) kernel ------------------------------------------

def fused_grad_bsr_multi_vmem(a: BlockELL, kp: int) -> int:
    """Resident VMEM working-set estimate for the multi-RHS BSR fused
    kernel: the per-request copies of x, the gradient accumulator, and the
    t/w/z strips all scale with kp; the staged block-row does not."""
    bs, ell = a.bs, a.ell
    nbc = a.shape[1] // bs
    db = jnp.dtype(a.data.dtype).itemsize
    return (2 * ell * bs * bs * db          # block-row stream, double-buffered
            + nbc * kp * bs * db            # resident x (nbc × kp × bs)
            + 2 * nbc * kp * bs * 4         # g accumulator + g out (f32)
            + kp * bs * 4                   # f accumulator strip
            + 6 * kp * bs * 4)              # t, w, z (kp × bs) strips


def _fused_grad_bsr_multi_kernel(cols_ref, a_ref, x_ref, t_ref, w_ref,
                                 f_ref, g_ref, z_ref, g_acc, f_acc, *,
                                 nbr: int, ell: int, loss: str, param: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    blocks = a_ref[0]                                    # (ell, bs, bs)
    if blocks.dtype != jnp.float32:
        blocks = blocks.astype(jnp.float32)    # sub-f32 storage upcast
    bs = blocks.shape[-1]
    kp = x_ref.shape[1]
    xall = x_ref[...]                                    # (nbc, kp, bs)

    # z for the whole block-row, all requests at once: each staged block is
    # contracted against the (kp × bs) slab of x for its block-column.
    def zstep(j, zacc):
        c = cols_ref[i * ell + j]
        xj = jax.lax.dynamic_index_in_dim(xall, c, 0, keepdims=False)
        bj = jax.lax.dynamic_index_in_dim(blocks, j, 0, keepdims=False)
        return zacc + jnp.dot(xj, bj.T, preferred_element_type=jnp.float32)

    z = jax.lax.fori_loop(0, ell, zstep, jnp.zeros((kp, bs), jnp.float32))
    le, r = row_loss_elem(z, t_ref[...], w_ref[...], loss, param)
    z_ref[...] = z
    f_acc[...] += le                                     # (kp, bs), summed on host

    # Second sweep over the SAME staged blocks (no HBM re-read): scatter-add
    # each (kp × bs) Aᵢⱼᵀ r slab into the resident block-column accumulator.
    def gstep(j, carry):
        c = cols_ref[i * ell + j]
        bj = jax.lax.dynamic_index_in_dim(blocks, j, 0, keepdims=False)
        contrib = jnp.dot(r.astype(bj.dtype), bj,
                          preferred_element_type=jnp.float32)
        cur = pl.load(g_acc, (pl.ds(c, 1), slice(None), slice(None)))
        pl.store(g_acc, (pl.ds(c, 1), slice(None), slice(None)),
                 cur + contrib[None])
        return carry

    jax.lax.fori_loop(0, ell, gstep, 0)

    @pl.when(i == nbr - 1)
    def _flush():
        g_ref[...] = g_acc[...]
        f_ref[...] = f_acc[...]


@functools.partial(jax.jit, static_argnames=("loss", "param", "interpret"))
def fused_grad_bsr_multi(a: BlockELL, x: Array, t: Array, w: Array, *,
                         loss: str, param: float = 1.0,
                         interpret: bool = False
                         ) -> tuple[Array, Array, Array]:
    """Request-batched fused (f, g, z) for a BlockELL shard: every stored
    block is read from HBM exactly once and serves all kp requests.
    x (kp, n), t/w (kp, m) over the padded BlockELL dims, kp % 8 == 0;
    outputs f (kp,), g (kp, n), z (kp, m) in float32."""
    m, n = a.shape
    kp = x.shape[0]
    assert kp % 8 == 0, kp
    assert x.shape == (kp, n) and t.shape == (kp, m) and w.shape == (kp, m), \
        (a.shape, x.shape, t.shape, w.shape)
    bs, ell = a.bs, a.ell
    nbr, nbc = m // bs, n // bs

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr,),
        in_specs=[
            pl.BlockSpec((1, ell, bs, bs), lambda i, cols: (i, 0, 0, 0)),
            pl.BlockSpec((nbc, kp, bs), lambda i, cols: (0, 0, 0)),
            pl.BlockSpec((kp, bs), lambda i, cols: (0, i)),
            pl.BlockSpec((kp, bs), lambda i, cols: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((kp, bs), lambda i, cols: (0, 0)),
            pl.BlockSpec((nbc, kp, bs), lambda i, cols: (0, 0, 0)),
            pl.BlockSpec((kp, bs), lambda i, cols: (0, i)),
        ],
        scratch_shapes=[pltpu.VMEM((nbc, kp, bs), jnp.float32),
                        pltpu.VMEM((kp, bs), jnp.float32)],
    )
    f, g, z = pl.pallas_call(
        functools.partial(_fused_grad_bsr_multi_kernel, nbr=nbr, ell=ell,
                          loss=loss, param=float(param)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((kp, bs), jnp.float32),
            jax.ShapeDtypeStruct((nbc, kp, bs), jnp.float32),
            jax.ShapeDtypeStruct((kp, m), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_fused_grad_bsr_multi",
    )(a.cols.reshape(-1), a.data.reshape(nbr, ell, bs, bs),
      x.reshape(kp, nbc, bs).transpose(1, 0, 2),
      t.reshape(kp, m), w.reshape(kp, m))
    return f.sum(axis=1), g.transpose(1, 0, 2).reshape(kp, n), z


# -- structured jnp forms (off-TPU dispatch targets) --------------------------

def fused_grad_jnp(a: Array, x: Array, t: Array, w: Array, *,
                   loss: str, param: float = 1.0
                   ) -> tuple[Array, Array, Array]:
    """Dense (f, g, z) with the same row-local loss math as the kernel;
    x/t/w are flat vectors here.  g is the row-vector contraction r·A —
    the kernel's own form, and measurably faster than Aᵀr on CPU too (no
    transposed operand)."""
    z = jnp.dot(a, x, preferred_element_type=jnp.float32)
    f, r = row_loss_grad(z, t, w, loss, param)
    # The residual stays f32 for sub-f32 storage (matching the kernel,
    # which never narrows r); for f32 a this cast is a no-op.
    rc = r.astype(a.dtype) if a.dtype == jnp.float32 else r
    g = jnp.dot(rc, a, preferred_element_type=jnp.float32)
    return f, g, z


def fused_grad_bsr_jnp(a: BlockELL, x: Array, t: Array, w: Array, *,
                       loss: str, param: float = 1.0
                       ) -> tuple[Array, Array, Array]:
    """BlockELL (f, g, z) via gather/einsum + scatter-add — flops ∝ stored
    blocks, no densification (the CPU dispatch target)."""
    bs = a.bs
    nbr, ell = a.data.shape[0], a.ell
    nbc = a.shape[1] // bs
    data = effective_data(a)
    xb = x.reshape(nbc, bs)
    gathered = xb[a.cols]                                 # (nbr, ell, bs)
    z = jnp.einsum("reij,rej->ri", data, gathered,
                   preferred_element_type=jnp.float32).reshape(a.shape[0])
    f, r = row_loss_grad(z, t, w, loss, param)
    rb = r.astype(data.dtype).reshape(nbr, bs)
    partial = jnp.einsum("reij,ri->rej", data, rb,
                         preferred_element_type=jnp.float32)
    g = jnp.zeros((nbc, bs), jnp.float32).at[a.cols.reshape(-1)].add(
        partial.reshape(nbr * ell, bs))
    return f, g.reshape(a.shape[1]), z


def fused_grad_multi_jnp(a: Array, x: Array, t: Array, w: Array, *,
                         loss: str, param: float = 1.0
                         ) -> tuple[Array, Array, Array]:
    """Dense multi-RHS (f, g, z) with the kernel's row-local loss math:
    x (k, n), t/w (k, m) → f (k,), g (k, n), z (k, m).  One logical pass
    over A shared by all k requests (XLA reads A once per contraction)."""
    z = jnp.dot(x, a.T, preferred_element_type=jnp.float32)
    le, r = row_loss_elem(z, t, w, loss, param)
    rc = r.astype(a.dtype) if a.dtype == jnp.float32 else r
    g = jnp.dot(rc, a, preferred_element_type=jnp.float32)
    return le.sum(axis=1), g, z


def fused_grad_bsr_multi_jnp(a: BlockELL, x: Array, t: Array, w: Array, *,
                             loss: str, param: float = 1.0
                             ) -> tuple[Array, Array, Array]:
    """BlockELL multi-RHS (f, g, z) via gather/einsum + scatter-add —
    flops ∝ stored blocks × k, no densification (the CPU dispatch target).
    x (k, n), t/w (k, m) → f (k,), g (k, n), z (k, m)."""
    bs = a.bs
    nbr, ell = a.data.shape[0], a.ell
    nbc = a.shape[1] // bs
    k = x.shape[0]
    data = effective_data(a)
    xb = x.reshape(k, nbc, bs)
    gathered = xb[:, a.cols]                              # (k, nbr, ell, bs)
    z = jnp.einsum("reij,krej->kri", data, gathered,
                   preferred_element_type=jnp.float32).reshape(k, a.shape[0])
    le, r = row_loss_elem(z, t, w, loss, param)
    rb = r.astype(data.dtype).reshape(k, nbr, bs)
    partial = jnp.einsum("reij,kri->krej", data, rb,
                         preferred_element_type=jnp.float32)
    g = jnp.zeros((k, nbc, bs), jnp.float32).at[:, a.cols.reshape(-1)].add(
        partial.reshape(k, nbr * ell, bs))
    return le.sum(axis=1), g.reshape(k, a.shape[1]), z
