"""Tall-skinny Gram kernel: G = AᵀA for m ≫ n (the DIMSUM hotspot, §3.1.2).

This is the per-shard compute inside RowMatrix.gram(): each chip reduces its
(m_local × n) row shard to an (n × n) partial Gram before the cross-chip
psum.  The kernel streams row blocks through VMEM while the full (n × n)
float32 accumulator stays resident — one pass over A, fully MXU-bound, no
(m × n) intermediate ever materialized in HBM.

Constraint: n ≤ ~1024 so the accumulator (n²·4 B) fits comfortably in VMEM
alongside the streaming row block — exactly the paper's "AᵀA fits on the
driver" regime, one level down the memory hierarchy (HBM → VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


def _tsgram_kernel(a_ref, o_ref, acc_ref, *, m_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = a_ref[...]
    # Sub-f32 storage upcasts in VMEM; the accumulator is f32 regardless.
    if blk.dtype != jnp.float32:
        blk = blk.astype(jnp.float32)
    acc_ref[...] += jnp.dot(blk.T, blk, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def tsgram(a: Array, *, bm: int, out_dtype=None,
           interpret: bool = False) -> Array:
    """G = AᵀA streaming over row blocks of size `bm` (autotuned by
    ops.tsgram).  m must be a multiple of bm and n a multiple of 128
    (ops.tsgram pads)."""
    m, n = a.shape
    assert m % bm == 0, (m, bm)
    out_dtype = out_dtype or a.dtype
    m_steps = m // bm

    return pl.pallas_call(
        functools.partial(_tsgram_kernel, m_steps=m_steps),
        grid=(m_steps,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_tsgram",
    )(a)
