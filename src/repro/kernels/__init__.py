"""Pallas TPU kernels for the paper's compute hot-spots (§4 of the paper):
GEMM (the BLAS benchmark), tall-skinny Gram (the SVD/DIMSUM hotspot),
streaming cross-Gram (the randomized-SVD sketch projection), block-sparse
matmul (§4.2 sparse kernels, adapted CCS→BSR for the MXU), the single-pass
fused composite gradient (the §3.3 optimizer hot path: f(Ax), Aᵀ∇f and Ax
in one A read), and fused flash attention (the LM-architecture hotspot).

Import `repro.kernels.ops` for the padded/dispatching public wrappers;
`repro.kernels.ref` holds the pure-jnp oracles."""
from .bsr import BlockELL

__all__ = ["BlockELL"]
