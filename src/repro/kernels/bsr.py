"""Block-sparse (BSR/ELL) × dense matmul — paper §4.2 adapted to the MXU.

MLlib stores local sparse matrices in CCS and hand-rolls SpMV/SpMM because
JVM BLAS has no sparse story.  A TPU has the *opposite* problem: scalar
gathers are slow but dense (8·k × 128·l) tiles are free, so the TPU-native
sparse format is block-CSR padded to ELL: every block-row holds a fixed
number of (bs × bs) blocks (zero-padded — a zero block contributes nothing,
which removes all control flow from the kernel).

The column indices live in SMEM via scalar prefetch, and the index_map
*gathers the needed X block directly* — the kernel body is one dense MXU
matmul per block, i.e. sparsity is handled entirely by the grid machinery.

Three kernels share the layout:

  * ``bsr_matmul``  — y = A @ X   (SpMM, gathers X blocks by column index);
  * ``bsr_matvec``  — y = A @ x   (SpMV: x stored block-partitioned, the
    block product is a (1 × bs)·(bs × bs) row-vector matmul on the MXU);
  * ``bsr_rmatmul`` — y = Aᵀ @ X  (transpose-multiply: the scatter-add over
    block columns is fused into the kernel — the full (nbc × bs × nx)
    accumulator stays resident in VMEM and each per-block partial Aᵢⱼᵀ Xᵢ
    is added at the dynamic offset cols[i, slot] as soon as it is computed.
    The grid is sequential ("arbitrary" on both axes), so the read-modify-
    write is race-free and no HBM partials buffer is needed.  When the
    resident accumulator would overflow the VMEM budget (n·nx too large)
    the kernel falls back to emitting (nbr·ell, bs, nx) partials + one XLA
    segment_sum — the old scheme, kept for the wide regime).

The ``*_jnp`` variants are structure-exploiting gather/einsum forms of the
same contractions (flops ∝ stored blocks, not m·n) — the off-TPU dispatch
target in kernels/ops.py.  The densifying oracles stay in kernels/ref.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("data", "cols", "scales"),
                   meta_fields=("shape",))
@dataclass(frozen=True)
class BlockELL:
    """Fixed-width block-sparse rows: data[i, j] is the j-th stored block of
    block-row i, at block-column col[i, j] (padding blocks are zero with
    col = 0).

    Quantized mode: with ``scales`` set (per stored block, f32), the stored
    block is ``data[i, j].astype(f32) * scales[i, j]`` — int8 data at 1/4
    the HBM traffic, dequantized on-chip by the kernels.  ``scales=None``
    is the exact mode (f32 or bf16 data)."""
    data: Array      # (n_block_rows, ell, bs, bs)
    cols: Array      # (n_block_rows, ell) int32
    shape: tuple[int, int]
    scales: Array | None = None    # (n_block_rows, ell) f32, int8 mode only

    @property
    def bs(self) -> int:
        return self.data.shape[-1]

    @property
    def ell(self) -> int:
        return self.data.shape[1]

    @staticmethod
    def from_dense(a: np.ndarray, bs: int, quantize: str = "none",
                   tol: float = 1e-3) -> "BlockELL":
        """Pack a dense (m × n) array into BlockELL.

        ``quantize``: "none" keeps a.dtype; "int8" stores int8 blocks with
        per-block f32 scales; "auto" asks the planner — the shard is
        quantized iff plan("sparse_matmul", ..., context={"tol": tol})
        picks the int8 precision (i.e. the tolerance clears the int8 guard
        AND the modeled byte savings clear the floor)."""
        m, n = a.shape
        assert m % bs == 0 and n % bs == 0, (a.shape, bs)
        nbr, nbc = m // bs, n // bs
        blocks = np.asarray(a).reshape(nbr, bs, nbc, bs).transpose(0, 2, 1, 3)
        nz = np.abs(blocks).sum(axis=(2, 3)) > 0          # (nbr, nbc)
        ell = max(int(nz.sum(1).max()), 1)
        # Stable argsort on ~nz packs each block-row's nonzero columns into
        # the leading slots in ascending column order (no Python loop).
        order = np.argsort(~nz, axis=1, kind="stable")[:, :ell]
        valid = np.take_along_axis(nz, order, axis=1)     # (nbr, ell)
        cols = np.where(valid, order, 0).astype(np.int32)
        data = blocks[np.arange(nbr)[:, None], order] * valid[..., None, None]
        out = BlockELL(jnp.asarray(data.astype(a.dtype)), jnp.asarray(cols),
                       (m, n))
        if quantize == "auto":
            from repro.launch import planner
            p = planner.plan("sparse_matmul",
                             {"m": m, "n": n, "nx": 1, "ell": ell, "bs": bs},
                             context={"tol": float(tol)})
            quantize = "int8" if p.precision == "int8" else "none"
        if quantize == "int8":
            return out.quantize_int8()
        if quantize != "none":
            raise ValueError(f"quantize must be 'none'|'int8'|'auto', "
                             f"got {quantize!r}")
        return out

    def quantize_int8(self) -> "BlockELL":
        """Int8 + per-block-scale form of this matrix: scale = absmax/127
        per stored block, data = round(block/scale).  Zero (padding) blocks
        get scale 1 so they stay exactly zero."""
        if self.scales is not None:
            return self
        d = self.data.astype(jnp.float32)
        absmax = jnp.max(jnp.abs(d), axis=(2, 3))          # (nbr, ell)
        scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.round(d / scales[..., None, None]).astype(jnp.int8)
        return BlockELL(q, self.cols, self.shape,
                        scales.astype(jnp.float32))

    def dequantize(self) -> "BlockELL":
        """Exact-mode (f32 data, no scales) copy of this matrix."""
        if self.scales is None:
            return self
        return BlockELL(effective_data(self), self.cols, self.shape)

    def to_dense(self) -> Array:
        m, n = self.shape
        data = effective_data(self)
        bs, nbr, ell = self.bs, data.shape[0], self.ell
        out = jnp.zeros((nbr, n // bs, bs, bs), data.dtype)
        rows = jnp.repeat(jnp.arange(nbr), ell)
        out = out.at[rows, self.cols.reshape(-1)].add(
            data.reshape(-1, bs, bs))
        return out.transpose(0, 2, 1, 3).reshape(m, n)

    def density(self) -> float:
        nbc = self.shape[1] // self.bs
        return self.ell / nbc


def effective_data(a: BlockELL) -> Array:
    """The stored blocks as the values they represent: dequantized (int8 ×
    per-block scale) or as stored.  The identity for exact-mode f32 data —
    the jnp paths below route through this, so the unquantized fast path
    is bit-for-bit what it always was."""
    if a.scales is not None:
        return a.data.astype(jnp.float32) * a.scales[..., None, None]
    return a.data


def _load_block(a_ref, s_ref):
    """One staged (bs × bs) block as f32: upcast sub-f32 storage on-chip
    and apply the per-block dequant scale when the matrix is quantized.
    The identity for exact-mode f32 data."""
    a = a_ref[0]
    if a.dtype != jnp.float32:
        a = a.astype(jnp.float32)
    if s_ref is not None:
        a = a * s_ref[0, 0]
    return a


def _bsr_kernel(cols_ref, a_ref, *args, ell: int, quantized: bool):
    del cols_ref   # consumed by the index_map gathers
    if quantized:
        s_ref, x_ref, o_ref, acc_ref = args
    else:
        (x_ref, o_ref, acc_ref), s_ref = args, None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(_load_block(a_ref, s_ref), x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == ell - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_matmul(a: BlockELL, x: Array, *, interpret: bool = False) -> Array:
    """y = A @ X for block-ELL A (m × n) and dense X (n × nx)."""
    m, n = a.shape
    assert x.shape[0] == n, (a.shape, x.shape)
    nx = x.shape[1]
    bs, ell = a.bs, a.ell
    nbr = m // bs
    flat = a.data.reshape(nbr * ell, bs, bs)
    cols = a.cols.reshape(-1)
    quantized = a.scales is not None

    in_specs = [
        pl.BlockSpec((1, bs, bs), lambda i, j, cols: (i * ell + j, 0, 0)),
    ]
    operands = [cols, flat]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda i, j, cols: (i * ell + j, 0)))
        operands.append(a.scales.reshape(nbr * ell, 1))
    in_specs.append(
        pl.BlockSpec((bs, nx), lambda i, j, cols: (cols[i * ell + j], 0)))
    operands.append(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, ell),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bs, nx), lambda i, j, cols: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bs, nx), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bsr_kernel, ell=ell, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nx), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="repro_bsr_matmul",
    )(*operands)


def _bsr_spmv_kernel(cols_ref, a_ref, *args, ell: int, quantized: bool):
    del cols_ref   # consumed by the index_map gathers
    if quantized:
        s_ref, x_ref, o_ref, acc_ref = args
    else:
        (x_ref, o_ref, acc_ref), s_ref = args, None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (1 × bs) · (bs × bs): the row-vector form of A_block @ x_block, so the
    # contraction still lands on the MXU.
    acc_ref[...] += jnp.dot(x_ref[...], _load_block(a_ref, s_ref).T,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == ell - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_matvec(a: BlockELL, x: Array, *, interpret: bool = False) -> Array:
    """y = A @ x for block-ELL A (m × n) and a dense vector x (n,)."""
    m, n = a.shape
    assert x.shape == (n,), (a.shape, x.shape)
    bs, ell = a.bs, a.ell
    nbr = m // bs
    flat = a.data.reshape(nbr * ell, bs, bs)
    cols = a.cols.reshape(-1)
    xb = x.reshape(n // bs, bs)
    quantized = a.scales is not None

    in_specs = [
        pl.BlockSpec((1, bs, bs), lambda i, j, cols: (i * ell + j, 0, 0)),
    ]
    operands = [cols, flat]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda i, j, cols: (i * ell + j, 0)))
        operands.append(a.scales.reshape(nbr * ell, 1))
    in_specs.append(
        pl.BlockSpec((1, bs), lambda i, j, cols: (cols[i * ell + j], 0)))
    operands.append(xb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, ell),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bs), lambda i, j, cols: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, bs), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_bsr_spmv_kernel, ell=ell, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nbr, bs), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="repro_bsr_matvec",
    )(*operands)
    return out.reshape(m)


def _bsr_rmm_kernel(cols_ref, a_ref, *args, nbr: int, ell: int,
                    quantized: bool):
    if quantized:
        s_ref, x_ref, o_ref, acc_ref = args
    else:
        (x_ref, o_ref, acc_ref), s_ref = args, None
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = cols_ref[i * ell + j]
    contrib = jnp.dot(_load_block(a_ref, s_ref).T, x_ref[...],
                      preferred_element_type=jnp.float32)
    cur = pl.load(acc_ref, (pl.ds(c, 1), slice(None), slice(None)))
    pl.store(acc_ref, (pl.ds(c, 1), slice(None), slice(None)),
             cur + contrib[None])

    @pl.when((i == nbr - 1) & (j == ell - 1))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _bsr_rmm_partials_kernel(a_ref, *args, quantized: bool):
    if quantized:
        s_ref, x_ref, o_ref = args
    else:
        (x_ref, o_ref), s_ref = args, None
    o_ref[...] = jnp.dot(_load_block(a_ref, s_ref).T, x_ref[...],
                         preferred_element_type=jnp.float32)[None]


# Double-buffered streams + the resident accumulator + the full output copy
# must fit VMEM for the fused-scatter kernel to be legal.
def _rmm_fused_vmem(nbc: int, bs: int, nx: int, itemsize: int) -> int:
    return (2 * bs * bs * itemsize + 2 * bs * nx * itemsize   # A, X streams
            + nbc * bs * nx * 4                               # f32 acc
            + nbc * bs * nx * itemsize)                       # out copy


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_rmatmul(a: BlockELL, x: Array, *, interpret: bool = False) -> Array:
    """y = Aᵀ @ X for block-ELL A (m × n) and dense X (m × nx).

    The transpose scatters: block (i, slot) contributes Aᵢⱼᵀ Xᵢ to output
    block-row j = cols[i, slot], and several grid steps can hit the same j.
    The scatter-add is fused into the kernel: the whole (nbc, bs, nx)
    accumulator is VMEM-resident and each partial is added at its dynamic
    block-column offset the moment the MXU produces it (the sequential grid
    makes the read-modify-write safe).  Padding slots carry zero data, so
    their contribution to block-row 0 vanishes.

    The resident accumulator scales with n·nx, so when it cannot fit the
    VMEM budget (wide matrix × wide right-hand side — sparserow strips nx
    at 512, but n is unbounded) the kernel falls back to the emit-partials
    form: one (nbr·ell, bs, nx) HBM buffer of per-block products plus an
    XLA segment_sum over block columns.
    """
    from . import autotune as _at
    m, n = a.shape
    assert x.shape[0] == m, (a.shape, x.shape)
    nx = x.shape[1]
    bs, ell = a.bs, a.ell
    nbr, nbc = m // bs, n // bs
    flat = a.data.reshape(nbr * ell, bs, bs)
    cols = a.cols.reshape(-1)
    quantized = a.scales is not None
    flat_scales = a.scales.reshape(nbr * ell, 1) if quantized else None

    if _rmm_fused_vmem(nbc, bs, nx, x.dtype.itemsize) > _at.VMEM_BUDGET:
        in_specs = [
            pl.BlockSpec((1, bs, bs), lambda i, j: (i * ell + j, 0, 0)),
        ]
        operands = [flat]
        if quantized:
            in_specs.append(
                pl.BlockSpec((1, 1), lambda i, j: (i * ell + j, 0)))
            operands.append(flat_scales)
        in_specs.append(pl.BlockSpec((bs, nx), lambda i, j: (i, 0)))
        operands.append(x)
        partial = pl.pallas_call(
            functools.partial(_bsr_rmm_partials_kernel, quantized=quantized),
            grid=(nbr, ell),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bs, nx),
                                   lambda i, j: (i * ell + j, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((nbr * ell, bs, nx), jnp.float32),
            compiler_params=compat.CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
            name="repro_bsr_rmatmul_partials",
        )(*operands)
        out = jax.ops.segment_sum(partial, cols, num_segments=nbc)
        return out.reshape(n, nx).astype(x.dtype)

    in_specs = [
        pl.BlockSpec((1, bs, bs), lambda i, j, cols: (i * ell + j, 0, 0)),
    ]
    operands = [cols, flat]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1), lambda i, j, cols: (i * ell + j, 0)))
        operands.append(flat_scales)
    in_specs.append(pl.BlockSpec((bs, nx), lambda i, j, cols: (i, 0)))
    operands.append(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, ell),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nbc, bs, nx), lambda i, j, cols: (0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((nbc, bs, nx), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_bsr_rmm_kernel, nbr=nbr, ell=ell,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nbc, bs, nx), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="repro_bsr_rmatmul",
    )(*operands)
    return out.reshape(n, nx)


# -- structure-exploiting jnp forms (off-TPU dispatch targets) ----------------

def bsr_matmul_jnp(a: BlockELL, x: Array) -> Array:
    """y = A @ X via gather + block einsum — flops ∝ stored blocks."""
    bs = a.bs
    xb = x.reshape(a.shape[1] // bs, bs, -1)              # (nbc, bs, nx)
    gathered = xb[a.cols]                                 # (nbr, ell, bs, nx)
    y = jnp.einsum("reij,rejn->rin", effective_data(a), gathered,
                   preferred_element_type=jnp.float32)
    return y.reshape(a.shape[0], -1).astype(x.dtype)


def bsr_matvec_jnp(a: BlockELL, x: Array) -> Array:
    """y = A @ x via gather + block einsum."""
    bs = a.bs
    xb = x.reshape(a.shape[1] // bs, bs)
    gathered = xb[a.cols]                                 # (nbr, ell, bs)
    y = jnp.einsum("reij,rej->ri", effective_data(a), gathered,
                   preferred_element_type=jnp.float32)
    return y.reshape(a.shape[0]).astype(x.dtype)


def bsr_rmatmul_jnp(a: BlockELL, x: Array) -> Array:
    """y = Aᵀ @ X: per-block partials + scatter-add over block columns."""
    bs = a.bs
    nbr = a.data.shape[0]
    nbc = a.shape[1] // bs
    xr = x.reshape(nbr, bs, -1)                           # (nbr, bs, nx)
    partial = jnp.einsum("reij,rin->rejn", effective_data(a), xr,
                         preferred_element_type=jnp.float32)
    out = jnp.zeros((nbc, bs, partial.shape[-1]), jnp.float32)
    out = out.at[a.cols.reshape(-1)].add(
        partial.reshape(-1, bs, partial.shape[-1]))
    return out.reshape(a.shape[1], -1).astype(x.dtype)
