"""Block-sparse (BSR/ELL) × dense matmul — paper §4.2 adapted to the MXU.

MLlib stores local sparse matrices in CCS and hand-rolls SpMV/SpMM because
JVM BLAS has no sparse story.  A TPU has the *opposite* problem: scalar
gathers are slow but dense (8·k × 128·l) tiles are free, so the TPU-native
sparse format is block-CSR padded to ELL: every block-row holds a fixed
number of (bs × bs) blocks (zero-padded — a zero block contributes nothing,
which removes all control flow from the kernel).

The column indices live in SMEM via scalar prefetch, and the index_map
*gathers the needed X block directly* — the kernel body is one dense MXU
matmul per block, i.e. sparsity is handled entirely by the grid machinery.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("data", "cols"), meta_fields=("shape",))
@dataclass(frozen=True)
class BlockELL:
    """Fixed-width block-sparse rows: data[i, j] is the j-th stored block of
    block-row i, at block-column col[i, j] (padding blocks are zero with
    col = 0)."""
    data: Array      # (n_block_rows, ell, bs, bs)
    cols: Array      # (n_block_rows, ell) int32
    shape: tuple[int, int]

    @property
    def bs(self) -> int:
        return self.data.shape[-1]

    @property
    def ell(self) -> int:
        return self.data.shape[1]

    @staticmethod
    def from_dense(a: np.ndarray, bs: int) -> "BlockELL":
        m, n = a.shape
        assert m % bs == 0 and n % bs == 0, (a.shape, bs)
        nbr, nbc = m // bs, n // bs
        blocks = a.reshape(nbr, bs, nbc, bs).transpose(0, 2, 1, 3)
        nz = np.abs(blocks).sum(axis=(2, 3)) > 0          # (nbr, nbc)
        ell = max(int(nz.sum(1).max()), 1)
        data = np.zeros((nbr, ell, bs, bs), a.dtype)
        cols = np.zeros((nbr, ell), np.int32)
        for i in range(nbr):
            js = np.nonzero(nz[i])[0]
            for slot, j in enumerate(js):
                data[i, slot] = blocks[i, j]
                cols[i, slot] = j
        return BlockELL(jnp.asarray(data), jnp.asarray(cols), (m, n))

    def to_dense(self) -> Array:
        m, n = self.shape
        bs, nbr, ell = self.bs, self.data.shape[0], self.ell
        out = jnp.zeros((nbr, n // bs, bs, bs), self.data.dtype)
        rows = jnp.repeat(jnp.arange(nbr), ell)
        out = out.at[rows, self.cols.reshape(-1)].add(
            self.data.reshape(-1, bs, bs))
        return out.transpose(0, 2, 1, 3).reshape(m, n)

    def density(self) -> float:
        nbc = self.shape[1] // self.bs
        return self.ell / nbc


def _bsr_kernel(cols_ref, a_ref, x_ref, o_ref, acc_ref, *, ell: int):
    del cols_ref   # consumed by the index_map gathers
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == ell - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_matmul(a: BlockELL, x: Array, *, interpret: bool = False) -> Array:
    """y = A @ X for block-ELL A (m × n) and dense X (n × nx)."""
    m, n = a.shape
    assert x.shape[0] == n, (a.shape, x.shape)
    nx = x.shape[1]
    bs, ell = a.bs, a.ell
    nbr = m // bs
    flat = a.data.reshape(nbr * ell, bs, bs)
    cols = a.cols.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, ell),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, j, cols: (i * ell + j, 0, 0)),
            pl.BlockSpec((bs, nx), lambda i, j, cols: (cols[i * ell + j], 0)),
        ],
        out_specs=pl.BlockSpec((bs, nx), lambda i, j, cols: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bs, nx), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bsr_kernel, ell=ell),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nx), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="repro_bsr_matmul",
    )(cols, flat, x)
