"""Streaming cross-Gram kernel: B = AᵀQ for m ≫ r (the randomized-SVD
projection hotspot).

This is the per-shard compute inside RowMatrix.project(): each chip reduces
its (m_local × n) row shard of A against the conforming (m_local × r) row
shard of the range basis Q down to an (n × r) partial projection before the
cross-chip psum.  Same VMEM-accumulator structure as tsgram (HBM→VMEM
streaming over row blocks, resident float32 accumulator, fully MXU-bound)
but generalized two ways:

  * two streamed operands — the (m × n) and (m × r) inputs are never joined
    in HBM; only the small (n × r) product ever exists;
  * the output is tiled over n (grid axis 0), so the accumulator is
    (bn × r) regardless of how wide A is — exactly the n > GRAM_THRESHOLD
    regime the randomized SVD mode dispatches to.  Each n-tile re-streams
    Q's row blocks (r ≤ k+p is tiny, so the re-read traffic is noise next
    to the single pass over A).

Not implemented as tsgram(a, a): the single-operand Gram kernel reads each
row block once where this one would DMA it twice — for the Gram hotspot
that is a 2× HBM-traffic difference, so the two kernels stay separate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


def _randsketch_kernel(a_ref, q_ref, o_ref, acc_ref, *, m_steps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    q = q_ref[...]
    # Sub-f32 storage upcasts in VMEM; the accumulator is f32 regardless.
    if a.dtype != jnp.float32:
        a = a.astype(jnp.float32)
    if q.dtype != jnp.float32:
        q = q.astype(jnp.float32)
    acc_ref[...] += jnp.dot(a.T, q, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == m_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret", "out_dtype"))
def randsketch(a: Array, q: Array, *, bm: int, bn: int,
               out_dtype=None, interpret: bool = False) -> Array:
    """B = AᵀQ streaming over conforming (bm)-row blocks, output tiled in
    (bn)-column strips (both autotuned by ops.randsketch).
    m % bm == 0, n % bn == 0, r % 128 == 0 (ops.randsketch pads)."""
    m, n = a.shape
    mq, r = q.shape
    assert m == mq, (m, mq)
    assert m % bm == 0, (m, bm)
    assert n % bn == 0, (n, bn)
    out_dtype = out_dtype or a.dtype
    m_steps, n_steps = m // bm, n // bn

    return pl.pallas_call(
        functools.partial(_randsketch_kernel, m_steps=m_steps),
        grid=(n_steps, m_steps),
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((bm, r), lambda j, i: (i, 0))],
        out_specs=pl.BlockSpec((bn, r), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, r), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="repro_randsketch",
    )(a, q)
