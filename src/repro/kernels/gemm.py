"""Blocked MXU GEMM — the TPU answer to the paper's §4 BLAS benchmark.

The paper asks "how close to the hardware can a managed runtime get for
GEMM?" and answers with netlib-java→OpenBLAS.  Here the managed runtime is
XLA and the hand-tuned path is this Pallas kernel: an (bm × bn) output tile
stays resident in a VMEM float32 accumulator while the K dimension streams
through in (bm × bk)·(bk × bn) MXU-aligned chunks.

Tiling rules (TPU v5e):
  * last dim multiples of 128 (lane), second-to-last multiples of 8
    (sublane; 16 for bf16) — callers pad via ops.gemm.
  * bm/bn/bk have no baked-in default: ops.gemm resolves them through the
    shape-aware autotuner (kernels/autotune.py), which enumerates
    layout-legal tiles under the double-buffered VMEM budget and ranks
    them by roofline cost (cached winners on real hardware).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    # Sub-f32 storage (bf16/fp8) upcasts in VMEM: A streams from HBM at the
    # narrow width, the MXU contraction runs in f32.  No-op for f32 input.
    if a.dtype != jnp.float32:
        a = a.astype(jnp.float32)
    if b.dtype != jnp.float32:
        b = b.astype(jnp.float32)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def gemm(a: Array, b: Array, *, bm: int, bn: int, bk: int,
         out_dtype=None, interpret: bool = False) -> Array:
    """C = A @ B with explicit VMEM tiling.  Shapes must be multiples of the
    tile sizes — `ops.gemm` pads arbitrary shapes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"({m},{k},{n}) not multiples of ({bm},{bk},{bn})"
    out_dtype = out_dtype or a.dtype
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_gemm",
    )(a, b)
