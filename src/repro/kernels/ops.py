"""Public jit'd wrappers: shape padding, layout handling, backend dispatch.

On TPU these call the Pallas kernels; on CPU they dispatch to the jnp
reference (identical semantics) unless `force_pallas=True`, which runs the
kernel body in interpret mode — that is how the test suite validates the
kernels on this CPU-only container.

Block sizes default to `tune="auto"`: the shape-aware autotuner
(`kernels/autotune.py`) resolves them per (kernel, backend, dtype,
shape-bucket) — persistent-cache winners when a sweep has run, roofline
cost-model ranking otherwise.  Explicit `bm=`/`bn=`/`bk=` kwargs always
override the tuner; `tune="off"` restores the legacy hand-picked constants.
Resolution is pure Python over static shapes, so it is trace-safe (the
distmat shard_map bodies call these wrappers mid-trace).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import autotune as _tune
from . import gemm as _gemm
from . import tsgram as _tsgram
from . import randsketch as _randsketch
from . import bsr as _bsr
from . import fusedgrad as _fg
from . import flash_attention as _fa
from . import selective_scan as _ss
from . import ref as _ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, axis: int, multiple: int) -> Array:
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def gemm(a: Array, b: Array, *, bm: int | None = None, bn: int | None = None,
         bk: int | None = None, tune: str = "auto", out_dtype=None,
         force_pallas: bool = False) -> Array:
    """C = A @ B, arbitrary shapes (padded up to tiles internally)."""
    if not (_on_tpu() or force_pallas):
        return _ref.gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    cfg = _tune.resolve("gemm", {"m": m, "k": k, "n": n}, a.dtype,
                        {"bm": bm, "bn": bn, "bk": bk}, tune=tune)
    bm_, bn_, bk_ = (min(cfg["bm"], _rup(m, 8)), min(cfg["bn"], _rup(n, 128)),
                     min(cfg["bk"], _rup(k, 128)))
    ap = _pad_to(_pad_to(a, 0, bm_), 1, bk_)
    bp = _pad_to(_pad_to(b, 0, bk_), 1, bn_)
    out = _gemm.gemm(ap, bp, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype,
                     interpret=not _on_tpu())
    return out[:m, :n]


def tsgram(a: Array, *, bm: int | None = None, tune: str = "auto",
           out_dtype=None, force_pallas: bool = False) -> Array:
    """G = AᵀA for tall-skinny A (n padded to lanes internally)."""
    if not (_on_tpu() or force_pallas):
        return _ref.tsgram_ref(a, out_dtype)
    m, n = a.shape
    cfg = _tune.resolve("tsgram", {"m": m, "n": n}, a.dtype, {"bm": bm},
                        tune=tune)
    bm_ = min(cfg["bm"], _rup(m, 8))
    ap = _pad_to(_pad_to(a, 0, bm_), 1, 128)
    out = _tsgram.tsgram(ap, bm=bm_, out_dtype=out_dtype,
                         interpret=not _on_tpu())
    return out[:n, :n]


def randsketch(a: Array, q: Array, *, bm: int | None = None,
               bn: int | None = None, tune: str = "auto", out_dtype=None,
               force_pallas: bool = False) -> Array:
    """B = AᵀQ for conforming tall-skinny A (m×n), Q (m×r) — the
    randomized-SVD projection.  Output is tiled in bn-wide strips so
    arbitrary n fits VMEM; n, r padded to tiles internally."""
    if not (_on_tpu() or force_pallas):
        return _ref.randsketch_ref(a, q, out_dtype)
    m, n = a.shape
    _, r = q.shape
    cfg = _tune.resolve("randsketch", {"m": m, "n": n, "r": r}, a.dtype,
                        {"bm": bm, "bn": bn}, tune=tune)
    bm_ = min(cfg["bm"], _rup(m, 8))
    bn_ = min(cfg["bn"], _rup(n, 128))
    ap = _pad_to(_pad_to(a, 0, bm_), 1, bn_)
    qp = _pad_to(_pad_to(q, 0, bm_), 1, 128)
    out = _randsketch.randsketch(ap, qp, bm=bm_, bn=bn_, out_dtype=out_dtype,
                                 interpret=not _on_tpu())
    return out[:n, :r]


def bsr_matmul(a: "_bsr.BlockELL", x: Array, *,
               force_pallas: bool = False) -> Array:
    """y = A @ X for block-sparse A.  Off-TPU dispatch goes to the
    structure-exploiting gather/einsum form (flops ∝ stored blocks), not the
    densifying oracle — the oracle stays in kernels/ref.py for tests."""
    if not (_on_tpu() or force_pallas):
        return _bsr.bsr_matmul_jnp(a, x)
    nx = x.shape[1]
    xp = _pad_to(x, 1, 128)
    out = _bsr.bsr_matmul(a, xp, interpret=not _on_tpu())
    return out[:, :nx]


def bsr_matvec(a: "_bsr.BlockELL", x: Array, *,
               force_pallas: bool = False) -> Array:
    """y = A @ x for block-sparse A and a vector x (n,)."""
    if not (_on_tpu() or force_pallas):
        return _bsr.bsr_matvec_jnp(a, x)
    return _bsr.bsr_matvec(a, x, interpret=not _on_tpu())


def bsr_rmatmul(a: "_bsr.BlockELL", x: Array, *,
                force_pallas: bool = False) -> Array:
    """y = Aᵀ @ X for block-sparse A and dense X (m × nx)."""
    if not (_on_tpu() or force_pallas):
        return _bsr.bsr_rmatmul_jnp(a, x)
    nx = x.shape[1]
    xp = _pad_to(x, 1, 128)
    out = _bsr.bsr_rmatmul(a, xp, interpret=not _on_tpu())
    return out[:, :nx]


def fused_grad(a: Array, x: Array, target: Array, weights: Array, *,
               loss: str, param: float = 1.0, bm: int | None = None,
               tune: str = "auto",
               force_pallas: bool = False) -> tuple[Array, Array, Array]:
    """(f, g, z) = (Σᵢ wᵢ ℓ((Ax)ᵢ, tᵢ), Aᵀ(w ∘ ℓ'(Ax, t)), Ax) for a dense
    row shard, reading A from HBM exactly once (kernels/fusedgrad).
    ``loss`` ∈ {"quad", "logistic", "huber", "poisson"}; ``param`` is the
    loss's static scalar (the huber δ).  Returns f float32 scalar, g (n,)
    in x.dtype, z (m,) row-space in float32."""
    if loss not in _fg.LOSSES:
        raise ValueError(f"loss must be one of {_fg.LOSSES}, got {loss!r}")
    m, n = a.shape
    if not (_on_tpu() or force_pallas):
        f, g, z = _fg.fused_grad_jnp(a, x, target, weights, loss=loss,
                                     param=param)
        return f, g.astype(x.dtype), z
    cfg = _tune.resolve("fusedgrad", {"m": m, "n": n}, a.dtype, {"bm": bm},
                        tune=tune)
    bm_ = min(cfg["bm"], _rup(m, 128))
    ap = _pad_to(_pad_to(a, 0, bm_), 1, 128)
    xp = _pad_to(x[None, :], 1, 128)
    # Padding rows get weight 0, so they contribute nothing to f or g.
    tp = _pad_to(target[None, :], 1, bm_)
    wp = _pad_to(weights[None, :], 1, bm_)
    f, g, z = _fg.fused_grad(ap, xp, tp, wp, loss=loss, param=param,
                             bm=bm_, interpret=not _on_tpu())
    return f[0, 0], g[0, :n].astype(x.dtype), z[0, :m]


def fused_grad_bsr(a: "_bsr.BlockELL", x: Array, target: Array,
                   weights: Array, *, loss: str, param: float = 1.0,
                   force_pallas: bool = False) -> tuple[Array, Array, Array]:
    """Fused (f, g, z) for a BlockELL shard — every stored block read once.
    Off-TPU dispatch goes to the gather/einsum structured form (flops ∝
    stored blocks); x/target/weights already conform to the padded dims.
    When the fused kernel's resident working set (x + gradient accumulator,
    ∝ n) cannot fit VMEM, falls back to a two-pass composition of the
    VMEM-safe BSR kernels (SpMV, residual on host-side jnp, transpose-
    multiply) — one extra read of the stored blocks, but it always runs."""
    if loss not in _fg.LOSSES:
        raise ValueError(f"loss must be one of {_fg.LOSSES}, got {loss!r}")
    if not (_on_tpu() or force_pallas):
        f, g, z = _fg.fused_grad_bsr_jnp(a, x, target, weights, loss=loss,
                                         param=param)
        return f, g.astype(x.dtype), z
    # int8-quantized shards compose the scale-aware SpMV/rmatmul kernels
    # (two reads of the stored blocks — still half the bytes of one f32
    # read); exact-mode shards keep the single-read fused kernel.
    if a.scales is not None or _fg.fused_grad_bsr_vmem(a) > _tune.VMEM_BUDGET:
        z = bsr_matvec(a, x, force_pallas=force_pallas)
        f, r = _fg.row_loss_grad(z, target, weights, loss, param)
        g = bsr_rmatmul(a, r.astype(x.dtype)[:, None],
                        force_pallas=force_pallas)[:, 0]
        return f, g.astype(x.dtype), z.astype(jnp.float32)
    f, g, z = _fg.fused_grad_bsr(a, x, target, weights, loss=loss,
                                 param=param, interpret=not _on_tpu())
    return f, g.astype(x.dtype), z


def fused_grad_multi(a: Array, x: Array, target: Array, weights: Array, *,
                     loss: str, param: float = 1.0, bm: int | None = None,
                     tune: str = "auto", force_pallas: bool = False
                     ) -> tuple[Array, Array, Array]:
    """Request-batched fused gradients for a dense row shard: k right-hand
    sides answered in ONE streaming pass over A.  x (k, n), target/weights
    (k, m) → f (k,) float32, g (k, n) in x.dtype, z (k, m) float32.
    Padding request slots carry zero weights, so they contribute nothing."""
    if loss not in _fg.LOSSES:
        raise ValueError(f"loss must be one of {_fg.LOSSES}, got {loss!r}")
    m, n = a.shape
    k = x.shape[0]
    if not (_on_tpu() or force_pallas):
        f, g, z = _fg.fused_grad_multi_jnp(a, x, target, weights, loss=loss,
                                           param=param)
        return f, g.astype(x.dtype), z
    cfg = _tune.resolve("fusedgrad", {"m": m, "n": n}, a.dtype, {"bm": bm},
                        tune=tune)
    bm_ = min(cfg["bm"], _rup(m, 128))
    ap = _pad_to(_pad_to(a, 0, bm_), 1, 128)
    # Pad the request axis to the f32 sublane multiple (8) and the feature
    # axis to the lane multiple; padding rows AND padding request slots get
    # weight 0, so they contribute nothing to f or g.
    xp = _pad_to(_pad_to(x, 0, 8), 1, 128)
    tp = _pad_to(_pad_to(target, 0, 8), 1, bm_)
    wp = _pad_to(_pad_to(weights, 0, 8), 1, bm_)
    f, g, z = _fg.fused_grad_multi(ap, xp, tp, wp, loss=loss, param=param,
                                   bm=bm_, interpret=not _on_tpu())
    return (f.sum(axis=1)[:k], g[:k, :n].astype(x.dtype), z[:k, :m])


def fused_grad_bsr_multi(a: "_bsr.BlockELL", x: Array, target: Array,
                         weights: Array, *, loss: str, param: float = 1.0,
                         force_pallas: bool = False
                         ) -> tuple[Array, Array, Array]:
    """Request-batched fused (f, g, z) for a BlockELL shard — every stored
    block read once, serving all k requests.  x (k, n), target/weights
    (k, m) over the padded BlockELL dims → f (k,), g (k, n), z (k, m).
    Falls back to a two-pass composition of the VMEM-safe BSR kernels when
    the kp-scaled resident working set cannot fit VMEM."""
    if loss not in _fg.LOSSES:
        raise ValueError(f"loss must be one of {_fg.LOSSES}, got {loss!r}")
    k = x.shape[0]
    if not (_on_tpu() or force_pallas):
        f, g, z = _fg.fused_grad_bsr_multi_jnp(a, x, target, weights,
                                               loss=loss, param=param)
        return f, g.astype(x.dtype), z
    kp = _rup(k, 8)
    # Quantized shards route through the scale-aware two-pass composition,
    # like the single-RHS form above.
    if a.scales is not None \
            or _fg.fused_grad_bsr_multi_vmem(a, kp) > _tune.VMEM_BUDGET:
        z = bsr_matmul(a, x.T, force_pallas=force_pallas).T
        le, r = _fg.row_loss_elem(z, target, weights, loss, param)
        g = bsr_rmatmul(a, r.astype(x.dtype).T, force_pallas=force_pallas).T
        return le.sum(axis=1), g.astype(x.dtype), z.astype(jnp.float32)
    xp = _pad_to(x, 0, 8)
    tp = _pad_to(target, 0, 8)
    wp = _pad_to(weights, 0, 8)
    f, g, z = _fg.fused_grad_bsr_multi(a, xp, tp, wp, loss=loss, param=param,
                                       interpret=not _on_tpu())
    return f[:k], g[:k].astype(x.dtype), z[:k]


def bsr_block_size(m: int, n: int, nnz: int, *, nx: int = 128,
                   dtype=jnp.float32, tune: str = "auto") -> int:
    """Autotuned BSR block size for an (m × n) matrix with `nnz` nonzeros.

    Resolved through the same persistent-cache/roofline machinery as the
    dense kernels: the cost model prices lane/sublane padding of small
    blocks against the extra zero-fill large blocks suffer at low density.
    Pure Python over static shapes — safe to call at trace/format time.
    """
    cfg = _tune.resolve("bsr", {"m": m, "n": n, "nnz": nnz, "nx": nx},
                        dtype, {"bs": None}, tune=tune)
    return int(cfg["bs"])


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    scale: float | None = None, bq: int | None = None,
                    bk: int | None = None, tune: str = "auto",
                    force_pallas: bool = False) -> Array:
    """q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq a multiple of Hkv.
    Returns (B, Hq, S, D)."""
    B, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if not (_on_tpu() or force_pallas):
        out = _ref.flash_attention_ref(
            q.reshape(B * hq, sq, d), k.reshape(B * hkv, sk, d),
            v.reshape(B * hkv, sk, d), scale=scale, causal=causal,
            q_heads_per_kv=group)
        return out.reshape(B, hq, sq, d)
    cfg = _tune.resolve(
        "flash_attention",
        {"sq": sq, "sk": sk, "d": d, "causal": int(causal)}, q.dtype,
        {"bq": bq, "bk": bk}, tune=tune)
    bq_ = min(cfg["bq"], _rup(sq, 8))
    bk_ = min(cfg["bk"], _rup(sk, 128))
    qp = _pad_to(q.reshape(B * hq, sq, d), 1, bq_)
    kp = _pad_to(k.reshape(B * hkv, sk, d), 1, bk_)
    vp = _pad_to(v.reshape(B * hkv, sk, d), 1, bk_)
    # Padded KV columns sit at causal positions > every real query row, so
    # with causal=True they are masked out automatically; for non-causal we
    # fall back to explicit slicing of K/V (pad only Q).
    if not causal and kp.shape[1] != sk:
        raise NotImplementedError("non-causal requires S_k % bk == 0")
    out = _fa.flash_attention(qp, kp, vp, scale=scale, causal=causal,
                              bq=bq_, bk=bk_, q_heads_per_kv=group,
                              interpret=not _on_tpu())
    return out[:, :sq].reshape(B, hq, sq, d)


def selective_scan(x, dt, A, B, C, D, *, q: int | None = None,
                   tune: str = "auto", force_pallas: bool = False):
    """Fused Mamba1 scan; pads S to q and d to 128 internally."""
    if not (_on_tpu() or force_pallas):
        return _ref.selective_scan_ref(x, dt, A, B, C, D)
    Bt, S, d = x.shape
    N = A.shape[1]
    cfg = _tune.resolve("selective_scan", {"s": S, "d": d, "n": N}, x.dtype,
                        {"q": q}, tune=tune)
    q_ = min(cfg["q"], _rup(S, 8))
    xp = _pad_to(_pad_to(x, 1, q_), 2, 128)
    dtp = _pad_to(_pad_to(dt, 1, q_), 2, 128)
    Bp = _pad_to(B, 1, q_)
    Cp = _pad_to(C, 1, q_)
    Ap = _pad_to(A, 0, 128)
    Dp = _pad_to(D, 0, 128)
    out = _ss.selective_scan(xp, dtp, Ap, Bp, Cp, Dp, q=q_,
                             bd=min(128, xp.shape[2]),
                             interpret=not _on_tpu())
    return out[:, :S, :d]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
