"""Fused causal flash attention (forward) with native GQA.

The perf-critical hotspot of every assigned LM architecture.  Online-softmax
streaming over KV blocks keeps the (bq × d) output tile and running
(m, l) statistics in VMEM — the (S × S) score matrix never exists in HBM,
which is what makes prefill_32k shapes feasible at all.

GQA is handled in the grid machinery, not by materializing repeated KV
heads: the flattened (batch·q_head) grid axis maps to its KV head inside
the BlockSpec index_maps (hkv = hq // group), so KV blocks are DMA'd once
per group position — no memory amplification.

Used for serving (prefill) and available for training forward; the training
path defaults to XLA attention + remat since this kernel is forward-only
(decision recorded in DESIGN.md — a Pallas backward is a beyond-paper
extension tracked in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array

MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)

    live = (ki * bk <= qi * bq + bq - 1) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        v = v_ref[0]                                   # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, MASK_VALUE)
        m_prev = m_ref[:, :1]                          # (bq, 1)
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _flush():
        lsum = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(lsum, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "scale",
                                             "interpret", "q_heads_per_kv"))
def flash_attention(q: Array, k: Array, v: Array, *, scale: float | None = None,
                    causal: bool = True, bq: int, bk: int,
                    q_heads_per_kv: int = 1,
                    interpret: bool = False) -> Array:
    """q: (BHq, S, D) flattened batch·q-heads; k, v: (BHkv, S, D).

    BHq = BHkv · q_heads_per_kv with q-head-major flattening per batch
    element (ops.flash_attention handles the reshapes and padding).
    """
    bhq, sq, d = q.shape
    bhkv, sk, _ = k.shape
    assert bhq == bhkv * q_heads_per_kv, (q.shape, k.shape, q_heads_per_kv)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    nq, nk = sq // bq, sk // bk
    g = q_heads_per_kv

    def kv_map(bh, qi, ki):
        return (bh // g, ki, 0)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk, nk=nk,
                          causal=causal),
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_flash_attention",
    )(q, k, v)
