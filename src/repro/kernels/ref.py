"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def gemm_ref(a: Array, b: Array, out_dtype=None) -> Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def tsgram_ref(a: Array, out_dtype=None) -> Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.T, a, preferred_element_type=jnp.float32).astype(out_dtype)


def randsketch_ref(a: Array, q: Array, out_dtype=None) -> Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.T, q, preferred_element_type=jnp.float32).astype(out_dtype)


def bsr_matmul_ref(a, x: Array) -> Array:
    """Oracle via densification of the BlockELL operand."""
    dense = a.to_dense().astype(jnp.float32)
    return jnp.dot(dense, x.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)


def bsr_matvec_ref(a, x: Array) -> Array:
    """SpMV oracle via densification of the BlockELL operand."""
    dense = a.to_dense().astype(jnp.float32)
    return jnp.dot(dense, x.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)


def bsr_rmatmul_ref(a, x: Array) -> Array:
    """Transpose-multiply (AᵀX) oracle via densification."""
    dense = a.to_dense().astype(jnp.float32)
    return jnp.dot(dense.T, x.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)


def fused_grad_ref(a, x: Array, target: Array, weights: Array, *,
                   loss: str, param: float = 1.0
                   ) -> tuple[Array, Array, Array]:
    """(f, g, z) oracle for the fused composite gradient — independent
    two-pass math in float64-free float32 (densifies BlockELL operands)."""
    if hasattr(a, "to_dense"):
        a = a.to_dense()
    af = a.astype(jnp.float32)
    z = af @ x.astype(jnp.float32)
    t = target.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    if loss == "quad":
        d = z - t
        f = 0.5 * jnp.sum(w * d * d)
        r = w * d
    elif loss == "logistic":
        mz = -t * z
        f = jnp.sum(w * jnp.logaddexp(0.0, mz))
        r = w * (-t) * jax.nn.sigmoid(mz)
    elif loss == "huber":
        delta = jnp.float32(param)
        d = z - t
        ad = jnp.abs(d)
        f = jnp.sum(w * jnp.where(ad <= delta, 0.5 * d * d,
                                  delta * (ad - 0.5 * delta)))
        r = w * jnp.clip(d, -delta, delta)
    elif loss == "poisson":
        ez = jnp.exp(z)
        f = jnp.sum(w * (ez - t * z))
        r = w * (ez - t)
    else:
        raise ValueError(loss)
    return f, af.T @ r, z


def flash_attention_ref(q: Array, k: Array, v: Array, *,
                        scale: float | None = None, causal: bool = True,
                        q_heads_per_kv: int = 1) -> Array:
    """Naive attention with explicit (S × S) scores, f32 softmax."""
    bhq, sq, d = q.shape
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    if q_heads_per_kv > 1:
        k = jnp.repeat(k, q_heads_per_kv, axis=0)
        v = jnp.repeat(v, q_heads_per_kv, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(x, dt, A, B, C, D):
    """Sequential oracle for the Mamba1 recurrence (f32)."""
    Bt, S, d = x.shape
    N = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dtf[:, t, :, None] * A[None])          # (Bt,d,N)
        h = decay * h + (dtf[:, t] * xf[:, t])[..., None] * \
            B[:, t, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, C[:, t].astype(jnp.float32)) \
            + D * xf[:, t]
        return h, y

    h0 = jnp.zeros((Bt, d, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
