"""Fused Mamba1 selective scan — the kernel §Perf hillclimb A calls for.

The XLA-expressible chunked associative scan moves O(passes · B·S·d·N)
f32 through HBM (~1.7 TB/layer/device measured on falcon-mamba train_4k;
three XLA-level levers measured refuted/marginal — EXPERIMENTS.md §Perf A).
This kernel keeps the recurrence state resident in VMEM and touches HBM
exactly once per input/output element:

    reads : x, dt (B,S,d) + B, C (B,S,N) + A (d,N), D (d)
    writes: y (B,S,d) [+ final state (B,d,N)]

→ traffic ≈ B·S·(2d + 2N)·4 B per layer ≈ 0.27 GB vs ~1.7 TB: the ~400×
the roofline analysis projects.

Layout: grid (B, d/bd, S/Q); the VMEM state tile is (N, bd) — N (=16)
on sublanes, the d-block (=128·k) on lanes, elementwise VPU math; the
sequential S dimension walks Q-sized chunks with the state carried in a
VMEM scratch across grid steps ("arbitrary" dimension semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

Array = jax.Array


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref,
                 *, q: int, s_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a_log = a_ref[...]                    # (N, bd)  (= A, laid out N×d)
    d_skip = d_ref[...]                   # (1, bd)

    def step(t, h):
        xt = x_ref[0, t]                  # (bd,)
        dtt = dt_ref[0, t]                # (bd,)
        bt = b_ref[0, t]                  # (N,)
        ct = c_ref[0, t]                  # (N,)
        decay = jnp.exp(dtt[None, :] * a_log)          # (N, bd)
        h = decay * h + (dtt * xt)[None, :] * bt[:, None]
        yt = jnp.sum(h * ct[:, None], axis=0) + d_skip[0] * xt
        y_ref[0, t] = yt.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, q, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("q", "bd", "interpret"))
def selective_scan(x: Array, dt: Array, A: Array, B: Array, C: Array,
                   D: Array, *, q: int, bd: int = 128,
                   interpret: bool = False) -> Array:
    """y[b,t,d] for h_t = exp(dt·A)∘h_{t-1} + dt·B_t·x_t, y_t = C_t·h_t + D·x_t.

    x, dt: (Bt, S, d); A: (d, N); B, C: (Bt, S, N); D: (d,).
    S % q == 0 and d % bd == 0 (ops wrapper pads)."""
    Bt, S, d = x.shape
    N = A.shape[1]
    assert S % q == 0 and d % bd == 0, (x.shape, q, bd)
    a_nd = A.T                                     # (N, d)
    d_2d = D[None, :]                              # (1, d)
    s_steps = S // q

    return pl.pallas_call(
        functools.partial(_scan_kernel, q=q, s_steps=s_steps),
        grid=(Bt, d // bd, s_steps),
        in_specs=[
            pl.BlockSpec((1, q, bd), lambda b, j, s: (b, s, j)),   # x
            pl.BlockSpec((1, q, bd), lambda b, j, s: (b, s, j)),   # dt
            pl.BlockSpec((1, q, N), lambda b, j, s: (b, s, 0)),    # B
            pl.BlockSpec((1, q, N), lambda b, j, s: (b, s, 0)),    # C
            pl.BlockSpec((N, bd), lambda b, j, s: (0, j)),         # A (N,d)
            pl.BlockSpec((1, bd), lambda b, j, s: (0, j)),         # D
        ],
        out_specs=pl.BlockSpec((1, q, bd), lambda b, j, s: (b, s, j)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, bd), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_selective_scan",
    )(x, dt, B, C, a_nd, d_2d)
