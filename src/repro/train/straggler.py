"""Straggler detection and mitigation hooks (launcher level).

Synchronous SPMD training has no per-task speculative execution (the Spark
notion doesn't transfer: every chip participates in every collective), so
production mitigation happens at the *step* granularity:

  * StepMonitor keeps an EMA of step wall time and flags steps slower than
    `threshold`× the EMA — the signal that a host is thermally throttling,
    a link is degraded, or a preemption notice landed;
  * ShardMonitor runs one StepMonitor per shard over per-iteration,
    per-shard timing telemetry and names WHICH shard is the straggler — the
    detector the elastic solver loop (core/optim/elastic.ElasticGroup, the
    serving frontend's GroupRunner) feeds so it can drop the slow shard and
    re-shard the distributed matrix mid-solve via train.elastic.remesh;
  * on `trip_limit` consecutive flags the policy callback fires; the default
    policy checkpoints and requests an elastic re-mesh (drop the slow host's
    pod and resume on the survivors — see train.elastic), which is what
    actual TPU fleets do;
  * `deadline_s` turns a hung collective (dead host) into a detectable
    failure instead of an infinite stall.

This is simulation-tested (tests/test_fault_tolerance.py, using the
train.faults injection harness) since the container has one host; the
monitor math is host-count independent.  The "fault tolerance & resumable
solves" section of examples/quickstart.py walks through the solver wiring.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable

from repro.launch import telemetry as _tel


@dataclasses.dataclass
class StragglerConfig:
    ema_alpha: float = 0.1
    threshold: float = 2.0          # × EMA → flagged
    trip_limit: int = 3             # consecutive flags → policy fires
    warmup_steps: int = 5           # ignore compile/first-step noise
    deadline_s: float | None = None


class StepMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 on_straggler: Callable[[dict], None] | None = None):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.ema: float | None = None
        self.steps = 0
        self.trips = 0
        self.flags: list[int] = []
        self._t0: float | None = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> dict:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, dt: float) -> dict:
        """Feed one step duration; returns the monitor verdict."""
        self.steps += 1
        verdict = {"step": self.steps, "dt": dt, "flagged": False,
                   "tripped": False, "deadline_exceeded": False}
        if self.cfg.deadline_s is not None and dt > self.cfg.deadline_s:
            # a blown deadline (hung collective / dead host) trips
            # immediately — no EMA evidence needed
            verdict["deadline_exceeded"] = True
            verdict["tripped"] = True
            if self.on_straggler is not None:
                self.on_straggler(dict(verdict, ema=self.ema))
            return verdict
        if self.steps <= self.cfg.warmup_steps:
            self.ema = dt if self.ema is None else self.ema
            return verdict
        if self.ema is None:
            self.ema = dt
            return verdict
        if dt > self.cfg.threshold * self.ema:
            verdict["flagged"] = True
            self.flags.append(self.steps)
            self.trips += 1
        else:
            self.trips = 0
        # only fold non-outliers into the EMA (don't learn the pathology)
        if not verdict["flagged"]:
            self.ema = (1 - self.cfg.ema_alpha) * self.ema \
                + self.cfg.ema_alpha * dt
        if self.trips >= self.cfg.trip_limit or verdict["deadline_exceeded"]:
            verdict["tripped"] = True
            self.trips = 0
            if self.on_straggler is not None:
                self.on_straggler(dict(verdict, ema=self.ema))
        return verdict


class ShardMonitor:
    """Per-shard straggler detection from per-iteration step telemetry.

    One StepMonitor per shard; `observe(shard_times)` feeds each shard its
    own duration.  A shard is named the straggler only when BOTH hold:

      * its own StepMonitor tripped (slower than its own EMA history for
        `trip_limit` consecutive iterations, or past `deadline_s`) — the
        thermal-throttle / degraded-link signature; and
      * it is `threshold`× slower than the median of the OTHER shards this
        iteration — so a uniform slowdown (new kernel shape, host noise)
        never looks like a straggler.  On a 1-shard mesh there are no
        others, so the shard's own trip decides alone.

    The verdict dict mirrors StepMonitor's: `tripped` plus `shard` (the
    flagged shard index, slowest first when several trip together).  After
    an elastic re-mesh the caller `reset(new_nshards)`s the monitor — the
    survivors' history no longer predicts the new shard shapes.
    """

    def __init__(self, nshards: int,
                 cfg: StragglerConfig = StragglerConfig(),
                 on_straggler: Callable[[dict], None] | None = None):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.reset(nshards)

    def reset(self, nshards: int) -> None:
        self.nshards = nshards
        self.monitors = [StepMonitor(self.cfg) for _ in range(nshards)]

    def observe(self, shard_times) -> dict:
        times = [float(t) for t in shard_times]
        assert len(times) == self.nshards, (len(times), self.nshards)
        verdicts = [m.observe(t) for m, t in zip(self.monitors, times)]
        suspects = []
        for i, (v, t) in enumerate(zip(verdicts, times)):
            if not v["tripped"]:
                continue
            others = times[:i] + times[i + 1:]
            if others and t <= self.cfg.threshold * statistics.median(others):
                continue                     # everybody slowed — not a straggler
            suspects.append((t, i))
        shard = max(suspects)[1] if suspects else None
        verdict = {"tripped": shard is not None, "shard": shard,
                   "times": times,
                   "deadline_exceeded": any(v["deadline_exceeded"]
                                            for v in verdicts),
                   "flagged": [i for i, v in enumerate(verdicts)
                               if v["flagged"] or v["tripped"]]}
        tel = _tel.current()
        if tel.enabled:
            # The per-shard EMAs double as live gauges: the same numbers
            # the trip decision runs on, readable from any snapshot.
            for i, m in enumerate(self.monitors):
                if m.ema is not None:
                    tel.gauge("straggler.ema_s", shard=i).set(m.ema)
            if verdict["tripped"]:
                tel.counter("straggler.trips").inc()
        if verdict["tripped"] and self.on_straggler is not None:
            self.on_straggler(dict(verdict))
        return verdict
