"""Elastic re-meshing: resume training — or continue a SOLVE — on a
different device count.

Failure story on a real fleet: a pod (or host) dies mid-run → the job
restarts on the surviving slice → `remesh` re-shards the latest checkpoint
onto the new mesh (possible because checkpoints are stored as logical
arrays + PartitionSpecs, not device dumps) → the data pipeline re-delivers
from the checkpointed step (deterministic step→batch mapping, see
data.pipeline) → training continues with an adjusted per-device batch.

The global batch is kept constant across re-meshes (more grad-accum
microbatches on fewer chips), so the optimization trajectory is unchanged
modulo floating-point reduction order.

The solver loop takes the cheaper road: because its iterate/gradient state
lives on the driver (replicated vectors), a mid-solve re-mesh only moves
the distributed MATRIX — `remesh_distmat` re-shards a RowMatrix /
SparseRowMatrix onto a shrunken mesh (`survivor_mesh` drops the straggling
shard named by train.straggler.ShardMonitor), `remesh_linop` rebuilds a
possibly-wrapped LinopMatrix around it, and the elastic executor
(core/optim/elastic.ElasticGroup) continues from the same iterate without
restarting.  See the "fault tolerance & resumable solves" section of
examples/quickstart.py.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from . import checkpoint as ckpt_mod


def remesh(tree, specs, new_mesh: Mesh):
    """Re-shard a live pytree onto a new mesh (same logical values)."""
    def leaf(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    from jax.sharding import PartitionSpec as P
    return jax.tree.map(leaf, tree, specs,
                        is_leaf=lambda v: isinstance(v, P) or
                        hasattr(v, "shape"))


def resume(ckpt_dir, tree_like, specs, new_mesh: Mesh, *,
           global_batch: int, old_microbatches: int, old_dp: int,
           new_dp: int):
    """Restore LATEST onto `new_mesh`; returns (tree, extra, microbatches).

    Microbatch count is rescaled to keep the global batch and per-device
    microbatch memory constant: mb_new = mb_old · old_dp / new_dp
    (rounded up to a divisor of the global batch)."""
    tree, extra = ckpt_mod.restore(ckpt_dir, tree_like, mesh=new_mesh,
                                   specs=specs)
    mb = max(1, (old_microbatches * old_dp + new_dp - 1) // new_dp)
    while global_batch % (mb * new_dp) and mb < global_batch:
        mb += 1
    return tree, extra, mb


# -- solver-side elastic re-mesh ----------------------------------------------

def survivor_mesh(mesh: Mesh, drop_shard: int) -> Mesh:
    """The mesh left after dropping row-shard `drop_shard`'s devices.

    Row shards map to rows of the device grid viewed as
    (row_shards, model); dropping a shard drops that whole row (its model
    slice dies with the host).  A 1-shard mesh has no survivors — the last
    shard is never dropped; the same devices come back as a fresh mesh, so
    callers can re-mesh unconditionally."""
    devs = np.asarray(mesh.devices)
    model = devs.shape[-1] if mesh.axis_names \
        and mesh.axis_names[-1] == "model" else 1
    rows = devs.reshape(-1, model)
    if rows.shape[0] > 1:
        rows = np.delete(rows, drop_shard % rows.shape[0], axis=0)
    return Mesh(rows, ("data", "model"))


def remesh_distmat(A, new_mesh: Mesh, row_axes=None):
    """Re-shard a distributed matrix (RowMatrix / SparseRowMatrix — anything
    with a `.remesh`) onto `new_mesh`; driver-local arrays pass through
    untouched (there is nothing to move)."""
    if hasattr(A, "remesh"):
        return A.remesh(new_mesh, row_axes)
    return A


def remesh_linop(linop, new_mesh: Mesh):
    """Rebuild a (possibly wrapped) linear operator onto `new_mesh`.

    Wrapper layers that carry a `.base` (CountingLinop, the fault-injection
    FaultyLinop, LinopAdjoint) are preserved with their state via
    dataclasses.replace; the LinopMatrix at the bottom gets its distmat
    re-sharded.  Operators with no distributed operand are returned as-is.
    """
    from repro.core.tfocs.linop import LinopMatrix
    if isinstance(linop, LinopMatrix):
        return LinopMatrix(remesh_distmat(linop.A, new_mesh))
    if dataclasses.is_dataclass(linop) and hasattr(linop, "base"):
        return dataclasses.replace(
            linop, base=remesh_linop(linop.base, new_mesh))
    return linop
