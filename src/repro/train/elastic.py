"""Elastic re-meshing: resume training on a different device count.

Failure story on a real fleet: a pod (or host) dies mid-run → the job
restarts on the surviving slice → `remesh` re-shards the latest checkpoint
onto the new mesh (possible because checkpoints are stored as logical
arrays + PartitionSpecs, not device dumps) → the data pipeline re-delivers
from the checkpointed step (deterministic step→batch mapping, see
data.pipeline) → training continues with an adjusted per-device batch.

The global batch is kept constant across re-meshes (more grad-accum
microbatches on fewer chips), so the optimization trajectory is unchanged
modulo floating-point reduction order.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from . import checkpoint as ckpt_mod


def remesh(tree, specs, new_mesh: Mesh):
    """Re-shard a live pytree onto a new mesh (same logical values)."""
    def leaf(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    from jax.sharding import PartitionSpec as P
    return jax.tree.map(leaf, tree, specs,
                        is_leaf=lambda v: isinstance(v, P) or
                        hasattr(v, "shape"))


def resume(ckpt_dir, tree_like, specs, new_mesh: Mesh, *,
           global_batch: int, old_microbatches: int, old_dp: int,
           new_dp: int):
    """Restore LATEST onto `new_mesh`; returns (tree, extra, microbatches).

    Microbatch count is rescaled to keep the global batch and per-device
    microbatch memory constant: mb_new = mb_old · old_dp / new_dp
    (rounded up to a divisor of the global batch)."""
    tree, extra = ckpt_mod.restore(ckpt_dir, tree_like, mesh=new_mesh,
                                   specs=specs)
    mb = max(1, (old_microbatches * old_dp + new_dp - 1) // new_dp)
    while global_batch % (mb * new_dp) and mb < global_batch:
        mb += 1
    return tree, extra, mb
