"""Deterministic fault-injection harness for the solver/serving stack.

Real fleets lose shards three ways — slow (thermal throttle, degraded
link), wrong (a transient NaN / dropped collective), and gone (host death).
This module simulates all three deterministically so the fault-tolerance
layer can be tested and benchmarked on one CPU host:

  * ``FaultyLinop`` wraps any linear operator (LinopMatrix, CountingLinop
    chains) and cooperates with the elastic executor
    (core/optim/elastic.ElasticGroup) through the ``fault_hook`` protocol:
    after every solver iteration the executor offers the hook
    (step, state, dt); the hook sleeps the injected shard delay (so
    deadlines and wall-clock telemetry are real), returns per-shard timing
    telemetry for train.straggler.ShardMonitor, and — per the seeded
    ``FaultPlan`` schedule — raises ``TransientShardError`` (retry-able),
    raises ``DeviceLostError`` (re-mesh), or poisons the state with NaN
    (rollback + retry).
  * ``FaultyMesh`` tracks simulated device loss: ``drop(shard)`` shrinks
    the healthy mesh via train.elastic.survivor_mesh, exactly what the
    executor's remesh callback needs.

Everything is seed-driven and host-side: injection happens BETWEEN jitted
solver iterations, never inside a traced program, so the numerics of the
wrapped operator are untouched.  Used by tests/test_fault_tolerance.py and
the recovery section of benchmarks/bench_serve.py; the quickstart's
"fault tolerance & resumable solves" section shows the wiring.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

# The exception types ARE the recovery contract with the executor, so they
# live beside it; re-exported here because injection sites import this
# module.
from repro.core.optim.elastic import DeviceLostError, TransientShardError

from . import elastic as _elastic

__all__ = ["FaultPlan", "FaultyLinop", "FaultyMesh",
           "TransientShardError", "DeviceLostError"]


@dataclass
class FaultPlan:
    """Seed-driven schedule of injected faults, indexed by solver iteration.

    shard_delays  — extra wall seconds added to the named shards every
                    iteration from `delay_from` on (the straggler
                    signature; starting mid-solve matches the thermal-
                    throttle reality AND what the detector can see — a
                    shard slow from iteration 0 just has a slow EMA);
                    cleared for a shard when it is dropped by a re-mesh.
    fail_steps    — iterations that raise TransientShardError once each.
    nan_steps     — iterations whose post-step state is poisoned with NaN
                    once each (a corrupted reduction).
    lose_shard_at — iteration at which `lost_shard`'s device dies
                    (DeviceLostError, raised once).
    base_dt/jitter — synthetic per-shard baseline seconds and seeded noise
                    for the telemetry, so detector thresholds see realistic
                    spread without depending on the host's actual speed.
    """
    seed: int = 0
    shard_delays: dict[int, float] = field(default_factory=dict)
    delay_from: int = 0
    fail_steps: tuple[int, ...] = ()
    nan_steps: tuple[int, ...] = ()
    lose_shard_at: int | None = None
    lost_shard: int = 0
    base_dt: float = 0.01
    jitter: float = 0.0005


@dataclass
class FaultyLinop:
    """Linop wrapper test double: delegates the whole operator protocol to
    `base` untouched and injects faults only through `fault_hook`, between
    iterations.  Composes with CountingLinop in either order and survives
    train.elastic.remesh_linop (dataclasses.replace keeps the mutable
    runtime state shared across the rebuild)."""
    base: object
    plan: FaultPlan = field(default_factory=FaultPlan)
    sleep: object = time.sleep          # injectable for fast tests
    # mutable runtime state (shared across remesh_linop rebuilds):
    delays: dict = None                 # live copy of plan.shard_delays
    fired: set = None                   # consumed one-shot fault steps
    lost: list = None                   # [True] once the device died
    dropped: list = None                # shards removed by re-meshes
    hooks: int = 0

    def __post_init__(self):
        if self.delays is None:
            self.delays = dict(self.plan.shard_delays)
        if self.fired is None:
            self.fired = set()
        if self.lost is None:
            self.lost = []
        if self.dropped is None:
            self.dropped = []

    # -- delegated operator protocol ----------------------------------------
    @property
    def in_shape(self):
        return self.base.in_shape

    @property
    def out_shape(self):
        return self.base.out_shape

    @property
    def A(self):
        return getattr(self.base, "A", None)

    def apply(self, x):
        return self.base.apply(x)

    def adjoint(self, y):
        return self.base.adjoint(y)

    def fused_grad(self, x, sep):
        return self.base.fused_grad(x, sep)

    def fused_grad_multi(self, x, seps):
        return self.base.fused_grad_multi(x, seps)

    def operand_dtype(self):
        return self.base.operand_dtype()

    def row_shards(self) -> int:
        return self.base.row_shards()

    def pad_data(self, b):
        return self.base.pad_data(b)

    def row_weights(self):
        return self.base.row_weights()

    # -- the injection protocol ---------------------------------------------
    def shard_times(self, step: int) -> list[float]:
        """Deterministic per-shard telemetry for iteration `step`: seeded
        baseline + jitter, plus the injected delay on straggling shards."""
        p = self.plan
        rng = np.random.default_rng((p.seed, step))
        n = self.row_shards()
        times = (p.base_dt + p.jitter * rng.random(n)).tolist()
        if step >= p.delay_from:
            for shard, extra in self.delays.items():
                if 0 <= shard < n:
                    times[shard] += extra
        return times

    def fault_hook(self, step: int, state, dt: float):
        """Called by the elastic executor after each solver iteration.
        Returns (state, telemetry); may sleep (injected delay) or raise
        (scheduled transient / device-loss faults)."""
        self.hooks += 1
        p = self.plan
        if self.delays and step >= p.delay_from:
            self.sleep(max(self.delays.values()))
        if step in p.fail_steps and ("fail", step) not in self.fired:
            self.fired.add(("fail", step))
            raise TransientShardError(f"injected transient fault @ {step}")
        if (p.lose_shard_at is not None and step >= p.lose_shard_at
                and not self.lost):
            self.lost.append(True)
            raise DeviceLostError(p.lost_shard)
        if step in p.nan_steps and ("nan", step) not in self.fired:
            self.fired.add(("nan", step))
            state = state._replace(F=jnp.full_like(state.F, jnp.nan))
        return state, {"shard_times": self.shard_times(step)}

    def on_remesh(self, dropped: int | None) -> None:
        """A re-mesh removed shard `dropped`: its injected delay goes with
        it (the straggling device is out of the job)."""
        if dropped is not None:
            self.delays.pop(dropped, None)
            self.dropped.append(dropped)


class FaultyMesh:
    """Simulated device loss for a mesh: `healthy` is the current surviving
    mesh; `drop(shard)` shrinks it (train.elastic.survivor_mesh) and
    records the casualty.  Pass ``drop`` as the elastic executor's
    `remesh_to` callback."""

    def __init__(self, mesh):
        self.healthy = mesh
        self.casualties: list[int] = []

    @property
    def mesh(self):
        return self.healthy

    def drop(self, shard: int | None):
        self.healthy = _elastic.survivor_mesh(self.healthy,
                                              0 if shard is None else shard)
        if shard is not None:
            self.casualties.append(shard)
        return self.healthy
