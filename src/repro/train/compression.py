"""Gradient compression for the cross-pod data-parallel reduction.

On the multi-pod mesh the once-per-step gradient all-reduce crosses the
(much slower) inter-pod links.  Two compressors, both with error feedback:

  * low-rank (PowerSGD-style) — and this is the paper's own machinery
    applied beyond the paper: the rank-r factor pair comes from one
    subspace iteration, i.e. a tall-skinny Gram/orthonormalization exactly
    like core.linalg (tsqr/gram).  Compress Δ ≈ P·Qᵀ with P (m×r), Q (n×r):
    the DP reduction then moves r(m+n) floats instead of m·n.
  * int8 — quantize to s8 with a per-tensor scale and stochastic rounding.

Both are pure pytree→pytree functions suitable for use as the
`grad_compressor` hook of build_train_step; error feedback state is carried
in a companion tree so compression error is re-injected next step (keeps
SGD convergence — Karimireddy et al. 2019).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    residual: dict          # same structure as grads


def init_error_feedback(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


# ------------------------------------------------------------- low-rank ----
def _lowrank_leaf(g: Array, r: int, key) -> Array:
    """One subspace iteration: G ≈ P Qᵀ (paper's tall-skinny algebra)."""
    if g.ndim < 2 or min(g.shape[-2:]) <= r:
        return g
    shape = g.shape
    m = int(jnp.prod(jnp.asarray(shape[:-1])))
    G = g.reshape(m, shape[-1]).astype(jnp.float32)
    n = shape[-1]
    Q = jax.random.normal(key, (n, r), jnp.float32)
    Pm = G @ Q                                   # (m, r) tall-skinny
    # Orthonormalize via the Gram route (AᵀA is r×r — "driver" math).
    # Rank-deficient directions (w ≈ 0, e.g. when rank(G) < r) are dropped
    # rather than amplified.
    gram = Pm.T @ Pm
    w, V = jnp.linalg.eigh(gram)
    wmax = jnp.maximum(w[-1], 1e-30)
    inv = jnp.where(w > 1e-9 * wmax, 1.0 / jnp.sqrt(jnp.maximum(w, 1e-30)),
                    0.0)
    Pm = Pm @ (V * inv)
    Qt = G.T @ Pm                                # (n, r)
    return (Pm @ Qt.T).reshape(shape).astype(g.dtype)


def lowrank_compressor(rank: int = 8, seed: int = 0):
    """Returns f(grads, ef) -> (approx_grads, new_ef)."""

    def compress(grads, ef: EFState):
        leaves = jax.tree_util.tree_leaves_with_path(grads)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        flat_corr = [g.astype(jnp.float32) + res for (_, g), res in zip(
            leaves, jax.tree_util.tree_leaves(ef.residual))]
        approx = [_lowrank_leaf(g, rank, k)
                  for g, k in zip(flat_corr, keys)]
        residual = [g - a.astype(jnp.float32)
                    for g, a in zip(flat_corr, approx)]
        treedef = jax.tree_util.tree_structure(grads)
        return (jax.tree_util.tree_unflatten(treedef, approx),
                EFState(jax.tree_util.tree_unflatten(treedef, residual)))

    return compress


# ----------------------------------------------------------------- int8 ----
def int8_compressor(seed: int = 0):
    """Per-tensor-scale int8 quantization with stochastic rounding + EF."""

    def _leaf(g: Array, res: Array, key) -> tuple[Array, Array]:
        gf = g.astype(jnp.float32) + res
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        noise = jax.random.uniform(key, gf.shape) - 0.5
        q = jnp.clip(jnp.round(gf / scale + noise), -127, 127)
        deq = (q * scale).astype(g.dtype)
        return deq, gf - deq.astype(jnp.float32)

    def compress(grads, ef: EFState):
        leaves = jax.tree_util.tree_leaves(grads)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        outs = [_leaf(g, r, k) for g, r, k in zip(
            leaves, jax.tree_util.tree_leaves(ef.residual), keys)]
        treedef = jax.tree_util.tree_structure(grads)
        return (jax.tree_util.tree_unflatten(treedef,
                                             [o[0] for o in outs]),
                EFState(jax.tree_util.tree_unflatten(
                    treedef, [o[1] for o in outs])))

    return compress


def compression_ratio(grads, rank: int = 8) -> float:
    """Wire-bytes ratio of the low-rank scheme (for the §Perf napkin math)."""
    dense = comp = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        dense += n
        if g.ndim >= 2 and min(g.shape[-2:]) > rank:
            m = n // g.shape[-1]
            comp += rank * (m + g.shape[-1])
        else:
            comp += n
    return comp / dense
