"""Gradient compression for the cross-pod data-parallel reduction.

On the multi-pod mesh the once-per-step gradient all-reduce crosses the
(much slower) inter-pod links.  Two compressors, both with error feedback:

  * low-rank (PowerSGD-style) — and this is the paper's own machinery
    applied beyond the paper: the rank-r factor pair comes from one
    subspace iteration, i.e. a tall-skinny Gram/orthonormalization exactly
    like core.linalg (tsqr/gram).  Compress Δ ≈ P·Qᵀ with P (m×r), Q (n×r):
    the DP reduction then moves r(m+n) floats instead of m·n.
  * int8 — quantize to s8 with a per-tensor scale and stochastic rounding.

Both are pure pytree→pytree functions suitable for use as the
`grad_compressor` hook of build_train_step; error feedback state is carried
in a companion tree so compression error is re-injected next step (keeps
SGD convergence — Karimireddy et al. 2019).

`psum_int8` is the in-collective form of the same idea: a drop-in
replacement for `jax.lax.psum` inside shard_map bodies that ships int8
payloads with a shared (pmax'd) scale and keeps the quantization error as
a per-shard f32 residual.  The distmat fused_grad/gram reductions use it
when the planner's precision sweep picks "psum8".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    residual: dict          # same structure as grads


def init_error_feedback(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


# ------------------------------------------------------------- low-rank ----
def _lowrank_leaf(g: Array, r: int, key) -> Array:
    """One subspace iteration: G ≈ P Qᵀ (paper's tall-skinny algebra).
    Takes and returns float32 — the caller owns the cast back to the
    original leaf dtype so the residual sees what was actually sent."""
    if g.ndim < 2 or min(g.shape[-2:]) <= r:
        return g
    shape = g.shape
    m = int(jnp.prod(jnp.asarray(shape[:-1])))
    G = g.reshape(m, shape[-1]).astype(jnp.float32)
    n = shape[-1]
    Q = jax.random.normal(key, (n, r), jnp.float32)
    Pm = G @ Q                                   # (m, r) tall-skinny
    # Orthonormalize via the Gram route (AᵀA is r×r — "driver" math).
    # Rank-deficient directions (w ≈ 0, e.g. when rank(G) < r) are dropped
    # rather than amplified.
    gram = Pm.T @ Pm
    w, V = jnp.linalg.eigh(gram)
    wmax = jnp.maximum(w[-1], 1e-30)
    inv = jnp.where(w > 1e-9 * wmax, 1.0 / jnp.sqrt(jnp.maximum(w, 1e-30)),
                    0.0)
    Pm = Pm @ (V * inv)
    Qt = G.T @ Pm                                # (n, r)
    return (Pm @ Qt.T).reshape(shape)


def lowrank_compressor(rank: int = 8, seed: int = 0):
    """Returns f(grads, ef) -> (approx_grads, new_ef)."""

    def compress(grads, ef: EFState):
        leaves = jax.tree_util.tree_leaves_with_path(grads)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        flat_corr = [g.astype(jnp.float32) + res for (_, g), res in zip(
            leaves, jax.tree_util.tree_leaves(ef.residual))]
        # The sent tensor is in the leaf's own dtype; the residual is
        # measured against what was actually sent, so sub-f32 leaves feed
        # their cast error back too instead of silently dropping it.
        approx = [_lowrank_leaf(gf, rank, k).astype(g.dtype)
                  for gf, ((_, g), k) in zip(flat_corr, zip(leaves, keys))]
        residual = [gf - a.astype(jnp.float32)
                    for gf, a in zip(flat_corr, approx)]
        treedef = jax.tree_util.tree_structure(grads)
        return (jax.tree_util.tree_unflatten(treedef, approx),
                EFState(jax.tree_util.tree_unflatten(treedef, residual)))

    return compress


# ----------------------------------------------------------------- int8 ----
def int8_compressor(seed: int = 0):
    """Per-tensor-scale int8 quantization with stochastic rounding + EF."""

    def _leaf(g: Array, res: Array, key) -> tuple[Array, Array]:
        gf = g.astype(jnp.float32) + res
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        noise = jax.random.uniform(key, gf.shape) - 0.5
        q = jnp.clip(jnp.round(gf / scale + noise), -127, 127)
        deq = (q * scale).astype(g.dtype)
        return deq, gf - deq.astype(jnp.float32)

    def compress(grads, ef: EFState):
        leaves = jax.tree_util.tree_leaves(grads)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        outs = [_leaf(g, r, k) for g, r, k in zip(
            leaves, jax.tree_util.tree_leaves(ef.residual), keys)]
        treedef = jax.tree_util.tree_structure(grads)
        return (jax.tree_util.tree_unflatten(treedef,
                                             [o[0] for o in outs]),
                EFState(jax.tree_util.tree_unflatten(
                    treedef, [o[1] for o in outs])))

    return compress


# -------------------------------------------------- compressed psum -------
def psum_int8(x: Array, res: Array, axis_names, nshards: int
              ) -> tuple[Array, Array]:
    """Quantized all-reduce with error feedback — a drop-in for
    ``jax.lax.psum(x, axis_names)`` inside shard_map bodies.

    The wire payload is int8: every shard quantizes its EF-corrected
    partial against a SHARED scale (one 4-byte ``pmax`` of the global
    absmax) with per-shard range ±(127 // nshards), so the summed int8
    payload is bounded by ±127 and the all-reduce itself runs on int8
    lanes — 4× fewer collective bytes than the f32 psum it replaces.
    Rounding is deterministic (round-to-nearest); the quantization error
    stays on-shard as a float32 residual and is re-injected next call, so
    the bias cancels across solver iterations (Karimireddy et al. 2019).

    Returns ``(total, new_res)``: the dequantized f32 all-reduced value
    and the updated per-shard residual.  With ``axis_names`` empty the
    collective degenerates to a local quantize→dequantize round-trip
    (same EF semantics, no wire traffic) — the single-shard test path.
    """
    axis_names = tuple(axis_names)
    gf = x.astype(jnp.float32) + res
    qmax = max(127 // max(int(nshards), 1), 1)
    amax = jnp.max(jnp.abs(gf))
    if axis_names:
        amax = jax.lax.pmax(amax, axis_names)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
    tot = jax.lax.psum(q, axis_names) if axis_names else q
    out = tot.astype(jnp.float32) * scale
    new_res = gf - q.astype(jnp.float32) * scale
    return out, new_res


def compression_ratio(grads, rank: int = 8) -> float:
    """Wire-bytes ratio of the low-rank scheme (for the §Perf napkin math)."""
    dense = comp = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        dense += n
        if g.ndim >= 2 and min(g.shape[-2:]) > rank:
            m = n // g.shape[-1]
            comp += rank * (m + g.shape[-1])
        else:
            comp += n
    return comp / dense
