"""Optimizers for LM training.

AdamW is the production default.  The paper's optimizer suite (L-BFGS and
accelerated gradient with restart, §3.3) is exposed as selectable LM
trainers through the same pure (init, update) interface — the driver/cluster
split survives intact: `update` is replicated vector math, the gradient it
consumes came from sharded cluster compute.

ZeRO-1: `zero1_specs` turns the param spec tree into optimizer-state specs
sharded over the data axes along each tensor's largest divisible dimension.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | lbfgs | acc_rb | sgdm
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    lbfgs_mem: int = 8
    momentum: float = 0.9
    moment_dtype: str = "float32"   # bf16 halves optimizer memory (671B)


def lr_at(cfg: OptimizerConfig, step: Array) -> Array:
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def _global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clip(tree, max_norm: float):
    g = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def make_adamw(cfg: OptimizerConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, mdt)
        return AdamWState(step=jnp.int32(0),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(grads, state, params):
        grads, gnorm = _clip(grads, cfg.clip_norm)
        step = state.step + 1
        lr = lr_at(cfg, step)
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            mh, vh = m2 / b1c, v2 / b2c
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
                cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                m2.astype(mdt), v2.astype(mdt)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_m, new_v), \
            {"grad_norm": gnorm, "lr": lr}

    return init, update


class SgdmState(NamedTuple):
    step: Array
    m: Any


def make_sgdm(cfg: OptimizerConfig):
    def init(params):
        return SgdmState(jnp.int32(0),
                         jax.tree.map(lambda p: jnp.zeros(p.shape,
                                                          jnp.float32),
                                      params))

    def update(grads, state, params):
        grads, gnorm = _clip(grads, cfg.clip_norm)
        step = state.step + 1
        lr = lr_at(cfg, step)

        def upd(p, g, m):
            m2 = cfg.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m2).astype(p.dtype), m2

        out = jax.tree.map(upd, params, grads, state.m)
        return (jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple)),
                SgdmState(step, jax.tree.map(
                    lambda t: t[1], out,
                    is_leaf=lambda t: isinstance(t, tuple))),
                {"grad_norm": gnorm, "lr": lr})

    return init, update


class AccState(NamedTuple):
    """Paper acc_rb (fixed-step variant for stochastic LM training):
    Nesterov momentum + gradient-test restart."""
    step: Array
    z: Any            # accelerated point
    theta: Array
    prev_update: Any


def make_acc_rb(cfg: OptimizerConfig):
    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return AccState(jnp.int32(0), jax.tree.map(zeros, params),
                        jnp.float32(1.0), jax.tree.map(zeros, params))

    def update(grads, state, params):
        grads, gnorm = _clip(grads, cfg.clip_norm)
        step = state.step + 1
        lr = lr_at(cfg, step)
        theta = state.theta
        theta_new = 2.0 / (1.0 + jnp.sqrt(1.0 + 4.0 / (theta * theta)))
        # Gradient test on the flattened trees: <g, Δx_prev> > 0 → restart.
        dot = sum(jnp.vdot(g.astype(jnp.float32), d)
                  for g, d in zip(jax.tree.leaves(grads),
                                  jax.tree.leaves(state.prev_update)))
        theta_new = jnp.where(dot > 0, 1.0, theta_new)

        def upd(p, g, z):
            pf = p.astype(jnp.float32)
            z2 = jnp.where(dot > 0, pf, z) - \
                (lr / jnp.maximum(theta_new, 1e-3)) * g.astype(jnp.float32)
            x2 = (1 - theta_new) * pf + theta_new * z2
            return x2.astype(p.dtype), z2, x2 - pf

        out = jax.tree.map(upd, params, grads, state.z)
        def pick(i):
            return jax.tree.map(lambda t: t[i], out,
                                is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AccState(step, pick(1), theta_new, pick(2)), \
            {"grad_norm": gnorm, "lr": lr, "theta": theta_new}

    return init, update


class LbfgsLMState(NamedTuple):
    """Fixed-step L-BFGS for stochastic training (no line search — the
    driver-side two-loop over a short history; see core.optim.lbfgs for the
    deterministic full-batch version with line search)."""
    step: Array
    S: Any            # (mem, ...) per-leaf history of param deltas
    Y: Any            # (mem, ...) per-leaf history of grad deltas
    rho: Array        # (mem,)
    idx: Array
    filled: Array
    prev_g: Any
    prev_p: Any


def make_lbfgs_lm(cfg: OptimizerConfig):
    mem = cfg.lbfgs_mem

    def init(params):
        def hist(p):
            return jnp.zeros((mem, *p.shape), jnp.float32)

        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return LbfgsLMState(jnp.int32(0), jax.tree.map(hist, params),
                            jax.tree.map(hist, params),
                            jnp.zeros((mem,), jnp.float32), jnp.int32(0),
                            jnp.int32(0), jax.tree.map(zeros, params),
                            jax.tree.map(zeros, params))

    def _tree_vdot(a, b):
        return sum(jnp.vdot(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    def update(grads, state, params):
        grads, gnorm = _clip(grads, cfg.clip_norm)
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        step = state.step + 1
        lr = lr_at(cfg, step)

        # two-loop recursion over tree-structured history
        def hist_at(H, i):
            return jax.tree.map(lambda h: h[i], H)

        q = gf
        alphas = jnp.zeros((mem,), jnp.float32)
        for i in range(mem):
            slot = (state.idx - 1 - i) % mem
            valid = (i < state.filled).astype(jnp.float32)
            a = valid * state.rho[slot] * _tree_vdot(hist_at(state.S, slot), q)
            q = jax.tree.map(lambda qq, yy: qq - a * yy[slot], q, state.Y)
            alphas = alphas.at[slot].set(a)
        newest = (state.idx - 1) % mem
        sy = _tree_vdot(hist_at(state.S, newest), hist_at(state.Y, newest))
        yy = _tree_vdot(hist_at(state.Y, newest), hist_at(state.Y, newest))
        gamma = jnp.where((state.filled > 0) & (yy > 0),
                          sy / jnp.maximum(yy, 1e-30), 1.0)
        r = jax.tree.map(lambda x: gamma * x, q)
        for i in range(mem):
            slot = (state.idx - state.filled + i) % mem
            valid = (i < state.filled).astype(jnp.float32)
            beta = valid * state.rho[slot] * _tree_vdot(
                hist_at(state.Y, slot), r)
            coef = alphas[slot] - beta
            r = jax.tree.map(lambda rr, ss: rr + coef * ss[slot], r, state.S)

        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) - lr * d).astype(p.dtype),
            params, r)
        s_new = jax.tree.map(
            lambda pn, po: pn.astype(jnp.float32) - po.astype(jnp.float32),
            new_params, params)
        y_new = jax.tree.map(lambda g, pg: g - pg, gf, state.prev_g)
        sy_new = _tree_vdot(s_new, y_new)
        keep = (state.step > 0) & (sy_new > 1e-10)

        def store(H, new):
            return jax.tree.map(
                lambda h, n: jnp.where(
                    keep, h.at[state.idx].set(n), h), H, new)

        S2, Y2 = store(state.S, s_new), store(state.Y, y_new)
        rho2 = jnp.where(keep, state.rho.at[state.idx].set(
            1.0 / jnp.maximum(sy_new, 1e-30)), state.rho)
        idx2 = jnp.where(keep, (state.idx + 1) % mem, state.idx)
        filled2 = jnp.where(keep, jnp.minimum(state.filled + 1, mem),
                            state.filled)
        return new_params, LbfgsLMState(step, S2, Y2, rho2, idx2, filled2,
                                        gf, jax.tree.map(
                                            lambda p: p.astype(jnp.float32),
                                            params)), \
            {"grad_norm": gnorm, "lr": lr}

    return init, update


def make_optimizer(cfg: OptimizerConfig):
    return {"adamw": make_adamw, "sgdm": make_sgdm, "acc_rb": make_acc_rb,
            "lbfgs": make_lbfgs_lm}[cfg.name](cfg)


# ------------------------------------------------------------- sharding ----
def make_opt_specs(init_fn, param_shapes, param_specs, *,
                   zero1: bool = False, mesh=None):
    """Build the optimizer-state spec tree by structural correspondence:
    any state leaf whose shape ends with a param leaf's shape inherits that
    spec (prefixed with None for history dims); everything else replicates."""
    from repro.models.sharding import batch_axes
    state_shapes = jax.eval_shape(init_fn, param_shapes)
    spec_of = {}
    for path, spec in jax.tree_util.tree_flatten_with_path(
            param_specs, is_leaf=lambda v: isinstance(v, P))[0]:
        shape_leaf = _get_path(param_shapes, path)
        spec_of[tuple(shape_leaf.shape)] = spec
    ba = batch_axes(mesh)
    dp = 1
    if mesh is not None:
        for a in ba:
            dp *= mesh.shape[a]

    def leaf(leafshape):
        shape = tuple(leafshape.shape)
        spec = None
        # longest suffix first: an exact-rank match must beat a 1-D norm
        for pshape in sorted(spec_of, key=len, reverse=True):
            if len(pshape) and len(shape) >= len(pshape) and \
                    shape[len(shape) - len(pshape):] == pshape:
                spec = P(*([None] * (len(shape) - len(pshape)) +
                           list(spec_of[pshape])))
                break
        if spec is None:
            spec = P(*([None] * len(shape)))
        if zero1 and mesh is not None:
            full = tuple(spec)
            used = set()
            for s in full:
                for a in (s if isinstance(s, tuple) else (s,)):
                    if a is not None:
                        used.add(a)
            # FSDP-sharded params already consume the data axes
            if not any(a in used for a in ba):
                for i, (dim, sp) in enumerate(zip(shape, full)):
                    if sp is None and dim % dp == 0 and dim >= dp:
                        full = full[:i] + (ba,) + full[i + 1:]
                        return P(*full)
        return spec

    return state_shapes, jax.tree.map(leaf, state_shapes)


def _get_path(tree, path):
    node = tree
    for p in path:
        if hasattr(p, "key"):
            node = node[p.key]
        elif hasattr(p, "idx"):
            node = node[p.idx]
        elif hasattr(p, "name"):
            node = getattr(node, p.name)
        else:
            raise TypeError(p)
    return node
