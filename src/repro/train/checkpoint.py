"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    ckpt_dir/
      step_000120/
        manifest.json          # tree structure, shapes, dtypes, spec strings
        shard_<host>.npz       # this host's unique shard data
      LATEST                   # atomically-updated pointer file

Design notes for multi-host fleets (documented behavior; this container is
single-host so host_count=1 paths execute):
  * every host writes only the addressable shards it owns; the manifest is
    written once by host 0;
  * a checkpoint is *committed* by the atomic rename of the step directory
    and then the LATEST pointer rewrite — a crash mid-write leaves a
    `.tmp` directory that restore ignores (fault tolerance);
  * `restore` re-shards onto WHATEVER mesh is passed in — restoring a
    512-chip checkpoint onto 256 chips (elastic downscale after a pod
    failure) is the same code path as same-size restore;
  * `save_async` offloads serialization to a worker thread after a
    device_get, so the train loop blocks only for the host transfer.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any

from repro.launch import telemetry as _tel


def _fsync_file(path: pathlib.Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: pathlib.Path) -> None:
    # Directory fsync makes the rename/replace itself durable; some
    # filesystems don't support it — best effort, never fatal.
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - exotic fs
        pass
    finally:
        os.close(fd)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _spec_to_str(spec) -> str:
    return json.dumps([list(s) if isinstance(s, tuple) else s
                       for s in (spec or ())])


def _spec_from_str(s: str) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e
               for e in json.loads(s)])


def save(ckpt_dir: str | os.PathLike, step: int, tree,
         specs=None, *, extra: dict | None = None) -> pathlib.Path:
    """Synchronous sharded save; returns the committed directory."""
    tel = _tel.current()
    t0 = time.perf_counter()
    with tel.span("checkpoint.write", step=step):
        final = _save(ckpt_dir, step, tree, specs, extra=extra)
    tel.histogram("checkpoint.write_s").observe(time.perf_counter() - t0)
    return final


def _save(ckpt_dir, step, tree, specs=None, *, extra=None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten(tree)
    spec_leaves = dict(_flatten(specs)) if specs is not None else {}
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    arrays = {}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"][name] = {
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "spec": _spec_to_str(spec_leaves.get(name)),
        }
    np.savez(tmp / "shard_0.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # Durability before the commit point: the shard data and manifest are
    # fsync'd while still under the .tmp name, so the rename can never
    # expose a directory whose contents are still in the page cache.
    _fsync_file(tmp / "shard_0.npz")
    _fsync_file(tmp / "manifest.json")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # commit point
    _fsync_dir(ckpt_dir)
    latest_tmp = ckpt_dir / ".LATEST.tmp"
    latest_tmp.write_text(final.name)
    # fsync the step marker BEFORE the atomic replace: a crash between the
    # two leaves the old LATEST intact, never a torn pointer — so
    # latest_step can never pick up a partially-written checkpoint.
    _fsync_file(latest_tmp)
    os.replace(latest_tmp, ckpt_dir / "LATEST")  # atomic pointer update
    _fsync_dir(ckpt_dir)
    return final


class AsyncCheckpointer:
    """Device→host transfer on the caller thread; disk I/O on a worker.

    Background-thread write errors are never dropped: the first
    ``save_async``/``wait`` after a failed write re-raises the worker's
    exception on the caller thread (and clears it, so one failure is
    reported exactly once rather than poisoning every later call)."""

    def __init__(self, ckpt_dir: str | os.PathLike):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self.last_error: BaseException | None = None

    def save_async(self, step: int, tree, specs=None, *, extra=None):
        # Propagate any pending background failure BEFORE doing new work —
        # callers learn about a lost checkpoint at the next save, not at
        # process exit.
        self.wait()
        tel = _tel.current()
        tel.counter("checkpoint.async_saves").inc()
        # Backlog gauge: 1 while a write is in flight on the worker, 0
        # once it commits — a stuck-at-1 gauge is the "checkpointing can't
        # keep up / disk stalled" signal.
        backlog = tel.gauge("checkpoint.backlog")
        backlog.set(1)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, specs, extra=extra)
            except BaseException as e:  # noqa: BLE001
                with self._lock:
                    self.last_error = e
            finally:
                backlog.set(0)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            err, self.last_error = self.last_error, None
        if err is not None:
            raise err


def _complete(step_dir: pathlib.Path) -> bool:
    return (step_dir / "manifest.json").exists() \
        and (step_dir / "shard_0.npz").exists()


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    """Newest *committed* step.  The LATEST pointer is only trusted when the
    directory it names is complete (manifest + shard data); otherwise fall
    back to scanning for the newest complete step directory — a
    partially-written checkpoint is never picked up."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ptr = ckpt_dir / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if name and _complete(ckpt_dir / name):
            return int(name.split("_")[-1])
    if not ckpt_dir.exists():
        return None
    steps = sorted((int(d.name.split("_")[-1]) for d in
                    ckpt_dir.glob("step_*") if _complete(d)), reverse=True)
    return steps[0] if steps else None


def restore(ckpt_dir: str | os.PathLike, tree_like, *,
            step: int | None = None, mesh: Mesh | None = None,
            specs=None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like`, sharded per `specs` onto
    `mesh` (which may have a different device count than the saver's —
    elastic restore is just device_put with the new sharding).
    Returns (tree, extra)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "shard_0.npz")
    spec_leaves = dict(_flatten(specs)) if specs is not None else {}

    leaves = _flatten(tree_like)
    out = []
    for name, like in leaves:
        info = manifest["leaves"].get(name)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = data[info["key"]]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{name}: shape {arr.shape} vs {like.shape}")
        spec = spec_leaves.get(name)
        if spec is None and info["spec"]:
            spec = _spec_from_str(info["spec"])
        if mesh is not None and spec is not None:
            val = jax.device_put(arr.astype(like.dtype),
                                 NamedSharding(mesh, spec))
        else:
            val = jnp.asarray(arr.astype(like.dtype))
        out.append(val)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
