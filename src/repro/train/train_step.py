"""Train-step builder: grad accumulation, mixed precision, sharding glue.

`build_train_step` closes over (model, optimizer) and returns a pure
function suitable for jit with donated (params, opt_state).  Microbatching
runs as a `lax.scan` over the leading split of the batch; gradients are
accumulated in f32 and the collective all-reduce over the data axes is
deferred to the (single) optimizer application — the GSPMD partitioner
therefore emits ONE gradient reduce per step regardless of microbatch
count, which is the overlap-friendly schedule (§Perf discusses the
psum_scatter/ZeRO-1 variant)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def build_train_step(model, opt_update, *, microbatches: int = 1,
                     grad_compressor=None,
                     accum_dtype=jnp.float32) -> Callable:
    """Returns f(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_compressor: optional (compress, decompress) pair applied to the
    accumulated gradient before the optimizer — the cross-pod DP reduction
    hook (see train.compression).
    accum_dtype: gradient accumulation buffer dtype (bf16 halves the
    accumulator footprint for ≳0.5T-param models)."""

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def micro(carry, mb):
                acc = carry
                (lo, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(accum_dtype), acc, g)
                return acc, (lo, met)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            grads, (losses, mets) = jax.lax.scan(micro, zeros, mbatch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), mets)

        if grad_compressor is not None:
            grads = grad_compressor(grads)

        params, opt_state, opt_metrics = opt_update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return step
