"""repro.api — the uniform request/result surface for the matrix suite.

One set of request dataclasses drives BOTH entry paths:

  * the direct call path — `solve(SolveRequest(...))`,
    `svd(SvdRequest(...))`, `similarities(SimilarityRequest(...))` run the
    job immediately and return a `Result`;
  * the serving path — `launch/serve.SolverServer.submit(...)` enqueues the
    SAME objects, groups solve requests that share a design matrix, and
    answers each group with one fused A-pass per iteration.

`minimize()`, `compute_svd()` and `column_similarities()` are thin wrappers
over the request objects, kept signature-compatible with their historical
homes (core.optim.api.minimize, core.linalg.svd.compute_svd, and the
distmat methods).

Every `Result.info` carries the standardized keys

  iterations — outer iterations (restarts for Lanczos, q for randomized)
  a_passes   — streaming passes over A consumed (the paper's cost unit)
  converged  — whether the stopping test fired before the iteration cap
  plan       — which execution plan answered it ("fused", "cached",
               "gram", "randomized", "lanczos", ...)
  degraded   — None for a full-quality answer, else why it was cut short
               ("deadline", "max_iterations", "fault", "overloaded")

plus solver-native detail; pre-existing solver-specific keys ("fused",
"n_evals", "mode", "passes_over_A", ...) remain as deprecated aliases for
one release.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix
from repro.core.distmat.sparserow import SparseRowMatrix
from repro.core.linalg.svd import compute_svd as _compute_svd
from repro.core.optim.api import minimize as _minimize
from repro.core.optim.problems import Problem
from repro.core.tfocs.linop import LinopMatrix
from repro.core.tfocs.prox import ProxL1, ProxL2Sq, ProxZero
from repro.core.tfocs.smooth import (SmoothHuber, SmoothLogLoss,
                                     SmoothPoisson, SmoothQuad)
from repro.kernels.fusedgrad import LOSSES

Array = jax.Array

REGS = ("none", "l1", "l2")
_ids = itertools.count()


def _next_id(prefix: str) -> str:
    return f"{prefix}-{next(_ids)}"


def _check_scalar(name: str, value, *, minimum=None,
                  exclusive: bool = False, optional: bool = False):
    """Shared typed validation for request scalars: finite, and bounded
    below when asked.  Rejecting NaN/negative knobs at construction keeps
    both entry paths (direct call and serving queue) from discovering a
    bad deadline or tolerance mid-solve."""
    if value is None:
        if optional:
            return
        raise ValueError(f"{name} must be set")
    v = float(value)
    if math.isnan(v) or math.isinf(v):
        raise ValueError(f"{name} must be finite, got {value!r}")
    if minimum is not None:
        if exclusive and not v > minimum:
            raise ValueError(f"{name} must be > {minimum}, got {value!r}")
        if not exclusive and not v >= minimum:
            raise ValueError(f"{name} must be >= {minimum}, got {value!r}")


@dataclass
class SolveRequest:
    """minimize f(Ax) + h(x): the work unit of both solve paths.

    The common case names a design matrix `A` (RowMatrix, SparseRowMatrix
    or a local array), a target `b` and a row-separable `loss` — exactly
    the shape the serving queue can batch (requests sharing A, loss and
    reg kind form one fused group).  `problem` / `smooth` / `prox` are
    escape hatches for prebuilt composites (those run the direct path but
    are served one-per-group)."""
    A: Any = None                 # RowMatrix | SparseRowMatrix | Array
    b: Any = None                 # (m,) target / labels / counts
    loss: str = "quad"            # quad | logistic | huber | poisson
    param: float = 1.0            # static loss scalar (huber δ)
    reg: str = "none"             # none | l1 | l2
    lam: float = 0.0              # regularizer weight
    method: str = "gra"           # gra | acc | acc_r | acc_b | acc_rb | lbfgs
    tol: float = 1e-8
    max_iters: int = 200
    L0: float = 1.0               # initial Lipschitz estimate (1/step)
    x0: Any = None
    # Compute/wire precision: "auto" lets the planner's precision sweep
    # pick {f32, bf16 storage, int8-compressed psum} with `tol` as the
    # error guard (see TfocsOptions.precision); "f32"/"bf16"/"psum8"
    # force the choice.  Result.info["precision"] reports what ran.
    precision: str = "auto"
    # fault tolerance / resumability (see core.optim.elastic):
    deadline_s: float | None = None     # wall budget; past it → best iterate
    checkpoint_dir: str | None = None   # periodic resumable snapshots
    checkpoint_every: int = 10          # iterations between snapshots
    resume: bool = False                # restore from checkpoint_dir first
    # escape hatches (direct path; served without cross-request batching):
    problem: Problem | None = None
    smooth: Any = None
    prox: Any = None
    # observability (launch/telemetry.py): True for a fresh recorder, or a
    # telemetry.Recorder to accumulate across requests.  Off by default
    # (near-zero overhead).  When set, the solve runs under
    # telemetry.recording() and Result.info["trace"] carries the span /
    # plan-vs-actual summary.
    telemetry: Any = None
    request_id: str = field(default_factory=lambda: _next_id("solve"))

    def __post_init__(self):
        if self.problem is None and self.smooth is None:
            if self.loss not in LOSSES:
                raise ValueError(f"loss must be one of {LOSSES}, "
                                 f"got {self.loss!r}")
            if self.reg not in REGS:
                raise ValueError(f"reg must be one of {REGS}, "
                                 f"got {self.reg!r}")
            if self.A is None or self.b is None:
                raise ValueError("SolveRequest needs (A, b) or a "
                                 "problem/smooth escape hatch")
        _check_scalar("tol", self.tol, minimum=0.0)
        _check_scalar("lam", self.lam, minimum=0.0)
        _check_scalar("L0", self.L0, minimum=0.0, exclusive=True)
        _check_scalar("param", self.param)
        _check_scalar("max_iters", self.max_iters, minimum=0,
                      exclusive=True)
        _check_scalar("deadline_s", self.deadline_s, minimum=0.0,
                      exclusive=True, optional=True)
        _check_scalar("checkpoint_every", self.checkpoint_every, minimum=0,
                      exclusive=True)
        if self.precision not in ("auto", "f32", "bf16", "psum8"):
            raise ValueError("precision must be auto | f32 | bf16 | psum8, "
                             f"got {self.precision!r}")
        if self.checkpoint_dir is not None:
            if self.problem is not None or self.smooth is not None \
                    or self.prox is not None:
                raise ValueError("checkpoint_dir needs the (A, b) request "
                                 "form (escape hatches aren't resumable)")
            if self.method not in ("gra", "lbfgs"):
                raise ValueError("checkpoint_dir needs method 'gra' or "
                                 f"'lbfgs', got {self.method!r}")
        if self.resume and self.checkpoint_dir is None:
            raise ValueError("resume=True needs checkpoint_dir")


@dataclass
class SvdRequest:
    """Truncated SVD of a distributed matrix (core.linalg.compute_svd)."""
    A: Any
    k: int
    compute_u: bool = True
    mode: str = "auto"            # auto | gram | lanczos | randomized
    options: dict = field(default_factory=dict)   # extra compute_svd kwargs
    deadline_s: float | None = None
    telemetry: Any = None         # True | telemetry.Recorder (see SolveRequest)
    request_id: str = field(default_factory=lambda: _next_id("svd"))

    def __post_init__(self):
        _check_scalar("k", self.k, minimum=0, exclusive=True)
        _check_scalar("deadline_s", self.deadline_s, minimum=0.0,
                      exclusive=True, optional=True)


@dataclass
class SimilarityRequest:
    """DIMSUM column similarities (exact at threshold=0, sampled above)."""
    A: Any
    threshold: float = 0.0
    gamma: float | None = None
    seed: int = 0
    deadline_s: float | None = None
    telemetry: Any = None         # True | telemetry.Recorder (see SolveRequest)
    request_id: str = field(default_factory=lambda: _next_id("sim"))

    def __post_init__(self):
        _check_scalar("threshold", self.threshold, minimum=0.0)
        _check_scalar("deadline_s", self.deadline_s, minimum=0.0,
                      exclusive=True, optional=True)


@dataclass
class Result:
    """Uniform answer envelope: `x` for solves, `factors` for SVD
    ((U, s, V)) and similarities ((sim,)), `info` with the standardized
    keys (iterations / a_passes / converged / plan, plus `degraded` — None
    for a full-quality answer, else why it was cut short: "deadline",
    "max_iterations", "fault", "overloaded")."""
    x: Array | None = None
    factors: tuple | None = None
    info: dict = field(default_factory=dict)
    request_id: str = ""


@dataclass
class Overloaded(Result):
    """Typed load-shed answer: the server refused the request at submit
    because its admission budget/queue bound was exhausted — carries no
    solution, only `info["degraded"] == "overloaded"`.  A typed result
    (instead of unbounded queueing or an exception mid-drain) lets clients
    distinguish "retry later" from "failed"."""

    def __post_init__(self):
        self.info.setdefault("degraded", "overloaded")
        self.info.setdefault("iterations", 0)
        self.info.setdefault("a_passes", 0)
        self.info.setdefault("converged", False)
        self.info.setdefault("plan", "rejected")


# -- request construction helpers (shared with launch/serve) ------------------

def solve_linop(req: SolveRequest) -> LinopMatrix:
    if req.problem is not None:
        return req.problem.linop
    A = req.A
    if isinstance(A, (RowMatrix, SparseRowMatrix)):
        return LinopMatrix(A)
    return LinopMatrix(jnp.asarray(A))


def solve_smooth(req: SolveRequest, linop: LinopMatrix):
    """The row-separable smooth for a request, padded to the linop's data
    space with padding rows weighted 0."""
    if req.problem is not None:
        return req.problem.smooth
    if req.smooth is not None:
        return req.smooth
    b = linop.pad_data(jnp.asarray(req.b, jnp.float32))
    w = linop.row_weights()
    if req.loss == "quad":
        return SmoothQuad(b=b, weights=w)
    if req.loss == "logistic":
        return SmoothLogLoss(y=b, weights=w)
    if req.loss == "huber":
        return SmoothHuber(b=b, delta=req.param, weights=w)
    return SmoothPoisson(y=b, weights=w)


def solve_prox(req: SolveRequest):
    if req.problem is not None:
        return req.problem.prox
    if req.prox is not None:
        return req.prox
    if req.reg == "l1":
        return ProxL1(req.lam)
    if req.reg == "l2":
        return ProxL2Sq(req.lam)
    return ProxZero()


# -- direct call path ---------------------------------------------------------

def _traced(req, kind: str, run) -> Result:
    """The ``telemetry=`` escape hatch: when the request asks for it, run
    the job under a scoped recorder (every instrumented component —
    elastic iterations, checkpoints, stragglers — resolves it via
    telemetry.current()) and attach the compact summary as
    ``Result.info["trace"]``.  Off (the default) adds no work at all."""
    if not req.telemetry:
        return run()
    from repro.launch import telemetry as _telemetry
    rec = req.telemetry if isinstance(req.telemetry, _telemetry.Recorder) \
        else _telemetry.Recorder()
    with _telemetry.recording(rec):
        with rec.span("api." + kind, request_id=req.request_id):
            res = run()
    res.info["trace"] = rec.summary()
    return res


def _solve_elastic(req: SolveRequest) -> Result:
    """Host-driven resumable/deadline-aware path (core.optim.elastic):
    taken when a direct-form gra/lbfgs request asks for a checkpoint or a
    wall deadline — the lax.while_loop solvers can't be interrupted or
    snapshotted mid-flight, the per-iteration driver can."""
    from repro.core.optim import elastic as _elastic
    ckpt = None
    if req.checkpoint_dir is not None:
        ckpt = _elastic.SolveCheckpoint(req.checkpoint_dir,
                                        every=req.checkpoint_every)
    cfg = _elastic.ElasticConfig(checkpoint=ckpt)
    x, info = _elastic.solve_elastic(
        solve_linop(req), req.loss, req.b, param=req.param, reg=req.reg,
        lam=req.lam, method=req.method, tol=req.tol,
        max_iters=req.max_iters, L0=req.L0, x0=req.x0,
        deadline_s=req.deadline_s, resume=req.resume, elastic=cfg)
    return Result(x=x, info=info, request_id=req.request_id)


def solve(req: SolveRequest, *, fused: bool | str = "auto") -> Result:
    """Run one SolveRequest immediately (no queue, no batching)."""
    return _traced(req, "solve", lambda: _solve(req, fused=fused))


def _solve(req: SolveRequest, *, fused: bool | str = "auto") -> Result:
    if req.problem is not None:
        x, info = _minimize(req.problem, req.method,
                            max_iters=req.max_iters, tol=req.tol,
                            fused=fused)
        info = dict(info)
        info.setdefault("degraded", None)
        return Result(x=x, info=info, request_id=req.request_id)
    if (req.checkpoint_dir is not None
            or (req.deadline_s is not None
                and req.method in ("gra", "lbfgs")
                and req.smooth is None and req.prox is None)):
        return _solve_elastic(req)

    from repro.core.optim.first_order import minimize_first_order
    from repro.core.tfocs.solver import TfocsOptions
    linop = solve_linop(req)
    smooth = solve_smooth(req, linop)
    prox = solve_prox(req)
    x0 = jnp.zeros(linop.in_shape, jnp.float32) if req.x0 is None \
        else jnp.asarray(req.x0, jnp.float32)
    opts = TfocsOptions(max_iters=req.max_iters, tol=req.tol, L0=req.L0,
                        fused=fused, precision=req.precision)
    if req.method == "lbfgs" and not isinstance(prox, ProxZero):
        raise ValueError("method='lbfgs' needs reg='none' (fold the "
                         "regularizer into a smooth loss)")
    t0 = time.perf_counter()
    x, info = minimize_first_order(req.method, smooth, linop, prox,
                                   x0=x0, opts=opts)
    info = dict(info)
    info.setdefault("degraded", None)
    if req.deadline_s is not None \
            and time.perf_counter() - t0 > req.deadline_s:
        # The accelerated while_loop variants can't stop mid-flight; the
        # overrun is reported post-hoc so callers still learn the budget
        # was blown.
        info["degraded"] = "deadline"
    return Result(x=x, info=info, request_id=req.request_id)


def svd(req: SvdRequest) -> Result:
    return _traced(req, "svd", lambda: _svd(req))


def _svd(req: SvdRequest) -> Result:
    t0 = time.perf_counter()
    res = _compute_svd(req.A, req.k, compute_u=req.compute_u,
                       mode=req.mode, **req.options)
    info = dict(res.info or {})
    info.setdefault("converged", True)
    info.setdefault("degraded", None)
    if req.deadline_s is not None \
            and time.perf_counter() - t0 > req.deadline_s:
        info["degraded"] = "deadline"
    return Result(factors=(res.U, res.s, res.V), info=info,
                  request_id=req.request_id)


def similarities(req: SimilarityRequest) -> Result:
    return _traced(req, "similarities", lambda: _similarities(req))


def _similarities(req: SimilarityRequest) -> Result:
    sim, info = req.A.column_similarities(
        req.threshold, gamma=req.gamma, seed=req.seed, return_info=True)
    info = dict(info or {})
    # DIMSUM is a single Gram-style reduction: one pass over A, no
    # iteration, deterministic completion.
    info.setdefault("iterations", 0)
    info.setdefault("a_passes", 1)
    info.setdefault("converged", True)
    info.setdefault("plan", "dimsum" if req.threshold > 0 else "gram")
    info.setdefault("degraded", None)
    return Result(factors=(sim,), info=info, request_id=req.request_id)


# -- thin signature-compatible wrappers ---------------------------------------

def minimize(problem: Problem, method: str, *, max_iters: int = 200,
             step_size: float | None = None, tol: float = 1e-10,
             fused: bool | str = "auto"):
    """Thin wrapper: a Problem-shaped SolveRequest through the same path
    the server drives.  Returns (x, info) like core.optim.minimize."""
    if step_size is not None:
        # Problem-based requests resolve L0 inside core.optim.api.minimize.
        return _minimize(problem, method, max_iters=max_iters,
                         step_size=step_size, tol=tol, fused=fused)
    res = solve(SolveRequest(problem=problem, method=method, tol=tol,
                             max_iters=max_iters), fused=fused)
    return res.x, res.info


def compute_svd(A, k: int, *, compute_u: bool = True, mode: str = "auto",
                **options):
    """Thin wrapper: an SvdRequest through the request path.  Returns the
    SVDResult-compatible (U, s, V, info) unpacked from the Result."""
    res = svd(SvdRequest(A=A, k=k, compute_u=compute_u, mode=mode,
                         options=options))
    U, s, V = res.factors
    return U, s, V, res.info


def column_similarities(A, threshold: float = 0.0, *,
                        gamma: float | None = None, seed: int = 0):
    """Thin wrapper: a SimilarityRequest through the request path.
    Returns (sim, info)."""
    res = similarities(SimilarityRequest(A=A, threshold=threshold,
                                         gamma=gamma, seed=seed))
    return res.factors[0], res.info
