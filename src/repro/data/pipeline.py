"""Deterministic, shardable, resumable synthetic data pipeline.

Real pretraining data layers (tokenized shards + samplers) reduce, for the
purposes of this framework, to a function `step → global batch` that is
(a) deterministic (restart-safe: re-delivers the same batch after a
checkpoint restore), (b) cheap to evaluate anywhere (any host can produce
any shard — elastic re-sharding needs no data movement), and (c) pure, so
it can run either host-side or in-graph.

`in_graph_batch` is the production path: the batch is *generated on the
devices* from (seed, step) via counter-based PRNG, so the input pipeline
can never be the straggler and needs no host↔device transfer at all.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 1234
    frontend: str | None = None
    frontend_len: int = 0
    d_model: int = 0


def from_model(cfg: ModelConfig, global_batch: int, seq_len: int,
               seed: int = 1234) -> DataConfig:
    return DataConfig(vocab_size=cfg.vocab_size, global_batch=global_batch,
                      seq_len=seq_len, seed=seed, frontend=cfg.frontend,
                      frontend_len=(seq_len if cfg.family == "encdec"
                                    else cfg.frontend_len),
                      d_model=cfg.d_model)


def in_graph_batch(dc: DataConfig, step) -> dict:
    """Pure (traceable) batch synthesis from the step counter."""
    key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
    k1, k2 = jax.random.split(key)
    batch = {"tokens": jax.random.randint(
        k1, (dc.global_batch, dc.seq_len), 0, dc.vocab_size, jnp.int32)}
    if dc.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            k2, (dc.global_batch, dc.frontend_len, dc.d_model),
            jnp.bfloat16) * 0.02
    return batch


class HostIterator:
    """Host-side equivalent with explicit, checkpointable state."""

    def __init__(self, dc: DataConfig, start_step: int = 0):
        self.dc = dc
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.dc.seed}

    @staticmethod
    def restore(dc: DataConfig, state: dict) -> "HostIterator":
        assert state["seed"] == dc.seed, "seed mismatch on restore"
        return HostIterator(dc, start_step=state["step"])

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.dc.seed, self.step))
        batch = {"tokens": rng.integers(
            0, self.dc.vocab_size,
            (self.dc.global_batch, self.dc.seq_len)).astype(np.int32)}
        if self.dc.frontend:
            batch["frontend_embeds"] = (rng.standard_normal(
                (self.dc.global_batch, self.dc.frontend_len,
                 self.dc.d_model)) * 0.02).astype(np.float32)
        self.step += 1
        return batch

    def shard_for(self, host_index: int, num_hosts: int) -> "ShardView":
        return ShardView(self, host_index, num_hosts)


class ShardView:
    """Per-host slice of the global batch (multi-host data loading)."""

    def __init__(self, it: HostIterator, idx: int, n: int):
        assert it.dc.global_batch % n == 0
        self.it, self.idx, self.n = it, idx, n

    def __next__(self) -> dict:
        full = next(self.it)
        per = self.it.dc.global_batch // self.n
        lo = self.idx * per
        return jax.tree.map(lambda x: x[lo:lo + per], full)
