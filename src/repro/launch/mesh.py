"""Production mesh construction.

Single-pod: (data=16, model=16) — 256 chips (one v5e pod slice).
Multi-pod : (pod=2, data=16, model=16) — 512 chips; 'pod' is an outer
data-parallel axis (the only cross-pod collective is the once-per-step
gradient all-reduce, optionally compressed — see train.compression).

Defined as functions so importing this module never touches jax device
state (the dry-run pins the device count before first jax init)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/drivers."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return compat.make_mesh((data, model), ("data", "model"))


def axis_sizes(mesh, axes=None) -> tuple[int, ...]:
    """Per-axis device counts of `mesh` (all axes, or the named subset, a
    single name included) — the topology key the planner's collective
    model prices reductions against (`MachineModel.collective`)."""
    if axes is None:
        names = tuple(mesh.axis_names)
    elif isinstance(axes, str):
        names = (axes,)
    else:
        names = tuple(axes)
    return tuple(int(mesh.shape[a]) for a in names)
