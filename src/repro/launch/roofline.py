"""Roofline terms from a compiled dry-run artifact.

  compute   = HLO_FLOPs        / (chips × 197 TFLOP/s bf16)
  memory    = HLO_bytes        / (chips × 819 GB/s HBM)
  collective= collective_bytes / (chips × 50 GB/s ICI link)

cost_analysis() on an SPMD executable reports the *per-device* module, so
the per-chip division is already done for compute/memory (verified in
tests/test_dryrun.py::test_cost_analysis_is_per_device).  Collective bytes
are not in cost_analysis — they are parsed from the optimized HLO: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op contributes its result-buffer bytes (per-device traffic; ring-algorithm
wire factors ~2(N−1)/N are noted, not applied).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.launch.machine import V5E

# Machine constants come from the one MachineModel home (launch/machine.py);
# the dry-run roofline prices bf16 training steps on the v5e reference.
PEAK_FLOPS = V5E.mxu_flops[2]      # bf16 per chip
HBM_BW = V5E.hbm_bw                # bytes/s per chip
LINK_BW = V5E.link_bw              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped buffer, e.g. bf16[8,128]{1,0} or f32[] or pred[4]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _buffer_bytes(text: str) -> float:
    """Sum bytes of every shaped buffer in `text` (handles tuples)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-category result-buffer bytes + op counts from optimized HLO."""
    out: dict[str, dict] = {c: {"bytes": 0.0, "count": 0}
                            for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # match "<result shape(s)> <op>(" with optional -start/-done forms
        m = re.match(r"%?[\w.\-]+ = (.+?) (\w[\w-]*)\(", line)
        if not m:
            continue
        result_part, op = m.groups()
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base]["bytes"] += _buffer_bytes(result_part)
            out[base]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float
    useful_fraction: float
    step_s: float
    roofline_fraction: float


def analyze(flops: float, hbm: float, collective_bytes: float,
            meta: dict) -> Roofline:
    """All inputs are PER-DEVICE quantities (SPMD modules report
    per-device costs; trip-count-corrected by launch.costmodel)."""
    chips = int(np.prod(list(meta["mesh"].values())))

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D serving (fwd only),
    # N = active params (MoE discount), D = tokens processed this step.
    n = meta["params_active"]
    d = meta["tokens_per_step"]
    mf = (6.0 if meta["kind"] == "train" else 2.0) * n * d
    mf_per_chip = mf / chips
    useful = mf_per_chip / flops if flops else 0.0

    step = max(terms.values())
    # Ideal step: useful model FLOPs at peak, floored by reading every
    # live byte (params + optimizer state + caches) exactly once — the
    # bandwidth bound that governs decode.
    arg_bytes = float(meta.get("argument_bytes", 0.0))
    ideal = max(mf_per_chip / PEAK_FLOPS, arg_bytes / HBM_BW)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=collective_bytes,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bound=bound, model_flops=mf,
        useful_fraction=useful, step_s=step,
        roofline_fraction=(ideal / step if step else 0.0))


def as_dict(r: Roofline) -> dict:
    return dataclasses.asdict(r)
