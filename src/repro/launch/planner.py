"""The unified execution planner — one code path for every "should we?".

Every dispatch decision the repo makes — which block config a Pallas kernel
runs with, BSR-vs-dense for a sparse shard, fused-vs-unfused composite
gradients, the BSR block size, the SVD mode — used to live in a different
module with its own copy of the machine constants.  ``plan()`` is now the
single entry point: it prices the alternatives against ONE
``MachineModel`` (launch/machine.py — calibrated per backend when sweep
timings have been recorded) and returns an ``ExecutionPlan`` that names the
chosen path, the block config, the modeled cost, and an ``explain()``
breakdown of why.

    >>> from repro.launch import planner
    >>> p = planner.plan("sparse_matmul",
    ...                  {"m": 4096, "n": 2048, "nx": 1, "ell": 2, "bs": 128})
    >>> p.choice
    'bsr'
    >>> print(p.explain())          # roofline terms + alternatives

Supported ops:

  kernel block selection   "gemm" | "tsgram" | "randsketch" | "fusedgrad" |
                           "flash_attention" | "selective_scan" | "bsr"
                           (dims = the kernel's logical dims; choice is the
                           kernel name, blocks the selected config — memo /
                           persistent sweep cache / model ranking, exactly
                           the ops-wrapper ``tune="auto"`` path)
  "sparse_matmul"          {m, n, nx, ell, bs} per-shard BSR-vs-dense
  "grad"                   {m, n} per-shard fused-vs-unfused composite
                           gradient (one A read vs two); with context
                           {"axes": mesh axis sizes} the psum of (f, g) is
                           priced end-to-end and an overlapped chunk count
                           is chosen (blocks["chunks"], 1 = eager)
  "bsr_bs"                 {m, n, nx} + context {"ell_by_bs": {bs: ell}}
                           block-size selection on actual ELL widths
  "svd"                    {m, n, k} + context {"kind": "row"|"sparse"|
                           "other", thresholds} → gram | randomized | lanczos
  "gram"                   {m, n} per-shard AᵀA + context {"axes": …}:
                           eager tsgram+psum vs column-chunked cross-grams
                           whose partial psums pipeline behind the next
                           chunk's compute (choice "eager"|"overlap",
                           blocks["chunks"])
  "matvec"                 {m, n} one streaming shard pass + context
                           {"axes": …} reduction of the n-vector result;
                           choice names the reduction (ring|tree|local)

Precision is a planner axis too: pass a solver tolerance via
``context={"tol": ...}`` and grad/gram/matvec/sparse_matmul plans sweep
{f32, bf16 storage, int8 BlockELL, int8 error-feedback compressed psum}
against the PRECISION_GUARDS accuracy ceilings, picking the fastest
candidate the tolerance admits that also clears a savings floor (tiny
shapes stay f32).  The chosen plan's ``precision`` field names the pick,
``explain()`` prints it plus the modeled byte savings, and the solvers'
``precision="auto"`` (core/optim/first_order.py) defers to this decision.

Distributed ops price their collectives with ``MachineModel.collective``
(ring vs tree by mesh shape and payload — pass mesh axis sizes via
``launch.mesh.axis_sizes``), and ``explain()`` reports the comm fraction.
Decision functions are memoized (the shard_map bodies consult them at trace
time); ``kernels.autotune.reset()`` clears every layer at once.
"""
from __future__ import annotations

import functools
import json
from dataclasses import dataclass, field
from typing import Mapping

from repro.kernels import autotune as at
from repro.launch import machine as _machine
from repro.launch.machine import LANE, CostTerms, MachineModel

KERNEL_OPS = tuple(at.KERNELS)
DECISION_OPS = ("sparse_matmul", "grad", "bsr_bs", "svd", "gram", "matvec")

# Overlap chunk counts the distributed deciders sweep (1 = eager
# compute-then-reduce); segments narrower than a lane never win.
CHUNK_CANDIDATES = (1, 2, 4, 8)

# BSR block-size candidates — the one definition (SparseRowMatrix's
# bs="auto" constructors and plan("bsr_bs") both sweep this list).
BS_CANDIDATES = (8, 16, 32, 64, 128)

# Precision as a planner axis.  When the caller passes a solver tolerance
# (context={"tol": ...}) and the operand is float32, grad/gram/matvec/
# sparse_matmul plans sweep lower-precision executions and pick the fastest
# candidate whose accuracy guard the tolerance clears:
#
#   "bf16"   A stored bfloat16, tiles upcast on-chip, f32 accumulation
#            (halves the HBM stream of every A pass)
#   "int8"   BlockELL data int8 + per-block f32 scale (sparse_matmul only)
#   "psum8"  error-feedback int8 compressed all-reduce for the distributed
#            (f, g) / gram reductions (train/compression.psum_int8) — the
#            wire payload drops 4×, a 4-byte shared-scale pmax rides along
#
# The guard values are worst-case relative-error ceilings per candidate
# (bf16 has ~3 decimal digits; int8 block quantization ~2; psum8 is tighter
# than its per-step quantization error because error feedback re-injects
# the residual, keeping the *converged* solution at tolerance).  A
# candidate is admissible iff tol >= guard.  On top of the guard, a
# savings floor keeps tiny shapes at f32: low precision must win by
# max(PRECISION_MIN_SAVINGS_FRAC of the f32 time, PRECISION_MIN_SAVINGS_S)
# or the plan stays exact — flipping precision for nanoseconds is all risk.
PRECISION_OPS = ("grad", "gram", "matvec", "sparse_matmul")
PRECISION_GUARDS = {"f32": 0.0, "psum8": 1e-6, "bf16": 1e-5, "int8": 1e-3}
PRECISION_MIN_SAVINGS_FRAC = 0.20
PRECISION_MIN_SAVINGS_S = 2e-6

# SVD auto-mode gates (paper §3.1 dispatch; see core/linalg/svd.py for the
# derivations of the two numbers).
GRAM_THRESHOLD = 8192
RANDOMIZED_K_THRESHOLD = 128


def _us(s: float) -> str:
    return f"{s * 1e6:.2f} us"


@dataclass(frozen=True)
class ExecutionPlan:
    """What to run and why — the planner's answer for one op instance."""
    op: str
    choice: str                       # chosen kernel/path/mode
    blocks: Mapping[str, int]         # block config ({} for path decisions)
    cost_s: float                     # modeled seconds of the choice
    dims: Mapping[str, int]
    dtype: str
    backend: str
    machine: str                      # MachineModel.name
    calibrated: bool                  # modeled with calibrated efficiencies?
    breakdown: Mapping[str, float] = field(default_factory=dict)
    alternatives: tuple = ()          # ((label, modeled_s), ...) ascending
    notes: tuple = ()
    terms: Mapping[str, float] = field(default_factory=dict)
    # ^ raw (efficiency-1) cost terms of the chosen path for decision ops
    #   that price collectives — lets actual_record() feed calibrate()
    #   with the comm column (kernel ops rebuild terms from blocks instead).
    precision: str = ""
    # ^ "" when the plan was not precision-swept (no context["tol"]);
    #   otherwise the chosen storage/wire precision: "f32" | "bf16" |
    #   "int8" | "psum8".  `dtype` stays the caller's logical operand
    #   dtype — precision names how the bytes move, not what x means.

    def explain(self) -> str:
        """Human-readable roofline breakdown of the decision."""
        dims = " ".join(f"{k}={v}" for k, v in self.dims.items())
        lines = [
            f"plan({self.op}) -> {self.choice}"
            + (f" {dict(self.blocks)}" if self.blocks else ""),
            f"  dims: {dims}  dtype={self.dtype}  backend={self.backend}",
            f"  machine: {self.machine}"
            f" ({'calibrated' if self.calibrated else 'builtin constants'})",
            f"  modeled: {_us(self.cost_s)}",
        ]
        if self.precision:
            lines.insert(2, f"  precision: {self.precision}")
        b = self.breakdown
        if b:
            lines.append(
                f"  roofline: compute {_us(b['compute_s'])}"
                f" | memory {_us(b['memory_s'])}"
                f" | steps {_us(b['step_s'])}  -> {b['bound']}-bound")
            comm_s = b.get("comm_s", 0.0)
            if comm_s:
                frac = comm_s / b["total_s"] if b["total_s"] > 0 else 0.0
                lines.append(f"  comm: {_us(comm_s)}"
                             f" ({frac:.0%} of modeled serial time)")
        if self.alternatives:
            selected = {self.choice,
                        json.dumps(dict(self.blocks), sort_keys=True)}
            lines.append("  alternatives:")
            for label, s in self.alternatives:
                marker = "*" if label in selected else " "
                lines.append(f"   {marker} {label}: {_us(s)}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)


def invalidate_cache() -> None:
    """Forget memoized decisions (recalibration / tests)."""
    _decide_cached.cache_clear()


def plan(op: str, dims: Mapping[str, int], dtype="float32", *,
         backend: str | None = None, machine: MachineModel | None = None,
         context: Mapping | None = None, top: int = 0) -> ExecutionPlan:
    """Price the alternatives for `op` and return the chosen ExecutionPlan.

    `backend` defaults to the jax default backend; `machine` overrides the
    calibrated-model lookup (and bypasses the decision memo).  `top` > 0
    attaches the top-N ranked block configs as alternatives for kernel ops.
    `context` carries op-specific non-shape inputs (see module docstring).
    """
    import jax
    import jax.numpy as jnp
    backend = backend or jax.default_backend()
    dtype_name = jnp.dtype(dtype).name
    if op in KERNEL_OPS:
        return _plan_kernel(op, dict(dims), dtype_name, backend,
                            machine, top)
    if op not in DECISION_OPS:
        raise ValueError(f"unknown op {op!r}; expected one of "
                         f"{KERNEL_OPS + DECISION_OPS}")
    dims_key = tuple(sorted((k, int(v)) for k, v in dims.items()))
    ctx_key = _freeze(context or {})
    if machine is not None:
        return _decide(op, dims_key, dtype_name, backend, ctx_key, machine)
    return _decide_cached(op, dims_key, dtype_name, backend, ctx_key)


def _freeze(obj):
    if isinstance(obj, Mapping):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


def _thaw_ctx(ctx_key) -> dict:
    out = {}
    for k, v in ctx_key:
        out[k] = dict(v) if isinstance(v, tuple) and v \
            and isinstance(v[0], tuple) else v
    return out


# -- kernel block selection ----------------------------------------------------

def _plan_kernel(op: str, dims: dict, dtype_name: str, backend: str,
                 machine: MachineModel | None, top: int) -> ExecutionPlan:
    explicit = machine is not None
    machine = machine or _machine.for_backend(backend)
    if explicit:
        blocks = at.rank(op, {k: at.bucket(int(v)) for k, v in dims.items()},
                         dtype_name, machine=machine)[0][1]
    else:
        # The memo → persistent sweep cache → ranking path the ops wrappers
        # have always dispatched through (kernels/autotune.get_config).
        blocks = at.get_config(op, dims, dtype_name, backend=backend)
    terms = at.cost_terms(op, blocks, dims, dtype_name)
    br = machine.breakdown(terms, dtype_name)
    alts = ()
    if top > 0:
        ranked = at.rank(op, dims, dtype_name, machine=machine)[:top]
        alts = tuple((json.dumps(b, sort_keys=True), s) for s, b in ranked)
    return ExecutionPlan(
        op=op, choice=op, blocks=dict(blocks), cost_s=br["total_s"],
        dims=dims, dtype=dtype_name, backend=backend, machine=machine.name,
        calibrated=machine.source == "calibrated", breakdown=br,
        alternatives=alts)


# -- path decisions ------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _decide_cached(op, dims_key, dtype_name, backend, ctx_key):
    return _decide(op, dims_key, dtype_name, backend, ctx_key,
                   _machine.for_backend(backend))


def _decide(op, dims_key, dtype_name, backend, ctx_key,
            machine: MachineModel) -> ExecutionPlan:
    d = dict(dims_key)
    ctx = _thaw_ctx(ctx_key)
    kw = dict(dims=d, dtype=dtype_name, backend=backend,
              machine=machine.name,
              calibrated=machine.source == "calibrated")
    if op in PRECISION_OPS and "tol" in ctx and dtype_name == "float32":
        return _decide_precision(op, d, dtype_name, machine, ctx, kw)
    if op == "sparse_matmul":
        return _decide_sparse(d, dtype_name, machine, ctx, kw)
    if op == "grad":
        return _decide_grad(d, dtype_name, machine, ctx, kw)
    if op == "bsr_bs":
        return _decide_bsr_bs(d, dtype_name, machine, ctx, kw)
    if op == "gram":
        return _decide_gram(d, dtype_name, machine, ctx, kw)
    if op == "matvec":
        return _decide_matvec(d, dtype_name, machine, ctx, kw)
    return _decide_svd(d, dtype_name, machine, ctx, kw)


# -- collective helpers --------------------------------------------------------

def _axes(ctx) -> tuple[int, ...]:
    """Mesh axis sizes the op reduces across (context["axes"]); () when the
    caller runs single-device / undistributed."""
    return tuple(int(a) for a in ctx.get("axes", ()) or ())


def _terms_dict(t: CostTerms) -> dict:
    return {"flops": t.flops, "hbm_bytes": t.hbm_bytes, "steps": t.steps,
            "mxu_util": t.mxu_util, "comm_bytes": t.comm_bytes,
            "comm_steps": t.comm_steps}


def _with_comm(t: CostTerms, coll: Mapping) -> CostTerms:
    import dataclasses
    return dataclasses.replace(
        t, comm_bytes=t.comm_bytes + coll["comm_bytes"],
        comm_steps=t.comm_steps + coll["comm_steps"])


def _pipeline_s(t_chunk: float, comm_chunk: float, chunks: int,
                pre: float = 0.0) -> float:
    """Modeled wall time of `chunks` compute→psum stages where chunk k's
    psum overlaps chunk k+1's compute: the first compute and the last psum
    are exposed, every middle stage costs max(compute, comm)."""
    if chunks <= 1:
        return pre + t_chunk + comm_chunk
    return (pre + t_chunk
            + (chunks - 1) * max(t_chunk, comm_chunk) + comm_chunk)


def _chunk_counts(n: int) -> tuple[int, ...]:
    """Chunk counts worth sweeping for an n-column segment split."""
    return tuple(c for c in CHUNK_CANDIDATES if c == 1 or n // c >= LANE)


def _psum_cost(machine, elems: float, axes, dtype_name, wire=None) -> dict:
    """Price the all-reduce of an `elems`-element f32 accumulator.

    Default wire format is the f32 payload itself.  wire="int8" prices the
    error-feedback compressed collective (train/compression.psum_int8):
    the payload ships as int8 (4× fewer wire bytes) plus one 4-byte
    shared-scale pmax per reduction — cheap on fat payloads, pure latency
    overhead on small ones, which is exactly what the sweep should see."""
    if wire == "int8":
        body = machine.collective(elems * 1.0, axes, "int8")
        scale = machine.collective(4.0, axes, dtype_name)
        return {"algorithm": f"{body['algorithm']}+int8",
                "comm_bytes": body["comm_bytes"] + scale["comm_bytes"],
                "comm_steps": body["comm_steps"] + scale["comm_steps"],
                "comm_s": body["comm_s"] + scale["comm_s"]}
    return machine.collective(elems * 4.0, axes, dtype_name)


def _decide_precision(op, d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """Sweep storage/wire precision for one decision op against the solver
    tolerance in context["tol"] (see PRECISION_GUARDS above).  Each
    candidate re-prices the op's full decision at the candidate's byte
    widths — bf16 swaps the storage dtype, psum8 swaps the collective wire
    format, int8 swaps the BlockELL data dtype — so precision composes
    with the existing fused/chunked/BSR choices rather than bypassing
    them.  The returned plan keeps the caller's logical dtype and reports
    the pick + modeled byte savings in `precision` / notes."""
    import dataclasses
    tol = float(ctx["tol"])
    sub = {k: v for k, v in ctx.items() if k != "tol"}

    def run(dname, wire=None):
        c = dict(sub)
        if wire:
            c["wire"] = wire
        kw2 = dict(kw, dtype=dname)
        if op == "sparse_matmul":
            return _decide_sparse(d, dname, machine, c, kw2)
        if op == "grad":
            return _decide_grad(d, dname, machine, c, kw2)
        if op == "gram":
            return _decide_gram(d, dname, machine, c, kw2)
        return _decide_matvec(d, dname, machine, c, kw2)

    base = run(dtype_name)
    cands = [("f32", base)]
    if tol >= PRECISION_GUARDS["psum8"] and op in ("grad", "gram") \
            and _axes(ctx):
        cands.append(("psum8", run(dtype_name, wire="int8")))
    if tol >= PRECISION_GUARDS["bf16"]:
        cands.append(("bf16", run("bfloat16")))
    if tol >= PRECISION_GUARDS["int8"] and op == "sparse_matmul":
        p8 = run("int8")
        if p8.choice == "bsr":     # only BlockELL data quantizes to int8
            cands.append(("int8", p8))

    floor = max(PRECISION_MIN_SAVINGS_S,
                PRECISION_MIN_SAVINGS_FRAC * base.cost_s)
    label, best = "f32", base
    for lb, p in cands[1:]:
        if base.cost_s - p.cost_s >= floor and p.cost_s < best.cost_s:
            label, best = lb, p

    def _moved(p):
        t = p.terms or {}
        return float(t.get("hbm_bytes", 0.0)) + float(t.get("comm_bytes", 0.0))

    b0, b1 = _moved(base), _moved(best)
    if label == "f32":
        note = (f"precision: f32 — no admissible candidate cleared the "
                f"savings floor max({PRECISION_MIN_SAVINGS_FRAC:.0%}, "
                f"{_us(PRECISION_MIN_SAVINGS_S)}) at tol={tol:g}")
    else:
        saved = 1.0 - b1 / b0 if b0 > 0 else 0.0
        note = (f"precision: {label} — modeled bytes {b0:.4g} -> {b1:.4g} "
                f"({saved:.0%} saved); tol={tol:g} clears guard "
                f"{PRECISION_GUARDS[label]:g}")
    return dataclasses.replace(
        best, precision=label, dtype=dtype_name,
        alternatives=best.alternatives + tuple(
            sorted(((f"precision:{lb}", p.cost_s) for lb, p in cands),
                   key=lambda t: t[1])),
        notes=best.notes + (note,))


def _decide_sparse(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """Per-shard BSR-vs-dense for an (m × n) BlockELL shard with `ell`
    stored blocks per block-row of size `bs`, times an (n × nx) operand
    (nx=1 for SpMV).  The BSR side pays lane/sublane padding on every
    stored block plus a per-block grid step; the dense side streams the
    full m·n at the best-ranked GEMM tiling.  At dtype int8 (the
    quantized-BlockELL candidate of the precision sweep) the BSR side
    also streams one f32 scale per stored block."""
    import dataclasses
    m, n, nx = d["m"], d["n"], max(d.get("nx", 1), 1)
    bsr_dims = {"m": m, "n": n, "nx": nx, "ell": d["ell"]}
    bsr_terms = at.cost_terms("bsr", {"bs": d["bs"]}, bsr_dims, dtype_name)
    if dtype_name == "int8":
        nbr = at._rup(m, d["bs"]) // d["bs"]
        bsr_terms = dataclasses.replace(
            bsr_terms,
            hbm_bytes=bsr_terms.hbm_bytes + nbr * d["ell"] * 4.0)
    bsr_s = machine.time(bsr_terms, dtype_name)
    gemm_dims = {"m": m, "k": n, "n": nx}
    dense_s, dense_blocks = at.rank("gemm", gemm_dims, dtype_name,
                                    machine=machine)[0]
    use_bsr = bsr_s <= dense_s
    chosen_terms = bsr_terms if use_bsr else at.cost_terms(
        "gemm", dense_blocks, gemm_dims, dtype_name)
    return ExecutionPlan(
        op="sparse_matmul", choice="bsr" if use_bsr else "dense",
        blocks={"bs": d["bs"]} if use_bsr else dict(dense_blocks),
        cost_s=min(bsr_s, dense_s),
        breakdown=machine.breakdown(chosen_terms, dtype_name),
        alternatives=tuple(sorted((("bsr", bsr_s), ("dense", dense_s)),
                                  key=lambda t: t[1])),
        notes=(f"stored-block fraction ell/nbc = "
               f"{d['ell'] / max(n // d['bs'], 1):.3f}",),
        terms=_terms_dict(chosen_terms), **kw)


def _decide_grad(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """Fused single-pass gradient vs apply + adjoint for an (m × n) shard.

    The fused side is the best-ranked fusedgrad config (ONE A read, but its
    t/w/z vector strips force lane-aligned row blocks).  The unfused side is
    two independent streaming passes, each priced on its OWN sublane-aligned
    layout — that asymmetry is the real trade: one read vs two, against
    lane-padding waste, so tiny row shards (m ≪ 128) pick unfused.

    With context {"axes": mesh axis sizes} the (f, g) psum is priced too,
    and a column-chunked overlapped schedule competes with the eager body:
    one full pass produces z and the residual, then the gradient is built
    per column segment with each segment's partial psum pipelined behind
    the next segment's compute (an extra read of A buys comm hiding —
    RowMatrix.fused_grad implements blocks["chunks"])."""
    import jax.numpy as jnp
    m, n = d["m"], d["n"]
    db = jnp.dtype(dtype_name).itemsize
    fused_s, fused_blocks = at.rank("fusedgrad", {"m": m, "n": n},
                                    dtype_name, machine=machine)[0]
    mp = at._rup(m, at.sublane(dtype_name))
    np_ = at._rup(n, LANE)
    bm = min(512, mp)
    pass_terms = CostTerms(flops=2.0 * mp * np_,
                           hbm_bytes=(mp * np_ + mp + np_) * db,
                           steps=-(-mp // bm))
    unfused_s = 2.0 * machine.time(pass_terms, dtype_name)
    axes = _axes(ctx)
    if not axes:
        use_fused = fused_s <= unfused_s
        # Breakdown of the CHOSEN side: the fused kernel's terms, or both
        # unfused passes together (2× one pass — max and steps scale alike).
        chosen_terms = at.cost_terms(
            "fusedgrad", fused_blocks, {"m": m, "n": n}, dtype_name) \
            if use_fused else CostTerms(flops=2 * pass_terms.flops,
                                        hbm_bytes=2 * pass_terms.hbm_bytes,
                                        steps=2 * pass_terms.steps)
        return ExecutionPlan(
            op="grad", choice="fused" if use_fused else "unfused",
            blocks=dict(fused_blocks) if use_fused else {},
            cost_s=min(fused_s, unfused_s),
            breakdown=machine.breakdown(chosen_terms, dtype_name),
            alternatives=tuple(sorted((("fused", fused_s),
                                       ("unfused", unfused_s)),
                                      key=lambda t: t[1])),
            notes=("unfused = 2 sublane-padded streaming passes; "
                   "fused = 1 lane-padded pass",),
            terms=_terms_dict(chosen_terms), **kw)

    # Distributed: every alternative ends in a psum of the f32 (g, f)
    # accumulator — (n+1) elements whatever the storage dtype; context
    # {"wire": "int8"} prices the compressed-collective wire format.
    wire = ctx.get("wire")
    coll = _psum_cost(machine, n + 1.0, axes, dtype_name, wire)
    fused_terms = at.cost_terms("fusedgrad", fused_blocks,
                                {"m": m, "n": n}, dtype_name)
    cands = [("fused", 1, fused_s + coll["comm_s"],
              _with_comm(fused_terms, coll))]
    pre = machine.time(pass_terms, dtype_name)
    for c in _chunk_counts(n):
        if c == 1:
            continue
        seg = -(-n // c)
        segp = at._rup(seg, LANE)
        chunk_terms = CostTerms(flops=2.0 * mp * segp,
                                hbm_bytes=(mp * segp + mp + segp) * db,
                                steps=-(-mp // bm))
        cc = _psum_cost(machine, float(seg), axes, dtype_name, wire)
        total = _pipeline_s(machine.time(chunk_terms, dtype_name),
                            cc["comm_s"], c, pre=pre)
        agg = CostTerms(
            flops=pass_terms.flops + c * chunk_terms.flops,
            hbm_bytes=pass_terms.hbm_bytes + c * chunk_terms.hbm_bytes,
            steps=pass_terms.steps + c * chunk_terms.steps,
            comm_bytes=c * cc["comm_bytes"], comm_steps=c * cc["comm_steps"])
        cands.append((f"fused-overlap{c}", c, total, agg))
    unfused_terms = _with_comm(
        CostTerms(flops=2 * pass_terms.flops,
                  hbm_bytes=2 * pass_terms.hbm_bytes,
                  steps=2 * pass_terms.steps), coll)
    cands.append(("unfused", 1, unfused_s + coll["comm_s"], unfused_terms))
    label, chunks, best_s, chosen_terms = min(cands, key=lambda t: t[2])
    use_fused = label != "unfused"
    notes = [f"psum({n}·4B) over axes={axes}: {coll['algorithm']} "
             f"all-reduce, {_us(coll['comm_s'])}"]
    if chunks > 1:
        notes.append(f"overlap: {chunks} column chunks pipeline each "
                     "partial psum behind the next chunk's compute "
                     "(one extra A read)")
    return ExecutionPlan(
        op="grad", choice="fused" if use_fused else "unfused",
        blocks={**dict(fused_blocks), "chunks": chunks} if use_fused else {},
        cost_s=best_s,
        breakdown=machine.breakdown(chosen_terms, dtype_name),
        alternatives=tuple(sorted(((lb, s) for lb, _, s, _ in cands),
                                  key=lambda t: t[1])),
        notes=tuple(notes), terms=_terms_dict(chosen_terms), **kw)


def _decide_gram(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """Distributed AᵀA for an (m × n) row shard: eager tsgram + one n×n
    psum, vs C column-segment cross-grams Aᵀ·A[:, seg] whose n×(n/C)
    partial psums pipeline behind the next segment's compute.  Chunking
    re-reads A once per segment — it wins only when the modeled collective
    time dominates that extra memory traffic (pod-scale meshes), so eager
    stays the dispatch default on small meshes."""
    m, n = d["m"], d["n"]
    gram_s, gram_blocks = at.rank("tsgram", {"m": m, "n": n},
                                  dtype_name, machine=machine)[0]
    axes = _axes(ctx)
    # The psum payload is the f32 accumulator, whatever the operand dtype;
    # context {"wire": "int8"} prices the compressed-collective format.
    wire = ctx.get("wire")
    coll = _psum_cost(machine, float(n) * n, axes, dtype_name, wire)
    gram_terms = at.cost_terms("tsgram", gram_blocks,
                               {"m": m, "n": n}, dtype_name)
    cands = [("eager", 1, gram_s + coll["comm_s"],
              _with_comm(gram_terms, coll))]
    for c in _chunk_counts(n):
        if c == 1:
            continue
        seg = -(-n // c)
        sk_s, sk_blocks = at.rank("randsketch", {"m": m, "n": n, "r": seg},
                                  dtype_name, machine=machine)[0]
        cc = _psum_cost(machine, float(n) * seg, axes, dtype_name, wire)
        total = _pipeline_s(sk_s, cc["comm_s"], c)
        sk_terms = at.cost_terms("randsketch", sk_blocks,
                                 {"m": m, "n": n, "r": seg}, dtype_name)
        agg = CostTerms(flops=c * sk_terms.flops,
                        hbm_bytes=c * sk_terms.hbm_bytes,
                        steps=c * sk_terms.steps, mxu_util=sk_terms.mxu_util,
                        comm_bytes=c * cc["comm_bytes"],
                        comm_steps=c * cc["comm_steps"])
        cands.append((f"overlap{c}", c, total, agg))
    label, chunks, best_s, chosen_terms = min(cands, key=lambda t: t[2])
    notes = [f"psum({n}x{n} f32) over axes={axes}: {coll['algorithm']} "
             f"all-reduce, {_us(coll['comm_s'])}"]
    if chunks > 1:
        notes.append(f"overlap: {chunks} column-segment cross-grams, each "
                     "partial psum hidden behind the next segment's "
                     "compute (A re-read per segment)")
    return ExecutionPlan(
        op="gram", choice="eager" if chunks == 1 else "overlap",
        blocks={"chunks": chunks}, cost_s=best_s,
        breakdown=machine.breakdown(chosen_terms, dtype_name),
        alternatives=tuple(sorted(((lb, s) for lb, _, s, _ in cands),
                                  key=lambda t: t[1])),
        notes=tuple(notes), terms=_terms_dict(chosen_terms), **kw)


def _decide_matvec(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """One streaming pass over an (m × n) row shard plus the reduction of
    its n-vector result (the rmatvec/adjoint psum; context
    {"reduce": False} prices the psum-free row-space matvec).  The choice
    names the reduction the link model picks for this mesh shape and
    payload — ring past the bandwidth break-even, tree under it."""
    import jax.numpy as jnp
    m, n = d["m"], d["n"]
    db = jnp.dtype(dtype_name).itemsize
    mp = at._rup(m, at.sublane(dtype_name))
    np_ = at._rup(n, LANE)
    bm = min(512, mp)
    pass_terms = CostTerms(flops=2.0 * mp * np_,
                           hbm_bytes=(mp * np_ + mp + np_) * db,
                           steps=-(-mp // bm))
    t_pass = machine.time(pass_terms, dtype_name)
    axes = _axes(ctx)
    # The reduced rmatvec result is the f32 accumulator, whatever the
    # storage dtype — n·4 wire bytes.
    payload = n * 4.0 if ctx.get("reduce", True) else 0.0
    if not axes or not payload:
        return ExecutionPlan(
            op="matvec", choice="local", blocks={}, cost_s=t_pass,
            breakdown=machine.breakdown(pass_terms, dtype_name),
            alternatives=(("local", t_pass),),
            notes=("no reduction: result stays shard-resident",),
            terms=_terms_dict(pass_terms), **kw)
    priced = {algo: machine.collective(payload, axes, dtype_name,
                                       algorithm=algo)
              for algo in ("ring", "tree")}
    choice = min(priced, key=lambda a: priced[a]["comm_s"])
    chosen_terms = _with_comm(pass_terms, priced[choice])
    return ExecutionPlan(
        op="matvec", choice=choice, blocks={},
        cost_s=t_pass + priced[choice]["comm_s"],
        breakdown=machine.breakdown(chosen_terms, dtype_name),
        alternatives=tuple(sorted(
            ((a, t_pass + priced[a]["comm_s"]) for a in priced),
            key=lambda t: t[1])),
        notes=(f"psum({n}·4B) over axes={axes}",),
        terms=_terms_dict(chosen_terms), **kw)


def _decide_bsr_bs(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """Block-size selection on the *actual* per-candidate ELL widths
    (context["ell_by_bs"]) — the nnz-only estimate of the "bsr" kernel op
    assumes uniform scatter, which is pessimistic for block-structured
    sparsity.  Used by SparseRowMatrix's bs="auto" constructors."""
    ell_by_bs = {int(k): int(v) for k, v in ctx["ell_by_bs"].items()}
    nx = max(d.get("nx", 1), 1)
    sub = at.sublane(dtype_name)
    scored = []
    for bs in ctx.get("bs_candidates", BS_CANDIDATES):
        if bs % sub or bs not in ell_by_bs:
            continue
        bdims = {"m": at._rup(d["m"], bs), "n": at._rup(d["n"], bs),
                 "nx": nx, "ell": ell_by_bs[bs]}
        scored.append((at.model_time("bsr", {"bs": bs}, bdims, dtype_name,
                                     machine=machine), bs))
    scored.sort()
    best_s, best_bs = scored[0]
    bdims = {"m": at._rup(d["m"], best_bs), "n": at._rup(d["n"], best_bs),
             "nx": nx, "ell": ell_by_bs[best_bs]}
    terms = at.cost_terms("bsr", {"bs": best_bs}, bdims, dtype_name)
    return ExecutionPlan(
        op="bsr_bs", choice=f"bs={best_bs}", blocks={"bs": best_bs},
        cost_s=best_s, breakdown=machine.breakdown(terms, dtype_name),
        alternatives=tuple((f"bs={bs}", s) for s, bs in scored),
        notes=("priced on actual ELL widths, not the uniform-scatter "
               "estimate",), **kw)


def _decide_svd(d, dtype_name, machine, ctx, kw) -> ExecutionPlan:
    """compute_svd mode auto-dispatch (paper §3.1): gram while the n×n Gram
    is a comfortable replicated object, the randomized sketch when A is too
    wide for Gram but k is small, matrix-free Lanczos for everything else
    (and always for sparse operators — matvec cost ∝ nnz, no dense Gram).

    The structural gates decide; the modeled A-pass costs of all three
    modes are attached so explain() shows what each gate saved."""
    import jax.numpy as jnp
    m, n, k = d["m"], d["n"], d["k"]
    kind = ctx.get("kind", "row")
    gram_threshold = int(ctx.get("gram_threshold", GRAM_THRESHOLD))
    rand_k = int(ctx.get("randomized_k_threshold", RANDOMIZED_K_THRESHOLD))
    q = int(ctx.get("power_iters", 2))
    p = int(ctx.get("oversampling", 8))
    db = jnp.dtype(dtype_name).itemsize
    nnz = int(ctx.get("nnz", m * n))
    a_bytes = (nnz if kind == "sparse" else m * n) * db

    # Modeled pass structure per mode (informational; iteration counts are
    # a-priori estimates, not convergence guarantees).
    gram = CostTerms(flops=2.0 * m * n * n, hbm_bytes=a_bytes + n * n * db)
    sketch_passes = 2 + 2 * q
    rand = CostTerms(flops=2.0 * m * n * (k + p) * sketch_passes,
                     hbm_bytes=a_bytes * sketch_passes)
    lanczos_iters = min(max(2 * k + 10, 20), min(m, n))
    lz = CostTerms(flops=4.0 * (nnz if kind == "sparse" else m * n)
                   * lanczos_iters,
                   hbm_bytes=2.0 * a_bytes * lanczos_iters)
    costs = {"gram": machine.time(gram, dtype_name),
             "randomized": machine.time(rand, dtype_name),
             "lanczos": machine.time(lz, dtype_name)}

    notes = []
    if kind == "sparse":
        choice = "lanczos"
        notes.append("sparse operator: matrix-free iteration, no dense Gram")
    elif kind == "row" and n <= gram_threshold:
        choice = "gram"
        notes.append(f"n={n} <= gram_threshold={gram_threshold}: "
                     "one all-reduce + local eigh")
    elif kind == "row" and k <= rand_k:
        choice = "randomized"
        notes.append(f"k={k} <= randomized_k_threshold={rand_k}: "
                     f"{sketch_passes}-pass sketch beats k sequential "
                     "Lanczos directions")
    else:
        choice = "lanczos"
        notes.append("wide + large-k (or no sketch primitives): "
                     "matrix-free Lanczos")
    terms = {"gram": gram, "randomized": rand, "lanczos": lz}[choice]
    return ExecutionPlan(
        op="svd", choice=choice, blocks={}, cost_s=costs[choice],
        breakdown=machine.breakdown(terms, dtype_name),
        alternatives=tuple(sorted(costs.items(), key=lambda t: t[1])),
        notes=tuple(notes), **kw)


# -- calibration plumbing ------------------------------------------------------

def calibration_record(kernel: str, dims: Mapping[str, int],
                       blocks: Mapping[str, int], dtype,
                       measured_s: float) -> dict:
    """One MachineModel.calibrate() record from a measured kernel run:
    the raw roofline terms (efficiency-1 work description) + the wall
    time.  bench_autotune/bench_planner build these from their sweeps."""
    import jax.numpy as jnp
    t = at.cost_terms(kernel, blocks, dims, jnp.dtype(dtype))
    return {"kernel": kernel, "dims": dict(dims), "blocks": dict(blocks),
            "dtype": jnp.dtype(dtype).name, "flops": t.flops,
            "hbm_bytes": t.hbm_bytes, "steps": t.steps,
            "mxu_util": t.mxu_util, "measured_s": float(measured_s)}


def actual_record(plan: ExecutionPlan, measured_s: float) -> dict:
    """One plan-vs-actual record: an ExecutionPlan's modeled cost next to
    a measured wall time.  For kernel ops with block configs the record is
    merged with ``calibration_record()``'s raw roofline terms, so the same
    record that shows drift in ``Result.info["trace"]`` feeds
    ``calibrate()`` unchanged (launch/telemetry.py collects them)."""
    rec = {"op": plan.op, "choice": plan.choice, "dims": dict(plan.dims),
           "dtype": plan.dtype, "backend": plan.backend,
           "modeled_s": float(plan.cost_s),
           "measured_s": float(measured_s),
           "ratio": (float(measured_s) / plan.cost_s
                     if plan.cost_s > 0 else None)}
    if plan.op in KERNEL_OPS and plan.blocks:
        rec.update(calibration_record(plan.op, plan.dims, plan.blocks,
                                      plan.dtype, measured_s))
    elif plan.terms:
        # Distributed decision ops carry their raw terms (including the
        # comm column) on the plan itself — same calibrate() contract.
        rec.update(dict(plan.terms))
    return rec


def calibrate(records, backend: str | None = None, *,
              write: bool = True) -> tuple[MachineModel, float, float]:
    """Fit the backend's machine model to measured records; returns
    (calibrated model, mean relative error before, after).  With
    write=True the fit is persisted next to the autotune cache and every
    subsequent plan() on this backend prefers it."""
    import jax
    backend = backend or jax.default_backend()
    # "before" = the model plan() was actually using for this backend (the
    # v5e reference until a calibration exists); the fit itself starts from
    # the backend's builtin instance so the efficiencies stay interpretable.
    reference = _machine.for_backend(backend, prefer_calibrated=False)
    fitted = _machine.builtin(backend).calibrate(records)
    err_before, err_after = reference.error(records), fitted.error(records)
    if write:
        _machine.save_calibration(backend, fitted)
        # Every memo layer must drop pre-calibration selections — including
        # autotune's get_config memo, whose ranked block configs were priced
        # on the old efficiencies (at.reset clears this cache too).
        at.reset()
    return fitted, err_before, err_after
