"""Solver serving frontend — concurrent matrix jobs, one A-pass per group.

    PYTHONPATH=src python -m repro.launch.serve --requests 16 --m 512 --n 64

The paper prices every iterative method in streaming passes over the
distributed matrix.  A serving deployment amortizes them: when several
clients solve against the SAME design matrix A (multi-user regression,
per-target least squares, one-vs-rest logistic), their iterations can share
each pass.  This module is that frontend:

  * ``SolverServer.submit`` enqueues the ``repro.api`` request objects
    (SolveRequest / SvdRequest / SimilarityRequest) — the exact dataclasses
    the direct call path uses;
  * solve requests sharing (A, loss, param, reg, engine) form a GROUP
    served by one ``GroupRunner``: per-request TFOCS/L-BFGS state is
    batched over the request axis and every solver iteration is ONE fused
    multi-RHS A-pass (kernels/fusedgrad via core/optim/batched), so a
    group of k requests costs the same passes per iteration as one;
  * the serving loop is continuous batching (the vLLM idiom, transplanted
    to solvers): a fixed number of slots per group, requests admitted and
    retired BETWEEN solver iterations by editing slot rows, inactive slots
    frozen by the engines' per-slot masks — no tail latency from waiting
    for the slowest request in a static batch;
  * admission control is planner-priced: ``launch/planner.plan`` prices a
    group's per-iteration device time on the calibrated machine model, and
    the scheduler packs groups into a per-step device-time budget.
    Joining an already-active group is FREE (the same pass serves one more
    right-hand side) — only opening a new group consumes budget.  The
    queue is strictly FIFO: a request that cannot be admitted (budget or
    slots) blocks those behind it, so overload degrades in arrival order.

Batched engines cover the whole Figure-1 family: ``gra`` and ``lbfgs``
share passes directly, and the accelerated variants (``acc``/``acc_rb``)
batch for quadratic losses via the affine u-vector trick (per-slot
u-vectors make the momentum point's gradient free — see
core/optim/batched.make_acc_group).  SVD / similarity requests and
non-batchable solves (escape-hatch problems, non-quadratic accelerated
requests) run as one-shot jobs through the same FIFO queue and budget,
via the same ``repro.api`` executors.

The frontend is hardened for real fleets (see the "fault tolerance &
resumable solves" section of examples/quickstart.py):

  * every GroupRunner drives core/optim/elastic.ElasticGroup, so a server
    built with an ElasticConfig gets straggler detection, mid-solve
    re-meshing and bounded retry-with-backoff per group — and the planner
    re-prices the group on its new shard shape after a re-mesh;
  * per-request ``deadline_s`` / ``max_iters`` degrade gracefully: an
    expired resident is retired with its best iterate, ``converged=False``
    and ``info["degraded"]`` naming the reason, instead of blocking the
    group;
  * ``max_pending`` sheds load at submit with a typed ``api.Overloaded``
    result instead of queueing without bound.

Every answer is a ``repro.api.Result`` whose info carries the standardized
keys; for served solves ``a_passes`` is the number of GROUP passes consumed
while the request was resident — the amortized cost the batching buys down.

Observability (launch/telemetry.py): the server's metrics are ALWAYS live —
typed counters behind the ``stats`` view (including the per-reason
``stats["degraded"]`` breakdown that separates shed/overloaded from
fault-retired from deadline-expired requests), plus ``serve.queue_wait_s``
and ``serve.latency_s`` histograms with real p50/p99.  Scheduler-action
spans (admit / oneshot / retire / shed / recover) and the solver's
per-iteration spans are recorded when the server is constructed while
``telemetry.enable()`` is in effect (or given an explicit ``telemetry=``
recorder); export with ``server.tel.export_chrome_trace(path)``.  See the
"observability" section of examples/quickstart.py.
"""
from __future__ import annotations

import argparse
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.optim import elastic as _elastic
from repro.launch import planner as _planner
from repro.launch import telemetry as _tel

Array = jax.Array

# Engines the group runner batches; everything else is served one-shot.
GROUP_METHODS = _elastic.GROUP_METHODS

# The server's aggregate counters (rendered by SolverServer.stats).
_STAT_KEYS = ("steps", "a_passes", "admitted", "oneshot", "deferred_steps",
              "shed", "expired", "remeshes")


def group_key(req: api.SolveRequest):
    """Requests with equal keys can share fused A-passes: same matrix
    object, same row-separable loss, same static loss scalar, same reg
    KIND (per-slot lam rides in the batched prox), same engine."""
    return (id(req.A), req.loss, float(req.param), req.reg, req.method)


def batchable(req: Any) -> bool:
    # Checkpointed solves run one-shot through the resumable elastic path:
    # their snapshots capture a single request's state, not a shared
    # group's.  Accelerated groups batch via the affine u-vector trick,
    # which only exists for quadratic losses — non-quad acc requests run
    # one-shot.
    return (isinstance(req, api.SolveRequest) and req.problem is None
            and req.smooth is None and req.prox is None
            and req.method in GROUP_METHODS
            and (req.loss == "quad"
                 or req.method not in _elastic.ACC_METHODS)
            and req.checkpoint_dir is None)


class GroupRunner:
    """Continuous-batching executor for one request group.

    Owns `slots` lanes of batched solver state (core/optim/batched) over a
    shared linop; `admit` writes a request into a free lane, `step` runs
    one solver iteration for every active lane in ONE fused group A-pass
    (plus shared backtracking attempts) and returns the lanes that
    finished as `api.Result`s.  Slots freed by retirement are reusable on
    the next admit — the engines freeze inactive lanes bit-for-bit, so
    residents never observe their neighbours churning.
    """

    def __init__(self, linop, kind: str, param: float = 1.0, *,
                 reg: str = "none", method: str = "gra", slots: int = 8,
                 mem: int = 10,
                 elastic: _elastic.ElasticConfig | None = None,
                 telemetry: _tel.Recorder | None = None):
        # All solver state lives in the elastic executor; the runner adds
        # the serving concerns on top (request metadata, deadlines,
        # retirement into api.Results, planner price cache).
        self.tel = telemetry if telemetry is not None else _tel.NULL
        self._eg = _elastic.ElasticGroup(linop, kind, param, reg=reg,
                                         method=method, slots=slots,
                                         mem=mem, elastic=elastic,
                                         telemetry=telemetry)
        self.kind, self.param = kind, param
        self.reg, self.method, self.slots = reg, method, slots
        self.meta: list[dict | None] = [None] * slots
        self._price_cache = 0.0    # planner-modeled seconds per iteration
        self._priced_remeshes = 0  # re-price when the shard shape changes

    # -- delegated solver state (the executor owns it) ------------------------

    @property
    def linop(self):
        return self._eg.linop

    @property
    def state(self):
        return self._eg.state

    @property
    def active(self):
        return self._eg.active

    @property
    def a_passes(self) -> int:
        return self._eg.a_passes

    @property
    def remeshes(self) -> int:
        return self._eg.remeshes

    # -- slot management ------------------------------------------------------

    def free_slots(self) -> int:
        return self._eg.free_slots()

    def busy(self) -> bool:
        return self._eg.busy()

    def admit(self, req: api.SolveRequest) -> int:
        """Write `req` into a free slot; costs no pass by itself (the next
        step's seed recomputes F/G for the whole group in one)."""
        i = self._eg.admit_slot(req.b, lam=float(req.lam),
                                tol=float(req.tol), x0=req.x0,
                                L0=float(req.L0))
        self.meta[i] = {"req": req, "admit_passes": self.a_passes,
                        "deadline_at": (time.monotonic() + req.deadline_s
                                        if req.deadline_s else None)}
        return i

    # -- the iteration --------------------------------------------------------

    def step(self) -> list[api.Result]:
        """One solver iteration for every active slot (one group A-pass plus
        shared backtracking/line-search attempts); returns retired lanes."""
        if not self.busy():
            return []
        out = self._expire_deadlines()
        if not self.busy():
            return out
        try:
            self._eg.step_iteration()
        except (_elastic.TransientShardError,
                _elastic.DeviceLostError) as e:
            # Recovery exhausted (or no re-mesh policy): fail the resident
            # requests gracefully with their best iterates rather than
            # poisoning the serving loop.
            with self.tel.span("serve.recover", error=str(e)):
                for i in range(self.slots):
                    if self.active[i]:
                        out.append(self._retire(i, False, degraded="fault",
                                                error=str(e)))
            return out
        done = np.asarray(self.state.done)
        k = np.asarray(self.state.k)
        for i in range(self.slots):
            if self.active[i] and (
                    done[i] or k[i] >= self.meta[i]["req"].max_iters):
                out.append(self._retire(i, bool(done[i])))
        return out

    def _expire_deadlines(self) -> list[api.Result]:
        """Retire residents whose wall deadline passed — best iterate,
        converged=False, degraded="deadline" — so one slow request cannot
        hold its slot (or block the group) past its budget."""
        if not any(m is not None and m["deadline_at"] is not None
                   for m in self.meta):
            return []
        now = time.monotonic()
        out = []
        for i in range(self.slots):
            m = self.meta[i]
            if self.active[i] and m is not None \
                    and m["deadline_at"] is not None \
                    and now > m["deadline_at"]:
                out.append(self._retire(i, False, degraded="deadline"))
        return out

    def _retire(self, i: int, converged: bool, *,
                degraded: str | None = None,
                error: str | None = None) -> api.Result:
        meta = self.meta[i]
        req = meta["req"]
        if degraded is None and not converged:
            degraded = "max_iterations"
        with self.tel.span("serve.retire", slot=i, converged=converged,
                           degraded=degraded,
                           request_id=req.request_id):
            info = {"iterations": int(self.state.k[i]),
                    # Group passes consumed while resident: the amortized
                    # cost (each pass also served every co-resident
                    # request).
                    "a_passes": self.a_passes - meta["admit_passes"],
                    "converged": converged, "plan": "fused-group",
                    "objective": float(self.state.obj[i]),
                    "slot": i, "degraded": degraded}
            if error is not None:
                info["error"] = error
            # Zero the weight row so the retired lane contributes nothing
            # to subsequent group passes; state rows are reset on the next
            # admit.
            self._eg.clear_slot(i)
            self.meta[i] = None
            return api.Result(x=jnp.asarray(self.state.X[i]), info=info,
                              request_id=req.request_id)


class SolverServer:
    """FIFO request queue + planner-priced admission + continuous batching.

    ``submit`` enqueues any repro.api request; ``step`` admits what the
    per-step device-time budget allows, runs one solver iteration per
    active group, and returns the requests that finished.  ``run`` drives
    steps until the queue and all groups drain.
    """

    def __init__(self, *, slots: int = 8, budget_s: float | None = None,
                 backend: str | None = None,
                 max_pending: int | None = None,
                 elastic_factory=None,
                 telemetry: _tel.Recorder | None = None):
        self.slots = slots
        self.budget_s = budget_s
        self.backend = backend
        # Load-shedding bound: submits past this queue depth are refused
        # with a typed api.Overloaded result instead of queueing unboundedly.
        self.max_pending = max_pending
        # () -> core.optim.elastic.ElasticConfig, called once per group so
        # each runner gets its own monitor/checkpoint instances.
        self.elastic_factory = elastic_factory
        # Metrics are always on (a private spanless recorder renders the
        # `stats` view); spans ride along when the server is built under
        # telemetry.enable() or given an explicit recorder.
        if telemetry is not None:
            self.tel = telemetry
        else:
            cur = _tel.current()
            self.tel = cur if cur.enabled else _tel.Recorder(spans=False)
        self._c = {k: self.tel.counter("serve." + k) for k in _STAT_KEYS}
        self._h_wait = self.tel.histogram("serve.queue_wait_s")
        self._h_latency = self.tel.histogram("serve.latency_s")
        self._queue: list[Any] = []
        self._runners: dict[Any, GroupRunner] = {}
        self._results: dict[str, api.Result] = {}
        self._submit_t: dict[str, float] = {}
        self._events: list[tuple[str, float, float]] = []

    @property
    def stats(self) -> dict:
        """Aggregate server statistics, rendered from the typed telemetry
        counters (same keys the old ad-hoc dict carried), plus the
        per-reason ``degraded`` breakdown that distinguishes
        shed/overloaded from fault-retired from deadline-expired requests
        — previously all invisible in aggregate."""
        s = {k: c.value for k, c in self._c.items()}
        s["degraded"] = {
            lbl.split("=", 1)[1]: v
            for lbl, v in self.tel.counters("serve.degraded").items()
            if "=" in lbl}
        return s

    # -- queue ----------------------------------------------------------------

    def submit(self, req) -> str:
        if isinstance(req, api.SolveRequest) and req.problem is None \
                and req.smooth is None and req.method == "lbfgs" \
                and req.reg != "none":
            raise ValueError("method='lbfgs' needs reg='none'")
        if self.max_pending is not None \
                and len(self._queue) >= self.max_pending:
            with self.tel.span("serve.shed", request_id=req.request_id,
                               pending=len(self._queue)):
                self._submit_t[req.request_id] = time.perf_counter()
                self._finish(api.Overloaded(request_id=req.request_id))
                self._c["shed"].inc()
            return req.request_id
        self._queue.append(req)
        self._submit_t[req.request_id] = time.perf_counter()
        return req.request_id

    def pending(self) -> int:
        return len(self._queue)

    def result(self, request_id: str) -> api.Result | None:
        return self._results.get(request_id)

    def latencies(self) -> list[float]:
        """Per-request submit→finish wall seconds, in completion order."""
        return [t1 - t0 for _, t0, t1 in self._events]

    # -- planner pricing ------------------------------------------------------

    def _price(self, req) -> float:
        """Modeled device-seconds: per-ITERATION for a group (one fused
        pass — independent of how many requests share it), whole-job for
        one-shots."""
        if isinstance(req, api.SolveRequest):
            m, n = (req.problem.linop.out_shape[0],
                    req.problem.linop.in_shape[0]) if req.problem is not None \
                else req.A.shape
            return _planner.plan("fusedgrad", {"m": int(m), "n": int(n)},
                                 backend=self.backend).cost_s
        if isinstance(req, api.SvdRequest):
            m, n = req.A.shape
            return _planner.plan("svd", {"m": int(m), "n": int(n),
                                         "k": int(req.k)},
                                 backend=self.backend).cost_s
        # Similarity: the Gram pass is the whole job — price it as the
        # gram-mode SVD of the same matrix minus nothing material.
        m, n = req.A.shape
        return _planner.plan("svd", {"m": int(m), "n": int(n), "k": 1},
                             backend=self.backend).cost_s

    def _active_cost(self) -> float:
        return sum(r._price_cache for r in self._runners.values()
                   if r.busy())

    # -- scheduling -----------------------------------------------------------

    def _admit(self) -> list[api.Result]:
        """FIFO admission under the device-time budget.  Joining an active
        group is free; opening a group (or running a one-shot) consumes
        budget.  The head of the queue blocks everything behind it — strict
        arrival-order degradation under overload.  When nothing is spending
        budget the head is always admitted, so a budget smaller than one
        group's iteration cannot deadlock the queue.  Returns the results
        of any one-shot jobs it ran."""
        done = []
        spent = self._active_cost()
        while self._queue:
            req = self._queue[0]
            expired = self._expire_queued(req)
            if expired is not None:
                self._queue.pop(0)
                self._finish(expired)
                done.append(expired)
                continue
            if batchable(req):
                key = group_key(req)
                runner = self._runners.get(key)
                if runner is not None and runner.busy():
                    if runner.free_slots() == 0:
                        break                      # group full → wait
                    with self.tel.span("serve.admit", mode="join",
                                       request_id=req.request_id):
                        runner.admit(req)          # marginal cost: zero
                else:
                    cost = self._price(req)
                    if self.budget_s is not None and spent > 0 \
                            and spent + cost > self.budget_s:
                        break                      # no budget → wait
                    with self.tel.span("serve.admit", mode="open",
                                       request_id=req.request_id):
                        if runner is None:
                            runner = GroupRunner(
                                api.solve_linop(req), req.loss, req.param,
                                reg=req.reg, method=req.method,
                                slots=self.slots,
                                elastic=(self.elastic_factory()
                                         if self.elastic_factory else None),
                                telemetry=self.tel)
                            runner._price_cache = cost
                            self._runners[key] = runner
                        runner.admit(req)
                    spent += cost
                self._c["admitted"].inc()
                self._observe_wait(req)
                self._queue.pop(0)
            else:
                cost = self._price(req)
                if self.budget_s is not None and spent > 0 \
                        and spent + cost > self.budget_s:
                    break
                self._queue.pop(0)
                self._observe_wait(req)
                with self.tel.span("serve.oneshot",
                                   request_id=req.request_id):
                    res = self._run_oneshot(req)
                self._finish(res)
                done.append(res)
                spent += cost
                self._c["oneshot"].inc()
        return done

    def _observe_wait(self, req) -> None:
        """Queue-wait histogram: submit→dequeue, observed at admission."""
        t0 = self._submit_t.get(req.request_id)
        if t0 is not None:
            self._h_wait.observe(time.perf_counter() - t0)

    def _expire_queued(self, req) -> api.Result | None:
        """Dequeue-time deadline check for one-shot jobs: a request whose
        wall budget was burnt WAITING in the queue is answered degraded
        immediately instead of spending device time on an answer its
        client has already abandoned."""
        deadline = getattr(req, "deadline_s", None)
        if deadline is None:
            return None
        t0 = self._submit_t.get(req.request_id)
        if t0 is None or time.perf_counter() - t0 <= deadline:
            return None
        self._c["expired"].inc()
        return api.Result(
            x=None, info={"iterations": 0, "a_passes": 0,
                          "converged": False, "plan": "expired",
                          "degraded": "deadline"},
            request_id=req.request_id)

    def _run_oneshot(self, req) -> api.Result:
        if isinstance(req, api.SolveRequest):
            return api.solve(req)
        if isinstance(req, api.SvdRequest):
            return api.svd(req)
        return api.similarities(req)

    def _finish(self, res: api.Result) -> None:
        self._results[res.request_id] = res
        t0 = self._submit_t.get(res.request_id, time.perf_counter())
        t1 = time.perf_counter()
        self._events.append((res.request_id, t0, t1))
        self._h_latency.observe(t1 - t0)
        reason = res.info.get("degraded") \
            if isinstance(res.info, dict) else None
        if reason:
            # Per-reason retirement accounting: "overloaded" (shed),
            # "fault", "deadline" and "max_iterations" each count apart,
            # so aggregate stats can tell load-shedding from failures.
            self.tel.counter("serve.degraded", reason=reason).inc()

    # -- the serving loop -----------------------------------------------------

    def step(self) -> list[api.Result]:
        """One scheduler tick: admit, then one solver iteration per active
        group; returns the requests that completed this tick."""
        self._c["steps"].inc()
        out = self._admit()
        if self._queue:
            self._c["deferred_steps"].inc()
        for runner in self._runners.values():
            if runner.busy():
                before = runner.a_passes
                out.extend(runner.step())
                self._c["a_passes"].inc(runner.a_passes - before)
                if runner.remeshes != runner._priced_remeshes:
                    # A mid-solve re-mesh changed the shard shape (and the
                    # padded row count with it): re-price the group so the
                    # admission budget sees the post-failure cost.
                    self._c["remeshes"].inc(runner.remeshes
                                            - runner._priced_remeshes)
                    runner._priced_remeshes = runner.remeshes
                    runner._price_cache = _planner.plan(
                        "fusedgrad", {"m": int(runner._eg.m_pad),
                                      "n": int(runner._eg.n)},
                        backend=self.backend).cost_s
        for res in out:
            self._finish(res)
        return out

    def busy(self) -> bool:
        return bool(self._queue) or any(r.busy()
                                        for r in self._runners.values())

    def run(self, max_steps: int = 100_000) -> list[api.Result]:
        out = []
        while self.busy() and self._c["steps"].value < max_steps:
            out.extend(self.step())
        return out


# -- demo CLI -----------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--budget-us", type=float, default=None,
                    help="per-step device-time budget (modeled µs)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    A = rng.normal(size=(args.m, args.n)).astype(np.float32)
    server = SolverServer(
        slots=args.slots,
        budget_s=args.budget_us * 1e-6 if args.budget_us else None)
    t0 = time.perf_counter()
    ids = [server.submit(api.SolveRequest(
        A=A, b=(A @ rng.normal(size=args.n)).astype(np.float32),
        loss="quad", method="gra", tol=1e-6, max_iters=200))
        for _ in range(args.requests)]
    results = server.run()
    wall = time.perf_counter() - t0
    lats = sorted(server.latencies())
    print(f"served {len(results)} requests in {wall:.3f}s "
          f"({len(results) / wall:.1f} req/s)")
    print(f"group A-passes: {server.stats['a_passes']} "
          f"(scheduler steps: {server.stats['steps']})")
    print(f"latency p50 {lats[len(lats) // 2] * 1e3:.1f}ms  "
          f"p99 {lats[int(len(lats) * 0.99)] * 1e3:.1f}ms")
    for rid in ids[:3]:
        info = server.result(rid).info
        print(f"  {rid}: iters={info['iterations']} "
              f"a_passes={info['a_passes']} converged={info['converged']}")


if __name__ == "__main__":
    main()
