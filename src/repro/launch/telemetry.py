"""Runtime telemetry — spans, metrics, and plan-vs-actual tracing.

The planner (launch/planner.py) predicts where time goes; this module
records where it actually went.  Gittens et al. and Dünner et al.
(PAPERS.md) both built their Spark analyses on exactly this kind of
instrumentation — per-phase compute-vs-communication breakdowns feeding a
calibrated performance model — and ``MachineModel.calibrate()`` closes the
same loop here: every traced solve emits plan-vs-actual records that
``planner.calibrate()`` accepts directly.

Zero-dependency (stdlib only at import; jax is imported lazily for the
optional device sync), three layers:

  * **Spans** — nestable, thread-safe wall-clock intervals around every
    elastic-solver iteration phase (fused A-pass, seed pass, host
    validation, checkpoint write, re-mesh/re-JIT) and every server
    scheduler action (admit, join, retire, shed, recover).  ``sync_on()``
    blocks on a device payload before the span closes so the recorded
    duration covers the device work, not just the dispatch.

  * **Metrics** — a registry of counters, gauges and histograms with FIXED
    log-spaced buckets (two histograms are always mergeable/comparable),
    giving the server real p50/p99 queue-wait and solve latency, per-reason
    ``degraded`` counters, fault/retry/remesh counters, and checkpoint
    write-duration/backlog gauges.

  * **Plan-vs-actual** — ``record_plan_actual(plan, measured_s)`` attaches
    the modeled cost of an ``ExecutionPlan`` to its measured wall time; for
    kernel ops the record carries the raw roofline terms, so
    ``calibration_records()`` feeds straight into ``planner.calibrate()``
    and modeled-vs-measured drift is visible in ``Result.info["trace"]``.

Exporters: ``snapshot()`` (in-memory, JSON-safe), ``export_jsonl(path)``
(one event per line), and ``export_chrome_trace(path)`` (Chrome/Perfetto
``traceEvents`` — load in https://ui.perfetto.dev for the span timeline).

Everything is OFF by default with near-zero overhead: the module-level
recorder is a ``NullRecorder`` whose ``span()`` returns one shared no-op
context manager and whose metric handles do nothing.  Components resolve
``current()`` at call time, so

    rec = telemetry.enable()           # or: with telemetry.recording() as rec
    ... run solves / serve requests ...
    rec.snapshot(); rec.export_chrome_trace("trace.json")

instruments the whole stack without threading a recorder through every
constructor (explicit ``telemetry=`` parameters on the api request objects
and SolverServer override the module default).  See the "observability"
section of examples/quickstart.py for the walkthrough.
"""
from __future__ import annotations

import contextlib
import json
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

__all__ = [
    "Counter", "Gauge", "Histogram", "Recorder", "NullRecorder", "Span",
    "Timing", "current", "enable", "disable", "recording", "timeit",
    "HIST_BOUNDS",
]


# -- fixed log-spaced histogram buckets ---------------------------------------
# 1 µs … ~1100 s in ×2 steps.  Fixed bounds (not per-instance) so any two
# histograms — a live server's and a benchmark's — merge and compare
# bucket-for-bucket.  Out-of-range observations clamp into the edge buckets.
HIST_MIN = 1e-6
HIST_FACTOR = 2.0
HIST_BUCKETS = 31
HIST_BOUNDS = tuple(HIST_MIN * HIST_FACTOR ** i for i in range(HIST_BUCKETS))
_LOG_MIN = math.log(HIST_MIN)
_LOG_FACTOR = math.log(HIST_FACTOR)


def _bucket_index(v: float) -> int:
    if v <= HIST_MIN:
        return 0
    i = int((math.log(v) - _LOG_MIN) / _LOG_FACTOR)
    return min(max(i, 0), HIST_BUCKETS - 1)


def _label_key(name: str, labels: Mapping[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone event count (thread-safe)."""
    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: Mapping[str, Any]):
        self.name, self.labels = name, dict(labels)
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> int:
        with self._lock:
            self.value += n
            return self.value


class Gauge:
    """Last-write-wins level (thread-safe enough: float stores are atomic)."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Mapping[str, Any]):
        self.name, self.labels = name, dict(labels)
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log-spaced-bucket histogram of seconds (thread-safe).

    Percentiles interpolate inside the chosen bucket geometrically and are
    clamped to the observed [min, max], so a histogram fed one constant
    value reports that value at every quantile.
    """
    __slots__ = ("name", "labels", "counts", "count", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str, labels: Mapping[str, Any]):
        self.name, self.labels = name, dict(labels)
        self.counts = [0] * HIST_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[_bucket_index(v)] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) from the bucket counts."""
        if self.count == 0:
            return float("nan")
        target = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo = HIST_BOUNDS[i]
                hi = lo * HIST_FACTOR
                frac = min(max((target - seen) / c, 0.0), 1.0)
                v = lo * (hi / lo) ** frac          # geometric interpolation
                return min(max(v, self.min), self.max)
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {"count": self.count, "sum_s": self.sum,
                "min_s": self.min if self.count else None,
                "max_s": self.max if self.count else None,
                "mean_s": (self.sum / self.count) if self.count else None,
                "p50_s": self.percentile(0.50) if self.count else None,
                "p90_s": self.percentile(0.90) if self.count else None,
                "p99_s": self.percentile(0.99) if self.count else None}


# -- spans --------------------------------------------------------------------

@dataclass
class Span:
    """One closed interval on one thread's span stack."""
    id: int
    parent: int | None
    name: str
    tid: int
    t_start_s: float            # seconds since the recorder's epoch
    dur_s: float = 0.0
    attrs: dict = field(default_factory=dict)


class _SpanCtx:
    """Context manager for one span; created by Recorder.span()."""
    __slots__ = ("_rec", "_span", "_t0", "_payload")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self._rec = rec
        self._payload = None
        tid = threading.get_ident()
        stack = rec._stack()
        parent = stack[-1] if stack else None
        self._span = Span(id=rec._next_id(), parent=parent, name=name,
                          tid=tid, t_start_s=0.0, attrs=attrs)

    def annotate(self, **attrs) -> "_SpanCtx":
        self._span.attrs.update(attrs)
        return self

    def sync_on(self, payload) -> "_SpanCtx":
        """Block on `payload` (any jax pytree) before the span closes, so
        the duration covers the device work the span launched."""
        self._payload = payload
        return self

    @property
    def dur_s(self) -> float:
        return self._span.dur_s

    def __enter__(self) -> "_SpanCtx":
        self._rec._stack().append(self._span.id)
        self._t0 = time.perf_counter()
        self._span.t_start_s = self._t0 - self._rec.epoch
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._payload is not None:
            _block_until_ready(self._payload)
        self._span.dur_s = time.perf_counter() - self._t0
        stack = self._rec._stack()
        if stack and stack[-1] == self._span.id:
            stack.pop()
        if exc_type is not None:
            self._span.attrs["error"] = f"{exc_type.__name__}: {exc}" \
                if exc is not None else exc_type.__name__
        self._rec._commit(self._span)


def _block_until_ready(payload) -> None:
    try:
        import jax
        jax.block_until_ready(payload)
    except ImportError:  # pragma: no cover - jax is always present here
        pass


class _NullSpanCtx:
    """Shared no-op span: one module-level instance, zero allocation on the
    disabled path."""
    __slots__ = ()
    dur_s = 0.0

    def annotate(self, **attrs):
        return self

    def sync_on(self, payload):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None


class _NullMetric:
    """Shared no-op counter/gauge/histogram."""
    __slots__ = ()
    value = 0
    count = 0

    def inc(self, n: int = 1) -> int:
        return 0

    def set(self, v: float) -> None:
        return None

    def observe(self, v: float) -> None:
        return None

    def percentile(self, q: float) -> float:
        return float("nan")

    def snapshot(self) -> dict:
        return {}


_NULL_SPAN = _NullSpanCtx()
_NULL_METRIC = _NullMetric()


# -- the recorder -------------------------------------------------------------

class Recorder:
    """One telemetry sink: spans + metrics registry + plan-vs-actual log.

    ``spans=False`` keeps the metrics registry live but makes ``span()``
    return the shared no-op context — the mode SolverServer uses for its
    always-on counters.  ``max_spans`` bounds memory on long-lived
    recorders: past it, new spans are dropped and counted in
    ``spans_dropped``.
    """
    enabled = True

    def __init__(self, *, spans: bool = True, max_spans: int = 100_000):
        self.record_spans = spans
        self.max_spans = int(max_spans)
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()
        self.spans: list[Span] = []
        self.spans_dropped = 0
        self._metrics: dict[str, Any] = {}
        self._plan_actual: list[dict] = []
        self._lock = threading.Lock()
        self._ids = iter(range(1, 1 << 62)).__next__
        self._local = threading.local()

    # -- span plumbing --------------------------------------------------------

    def _stack(self) -> list[int]:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _next_id(self) -> int:
        with self._lock:
            return self._ids()

    def _commit(self, span: Span) -> None:
        with self._lock:
            if len(self.spans) >= self.max_spans:
                self.spans_dropped += 1
                return
            self.spans.append(span)

    def span(self, name: str, **attrs):
        """Open a nested span; use as ``with rec.span("phase") as sp:``."""
        if not self.record_spans:
            return _NULL_SPAN
        return _SpanCtx(self, name, attrs)

    # -- metrics registry -----------------------------------------------------

    def _metric(self, cls, name: str, labels: Mapping[str, Any]):
        key = _label_key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(key, cls(name, labels))
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._metric(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._metric(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._metric(Histogram, name, labels)

    def counters(self, name: str) -> dict[str, int]:
        """{label-suffix: value} for every counter named `name` (the
        per-reason breakdown view, e.g. ``counters("serve.degraded")``)."""
        out = {}
        for m in list(self._metrics.values()):
            if isinstance(m, Counter) and m.name == name:
                lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
                out[lbl or "total"] = m.value
        return out

    # -- plan-vs-actual -------------------------------------------------------

    def record_plan_actual(self, plan, measured_s: float, **attrs) -> dict:
        """Attach a measured wall time to an ExecutionPlan.  The stored
        record carries op/choice/modeled/measured/ratio (drift is
        ``ratio``), plus — for kernel ops — the raw roofline terms, so it
        feeds ``planner.calibrate()`` unchanged."""
        from repro.launch import planner as _planner
        rec = _planner.actual_record(plan, measured_s)
        rec.update(attrs)
        with self._lock:
            self._plan_actual.append(rec)
        return rec

    def plan_actual(self) -> list[dict]:
        with self._lock:
            return list(self._plan_actual)

    def calibration_records(self) -> list[dict]:
        """The plan-vs-actual records that carry raw roofline terms — the
        exact shape ``planner.calibrate()`` / ``MachineModel.calibrate()``
        consume."""
        return [r for r in self.plan_actual() if "flops" in r]

    # -- exporters ------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe point-in-time view of every metric + span/record
        counts."""
        counters, gauges, hists = {}, {}, {}
        for key, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                counters[key] = m.value
            elif isinstance(m, Gauge):
                gauges[key] = None if math.isnan(m.value) else m.value
            else:
                hists[key] = m.snapshot()
        return {"counters": counters, "gauges": gauges,
                "histograms": hists, "spans": len(self.spans),
                "spans_dropped": self.spans_dropped,
                "plan_actual_records": len(self._plan_actual)}

    def summary(self) -> dict:
        """Compact per-solve digest for ``Result.info["trace"]``: total
        time per span phase plus the plan-vs-actual drift per op."""
        phases: dict[str, dict] = {}
        with self._lock:
            spans = list(self.spans)
            pa = list(self._plan_actual)
        for s in spans:
            p = phases.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                           "max_s": 0.0})
            p["count"] += 1
            p["total_s"] += s.dur_s
            p["max_s"] = max(p["max_s"], s.dur_s)
        drift: dict[str, dict] = {}
        for r in pa:
            d = drift.setdefault(r["op"], {"records": 0, "modeled_s": 0.0,
                                           "measured_s": 0.0})
            d["records"] += 1
            d["modeled_s"] += r["modeled_s"]
            d["measured_s"] += r["measured_s"]
        for d in drift.values():
            d["ratio"] = (d["measured_s"] / d["modeled_s"]
                          if d["modeled_s"] > 0 else None)
        return {"spans": len(spans), "phases": phases,
                "plan_vs_actual": drift,
                "counters": {k: v for k, v in
                             self.snapshot()["counters"].items()}}

    def events(self) -> list[dict]:
        """Every recorded event as a JSON-safe dict (the JSONL payload)."""
        out = []
        with self._lock:
            spans = list(self.spans)
            pa = list(self._plan_actual)
        for s in spans:
            out.append({"type": "span", "id": s.id, "parent": s.parent,
                        "name": s.name, "tid": s.tid,
                        "t_start_s": s.t_start_s, "dur_s": s.dur_s,
                        "attrs": s.attrs})
        for r in pa:
            out.append(dict(r, type="plan_actual"))
        snap = self.snapshot()
        for kind in ("counters", "gauges"):
            for key, v in snap[kind].items():
                out.append({"type": kind[:-1], "key": key, "value": v})
        for key, h in snap["histograms"].items():
            out.append(dict(h, type="histogram", key=key))
        return out

    def export_jsonl(self, path) -> int:
        """Write one JSON event per line; returns the event count."""
        evs = self.events()
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e, default=_json_default) + "\n")
        return len(evs)

    def chrome_trace(self) -> dict:
        """Chrome/Perfetto ``traceEvents`` document of the span timeline
        (complete "X" events, µs timebase; one row per thread)."""
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "repro solver"}}]
        tids = {}
        with self._lock:
            spans = list(self.spans)
        for s in spans:
            tid = tids.setdefault(s.tid, len(tids))
            events.append({
                "name": s.name, "ph": "X", "pid": 0, "tid": tid,
                "ts": round(s.t_start_s * 1e6, 3),
                "dur": round(s.dur_s * 1e6, 3),
                "args": {k: _json_safe(v) for k, v in s.attrs.items()}})
        for real_tid, tid in tids.items():
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid,
                           "args": {"name": f"thread-{real_tid}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"epoch_unix_s": self.epoch_unix}}

    def export_chrome_trace(self, path) -> int:
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.spans_dropped = 0
            self._metrics.clear()
            self._plan_actual.clear()


def _json_safe(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def _json_default(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class NullRecorder(Recorder):
    """The disabled default: every operation is a no-op returning shared
    singletons — the near-zero-overhead path the escape hatches buy out
    of."""
    enabled = False

    def __init__(self):
        super().__init__(spans=False, max_spans=0)

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def counter(self, name: str, **labels):
        return _NULL_METRIC

    def gauge(self, name: str, **labels):
        return _NULL_METRIC

    def histogram(self, name: str, **labels):
        return _NULL_METRIC

    def record_plan_actual(self, plan, measured_s: float, **attrs) -> dict:
        return {}


NULL = NullRecorder()
_current: Recorder = NULL


def current() -> Recorder:
    """The active module-level recorder (a NullRecorder unless enabled)."""
    return _current


def enable(recorder: Recorder | None = None) -> Recorder:
    """Install `recorder` (or a fresh one) as the module default; every
    component that resolves ``current()`` starts recording into it."""
    global _current
    _current = recorder if recorder is not None else Recorder()
    return _current


def disable() -> None:
    global _current
    _current = NULL


@contextlib.contextmanager
def recording(recorder: Recorder | None = None):
    """Scoped enable(): installs a recorder for the body, restores the
    previous one after — the api-level ``telemetry=`` escape hatch uses
    this so one traced request never leaks instrumentation into the
    next."""
    global _current
    prev = _current
    rec = recorder if recorder is not None else Recorder()
    _current = rec
    try:
        yield rec
    finally:
        _current = prev


# -- the shared timing helper -------------------------------------------------

@dataclass
class Timing:
    """Warm repeated-call timing: the one measurement path shared by the
    benchmarks' BENCH json and the live metrics (same block-until-ready
    discipline, same statistics)."""
    times: list[float]

    @property
    def median_s(self) -> float:
        s = sorted(self.times)
        return s[len(s) // 2]

    @property
    def mean_s(self) -> float:
        return sum(self.times) / len(self.times)

    @property
    def min_s(self) -> float:
        return min(self.times)

    @property
    def mean_us(self) -> float:
        return self.mean_s * 1e6

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6


def timeit(fn: Callable[[], Any], *, reps: int = 3, warmup: int = 1,
           hist: Histogram | None = None) -> Timing:
    """Time ``fn()`` over `reps` warm calls (after `warmup` compile-eating
    calls), blocking on each call's result so async dispatch doesn't leak
    between reps.  Every benchmark timing loop routes through here; pass
    ``hist=`` to additionally feed a live histogram so offline BENCH
    numbers and online metrics share one measurement path."""
    for _ in range(warmup):
        _block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block_until_ready(fn())
        dt = time.perf_counter() - t0
        times.append(dt)
        if hist is not None:
            hist.observe(dt)
    return Timing(times=times)
