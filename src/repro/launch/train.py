"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --optimizer adamw --ckpt-dir /tmp/ckpt

Runs for real on whatever devices exist (CPU smoke configs included),
with the full production substrate engaged: deterministic data pipeline,
grad accumulation, checkpoint/restart (resumable via --resume), straggler
monitoring, and the paper's optimizers as selectable trainers.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data import pipeline as dp
from repro.launch.mesh import make_host_mesh
from repro.models import build, smoke_config
from repro.models.sharding import use_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.straggler import StepMonitor, StragglerConfig
from repro.train.train_step import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgdm", "acc_rb", "lbfgs"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", type=int, default=1, help="mesh data dim")
    ap.add_argument("--model", type=int, default=1, help="mesh model dim")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(args.data, args.model)

    with mesh, use_mesh(mesh):
        model = build(cfg)
        ocfg = opt_mod.OptimizerConfig(name=args.optimizer, lr=args.lr,
                                       warmup_steps=max(args.steps // 10, 1),
                                       total_steps=args.steps)
        opt_init, opt_update = opt_mod.make_optimizer(ocfg)
        step_fn = jax.jit(build_train_step(
            model, opt_update, microbatches=args.microbatches),
            donate_argnums=(0, 1))

        dc = dp.from_model(cfg, args.global_batch, args.seq_len)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt_init(params)
        start = 0

        _, specs = model.specs()
        if args.resume and args.ckpt_dir:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                (params, opt_state), extra = ckpt.restore(
                    args.ckpt_dir, (params, opt_state), mesh=mesh)
                start = extra["data_step"]
                print(f"resumed from step {start}")

        saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir \
            else None
        monitor = StepMonitor(StragglerConfig())
        batch_fn = jax.jit(lambda s: dp.in_graph_batch(dc, s))

        for step in range(start, args.steps):
            monitor.start()
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            verdict = monitor.stop()
            flag = " [straggler]" if verdict["flagged"] else ""
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics.get('grad_norm', 0):.3f} "
                  f"dt={verdict['dt']*1e3:.0f}ms{flag}")
            if saver and (step + 1) % args.ckpt_every == 0:
                saver.save_async(step + 1, (params, opt_state),
                                 extra={"data_step": step + 1})
        if saver:
            saver.save_async(args.steps, (params, opt_state),
                             extra={"data_step": args.steps})
            saver.wait()
            print(f"checkpoint committed at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
