"""Per-(arch × shape × mesh) lowering plans.

`make_plan` assembles everything the dry-run needs: the step function
(train / prefill / decode), ShapeDtypeStruct stand-ins for every input with
their NamedShardings attached (no allocation — the 671B config lowers on a
CPU container), and workload metadata for the roofline.

Sharding policy (defaults; §Perf iterates on these):
  * params: TP specs from the model; FSDP (extra data-axis sharding of the
    largest free dim) switched on automatically when the replicated-over-dp
    footprint would not fit HBM;
  * optimizer state: ZeRO-1 (sharded over data axes) always;
  * batch: sharded over (pod, data); decode cells with global_batch <
    dp_size shard the KV cache *sequence* dim instead (single-stream
    long-context decode).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.models import build
from repro.models.sharding import use_mesh, batch_axes
from repro.data import pipeline as data_pipeline
from repro.train import optimizer as opt_mod
from repro.train.train_step import build_train_step

Array = jax.Array

HBM_BYTES = 16e9           # v5e
FSDP_PARAM_THRESHOLD = 6e9  # bytes/device above which params go FSDP


def _sds(shape_dtype, sharding):
    return jax.ShapeDtypeStruct(shape_dtype.shape, shape_dtype.dtype,
                                sharding=sharding)


def _tree_sds(shapes, specs, mesh):
    return jax.tree.map(
        lambda sd, sp: _sds(sd, NamedSharding(mesh, sp)), shapes, specs,
        is_leaf=lambda v: isinstance(v, P) or hasattr(v, "shape"))


def _fsdp_specs(shapes, specs, mesh):
    """Shard the largest None-dim of each leaf over the data axes."""
    ba = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in ba]))

    def leaf(sd, sp):
        full = list(sp) + [None] * (len(sd.shape) - len(sp))
        used = {a for s in full if s is not None
                for a in (s if isinstance(s, tuple) else (s,))}
        if any(a in used for a in ba):
            return P(*full)            # already dp-sharded (e.g. moe_2d)
        best, best_dim = -1, -1
        for i, (dim, s) in enumerate(zip(sd.shape, full)):
            if s is None and dim % dp == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0:
            full[best] = ba
        return P(*full)

    return jax.tree.map(leaf, shapes, specs,
                        is_leaf=lambda v: isinstance(v, P))


def _param_bytes(shapes) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(shapes))


def _count_params(shapes, cfg) -> tuple[int, int]:
    """(total, active) parameter counts (active discounts routed experts)."""
    total = active = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.moe and "ffn" in keys and any(
                k in ("w_gate", "w_up", "w_down") for k in keys) \
                and len(leaf.shape) == 4:
            active += int(n * cfg.moe.top_k / cfg.moe.num_experts)
        else:
            active += n
    return total, active


def _seq_shard_caches(shapes, specs, mesh):
    """long_500k: batch=1 → shard cache sequence dim over the data axes."""
    ba = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in ba]))

    def leaf(sd, sp):
        full = list(sp) + [None] * (len(sd.shape) - len(sp))
        # drop batch-axes sharding (batch dim is 1)
        out = [None if (isinstance(s, tuple) or (isinstance(s, str)
                        and s in ba)) else s for s in full]
        # shard the largest remaining free dim (the sequence) instead
        cands = [i for i, (dim, s) in enumerate(zip(sd.shape, out))
                 if s is None and dim % dp == 0 and dim >= dp]
        if cands:
            i = max(cands, key=lambda j: sd.shape[j])
            out[i] = ba
        return P(*out)

    return jax.tree.map(leaf, shapes, specs,
                        is_leaf=lambda v: isinstance(v, P))


@dataclasses.dataclass
class Plan:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple
    donate: tuple
    mesh: Mesh
    meta: dict

    def lower(self):
        with self.mesh, use_mesh(self.mesh):
            return jax.jit(self.fn, donate_argnums=self.donate).lower(
                *self.args)


def make_plan(arch: str, shape_name: str, mesh: Mesh, *,
              microbatches: int | None = None, fsdp: bool | None = None,
              zero1: bool = True, moment_dtype: str | None = None,
              optimizer: str = "adamw",
              overrides: dict | None = None) -> Plan:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape.kind != "train":
        cfg = cfg.scaled(remat="none")   # no backward pass → never remat
    if overrides:
        overrides = dict(overrides)
        ssm_chunk = overrides.pop("ssm_chunk", None)
        if ssm_chunk and cfg.ssm:
            cfg = cfg.scaled(ssm=dataclasses.replace(cfg.ssm,
                                                     chunk=int(ssm_chunk)))
        cfg = cfg.scaled(**overrides)
    runs, why = applicable(cfg, shape_name)
    if not runs:
        raise ValueError(f"{arch} × {shape_name} skipped: {why}")

    with use_mesh(mesh):
        model = build(cfg)
        p_shapes, p_specs = model.specs()
        ba = batch_axes(mesh)
        dp = int(np.prod([mesh.shape[a] for a in ba]))

        per_dev = _param_bytes(p_shapes) / mesh.shape["model"]
        use_fsdp = fsdp if fsdp is not None else per_dev > FSDP_PARAM_THRESHOLD
        if microbatches is None:
            # default: ~2 sequences per device per microbatch
            microbatches = max(1, shape.global_batch // (dp * 2)) \
                if shape.kind == "train" else 1
        if use_fsdp:
            p_specs = _fsdp_specs(p_shapes, p_specs, mesh)
        params = _tree_sds(p_shapes, p_specs, mesh)

        total, active = _count_params(p_shapes, cfg)
        meta = {
            "arch": arch, "shape": shape_name, "kind": shape.kind,
            "params_total": total, "params_active": active,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "fsdp": use_fsdp, "zero1": zero1,
            "microbatches": microbatches,
            "mesh": dict(mesh.shape),
        }

        if shape.kind == "train":
            big = _param_bytes(p_shapes) > 8e11
            mdt = moment_dtype or ("bfloat16" if big else "float32")
            ocfg = opt_mod.OptimizerConfig(name=optimizer, moment_dtype=mdt)
            opt_init, opt_update = opt_mod.make_optimizer(ocfg)
            o_shapes, o_specs = opt_mod.make_opt_specs(
                opt_init, p_shapes, p_specs, zero1=zero1, mesh=mesh)
            opt_state = _tree_sds(o_shapes, o_specs, mesh)
            dc = data_pipeline.from_model(cfg, shape.global_batch,
                                          shape.seq_len)
            batch_shapes = jax.eval_shape(
                lambda: data_pipeline.in_graph_batch(dc, 0))
            bspec = {"tokens": P(ba, None)}
            if "frontend_embeds" in batch_shapes:
                bspec["frontend_embeds"] = P(ba, None, None)
            batch = _tree_sds(batch_shapes, bspec, mesh)
            import jax.numpy as _jnp
            step = build_train_step(model, opt_update,
                                    microbatches=microbatches,
                                    accum_dtype=(_jnp.bfloat16 if big
                                                 else _jnp.float32))
            meta["tokens_per_step"] = shape.global_batch * shape.seq_len
            meta["moment_dtype"] = mdt
            meta["accum_dtype"] = "bfloat16" if big else "float32"
            return Plan(arch, shape_name, "train", step,
                        (params, opt_state, batch), (0, 1), mesh, meta)

        # ---- serving cells ----
        gb, S = shape.global_batch, shape.seq_len
        box = {}

        def cache_shapes():
            if cfg.family == "encdec":
                c, s = model.init_caches(gb, S, S)
            else:
                c, s = model.init_caches(gb, S)
            box["s"] = s
            return c

        c_shapes = jax.eval_shape(cache_shapes)
        c_specs = box["s"]
        if gb < dp:
            c_specs = _seq_shard_caches(c_shapes, c_specs, mesh)
        caches = _tree_sds(c_shapes, c_specs, mesh)
        tok_spec = P(ba, None) if gb >= dp else P(None, None)

        if shape.kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct(
                (gb, S), jnp.int32,
                sharding=NamedSharding(mesh, tok_spec))}
            if cfg.frontend:
                flen = S if cfg.family == "encdec" else cfg.frontend_len
                batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (gb, flen, cfg.d_model), jnp.bfloat16,
                    sharding=NamedSharding(
                        mesh, P(ba, None, None) if gb >= dp
                        else P(None, None, None)))
            fn = model.prefill
            meta["tokens_per_step"] = gb * S
            return Plan(arch, shape_name, "prefill", fn,
                        (params, batch, caches), (2,), mesh, meta)

        # decode: one token against a cache of length S
        tokens = jax.ShapeDtypeStruct(
            (gb, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        fn = model.decode_step
        meta["tokens_per_step"] = gb
        return Plan(arch, shape_name, "decode", fn,
                    (params, tokens, caches, pos), (2,), mesh, meta)
