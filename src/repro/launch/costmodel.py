"""Trip-count-correct cost extraction for scanned programs.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the production (scan-over-layers) module under-reports FLOPs,
bytes, and collective bytes by ~the layer count.  The dry-run therefore
measures costs structurally:

  1. the FULL config is lowered+compiled with scan (the runnability proof
     and the *memory* analysis — buffer accounting is trip-count-exact);
  2. two/three REDUCED-DEPTH variants with `scan_unroll=True` (straight-line
     HLO, every op counted) are lowered+compiled; per-stack slopes come
     from differencing, and totals extrapolate linearly to the full depth:

        cost(depths) = fixed + Σ_stack slope_stack · n_stack

Linear extrapolation is exact here: layers within a stack are structurally
identical (same shapes, same collectives) — the whole point of stacking
them for scan in the first place.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro import compat, configs
from repro.launch import roofline as RL


def depth_variants(cfg) -> tuple[list[dict], list[dict], dict]:
    """Returns (override_list, stack_count_list, full_counts).

    Each override dict produces a reduced config; stack_counts gives the
    per-stack layer counts of that variant; full_counts those of the real
    config.  Variant 0 must be the smallest (used for the fixed cost)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "ssm"):
        full = {"blocks": cfg.num_layers}
        return ([{"num_layers": 1}, {"num_layers": 2}],
                [{"blocks": 1}, {"blocks": 2}], full)
    if fam == "moe":
        fk = cfg.moe.first_k_dense
        full = {"dense_prefix": fk, "moe_blocks": cfg.num_layers - fk}
        def mk(d, m):
            return {"num_layers": d + m,
                    "moe": dataclasses.replace(cfg.moe, first_k_dense=d)}
        return ([mk(1, 1), mk(2, 1), mk(1, 2)],
                [{"dense_prefix": 1, "moe_blocks": 1},
                 {"dense_prefix": 2, "moe_blocks": 1},
                 {"dense_prefix": 1, "moe_blocks": 2}], full)
    if fam == "hybrid":
        per = cfg.ssm.attn_every
        n_groups = cfg.num_layers // per
        rem = cfg.num_layers - n_groups * per
        full = {"groups": n_groups, "tail": rem}
        return ([{"num_layers": per}, {"num_layers": 2 * per},
                 {"num_layers": per + 1}],
                [{"groups": 1, "tail": 0}, {"groups": 2, "tail": 0},
                 {"groups": 1, "tail": 1}], full)
    if fam == "encdec":
        full = {"encoder": cfg.encoder_layers, "decoder": cfg.num_layers}
        return ([{"encoder_layers": 1, "num_layers": 1},
                 {"encoder_layers": 2, "num_layers": 1},
                 {"encoder_layers": 1, "num_layers": 2}],
                [{"encoder": 1, "decoder": 1}, {"encoder": 2, "decoder": 1},
                 {"encoder": 1, "decoder": 2}], full)
    raise ValueError(fam)


def _solve(stack_counts: list[dict], values: list[float],
           full: dict) -> float:
    """Least-squares fit cost = fixed + Σ slope_s·n_s, evaluate at full."""
    stacks = sorted(full.keys())
    A = np.array([[1.0] + [sc.get(s, 0) for s in stacks]
                  for sc in stack_counts])
    y = np.array(values, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    est = coef[0] + sum(coef[1 + i] * full[s] for i, s in enumerate(stacks))
    return float(max(est, 0.0))


def measure(arch: str, shape: str, mesh, make_plan_fn, plan_kw: dict,
            verbose: bool = True) -> dict:
    """Lower/compile the reduced unrolled variants; extrapolate
    (flops, hbm_bytes, collective bytes by category) to the full depth."""
    cfg = configs.get(arch)
    overrides_list, counts_list, full = depth_variants(cfg)

    flops, hbm, coll = [], [], []
    base_ov = dict(plan_kw.get("overrides") or {})
    plan_kw = {k: v for k, v in plan_kw.items() if k != "overrides"}
    for ov in overrides_list:
        ov = dict(base_ov, **ov, scan_unroll=True)
        # microbatching is a while loop too — measure the step as a single
        # microbatch (identical totals: same tokens, one grad reduce)
        plan = make_plan_fn(arch, shape, mesh,
                            **{**plan_kw, "microbatches": 1,
                               "overrides": ov})
        compiled = plan.lower().compile()
        cost = compat.cost_analysis(compiled)
        flops.append(float(cost.get("flops", 0.0)))
        hbm.append(float(cost.get("bytes accessed", 0.0)))
        coll.append(RL.parse_collectives(compiled.as_text()))
        if verbose:
            print(f"    [variant {ov}] flops={flops[-1]:.3e} "
                  f"bytes={hbm[-1]:.3e} coll={coll[-1]['total_bytes']:.3e}")

    out = {
        "flops": _solve(counts_list, flops, full),
        "hbm_bytes": _solve(counts_list, hbm, full),
        "collective_bytes": _solve(
            counts_list, [c["total_bytes"] for c in coll], full),
        "collectives": {},
        "variants": {"counts": counts_list, "flops": flops,
                     "hbm_bytes": hbm,
                     "collective_bytes": [c["total_bytes"] for c in coll],
                     "full": full},
    }
    for cat in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"):
        out["collectives"][cat] = {
            "bytes": _solve(counts_list, [c[cat]["bytes"] for c in coll],
                            full),
            "count": _solve(counts_list,
                            [float(c[cat]["count"]) for c in coll], full),
        }
    return out


# -- density-aware sparse dispatch (distmat SparseRowMatrix) -----------------
#
# A BlockELL shard only pays off while its stored-block fraction is low: the
# BSR kernel spends MXU time on nbr·ell layout-padded blocks, a dense GEMM on
# the full m·n — but the dense path streams with perfect MXU utilization.
# Both sides are priced with the same roofline constants the autotuner uses
# (kernels/autotune.py), so the break-even moves with dtype and hardware
# generation.  Everything here is pure Python over static shapes: the
# SparseRowMatrix shard_map bodies consult it at trace time.

@dataclasses.dataclass(frozen=True)
class SparseDispatch:
    bsr_s: float          # modeled per-shard seconds on the BSR path
    dense_s: float        # modeled per-shard seconds on the dense GEMM path
    use_bsr: bool


@functools.lru_cache(maxsize=512)
def _sparse_dispatch_cached(m: int, n: int, nx: int, ell: int, bs: int,
                            dtype_name: str) -> SparseDispatch:
    import jax.numpy as jnp
    from repro.kernels import autotune as at
    dtype = jnp.dtype(dtype_name)
    bsr_s = at.model_time("bsr", {"bs": bs},
                          {"m": m, "n": n, "nx": nx, "ell": ell}, dtype)
    # Dense comparison point: the best-ranked GEMM tile for this shard shape
    # (matvec is priced as nx=1; the ranker clamps tiles to the shape).
    dense_s = at.rank("gemm", {"m": m, "k": n, "n": max(nx, 1)}, dtype)[0][0]
    return SparseDispatch(bsr_s=bsr_s, dense_s=dense_s,
                          use_bsr=bsr_s <= dense_s)


def sparse_dispatch(m: int, n: int, nx: int, ell: int, bs: int,
                    dtype="float32") -> SparseDispatch:
    """Per-shard BSR-vs-dense decision for an (m × n) BlockELL shard with
    `ell` stored blocks per block-row of size `bs`, multiplied against an
    (n × nx) dense operand (nx=1 for SpMV)."""
    import jax.numpy as jnp
    return _sparse_dispatch_cached(int(m), int(n), int(max(nx, 1)), int(ell),
                                   int(bs), jnp.dtype(dtype).name)


# -- fused-vs-unfused composite gradient (tfocs/lbfgs hot path) ---------------
#
# One (value, gradient) evaluation of f(Ax) either streams A twice (apply
# z = A x, then adjoint g = Aᵀ∇f(z)) or once through the fused kernel
# (kernels/fusedgrad), which evaluates the row-local residual on-chip
# between the two products.  Both sides are priced with the autotuner's
# roofline constants; on an HBM-bound shard the fused side models at ~half
# the time, and the solvers' fused="auto" consults this decision.

@dataclasses.dataclass(frozen=True)
class FusedGradDispatch:
    fused_s: float        # modeled per-shard seconds, single fused pass
    unfused_s: float      # modeled per-shard seconds, apply + adjoint
    use_fused: bool


@functools.lru_cache(maxsize=512)
def _fused_grad_dispatch_cached(m: int, n: int,
                                dtype_name: str) -> FusedGradDispatch:
    import jax.numpy as jnp
    from repro.kernels import autotune as at

    def _rup(x, mult):
        return (x + mult - 1) // mult * mult

    dtype = jnp.dtype(dtype_name)
    db = dtype.itemsize
    fused_s = at.rank("fusedgrad", {"m": m, "n": n}, dtype)[0][0]
    # Unfused = two independent streaming passes (apply, adjoint), each
    # priced on its OWN layout: matvec-style kernels tile m on sublane
    # boundaries, while the fused kernel's t/w/z vector strips force
    # lane-aligned (128-row) blocks and pad m accordingly.  That asymmetry
    # is the real trade: one A read vs two, against lane-padding waste —
    # for tiny row shards (m ≪ 128) two sublane-padded passes move fewer
    # bytes than one lane-padded fused pass and the dispatch says so.
    mp = _rup(m, at.sublane(dtype))
    np_ = _rup(n, at.LANE)
    compute = 2.0 * mp * np_ / at.MXU_FLOPS.get(db, at.MXU_FLOPS[4])
    bm = min(512, mp)
    one_pass = (max(compute, (mp * np_ + mp + np_) * db / at.HBM_BW)
                + -(-mp // bm) * at.STEP_OVERHEAD_S)
    unfused_s = 2.0 * one_pass
    return FusedGradDispatch(fused_s=fused_s, unfused_s=unfused_s,
                             use_fused=fused_s <= unfused_s)


def fused_grad_dispatch(m: int, n: int, dtype="float32") -> FusedGradDispatch:
    """Fused single-pass gradient vs apply+adjoint (two A reads) for an
    (m × n) operator shard — pure Python over static shapes, trace-safe."""
    import jax.numpy as jnp
    return _fused_grad_dispatch_cached(int(m), int(n),
                                       jnp.dtype(dtype).name)
