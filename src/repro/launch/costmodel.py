"""Trip-count-correct cost extraction for scanned programs.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the production (scan-over-layers) module under-reports FLOPs,
bytes, and collective bytes by ~the layer count.  The dry-run therefore
measures costs structurally:

  1. the FULL config is lowered+compiled with scan (the runnability proof
     and the *memory* analysis — buffer accounting is trip-count-exact);
  2. two/three REDUCED-DEPTH variants with `scan_unroll=True` (straight-line
     HLO, every op counted) are lowered+compiled; per-stack slopes come
     from differencing, and totals extrapolate linearly to the full depth:

        cost(depths) = fixed + Σ_stack slope_stack · n_stack

Linear extrapolation is exact here: layers within a stack are structurally
identical (same shapes, same collectives) — the whole point of stacking
them for scan in the first place.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import compat, configs
from repro.launch import roofline as RL


def depth_variants(cfg) -> tuple[list[dict], list[dict], dict]:
    """Returns (override_list, stack_count_list, full_counts).

    Each override dict produces a reduced config; stack_counts gives the
    per-stack layer counts of that variant; full_counts those of the real
    config.  Variant 0 must be the smallest (used for the fixed cost)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "ssm"):
        full = {"blocks": cfg.num_layers}
        return ([{"num_layers": 1}, {"num_layers": 2}],
                [{"blocks": 1}, {"blocks": 2}], full)
    if fam == "moe":
        fk = cfg.moe.first_k_dense
        full = {"dense_prefix": fk, "moe_blocks": cfg.num_layers - fk}
        def mk(d, m):
            return {"num_layers": d + m,
                    "moe": dataclasses.replace(cfg.moe, first_k_dense=d)}
        return ([mk(1, 1), mk(2, 1), mk(1, 2)],
                [{"dense_prefix": 1, "moe_blocks": 1},
                 {"dense_prefix": 2, "moe_blocks": 1},
                 {"dense_prefix": 1, "moe_blocks": 2}], full)
    if fam == "hybrid":
        per = cfg.ssm.attn_every
        n_groups = cfg.num_layers // per
        rem = cfg.num_layers - n_groups * per
        full = {"groups": n_groups, "tail": rem}
        return ([{"num_layers": per}, {"num_layers": 2 * per},
                 {"num_layers": per + 1}],
                [{"groups": 1, "tail": 0}, {"groups": 2, "tail": 0},
                 {"groups": 1, "tail": 1}], full)
    if fam == "encdec":
        full = {"encoder": cfg.encoder_layers, "decoder": cfg.num_layers}
        return ([{"encoder_layers": 1, "num_layers": 1},
                 {"encoder_layers": 2, "num_layers": 1},
                 {"encoder_layers": 1, "num_layers": 2}],
                [{"encoder": 1, "decoder": 1}, {"encoder": 2, "decoder": 1},
                 {"encoder": 1, "decoder": 2}], full)
    raise ValueError(fam)


def _solve(stack_counts: list[dict], values: list[float],
           full: dict) -> float:
    """Least-squares fit cost = fixed + Σ slope_s·n_s, evaluate at full."""
    stacks = sorted(full.keys())
    A = np.array([[1.0] + [sc.get(s, 0) for s in stacks]
                  for sc in stack_counts])
    y = np.array(values, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    est = coef[0] + sum(coef[1 + i] * full[s] for i, s in enumerate(stacks))
    return float(max(est, 0.0))


def measure(arch: str, shape: str, mesh, make_plan_fn, plan_kw: dict,
            verbose: bool = True) -> dict:
    """Lower/compile the reduced unrolled variants; extrapolate
    (flops, hbm_bytes, collective bytes by category) to the full depth."""
    cfg = configs.get(arch)
    overrides_list, counts_list, full = depth_variants(cfg)

    flops, hbm, coll = [], [], []
    base_ov = dict(plan_kw.get("overrides") or {})
    plan_kw = {k: v for k, v in plan_kw.items() if k != "overrides"}
    for ov in overrides_list:
        ov = dict(base_ov, **ov, scan_unroll=True)
        # microbatching is a while loop too — measure the step as a single
        # microbatch (identical totals: same tokens, one grad reduce)
        plan = make_plan_fn(arch, shape, mesh,
                            **{**plan_kw, "microbatches": 1,
                               "overrides": ov})
        compiled = plan.lower().compile()
        cost = compat.cost_analysis(compiled)
        flops.append(float(cost.get("flops", 0.0)))
        hbm.append(float(cost.get("bytes accessed", 0.0)))
        coll.append(RL.parse_collectives(compiled.as_text()))
        if verbose:
            print(f"    [variant {ov}] flops={flops[-1]:.3e} "
                  f"bytes={hbm[-1]:.3e} coll={coll[-1]['total_bytes']:.3e}")

    out = {
        "flops": _solve(counts_list, flops, full),
        "hbm_bytes": _solve(counts_list, hbm, full),
        "collective_bytes": _solve(
            counts_list, [c["total_bytes"] for c in coll], full),
        "collectives": {},
        "variants": {"counts": counts_list, "flops": flops,
                     "hbm_bytes": hbm,
                     "collective_bytes": [c["total_bytes"] for c in coll],
                     "full": full},
    }
    for cat in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"):
        out["collectives"][cat] = {
            "bytes": _solve(counts_list, [c[cat]["bytes"] for c in coll],
                            full),
            "count": _solve(counts_list,
                            [float(c[cat]["count"]) for c in coll], full),
        }
    return out


# The density-aware sparse dispatch and the fused-vs-unfused gradient
# dispatch that used to live here (with their own copies of the machine
# constants) are now ``launch/planner.plan("sparse_matmul", ...)`` and
# ``plan("grad", ...)`` — one calibrated MachineModel behind every
# decision (launch/machine.py).
