"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
recorded cell JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_bytes(b: float) -> str:
    if b is None:
        return "-"
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def load(dir_: pathlib.Path) -> list[dict]:
    return [json.loads(p.read_text()) for p in sorted(dir_.glob("*.json"))]


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | per-device mem (args+temp) |"
             " compile s | collective bytes/step/dev |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP (sub-quadratic rule) | - | - | - |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r['error'][:60]} | - | - | - |")
            continue
        m = r["memory"]
        coll = r.get("collectives") or {}
        tot = coll.get("total_bytes") if isinstance(
            coll.get("total_bytes"), (int, float)) else (
            sum(v["bytes"] for v in coll.values()
                if isinstance(v, dict)) if coll else None)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{fmt_bytes(m['argument_bytes'])}+{fmt_bytes(m['temp_bytes'])} |"
            f" {r['timing']['compile_s']:.0f} | {fmt_bytes(tot)} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | bound |"
             " MODEL_FLOPS | useful frac | roofline frac | bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "pod" or not r.get("roofline"):
            continue
        rf = r["roofline"]
        note = {
            "compute": "MXU-bound: more fusion / lower precision",
            "memory": "HBM-bound: flash-attn kernel + fewer f32 "
                      "intermediates move this",
            "collective": "ICI-bound: reshard/overlap or compress "
                          "collectives",
        }[rf["bound"]]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['bound']}** | {rf['model_flops']:.3e} | "
            f"{rf['useful_fraction']:.2f} | "
            f"{rf['roofline_fraction']*100:.1f}% | {note} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(pathlib.Path(args.dir))
    print("## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16×16, per-device terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
