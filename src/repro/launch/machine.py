"""The machine model — the single home of every hardware constant.

Before this module existed the repo priced execution three different ways
with three copies of the same numbers: the autotuner's per-kernel ``_cost``
functions (kernels/autotune.py), the sparse/fused dispatch arithmetic
(launch/costmodel.py), and the dry-run roofline (launch/roofline.py).
Dünner et al. ("Understanding and Optimizing the Performance of Distributed
ML Applications on Apache Spark", 2016) make the case that one *calibrated*
analytical model of compute and bandwidth predicts the winning configuration
across a whole workload family; this module is that model, and
launch/planner.py is the one code path that consults it.

Two layers:

  * ``CostTerms`` — a declarative, machine-independent description of what
    an op does: FLOPs issued, HBM bytes moved, grid steps launched, and the
    MXU utilization fraction its tiling achieves.  The per-kernel terms
    functions in kernels/autotune.py produce these; nothing in them knows a
    bandwidth or a peak-FLOPs number.

  * ``MachineModel`` — turns terms into seconds:

        time = max(flops / (peak·util·mxu_eff), bytes / (bw·hbm_eff))
               + steps · step_overhead

    The built-in instances (``V5E``, ``CPU``) carry datasheet constants;
    ``calibrate()`` regresses the *effective* efficiencies ``mxu_eff`` /
    ``hbm_eff`` per dtype from recorded sweep timings (least squares on the
    roofline terms — eating our own optimizer), and ``save_calibration()``
    persists them next to the autotune config cache so every later
    ``planner.plan()`` prefers the calibrated constants.

Until a backend has been calibrated, every backend plans against the v5e
reference instance — deliberately: the CPU container ranks configs "as if
v5e" (deterministically, matching the shipped defaults), and dispatch
decisions are byte-ratio comparisons that a reference machine prices
correctly.  Calibrating a backend switches its plans to measured reality.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Mapping, Sequence

import numpy as np

# Layout constants (TPU tiled-memory geometry, not per-generation numbers).
LANE = 128
SUBLANE_BY_ITEMSIZE = {8: 8, 4: 8, 2: 16, 1: 32}


def _itemsize(dtype) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dtype).itemsize


def _dtype_name(dtype) -> str:
    import jax.numpy as jnp
    return jnp.dtype(dtype).name


@dataclass(frozen=True)
class CostTerms:
    """What an op does, independent of the machine that runs it."""
    flops: float = 0.0           # MXU/VPU flops issued (padded shapes)
    hbm_bytes: float = 0.0       # bytes moved through HBM
    steps: float = 0.0           # grid steps launched
    mxu_util: float = 1.0        # utilization fraction of the tiling
    comm_bytes: float = 0.0      # bytes on the busiest ICI link (collectives)
    comm_steps: float = 0.0      # serial collective hops (latency term)


def collective_cost(n_devices: int, payload_bytes: float,
                    algorithm: str) -> tuple[float, float]:
    """(bytes on the busiest link, serial hops) for one all-reduce over a
    single torus axis of ``n_devices``.  Ring moves 2·P·(N−1)/N bytes in
    2·(N−1) hops (bandwidth-optimal); a binary reduce+broadcast tree moves
    2·P·⌈log₂N⌉ bytes in 2·⌈log₂N⌉ hops (latency-optimal for small P)."""
    n = int(n_devices)
    if n <= 1:
        return 0.0, 0.0
    if algorithm == "ring":
        return 2.0 * payload_bytes * (n - 1) / n, 2.0 * (n - 1)
    if algorithm == "tree":
        depth = math.ceil(math.log2(n))
        return 2.0 * payload_bytes * depth, 2.0 * depth
    raise ValueError(f"algorithm must be 'ring' or 'tree', got {algorithm!r}")


@dataclass(frozen=True)
class MachineModel:
    """Per-backend machine constants + calibrated effective efficiencies."""
    name: str
    mxu_flops: Mapping[int, float]      # peak FLOP/s by operand itemsize
    hbm_bw: float                       # bytes/s per chip
    step_overhead_s: float              # per-grid-step issue cost
    link_bw: float                      # bytes/s per ICI link
    vmem_bytes: int                     # fast scratch per core
    mxu_eff: Mapping[str, float] = field(default_factory=dict)  # dtype name
    hbm_eff: Mapping[str, float] = field(default_factory=dict)  # dtype name
    link_eff: Mapping[str, float] = field(default_factory=dict)  # dtype name
    link_latency_s: float = 1e-6        # per-hop collective latency
    source: str = "builtin"             # "builtin" | "calibrated"

    # -- constants, efficiency-adjusted --------------------------------------
    def peak_flops(self, dtype) -> float:
        base = self.mxu_flops.get(_itemsize(dtype),
                                  self.mxu_flops[max(self.mxu_flops)])
        return base * self.mxu_eff.get(_dtype_name(dtype), 1.0)

    def bandwidth(self, dtype) -> float:
        return self.hbm_bw * self.hbm_eff.get(_dtype_name(dtype), 1.0)

    def link_bandwidth(self, dtype) -> float:
        return self.link_bw * self.link_eff.get(_dtype_name(dtype), 1.0)

    # -- terms → seconds -----------------------------------------------------
    def breakdown(self, terms: CostTerms, dtype) -> dict:
        """The roofline decomposition plan().explain() prints."""
        compute_s = terms.flops / (self.peak_flops(dtype)
                                   * max(terms.mxu_util, 1e-9))
        memory_s = terms.hbm_bytes / self.bandwidth(dtype)
        step_s = terms.steps * self.step_overhead_s
        comm_s = 0.0
        if terms.comm_bytes or terms.comm_steps:
            comm_s = (terms.comm_bytes / self.link_bandwidth(dtype)
                      + terms.comm_steps * self.link_latency_s)
        bound = "compute" if compute_s >= memory_s else "memory"
        if comm_s > max(compute_s, memory_s):
            bound = "comm"
        total = max(compute_s, memory_s) + step_s
        if comm_s:     # keep the comm-free total bit-identical to the seed
            total += comm_s
        return {"compute_s": compute_s, "memory_s": memory_s,
                "step_s": step_s, "comm_s": comm_s, "bound": bound,
                "total_s": total}

    def time(self, terms: CostTerms, dtype) -> float:
        return self.breakdown(terms, dtype)["total_s"]

    # -- collectives ---------------------------------------------------------
    def collective(self, payload_bytes: float, axis_sizes: Sequence[int],
                   dtype="float32", algorithm: str = "auto") -> dict:
        """Price one all-reduce (psum) of ``payload_bytes`` over the mesh
        axes it reduces across — a sequential per-axis reduction, the way
        XLA lowers multi-axis psums on a torus.  ``algorithm`` picks ring
        vs tree per the link model; "auto" takes whichever is cheaper for
        this payload and topology (ring past the bandwidth break-even,
        tree under it)."""
        algos = ("ring", "tree") if algorithm == "auto" else (algorithm,)
        best = None
        for algo in algos:
            cb = cs = 0.0
            for nax in axis_sizes:
                b, s = collective_cost(nax, payload_bytes, algo)
                cb += b
                cs += s
            t = (cb / self.link_bandwidth(dtype)
                 + cs * self.link_latency_s)
            if best is None or t < best["comm_s"]:
                best = {"algorithm": algo, "comm_bytes": cb,
                        "comm_steps": cs, "comm_s": t}
        return best

    # -- calibration ---------------------------------------------------------
    def calibrate(self, records: Sequence[Mapping]) -> "MachineModel":
        """Fit effective MXU/HBM efficiencies per dtype from measured
        timings.  Each record carries its raw roofline terms (priced with
        efficiency 1 — ``planner.calibration_record`` builds them) plus the
        measured seconds:

            {"dtype": "float32", "flops": …, "hbm_bytes": …, "steps": …,
             "mxu_util": …, "measured_s": …}

        Least squares on the additive roofline relaxation
            measured − steps·overhead − comm_steps·latency
                ≈ a·compute_raw + b·hbm_raw [+ c·comm_raw]
        gives inverse efficiencies a = 1/mxu_eff, b = 1/hbm_eff, and —
        when any record carries collective terms (``comm_bytes`` from a
        distributed plan-vs-actual span or bench_collectives sweep) —
        c = 1/link_eff (the max() roofline is not linear; the sum is its
        standard regression surrogate and upper-bounds it within 2×).  The
        comm column joins the parameter vector only when the records
        exercise it, so compute-only sweeps reproduce the seed's two-term
        fit exactly.  Rows are weighted by 1/measured so the fit minimizes
        *relative* error — the metric ``error()`` scores and plan()
        decisions care about — instead of letting the largest shape
        dominate.  Coefficients are clamped positive; a dtype needs ≥ 2
        records to be fit."""
        by_dtype: dict[str, list[Mapping]] = {}
        for r in records:
            by_dtype.setdefault(str(r["dtype"]), []).append(r)
        mxu_eff = dict(self.mxu_eff)
        hbm_eff = dict(self.hbm_eff)
        link_eff = dict(self.link_eff)
        for dname, recs in by_dtype.items():
            if len(recs) < 2:
                continue
            has_comm = any(float(r.get("comm_bytes", 0.0)) > 0 for r in recs)
            A, y = [], []
            for r in recs:
                compute_raw = (float(r["flops"])
                               / (self.peak_flops_raw(dname)
                                  * max(float(r.get("mxu_util", 1.0)), 1e-9)))
                hbm_raw = float(r["hbm_bytes"]) / self.hbm_bw
                resid = (float(r["measured_s"])
                         - float(r.get("steps", 0.0)) * self.step_overhead_s
                         - (float(r.get("comm_steps", 0.0))
                            * self.link_latency_s))
                scale = 1.0 / max(float(r["measured_s"]), 1e-12)
                row = [compute_raw * scale, hbm_raw * scale]
                if has_comm:
                    row.append(float(r.get("comm_bytes", 0.0))
                               / self.link_bw * scale)
                A.append(row)
                y.append(max(resid, 0.0) * scale)
            A = np.asarray(A, np.float64)
            y = np.asarray(y, np.float64)
            ncol = A.shape[1]
            coef, *_ = np.linalg.lstsq(A, y, rcond=None)
            coef = [float(v) for v in coef]
            if coef[0] <= 0 or coef[1] <= 0:
                # Degenerate fit (one term dominates every record, or the
                # terms are collinear): projected NNLS — take whichever
                # single-slope fit leaves the smaller residual.
                fits = []
                for col in range(ncol):
                    s = float(A[:, col] @ y
                              / max(A[:, col] @ A[:, col], 1e-30))
                    s = max(s, 0.0)
                    sse = float(((A[:, col] * s - y) ** 2).sum())
                    fits.append((sse, col, s))
                _, col, s = min(fits)
                coef = [0.0] * ncol
                coef[col] = s
            a, b = coef[0], coef[1]
            c = coef[2] if ncol > 2 else 0.0
            if a > 0:
                mxu_eff[dname] = float(np.clip(1.0 / a, 1e-4, 16.0))
            if b > 0:
                hbm_eff[dname] = float(np.clip(1.0 / b, 1e-4, 16.0))
            if c > 0:
                link_eff[dname] = float(np.clip(1.0 / c, 1e-4, 16.0))
        return dataclasses.replace(self, mxu_eff=mxu_eff, hbm_eff=hbm_eff,
                                   link_eff=link_eff, source="calibrated")

    def peak_flops_raw(self, dname: str) -> float:
        import jax.numpy as jnp
        it = jnp.dtype(dname).itemsize
        return self.mxu_flops.get(it, self.mxu_flops[max(self.mxu_flops)])

    def error(self, records: Sequence[Mapping]) -> float:
        """Mean relative |modeled − measured| / measured over records —
        the number calibration must tighten."""
        errs = []
        for r in records:
            t = self.time(
                CostTerms(flops=float(r["flops"]),
                          hbm_bytes=float(r["hbm_bytes"]),
                          steps=float(r.get("steps", 0.0)),
                          mxu_util=float(r.get("mxu_util", 1.0)),
                          comm_bytes=float(r.get("comm_bytes", 0.0)),
                          comm_steps=float(r.get("comm_steps", 0.0))),
                str(r["dtype"]))
            meas = float(r["measured_s"])
            if meas > 0:
                errs.append(abs(t - meas) / meas)
        return float(np.mean(errs)) if errs else float("nan")

    # -- persistence ---------------------------------------------------------
    def as_dict(self) -> dict:
        return {"name": self.name,
                "mxu_flops": {str(k): v for k, v in self.mxu_flops.items()},
                "hbm_bw": self.hbm_bw,
                "step_overhead_s": self.step_overhead_s,
                "link_bw": self.link_bw, "vmem_bytes": self.vmem_bytes,
                "mxu_eff": dict(self.mxu_eff), "hbm_eff": dict(self.hbm_eff),
                "link_eff": dict(self.link_eff),
                "link_latency_s": self.link_latency_s,
                "source": self.source}

    @staticmethod
    def from_dict(d: Mapping) -> "MachineModel":
        return MachineModel(
            name=d["name"],
            mxu_flops={int(k): float(v) for k, v in d["mxu_flops"].items()},
            hbm_bw=float(d["hbm_bw"]),
            step_overhead_s=float(d["step_overhead_s"]),
            link_bw=float(d["link_bw"]), vmem_bytes=int(d["vmem_bytes"]),
            mxu_eff=dict(d.get("mxu_eff", {})),
            hbm_eff=dict(d.get("hbm_eff", {})),
            link_eff=dict(d.get("link_eff", {})),
            link_latency_s=float(d.get("link_latency_s", 1e-6)),
            source=d.get("source", "builtin"))


# -- built-in instances -------------------------------------------------------
# The ONLY place these numbers appear in src/: every roofline, every
# dispatch, every ranking imports them from here.

V5E = MachineModel(
    name="tpu-v5e",
    mxu_flops={1: 394e12, 2: 197e12, 4: 98.5e12},  # int8 / bf16 / f32 peak
    hbm_bw=819e9,                        # bytes/s per chip
    step_overhead_s=2e-7,                # per-grid-step issue cost
    link_bw=50e9,                        # bytes/s per ICI link
    vmem_bytes=16 * 2**20,
    link_latency_s=1e-6)                 # per-ICI-hop collective latency

CPU = MachineModel(
    name="cpu-host",
    mxu_flops={1: 1e11, 2: 1e11, 4: 1e11},  # a few vector cores' worth
    hbm_bw=3e10,                         # one socket's DRAM stream
    step_overhead_s=1e-6,                # dispatch/loop overhead per tile
    link_bw=1e10,
    vmem_bytes=16 * 2**20,               # keeps tilings TPU-shaped
    link_latency_s=2e-6)                 # shared-memory "hop" (host psum)

_BUILTIN = {"tpu": V5E, "cpu": CPU}


def builtin(backend: str) -> MachineModel:
    return _BUILTIN.get(backend, CPU)


# -- calibration cache (next to the autotune config cache) --------------------

def calibration_path() -> Path:
    """machine.json in the same directory as the autotune config cache
    ($REPRO_AUTOTUNE_CACHE redirects both)."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    base = Path(env) if env else Path.home() / ".cache" / "repro" / "autotune.json"
    return base.with_name("machine.json")


_loaded: dict[Path, dict] = {}


def invalidate_cache() -> None:
    """Forget loaded calibrations (tests; after save_calibration)."""
    _loaded.clear()


def _calibrations(path: Path) -> dict:
    if path not in _loaded:
        try:
            data = json.loads(Path(path).read_text())
            _loaded[path] = dict(data.get("backends", {}))
        except (OSError, ValueError):
            _loaded[path] = {}
    return _loaded[path]


def save_calibration(backend: str, model: MachineModel,
                     path: Path | None = None) -> Path:
    """Persist a calibrated model for `backend`; later for_backend() calls
    prefer it over the builtin reference."""
    path = Path(path) if path else calibration_path()
    try:
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        data = {"version": 1, "backends": {}}
    data.setdefault("backends", {})[backend] = model.as_dict()
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(data, indent=1, sort_keys=True))
    tmp.replace(path)
    invalidate_cache()
    return path


def for_backend(backend: str | None = None, *,
                prefer_calibrated: bool = True) -> MachineModel:
    """The machine model every dispatch decision prices against: the
    calibrated model for this backend when one has been recorded, else the
    v5e reference instance (see module docstring for why the reference —
    not the CPU instance — is the uncalibrated default everywhere)."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    if prefer_calibrated:
        entry = _calibrations(calibration_path()).get(backend)
        if entry is not None:
            return MachineModel.from_dict(entry)
    return V5E
