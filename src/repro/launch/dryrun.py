import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), record memory/cost analysis and
roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count at first init.  Results land in experiments/dryrun/*.json and
are skipped when already present (resumable)."""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: pathlib.Path,
             *, force: bool = False, plan_kw: dict | None = None,
             tag: str = "", no_full: bool = False) -> dict | None:
    from repro.configs.shapes import applicable
    from repro import configs as cfgs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import make_plan
    from repro.launch import roofline as RL

    name = f"{arch}__{shape}__{mesh_kind}{tag}"
    out_path = out_dir / f"{name}.json"
    if out_path.exists() and not force:
        print(f"[skip-cached] {name}")
        return json.loads(out_path.read_text())

    cfg = cfgs.get(arch)
    runs, why = applicable(cfg, shape)
    if not runs:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "skipped", "reason": why}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip] {name}: {why}")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    try:
        from repro.launch import costmodel as CM
        kw = dict(plan_kw or {})
        # 1) full config, scan-over-layers: THE runnability/memory proof
        plan = make_plan(arch, shape, mesh, **kw)
        if no_full:
            # §Perf fast path: skip the full-depth compile; per-layer
            # roofline deltas come from the cost-model variants alone.
            # argument bytes computed analytically from the arg shardings.
            import numpy as _np

            def _pd(sds):
                sh = sds.sharding
                n = 1
                for ent in (sh.spec or ()):
                    if ent is None:
                        continue
                    for a in (ent if isinstance(ent, tuple) else (ent,)):
                        n *= sh.mesh.shape[a]
                return int(_np.prod(sds.shape)) * sds.dtype.itemsize / n

            arg_bytes = sum(_pd(x) for x in jax.tree.leaves(plan.args))
            t_lower = t_compile = 0.0

            class _M:
                argument_size_in_bytes = int(arg_bytes)
                output_size_in_bytes = 0
                temp_size_in_bytes = 0
                alias_size_in_bytes = 0
                generated_code_size_in_bytes = 0

            mem = _M()
        else:
            lowered = plan.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
        plan.meta["argument_bytes"] = mem.argument_size_in_bytes
        # 2) trip-count-correct costs from reduced unrolled variants
        #    (pin the full plan's sharding policy so layers are identical).
        #    The roofline table is single-pod; multipod cells only need the
        #    compile/memory proof, so skip the cost model there.
        if mesh_kind == "multipod" and not (plan_kw or {}).get(
                "force_costmodel"):
            costs = None
            roof = None
        else:
            kw.setdefault("fsdp", plan.meta["fsdp"])
            kw.pop("microbatches", None)  # cost model pins microbatches=1
            kw.pop("force_costmodel", None)
            costs = CM.measure(arch, shape, mesh, make_plan, kw)
            roof = RL.as_dict(RL.analyze(costs["flops"],
                                         costs["hbm_bytes"],
                                         costs["collective_bytes"],
                                         plan.meta))
        t_cost = time.time() - t0 - t_lower - t_compile
        rec = {
            "arch": arch, "shape": shape, "mesh": mesh_kind,
            "status": "ok",
            "meta": plan.meta,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                "peak_per_device": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            },
            "roofline": roof,
            "collectives": costs["collectives"] if costs else None,
            "cost_variants": costs["variants"] if costs else None,
            "timing": {"lower_s": t_lower, "compile_s": t_compile,
                       "costmodel_s": t_cost},
        }
        out_path.write_text(json.dumps(rec, indent=2))
        fit = rec["memory"]["peak_per_device"] / 16e9
        if roof:
            print(f"[ok] {name}: bound={roof['bound']} "
                  f"step={roof['step_s']*1e3:.2f}ms "
                  f"roofline={roof['roofline_fraction']*100:.1f}% "
                  f"mem={fit*100:.0f}% of HBM "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        else:
            print(f"[ok] {name}: compiled; mem={fit*100:.0f}% of HBM "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        return rec
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[FAIL] {name}: {type(e).__name__}: {e}")
        return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--tag", default="")
    ap.add_argument("--fsdp", choices=["auto", "on", "off"], default="auto")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the full-depth compile (cost model only)")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value (int/float/str)")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, \
        "dry-run requires the 512 placeholder devices (import order bug?)"

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    plan_kw = {"microbatches": args.microbatches,
               "optimizer": args.optimizer}
    if args.fsdp != "auto":
        plan_kw["fsdp"] = args.fsdp == "on"
    if args.set:
        ov = {}
        for kv in args.set:
            k, v = kv.split("=", 1)
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
            ov[k] = v
        plan_kw["overrides"] = ov

    from repro import configs as cfgs
    from repro.configs.shapes import SHAPES
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in cfgs.ARCHES:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    failures = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, out_dir, force=args.force, plan_kw=plan_kw,
                       tag=args.tag, no_full=args.no_full)
        if rec and rec.get("status") == "error":
            failures += 1
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
