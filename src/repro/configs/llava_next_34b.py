"""llava-next-34b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].  Backbone only; the
vision tower is a stub (input_specs provides anyres patch embeddings that
occupy the leading positions)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64000,
    rope_theta=5e6,
    frontend="patches", frontend_len=2880,   # anyres: 5 tiles x 576
)
