"""Assigned input shapes (per-arch cells) + skip rules.

  train_4k     seq_len=4096    global_batch=256   (training)
  prefill_32k  seq_len=32768   global_batch=32    (inference-prefill)
  decode_32k   seq_len=32768   global_batch=128   (inference-decode:
               one new token against a KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     (long-context-decode;
               sub-quadratic archs only)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped).  long_500k needs sub-quadratic attention;
    every assigned arch has a decoder, so decode shapes always apply."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure quadratic-attention arch: 500k decode KV cache "
                       "is the full-attention regime the assignment skips "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


def cells(arches: list[str]):
    """All (arch, shape) cells with skip annotations."""
    from repro.configs import get
    out = []
    for a in arches:
        cfg = get(a)
        for s in SHAPES:
            runs, why = applicable(cfg, s)
            out.append((a, s, runs, why))
    return out
