"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]."""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=2048, vocab_size=129280,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_k_dense=3,
                  dense_d_ff=18432),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    mtp_depth=1,
)
