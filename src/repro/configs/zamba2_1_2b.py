"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attn block
[arXiv:2411.15242; hf].  38 mamba2 layers; one shared-weight transformer
block applied every 6 layers (6 applications; 2 trailing mamba layers)."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(version=2, state_dim=64, conv_dim=4, expand=2,
                  head_dim=64, chunk=256, attn_every=6),
    subquadratic=True,
)
