"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].
Backbone only; the audio frontend is a stub (input_specs provides
precomputed frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=8192, vocab_size=256206,
    mlp_type="gelu", norm_type="layernorm",
    frontend="frames",
)
