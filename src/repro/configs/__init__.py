"""Assigned architecture registry: `get(name)` → ModelConfig;
`ARCHES` lists all ids.  Shapes live in .shapes."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHES = [
    "deepseek-coder-33b",
    "qwen3-4b",
    "llama3.2-3b",
    "qwen2.5-32b",
    "seamless-m4t-large-v2",
    "zamba2-1.2b",
    "llava-next-34b",
    "deepseek-v2-236b",
    "deepseek-v3-671b",
    "falcon-mamba-7b",
]

_MODULES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-4b": "qwen3_4b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-1.2b": "zamba2_1_2b",
    "llava-next-34b": "llava_next_34b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
