"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free
[arXiv:2410.05355; unverified]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(version=1, state_dim=16, conv_dim=4, expand=2,
                  dt_rank=256, chunk=256),
    subquadratic=True,
)
