"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=1536, vocab_size=102400,
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, first_k_dense=1,
                  dense_d_ff=12288),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
)
