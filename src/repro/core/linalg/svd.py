"""computeSVD / computePCA — paper §3.1.

Dispatch mirrors MLlib's RowMatrix.computeSVD: the *user does not choose* —
tall-and-skinny matrices (n small enough that the n×n Gram fits "on the
driver", i.e. replicated per chip) take the Gram path (§3.1.2); otherwise the
ARPACK-analogue matrix-free Lanczos path (§3.1.1).  Wide-and-short inputs are
handled through their transpose, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix
from . import lanczos as _lanczos

Array = jax.Array

# n at which an n×n float32 Gram stops being a comfortable "driver" object.
# 16 GB HBM chip → reserve ≲ 1 GB for the replicated Gram → n ≈ 16384.
GRAM_THRESHOLD = 8192


@dataclass(frozen=True)
class SVDResult:
    U: RowMatrix | None     # (m, k) distributed left singular vectors
    s: Array                # (k,) singular values, descending (replicated)
    V: Array                # (n, k) right singular vectors (replicated)
    info: dict | None = None


def _recover_u(A: RowMatrix, s: Array, V: Array, rcond: float) -> RowMatrix:
    """U = A (V Σ⁻¹): broadcast the small factor (paper: "embarrassingly
    parallel"), one local GEMM per row shard, no collectives at all."""
    inv = jnp.where(s > rcond * jnp.max(s), 1.0 / jnp.maximum(s, 1e-30), 0.0)
    return A.multiply_local(V * inv[None, :])


def compute_svd(A, k: int, *, compute_u: bool = True,
                mode: Literal["auto", "gram", "lanczos"] = "auto",
                gram_threshold: int = GRAM_THRESHOLD,
                rcond: float = 1e-9, **lanczos_kw) -> SVDResult:
    m, n = A.shape
    k = min(k, min(m, n))
    if mode == "auto":
        mode = "gram" if (isinstance(A, RowMatrix) and n <= gram_threshold) \
            else "lanczos"

    if mode == "gram":
        # §3.1.2 tall-and-skinny: one all-reduce builds AᵀA, the
        # eigendecomposition is a driver-local (replicated) op.
        G = A.gram().astype(jnp.float32)
        w, V = jnp.linalg.eigh(G)
        w, V = w[::-1][:k], V[:, ::-1][:, :k]
        s = jnp.sqrt(jnp.maximum(w, 0.0))
        info = {"mode": "gram"}
    else:
        # §3.1.1 square/sparse: ARPACK-analogue matrix-free Lanczos.
        s, V, info = _lanczos.svd_via_lanczos(A, k, **lanczos_kw)
        info = dict(info, mode="lanczos")

    U = _recover_u(A, s, V, rcond) if (compute_u and
                                       isinstance(A, RowMatrix)) else None
    return SVDResult(U=U, s=s, V=V, info=info)


def compute_pca(A: RowMatrix, k: int) -> tuple[Array, Array]:
    """Principal components from the Gram matrix with the rank-one mean
    correction — never materializes the centered matrix (it would be dense
    even when A is sparse).  Returns (components (n,k), explained variance)."""
    m, n = A.shape
    stats = A.column_stats()
    mu = stats["mean"]
    G = A.gram().astype(jnp.float32)
    cov = (G - m * jnp.outer(mu, mu)) / max(m - 1, 1)
    w, V = jnp.linalg.eigh(cov)
    w, V = w[::-1][:k], V[:, ::-1][:, :k]
    return V, jnp.maximum(w, 0.0)
