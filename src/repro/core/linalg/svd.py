"""computeSVD / computePCA — paper §3.1, plus a randomized third path.

Dispatch mirrors MLlib's RowMatrix.computeSVD: the *user does not choose* —
`mode="auto"` asks the execution planner (launch/planner.plan("svd", ...),
the same calibrated-machine-model path every other dispatch decision takes)
to pick among three paths by (n, k):

  * ``gram``        — n ≤ GRAM_THRESHOLD (=8192): the n×n Gram fits "on the
    driver" (replicated per chip); one all-reduce, then a local eigh
    (§3.1.2 tall-and-skinny).
  * ``randomized``  — n > GRAM_THRESHOLD and k ≤ RANDOMIZED_K_THRESHOLD
    (=128), RowMatrix only: blocked Gaussian range finder with TSQR
    re-orthonormalization and 2+2q passes over A (Li–Kluger–Tygert; see
    randsvd.py).  Wins when A is too wide for Gram but dense enough that
    Lanczos' one-direction-per-matvec iteration is the bottleneck.
  * ``lanczos``     — everything else: ARPACK-analogue matrix-free
    thick-restart Lanczos (§3.1.1); the right tool for very sparse
    operators and for k too large for a sketch to be cheap.

Wide-and-short inputs (m < n) route through the transpose, exactly as the
paper describes: SVD(Aᵀ) = U'ΣV'ᵀ gives A = V'ΣU'ᵀ, so the factors swap.
CoordinateMatrix transposes for free (index swap); RowMatrix and
SparseRowMatrix transpose at driver scale (the paper's format-conversion
shuffle warning applies).  The transposed problem then picks among the same
three modes on its own (n', k) — in particular Lanczos now iterates on the
small AAᵀ instead of the large AᵀA.

SparseRowMatrix inputs drive Lanczos through the block-sparse
matvec/rmatvec (auto mode; the Gram path is available explicitly when n is
small), and U is recovered by the same broadcast-V multiply — the product
of a sparse matrix with the dense small factor is a dense RowMatrix.

All modes report their convergence evidence in ``SVDResult.info`` (gram:
exact; randomized: ``tail_ratio``; lanczos: restarts/residuals).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.distmat.coordinatematrix import CoordinateMatrix
from repro.core.distmat.rowmatrix import RowMatrix
from repro.core.distmat.sparserow import SparseRowMatrix
from . import lanczos as _lanczos
from . import randsvd as _randsvd

Array = jax.Array

# n at which an n×n float32 Gram stops being a comfortable "driver" object.
# 16 GB HBM chip → reserve ≲ 1 GB for the replicated Gram → n ≈ 16384.
GRAM_THRESHOLD = 8192

# Largest k for which the (k+p)-wide sketch beats Lanczos' k sequential
# directions: past this, sketch passes stop amortizing the extra flops and
# the (n × k+p) projections crowd VMEM in the streaming kernel.
RANDOMIZED_K_THRESHOLD = 128


@dataclass(frozen=True)
class SVDResult:
    U: RowMatrix | None     # (m, k) distributed left singular vectors
    s: Array                # (k,) singular values, descending (replicated)
    V: Array                # (n, k) right singular vectors (replicated)
    info: dict | None = None


def _recover_u(A, s: Array, V: Array, rcond: float) -> RowMatrix:
    """U = A (V Σ⁻¹): broadcast the small factor (paper: "embarrassingly
    parallel"), one local GEMM (or BSR SpMM) per row shard, no collectives
    at all.  Works for any row-sharded type with multiply_local."""
    inv = jnp.where(s > rcond * jnp.max(s), 1.0 / jnp.maximum(s, 1e-30), 0.0)
    return A.multiply_local(V * inv[None, :])


def _transpose(A):
    """Type-specific Aᵀ for the wide-and-short dispatch; None when the type
    has no transpose (those inputs keep the direct Lanczos-on-AᵀA path)."""
    if isinstance(A, (CoordinateMatrix, SparseRowMatrix)):
        return A.transpose()
    if isinstance(A, RowMatrix):
        return RowMatrix.create(jnp.asarray(A.to_local()).T, A.mesh,
                                A.row_axes)
    return None


def _swap_transposed(A, At, res: "SVDResult", compute_u: bool,
                     rcond: float) -> "SVDResult":
    """Map SVD(Aᵀ) = U'ΣV'ᵀ back to A = V'ΣU'ᵀ: V of A is the distributed
    U' (replicated on the way out — it is the paper's driver-side factor),
    U of A is the small V', re-wrapped row-sharded."""
    s = res.s
    if res.U is not None:
        V = jnp.asarray(res.U.to_local())
    else:
        # Generic U' = AᵀV'Σ⁻¹ via k driver-looped matvecs (CoordinateMatrix
        # returns replicated vectors, so this is vector-scale work).
        inv = jnp.where(s > rcond * jnp.max(s),
                        1.0 / jnp.maximum(s, 1e-30), 0.0)
        V = jnp.stack([At.matvec(res.V[:, i]) * inv[i]
                       for i in range(res.V.shape[1])], axis=1)
    U = None
    if compute_u:
        U = RowMatrix.create(res.V, getattr(A, "mesh", None),
                             getattr(A, "row_axes", None))
    return SVDResult(U=U, s=s, V=V,
                     info=dict(res.info or {}, transposed=True))


def compute_svd(A, k: int, *, compute_u: bool = True,
                mode: Literal["auto", "gram", "lanczos",
                              "randomized"] = "auto",
                gram_threshold: int = GRAM_THRESHOLD,
                randomized_k_threshold: int = RANDOMIZED_K_THRESHOLD,
                oversampling: int = _randsvd.OVERSAMPLING,
                power_iters: int = _randsvd.POWER_ITERS,
                rcond: float = 1e-9, seed: int = 0,
                **lanczos_kw) -> SVDResult:
    m, n = A.shape
    k = min(k, min(m, n))
    if mode not in ("auto", "gram", "lanczos", "randomized"):
        raise ValueError(f"unknown mode {mode!r}; expected auto | gram | "
                         "lanczos | randomized")
    if m < n and (At := _transpose(A)) is not None:
        # Paper: wide-and-short inputs go through the transpose, which is
        # tall-and-skinny and picks among the same three modes on (n', k);
        # SVD(Aᵀ) = U'ΣV'ᵀ ⇒ A = V'ΣU'ᵀ, so the factors swap on the way out.
        # Types without a transpose (BlockMatrix, IndexedRowMatrix) keep the
        # direct matrix-free path below.
        res = compute_svd(At, k, compute_u=True, mode=mode,
                          gram_threshold=gram_threshold,
                          randomized_k_threshold=randomized_k_threshold,
                          oversampling=oversampling, power_iters=power_iters,
                          rcond=rcond, seed=seed, **lanczos_kw)
        return _swap_transposed(A, At, res, compute_u, rcond)
    if mode == "auto":
        # §3.1 mode dispatch now lives in the execution planner (one
        # calibrated machine model behind every decision): sparse operators
        # take the matrix-free iteration (matvec ∝ nnz, no dense Gram),
        # RowMatrix picks gram / randomized / lanczos by (n, k).
        # plan(...).explain() shows the modeled A-pass cost of each mode.
        from repro.launch import planner as _planner
        kind = ("sparse" if isinstance(A, SparseRowMatrix)
                else "row" if isinstance(A, RowMatrix) else "other")
        ctx = {"kind": kind, "gram_threshold": gram_threshold,
               "randomized_k_threshold": randomized_k_threshold,
               "oversampling": oversampling, "power_iters": power_iters}
        if isinstance(A, SparseRowMatrix):
            ctx["nnz"] = A.nnz
        mode = _planner.plan("svd", {"m": m, "n": n, "k": k},
                             context=ctx).choice

    # All branches report the standardized info keys (iterations / a_passes
    # / converged / plan) alongside their native diagnostics; the native
    # mode-specific keys ("mode", "restarts", "passes_over_A", ...) are
    # deprecated aliases kept for one release.
    if mode == "gram":
        # §3.1.2 tall-and-skinny: one all-reduce builds AᵀA, the
        # eigendecomposition is a driver-local (replicated) op.
        G = A.gram().astype(jnp.float32)
        w, V = jnp.linalg.eigh(G)
        w, V = w[::-1][:k], V[:, ::-1][:, :k]
        s = jnp.sqrt(jnp.maximum(w, 0.0))
        info = {"mode": "gram", "plan": "gram", "iterations": 0,
                "a_passes": 1, "converged": True}
    elif mode == "randomized":
        # Few-pass sketch path: U falls out of the range basis for free, so
        # recover it there instead of paying _recover_u's extra pass.
        if not isinstance(A, RowMatrix):
            raise ValueError("mode='randomized' needs a RowMatrix "
                             "(row-sharded sketch/project primitives)")
        U, s, V, info = _randsvd.randomized_svd(
            A, k, oversampling=oversampling, power_iters=power_iters,
            seed=seed, compute_u=compute_u)
        info = dict(info, plan="randomized", iterations=power_iters,
                    a_passes=info["passes_over_A"], converged=True)
        return SVDResult(U=U, s=s, V=V, info=info)
    else:
        # §3.1.1 square/sparse: ARPACK-analogue matrix-free Lanczos.
        s, V, info = _lanczos.svd_via_lanczos(A, k, seed=seed, **lanczos_kw)
        # Each normal-equations op call is a matvec + rmatvec = 2 A-passes.
        info = dict(info, mode="lanczos", plan="lanczos",
                    iterations=info["restarts"],
                    a_passes=2 * info["op_calls"])

    U = _recover_u(A, s, V, rcond) if (
        compute_u and isinstance(A, (RowMatrix, SparseRowMatrix))) else None
    if U is not None:
        info = dict(info, a_passes=info["a_passes"] + 1)  # the U = A(VΣ⁻¹) pass
    return SVDResult(U=U, s=s, V=V, info=info)


def compute_pca(A: RowMatrix, k: int) -> tuple[Array, Array]:
    """Principal components from the Gram matrix with the rank-one mean
    correction — never materializes the centered matrix (it would be dense
    even when A is sparse).  Returns (components (n,k), explained variance)."""
    m, n = A.shape
    stats = A.column_stats()
    mu = stats["mean"]
    G = A.gram().astype(jnp.float32)
    cov = (G - m * jnp.outer(mu, mu)) / max(m - 1, 1)
    w, V = jnp.linalg.eigh(cov)
    w, V = w[::-1][:k], V[:, ::-1][:, :k]
    return V, jnp.maximum(w, 0.0)
