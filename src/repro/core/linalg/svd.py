"""computeSVD / computePCA — paper §3.1, plus a randomized third path.

Dispatch mirrors MLlib's RowMatrix.computeSVD: the *user does not choose* —
`mode="auto"` picks among three paths by (n, k):

  * ``gram``        — n ≤ GRAM_THRESHOLD (=8192): the n×n Gram fits "on the
    driver" (replicated per chip); one all-reduce, then a local eigh
    (§3.1.2 tall-and-skinny).
  * ``randomized``  — n > GRAM_THRESHOLD and k ≤ RANDOMIZED_K_THRESHOLD
    (=128), RowMatrix only: blocked Gaussian range finder with TSQR
    re-orthonormalization and 2+2q passes over A (Li–Kluger–Tygert; see
    randsvd.py).  Wins when A is too wide for Gram but dense enough that
    Lanczos' one-direction-per-matvec iteration is the bottleneck.
  * ``lanczos``     — everything else: ARPACK-analogue matrix-free
    thick-restart Lanczos (§3.1.1); the right tool for very sparse
    operators and for k too large for a sketch to be cheap.

Transpose dispatch for wide-and-short inputs (the paper handles those via
Aᵀ) is not implemented yet — callers pass m ≥ n layouts (ROADMAP open item).
All modes report their convergence evidence in ``SVDResult.info`` (gram:
exact; randomized: ``tail_ratio``; lanczos: restarts/residuals).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix
from . import lanczos as _lanczos
from . import randsvd as _randsvd

Array = jax.Array

# n at which an n×n float32 Gram stops being a comfortable "driver" object.
# 16 GB HBM chip → reserve ≲ 1 GB for the replicated Gram → n ≈ 16384.
GRAM_THRESHOLD = 8192

# Largest k for which the (k+p)-wide sketch beats Lanczos' k sequential
# directions: past this, sketch passes stop amortizing the extra flops and
# the (n × k+p) projections crowd VMEM in the streaming kernel.
RANDOMIZED_K_THRESHOLD = 128


@dataclass(frozen=True)
class SVDResult:
    U: RowMatrix | None     # (m, k) distributed left singular vectors
    s: Array                # (k,) singular values, descending (replicated)
    V: Array                # (n, k) right singular vectors (replicated)
    info: dict | None = None


def _recover_u(A: RowMatrix, s: Array, V: Array, rcond: float) -> RowMatrix:
    """U = A (V Σ⁻¹): broadcast the small factor (paper: "embarrassingly
    parallel"), one local GEMM per row shard, no collectives at all."""
    inv = jnp.where(s > rcond * jnp.max(s), 1.0 / jnp.maximum(s, 1e-30), 0.0)
    return A.multiply_local(V * inv[None, :])


def compute_svd(A, k: int, *, compute_u: bool = True,
                mode: Literal["auto", "gram", "lanczos",
                              "randomized"] = "auto",
                gram_threshold: int = GRAM_THRESHOLD,
                randomized_k_threshold: int = RANDOMIZED_K_THRESHOLD,
                oversampling: int = _randsvd.OVERSAMPLING,
                power_iters: int = _randsvd.POWER_ITERS,
                rcond: float = 1e-9, seed: int = 0,
                **lanczos_kw) -> SVDResult:
    m, n = A.shape
    k = min(k, min(m, n))
    if mode not in ("auto", "gram", "lanczos", "randomized"):
        raise ValueError(f"unknown mode {mode!r}; expected auto | gram | "
                         "lanczos | randomized")
    if mode == "auto":
        if isinstance(A, RowMatrix) and n <= gram_threshold:
            mode = "gram"
        elif isinstance(A, RowMatrix) and k <= randomized_k_threshold:
            mode = "randomized"
        else:
            mode = "lanczos"

    if mode == "gram":
        # §3.1.2 tall-and-skinny: one all-reduce builds AᵀA, the
        # eigendecomposition is a driver-local (replicated) op.
        G = A.gram().astype(jnp.float32)
        w, V = jnp.linalg.eigh(G)
        w, V = w[::-1][:k], V[:, ::-1][:, :k]
        s = jnp.sqrt(jnp.maximum(w, 0.0))
        info = {"mode": "gram"}
    elif mode == "randomized":
        # Few-pass sketch path: U falls out of the range basis for free, so
        # recover it there instead of paying _recover_u's extra pass.
        if not isinstance(A, RowMatrix):
            raise ValueError("mode='randomized' needs a RowMatrix "
                             "(row-sharded sketch/project primitives)")
        U, s, V, info = _randsvd.randomized_svd(
            A, k, oversampling=oversampling, power_iters=power_iters,
            seed=seed, compute_u=compute_u)
        return SVDResult(U=U, s=s, V=V, info=info)
    else:
        # §3.1.1 square/sparse: ARPACK-analogue matrix-free Lanczos.
        s, V, info = _lanczos.svd_via_lanczos(A, k, seed=seed, **lanczos_kw)
        info = dict(info, mode="lanczos")

    U = _recover_u(A, s, V, rcond) if (compute_u and
                                       isinstance(A, RowMatrix)) else None
    return SVDResult(U=U, s=s, V=V, info=info)


def compute_pca(A: RowMatrix, k: int) -> tuple[Array, Array]:
    """Principal components from the Gram matrix with the rank-one mean
    correction — never materializes the centered matrix (it would be dense
    even when A is sparse).  Returns (components (n,k), explained variance)."""
    m, n = A.shape
    stats = A.column_stats()
    mu = stats["mean"]
    G = A.gram().astype(jnp.float32)
    cov = (G - m * jnp.outer(mu, mu)) / max(m - 1, 1)
    w, V = jnp.linalg.eigh(cov)
    w, V = w[::-1][:k], V[:, ::-1][:, :k]
    return V, jnp.maximum(w, 0.0)
