"""Matrix-free thick-restart Lanczos — the ARPACK (IRLM) analogue.

Paper §3.1.1: ARPACK's implicitly-restarted Lanczos runs *on the driver* and
only ever touches the matrix through caller-supplied matvecs, which Spark
ships to the cluster.  We reproduce that control structure exactly, TPU-style:

  * the "driver" state — the (ncv+1) × n Krylov basis, the small projected
    matrix T, Ritz math — is replicated (every chip holds the same copy;
    vector ops are tiny, so the redundancy is free);
  * the only cluster interaction is `op(v)` = `v ↦ Aᵀ(A v)`, a shard_map
    matvec over the distributed matrix (RowMatrix / CoordinateMatrix /
    BlockMatrix all expose it);
  * ARPACK's reverse-communication loop becomes `jax.lax.while_loop` /
    `fori_loop` — the same separation, no Fortran, one XLA program.

For symmetric operators, thick restart (Wu & Simon 2000) is algebraically
equivalent to ARPACK's implicit restart; we use it because the restart step
is a dense (ncv × ncv) eigendecomposition — a pure driver/vector op.
Full (DGKS, twice) reorthogonalization is used: float32 Lanczos loses
orthogonality fast, and the reorth cost is ncv·n per step — vector-scale,
i.e. "driver" work by the paper's accounting.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class LanczosState(NamedTuple):
    V: Array          # (ncv+1, n) basis buffer (replicated / driver)
    T: Array          # (ncv, ncv) projected symmetric matrix
    j: Array          # current Lanczos step (int32)
    beta: Array       # trailing residual norm
    ritz: Array       # (ncv,) current Ritz values (descending)
    resid: Array      # (ncv,) Ritz residual estimates
    restarts: Array   # restart counter
    done: Array       # convergence flag


def _orthogonalize(w: Array, V: Array, upto: Array) -> Array:
    """Project w against the first `upto` rows of V, twice (DGKS)."""
    mask = (jnp.arange(V.shape[0]) < upto).astype(w.dtype)[:, None]
    Vm = V * mask
    for _ in range(2):          # "twice is enough" — Kahan/Parlett
        w = w - Vm.T @ (Vm @ w)
    return w


def lanczos_eigsh(op: Callable[[Array], Array], n: int, k: int,
                  *, ncv: int | None = None, max_restarts: int = 40,
                  tol: float = 1e-6, seed: int = 0,
                  dtype=jnp.float32) -> tuple[Array, Array, dict]:
    """Top-k eigenpairs of a symmetric PSD operator `op` of size n.

    Returns (eigenvalues desc (k,), eigenvectors (n, k), info dict).
    Fully jit-traceable; `op` may contain shard_map collectives.
    """
    ncv = ncv or min(n, max(2 * k + 1, 20))
    if not (k < ncv <= n):
        raise ValueError(f"need k < ncv <= n, got k={k} ncv={ncv} n={n}")

    def expand(state: LanczosState) -> LanczosState:
        """One Lanczos step: a cluster matvec + driver vector math.

        Writing the full masked coefficient column keeps T correct in both
        the tridiagonal phase and the thick-restart arrowhead phase (the
        inner products reproduce the coupling entries exactly).
        """
        V, T, j = state.V, state.T, state.j
        v = jax.lax.dynamic_index_in_dim(V, j, axis=0, keepdims=False)
        w = op(v)                                       # ← the cluster op
        colmask = (jnp.arange(ncv) <= j).astype(dtype)
        coeffs = (V[:-1] @ w) * colmask                 # T[:, j]
        w = _orthogonalize(w, V, j + 1)
        beta = jnp.linalg.norm(w)
        vnext = w / jnp.where(beta > 0, beta, 1.0)
        T = T.at[:, j].set(coeffs)
        T = T.at[j, :].set(coeffs)
        in_window = (j + 1) < ncv
        T = jax.lax.cond(
            in_window,
            lambda t: t.at[j + 1, j].set(beta).at[j, j + 1].set(beta),
            lambda t: t, T)
        V = jax.lax.dynamic_update_index_in_dim(V, vnext, j + 1, axis=0)
        return state._replace(V=V, T=T, j=j + 1, beta=beta)

    def restart(state: LanczosState) -> LanczosState:
        """Driver-side Ritz extraction + thick restart (≙ ARPACK dsaupd)."""
        V, T = state.V, state.T
        theta, S = jnp.linalg.eigh(T)                 # ascending
        theta, S = theta[::-1], S[:, ::-1]            # descending
        resid = jnp.abs(state.beta * S[-1, :])        # per-Ritz residual
        scale = jnp.maximum(jnp.max(jnp.abs(theta)), 1e-30)
        done = jnp.all(resid[:k] <= tol * scale)
        Y = S[:, :k].T @ V[:-1]                       # (k, n) Ritz vectors
        Vnew = jnp.zeros_like(V).at[:k].set(Y).at[k].set(V[-1])
        b = state.beta * S[-1, :k]                    # arrowhead coupling
        Tnew = jnp.zeros_like(T)
        Tnew = Tnew.at[jnp.arange(k), jnp.arange(k)].set(theta[:k])
        Tnew = Tnew.at[k, :k].set(b).at[:k, k].set(b)
        return state._replace(V=Vnew, T=Tnew, j=jnp.int32(k),
                              ritz=theta, resid=resid,
                              restarts=state.restarts + 1, done=done)

    def cycle(state: LanczosState) -> LanczosState:
        def body(_, s):
            return jax.lax.cond(s.j < ncv, expand, lambda x: x, s)
        return restart(jax.lax.fori_loop(0, ncv, body, state))

    def cond(state: LanczosState) -> Array:
        return (~state.done) & (state.restarts < max_restarts)

    v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    init = LanczosState(
        V=jnp.zeros((ncv + 1, n), dtype).at[0].set(v0),
        T=jnp.zeros((ncv, ncv), dtype),
        j=jnp.int32(0), beta=jnp.asarray(0.0, dtype),
        ritz=jnp.zeros((ncv,), dtype),
        resid=jnp.full((ncv,), jnp.inf, dtype),
        restarts=jnp.int32(0), done=jnp.asarray(False))
    final = jax.lax.while_loop(cond, cycle, init)
    vals = final.ritz[:k]
    vecs = final.V[:k].T                               # (n, k)
    # op_calls is structural: the first cycle runs ncv expand steps, every
    # later cycle resumes from the k retained Ritz vectors (ncv − k steps).
    op_calls = ncv + jnp.maximum(final.restarts - 1, 0) * (ncv - k)
    info = {"restarts": final.restarts, "resid": final.resid[:k],
            "converged": final.done, "ncv": ncv, "op_calls": op_calls}
    return vals, vecs, info


def svd_via_lanczos(A, k: int, **kw):
    """Paper §3.1.1: SVD of A from the eigendecomposition of AᵀA, where the
    Lanczos driver only calls the distributed normal-equations matvec."""
    _, n = A.shape
    vals, V, info = lanczos_eigsh(A.normal_op(), n, k, **kw)
    sigma = jnp.sqrt(jnp.maximum(vals, 0.0))
    return sigma, V, info
