"""Randomized SVD via a blocked Gaussian range finder (Halko–Martinsson–
Tropp; distributed form after Li–Kluger–Tygert, "Randomized algorithms for
distributed computation of PCA and SVD").

The third `compute_svd` mode, for the regime both paper paths handle badly:
n too large for the Gram path (the n×n Gram no longer fits "on the driver")
but A dense enough that Lanczos' O(k) sequential passes dominate.  The range
finder needs only 2 + 2·q passes over A, all built from the cluster
primitives the repo already has:

  * ``A.sketch(r)``     — Y = AΩ, with Ω derived per-shard from a seed so
    the (n × r) test matrix is never materialized on the driver;
  * ``tsqr``            — distributed re-orthonormalization of the (m × r)
    tall-skinny basis after every pass (float32 loses the range fast;
    Li–Kluger–Tygert re-orthonormalize every pass, so we do too);
  * ``A.project(Q)``    — B = AᵀQ, a per-shard streaming cross-Gram
    (Pallas ``randsketch`` kernel) + one all-reduce;
  * a driver-local (replicated) SVD of the small (r × n) projection.

Cost per pass is one sweep of A's HBM bytes + an (n·r) all-reduce — the
same collective budget as one Lanczos matvec, but each pass advances r = k+p
directions at once instead of one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix
from . import tsqr as _tsqr

Array = jax.Array

# Default knobs (Halko et al. §4.3: small constant oversampling plus a
# couple of power iterations is enough for spectra with any visible decay).
OVERSAMPLING = 10
POWER_ITERS = 2


def randomized_range_finder(A: RowMatrix, r: int, *, power_iters: int,
                            seed: int) -> RowMatrix:
    """Orthonormal (m × r) basis Q for the range of (A Aᵀ)^q A, distributed.

    Every pass re-orthonormalizes: the tall (m × r) factor through the
    distributed TSQR, the small (n × r) factor through a driver-local QR —
    without this, float32 power iterations collapse onto the top singular
    direction and the trailing basis vectors turn to noise.
    """
    Y = A.sketch(r, seed=seed)                    # 1 pass:  Y = AΩ
    Q, _ = _tsqr.tsqr(Y)
    for _ in range(power_iters):
        Z = A.project(Q)                          # 1 pass:  Z = AᵀQ  (n × r)
        Z, _ = jnp.linalg.qr(Z)                   # driver-local reorth
        Y = A.multiply_local(Z)                   # 1 pass:  Y = AZ   (m × r)
        Q, _ = _tsqr.tsqr(Y)
    return Q


def randomized_svd(A: RowMatrix, k: int, *, oversampling: int = OVERSAMPLING,
                   power_iters: int = POWER_ITERS, seed: int = 0,
                   compute_u: bool = True
                   ) -> tuple[RowMatrix | None, Array, Array, dict]:
    """Rank-k truncated SVD of a row-sharded A.

    Returns (U (m×k) RowMatrix or None, s (k,), V (n,k), info).  U comes
    from rotating the range basis, U = Q · Ub — a broadcast-small-factor
    local multiply, no extra pass over A."""
    m, n = A.shape
    r = min(k + oversampling, min(m, n))
    if not k <= r:
        raise ValueError(f"need k <= k+p <= min(m,n), got k={k} r={r}")

    Q = randomized_range_finder(A, r, power_iters=power_iters, seed=seed)
    B = A.project(Q)                              # (n × r), Bᵀ = QᵀA
    # Driver-local small SVD: Bᵀ = Ub Σ Vᵀ  ⇒  A ≈ (Q Ub) Σ Vᵀ.
    Ub, s, Vt = jnp.linalg.svd(B.T.astype(jnp.float32), full_matrices=False)
    info = {
        "mode": "randomized",
        "rank": r,
        "oversampling": oversampling,
        "power_iters": power_iters,
        "seed": seed,
        "passes_over_A": 2 + 2 * power_iters,
        # Convergence proxy: how much spectrum the oversampled tail still
        # carries.  Near-zero ⇒ the basis captured the top-k subspace; large
        # ⇒ raise oversampling / power_iters.
        "tail_ratio": (s[k] / jnp.maximum(s[0], 1e-30)) if r > k
        else jnp.float32(jnp.nan),
    }
    U = Q.multiply_local(Ub[:, :k]) if compute_u else None
    return U, s[:k], Vt[:k].T, info
