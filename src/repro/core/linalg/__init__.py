from .lanczos import lanczos_eigsh, svd_via_lanczos
from .svd import compute_svd, compute_pca, SVDResult, GRAM_THRESHOLD
from .tsqr import tsqr

__all__ = ["lanczos_eigsh", "svd_via_lanczos", "compute_svd", "compute_pca",
           "SVDResult", "GRAM_THRESHOLD", "tsqr"]
