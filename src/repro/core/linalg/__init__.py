from .lanczos import lanczos_eigsh, svd_via_lanczos
from .randsvd import randomized_svd
from .svd import (compute_svd, compute_pca, SVDResult, GRAM_THRESHOLD,
                  RANDOMIZED_K_THRESHOLD)
from .tsqr import tsqr

__all__ = ["lanczos_eigsh", "svd_via_lanczos", "randomized_svd",
           "compute_svd", "compute_pca", "SVDResult", "GRAM_THRESHOLD",
           "RANDOMIZED_K_THRESHOLD", "tsqr"]
