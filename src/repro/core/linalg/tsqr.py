"""Tall-and-skinny QR (paper §3.4, ref [2] Benson–Gleich–Demmel).

Indirect TSQR adapted from MapReduce to the mesh: each row shard computes a
local Householder QR (map), the small R factors are concatenated and
re-factored (reduce — on TPU this is an all-gather of n×n tiles followed by
a replicated QR, i.e. a driver/vector op), and Q is recovered by a
triangular solve against the broadcast R — the same "broadcast the small
factor" pattern as U-recovery in the SVD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.distmat import types as T
from repro.core.distmat.rowmatrix import RowMatrix

Array = jax.Array


def _nonneg_diag(R: Array) -> Array:
    """Fix the sign convention (R diagonal ≥ 0) for determinism."""
    d = jnp.sign(jnp.diagonal(R))
    d = jnp.where(d == 0, 1.0, d)
    return R * d[:, None]


def tsqr(A: RowMatrix) -> tuple[RowMatrix, Array]:
    """Returns (Q as RowMatrix, R replicated (n, n)) with A = Q R."""
    mesh, row_axes = A.mesh, A.row_axes
    spec = P(row_axes, None)
    n = A.rows.shape[1]

    def local_r(a):
        # Map step: local QR, keep only R.  Padding rows are zero and only
        # shrink the local R's column norms consistently — harmless.
        r = jnp.linalg.qr(a, mode="r")
        return _nonneg_diag(r)

    Rs = compat.shard_map(local_r, mesh=mesh, in_specs=(spec,),
                          out_specs=spec)(A.rows)    # (P·n, n) row-sharded
    # Reduce step: replicated second-level QR of the stacked R factors.
    R = _nonneg_diag(jnp.linalg.qr(
        T.put(Rs, T.replicated(mesh)), mode="r"))

    # Q = A R⁻¹ — form R⁻¹ once (replicated n×n triangular solve), then
    # broadcast it and recover Q with a per-shard autotuned GEMM — the same
    # "broadcast the small factor" pattern as U-recovery in the SVD, now
    # inheriting tuned block sizes from kernels/autotune.py on TPU.
    # Orthogonality of the recovered Q degrades as cond(R)·eps either way
    # (explicit-inverse multiply and per-shard back-substitution share that
    # bound); callers needing better than that for severely ill-conditioned
    # inputs should re-run tsqr on Q (one extra pass halves the defect).
    from repro.kernels import ops as _ops
    r_inv = jax.scipy.linalg.solve_triangular(
        R, jnp.eye(n, dtype=R.dtype), lower=False)

    def recover_q(a, ri):
        return _ops.gemm(a, ri, out_dtype=a.dtype)

    Q = compat.shard_map(recover_q, mesh=mesh, in_specs=(spec, P()),
                         out_specs=spec)(A.rows, r_inv)
    from dataclasses import replace
    return replace(A, rows=Q), R
