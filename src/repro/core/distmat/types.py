"""Mesh plumbing and the DistMatrix protocol.

The paper lays matrices out across a cluster as RDDs; here the cluster is a
TPU mesh and the layout is a NamedSharding.  Every distributed matrix type
carries (data, mesh, row_axes, col_axis) and exposes the same small protocol
(shape / matvec / rmatvec / to_local) so the linalg layer is representation
agnostic, exactly like MLlib's DistributedMatrix interface.

"Driver-local" quantities (the paper's vectors) are replicated arrays:
PartitionSpec() over the same mesh.  "Cluster" quantities are sharded.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

Array = jax.Array

# Default logical axis names.  Row-sharding uses the batch-like axes; column /
# block sharding uses the model axis.  The multi-pod mesh adds a leading
# "pod" axis which is treated as an extra row axis.
ROW_AXES = ("data",)
COL_AXIS = "model"


@functools.cache
def single_device_mesh() -> Mesh:
    """A (1, 1) mesh so the same shard_map code path runs on one CPU."""
    return compat.make_mesh((1, 1), ("data", "model"))


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    return compat.make_mesh(shape, names)


def row_axes_for(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes that shard rows: ('pod','data') on multi-pod meshes."""
    return tuple(n for n in mesh.axis_names if n != COL_AXIS)


def axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def row_sharding(mesh: Mesh, row_axes: Sequence[str] | None = None) -> NamedSharding:
    row_axes = tuple(row_axes) if row_axes is not None else row_axes_for(mesh)
    return NamedSharding(mesh, P(row_axes, None))


def block_sharding(mesh: Mesh, row_axes: Sequence[str] | None = None,
                   col_axis: str = COL_AXIS) -> NamedSharding:
    row_axes = tuple(row_axes) if row_axes is not None else row_axes_for(mesh)
    return NamedSharding(mesh, P(row_axes, col_axis))


def put(x: Array, sharding: NamedSharding) -> Array:
    """Place `x` with `sharding` (device_put works inside or outside jit)."""
    return jax.device_put(jnp.asarray(x), sharding)


def pad_rows(x: Array, multiple: int) -> tuple[Array, int]:
    """Pad axis 0 of `x` to a multiple; returns (padded, original_rows)."""
    m = x.shape[0]
    rem = (-m) % multiple
    if rem:
        pad_width = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad_width)
    return x, m


@dataclass(frozen=True)
class DistMatrix:
    """Base for distributed matrices; subclasses set `data` layout."""

    @property
    def shape(self) -> tuple[int, int]:  # pragma: no cover - abstract
        raise NotImplementedError

    def matvec(self, v: Array) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def rmatvec(self, u: Array) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def to_local(self) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def normal_op(self) -> Callable[[Array], Array]:
        """v ↦ Aᵀ(A v): the only operator ARPACK-style SVD ever needs."""
        return lambda v: self.rmatvec(self.matvec(v))
