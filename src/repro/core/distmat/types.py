"""Mesh plumbing and the DistMatrix protocol.

The paper lays matrices out across a cluster as RDDs; here the cluster is a
TPU mesh and the layout is a NamedSharding.  Every distributed matrix type
carries (data, mesh, row_axes, col_axis) and exposes the same small protocol
(shape / matvec / rmatvec / to_local) so the linalg layer is representation
agnostic, exactly like MLlib's DistributedMatrix interface.

"Driver-local" quantities (the paper's vectors) are replicated arrays:
PartitionSpec() over the same mesh.  "Cluster" quantities are sharded.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

Array = jax.Array

# Default logical axis names.  Row-sharding uses the batch-like axes; column /
# block sharding uses the model axis.  The multi-pod mesh adds a leading
# "pod" axis which is treated as an extra row axis.
ROW_AXES = ("data",)
COL_AXIS = "model"


@functools.cache
def single_device_mesh() -> Mesh:
    """A (1, 1) mesh so the same shard_map code path runs on one CPU."""
    return compat.make_mesh((1, 1), ("data", "model"))


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    return compat.make_mesh(shape, names)


def row_axes_for(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes that shard rows: ('pod','data') on multi-pod meshes."""
    return tuple(n for n in mesh.axis_names if n != COL_AXIS)


def axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def row_sharding(mesh: Mesh, row_axes: Sequence[str] | None = None) -> NamedSharding:
    row_axes = tuple(row_axes) if row_axes is not None else row_axes_for(mesh)
    return NamedSharding(mesh, P(row_axes, None))


def block_sharding(mesh: Mesh, row_axes: Sequence[str] | None = None,
                   col_axis: str = COL_AXIS) -> NamedSharding:
    row_axes = tuple(row_axes) if row_axes is not None else row_axes_for(mesh)
    return NamedSharding(mesh, P(row_axes, col_axis))


def put(x: Array, sharding: NamedSharding) -> Array:
    """Place `x` with `sharding` (device_put works inside or outside jit)."""
    return jax.device_put(jnp.asarray(x), sharding)


def pad_rows(x: Array, multiple: int) -> tuple[Array, int]:
    """Pad axis 0 of `x` to a multiple; returns (padded, original_rows)."""
    m = x.shape[0]
    rem = (-m) % multiple
    if rem:
        pad_width = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad_width)
    return x, m


def row_separable_inputs(smooth, m_pad: int, row_mask_fn):
    """Resolve a smooth (or its RowSeparable form) into fused-gradient
    kernel inputs: (kind, target, weights, param) with the data-space
    vectors padded to the sharded row count `m_pad`.  Default weights come
    from `row_mask_fn()` so padding rows contribute nothing; explicit
    weights are zero-padded, same effect.  `param` is the loss's static
    scalar (huber δ; 1.0 elsewhere).  Shared by RowMatrix.fused_grad and
    SparseRowMatrix.fused_grad."""
    sep = smooth if hasattr(smooth, "kind") else (
        smooth.as_row_separable()
        if hasattr(smooth, "as_row_separable") else None)
    if sep is None:
        raise ValueError("fused_grad needs a row-separable smooth")
    t = jnp.asarray(sep.target)
    t = jnp.pad(t, (0, m_pad - t.shape[0])) if t.shape[0] < m_pad else t
    if sep.weights is None:
        w = row_mask_fn()
    else:
        w = jnp.asarray(sep.weights)
        w = jnp.pad(w, (0, m_pad - w.shape[0])) if w.shape[0] < m_pad else w
    return sep.kind, t, w, float(getattr(sep, "param", 1.0))


def row_separable_batch_inputs(smooths, m_pad: int, row_mask_fn):
    """Resolve a *group* of row-separable smooths into multi-RHS fused
    kernel inputs: (kind, targets (k × m_pad), weights (k × m_pad), param).

    `smooths` is either a sequence of k smooths (all must share the same
    loss kind and static param — that is what makes them one servable
    group) or a single smooth whose target/weights are already stacked
    2-D (k × m) arrays.  Shared by RowMatrix.fused_grad_multi and
    SparseRowMatrix.fused_grad_multi."""
    def resolve(s):
        sep = s if hasattr(s, "kind") else (
            s.as_row_separable() if hasattr(s, "as_row_separable") else None)
        if sep is None:
            raise ValueError("fused_grad_multi needs row-separable smooths")
        return sep

    if not isinstance(smooths, (list, tuple)):
        sep = resolve(smooths)
        t = jnp.atleast_2d(jnp.asarray(sep.target))
        seps = [sep]
        ts = [t[i] for i in range(t.shape[0])]
        ws = ([None] * t.shape[0] if sep.weights is None else
              [jnp.atleast_2d(jnp.asarray(sep.weights))[i]
               for i in range(t.shape[0])])
    else:
        seps = [resolve(s) for s in smooths]
        ts = [jnp.asarray(s.target) for s in seps]
        ws = [None if s.weights is None else jnp.asarray(s.weights)
              for s in seps]

    kinds = {s.kind for s in seps}
    params = {float(getattr(s, "param", 1.0)) for s in seps}
    if len(kinds) != 1 or len(params) != 1:
        raise ValueError(
            f"a fused group must share one loss kind/param, got "
            f"{sorted(kinds)} / {sorted(params)}")

    mask = row_mask_fn()

    def pad1(v):
        return jnp.pad(v, (0, m_pad - v.shape[0])) if v.shape[0] < m_pad else v

    t2 = jnp.stack([pad1(t) for t in ts])
    w2 = jnp.stack([mask if w is None else pad1(w) for w in ws])
    return kinds.pop(), t2, w2, params.pop()


def dimsum_variance(s2: Array, p: Array) -> Array:
    """Per-pair sampled-DIMSUM estimator variance,
        Var[ŝᵢⱼ] = Σ_k (ã_ki ã_kj)² · (1/(pᵢpⱼ) − 1),
    from the Gram `s2` of the squared column-scaled matrix and the
    per-column keep probabilities `p`.  The diagonal is written exactly by
    the estimator, so its variance is 0.  Shared by both distmat types."""
    n = p.shape[0]
    pp = p[:, None] * p[None, :]
    var = s2 * jnp.where(pp > 0, 1.0 / jnp.maximum(pp, 1e-30) - 1.0, 0.0)
    return var.at[jnp.arange(n), jnp.arange(n)].set(0.0)


@dataclass(frozen=True)
class DistMatrix:
    """Base for distributed matrices; subclasses set `data` layout."""

    @property
    def shape(self) -> tuple[int, int]:  # pragma: no cover - abstract
        raise NotImplementedError

    def matvec(self, v: Array) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def rmatvec(self, u: Array) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def to_local(self) -> Array:  # pragma: no cover - abstract
        raise NotImplementedError

    def normal_op(self) -> Callable[[Array], Array]:
        """v ↦ Aᵀ(A v): the only operator ARPACK-style SVD ever needs."""
        return lambda v: self.rmatvec(self.matvec(v))
