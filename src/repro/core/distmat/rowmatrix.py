"""RowMatrix / IndexedRowMatrix — row-sharded distributed matrices.

Paper §2.1: "a row-oriented distributed matrix ... backed by an RDD of its
rows, where each row is a local vector".  On the TPU mesh the RDD becomes a
2-D array sharded over the row axes (('pod','data') on multi-pod meshes) and
"local vector" means the row lives whole inside one device's HBM shard.

All cluster/driver separation from the paper is explicit here:
  * matrix ops (gram, matvec, multiply_local, column stats) are `shard_map`
    bodies — they run on the cluster shards with explicit collectives;
  * vector results (gram output, rmatvec output, stats) come back replicated
    (the "driver" copy, which on a TPU pod is every chip redundantly).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from . import types as T

Array = jax.Array


def _shard_index(axes: Sequence[str]) -> Array:
    """Flat index of this shard along the given (major→minor) mesh axes."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def chunk_bounds(n: int, chunks: int) -> tuple[tuple[int, int], ...]:
    """Static column-segment bounds for the overlapped collective bodies:
    `chunks` contiguous [s0, s1) segments covering [0, n)."""
    c = max(min(int(chunks), n), 1)
    step = -(-n // c)
    return tuple((s0, min(s0 + step, n)) for s0 in range(0, n, step))


def _record_collective(plan, span, **attrs) -> None:
    """Plan-vs-actual for one distributed op: the span's synced duration
    next to the comm-priced plan (launch/telemetry collects the records;
    their comm terms feed MachineModel.calibrate's link column)."""
    from repro.launch import telemetry as _tel
    rec = _tel.current()
    if rec.enabled and span.dur_s > 0:
        rec.record_plan_actual(plan, span.dur_s, **attrs)


@dataclass(frozen=True)
class RowMatrix(T.DistMatrix):
    rows: Array                      # (m_padded, n), sharded P(row_axes, None)
    n_rows: int                      # true row count (pre-padding)
    mesh: Mesh = field(repr=False)
    row_axes: tuple[str, ...] = T.ROW_AXES

    # -- construction ------------------------------------------------------
    @staticmethod
    def create(rows: Array, mesh: Mesh | None = None,
               row_axes: Sequence[str] | None = None,
               store_dtype=None) -> "RowMatrix":
        """`store_dtype` (bf16/fp8 where the platform supports it) keeps
        the sharded residency at reduced width; every compute path upcasts
        tiles on-chip and accumulates float32, so results come back at the
        logical `out_dtype` (f32 for sub-f32 storage)."""
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        nshards = T.axes_size(mesh, row_axes)
        rows = jnp.asarray(rows)
        if store_dtype is not None:
            rows = rows.astype(store_dtype)
        padded, m = T.pad_rows(rows, nshards)
        padded = T.put(padded, NamedSharding(mesh, P(row_axes, None)))
        return RowMatrix(rows=padded, n_rows=m, mesh=mesh, row_axes=row_axes)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n_rows, self.rows.shape[1])

    @property
    def out_dtype(self):
        """Logical result dtype: float32 when storage is sub-f32 (bf16/
        fp8) — low-precision residency never narrows the math the caller
        sees."""
        d = self.rows.dtype
        return jnp.dtype(jnp.float32) if d.itemsize < 4 else d

    def astype_store(self, dtype) -> "RowMatrix":
        """Recast the sharded storage (the planner's bf16 pick lands
        here).  Row padding and sharding are preserved; identity when the
        dtype already matches."""
        dtype = jnp.dtype(dtype)
        if dtype == self.rows.dtype:
            return self
        return replace(self, rows=self.rows.astype(dtype))

    @property
    def _spec(self) -> P:
        return P(self.row_axes, None)

    def _smap(self, f, in_specs, out_specs):
        return compat.shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs)

    def _local_rows(self) -> int:
        return self.rows.shape[0] // T.axes_size(self.mesh, self.row_axes)

    def _collective_plan(self, op: str, dims):
        """Comm-priced plan for a distributed op on this mesh: per-shard
        dims + the row-axis device counts as the collective topology."""
        from repro.launch import mesh as _mesh
        from repro.launch import planner as _planner
        return _planner.plan(
            op, dims, self.rows.dtype.name,
            context={"axes": _mesh.axis_sizes(self.mesh, self.row_axes)})

    def _resolve_chunks(self, chunks, plan) -> int:
        """The overlap chunk count: planner-chosen on "auto" (1 = eager),
        else the caller's explicit override (tests force both paths)."""
        if chunks == "auto":
            return int(plan.blocks.get("chunks", 1))
        return max(int(chunks), 1)

    def _row_mask(self) -> Array:
        """Row-sharded {0,1} mask of true (non-padding) rows."""
        m, nshards = self.n_rows, T.axes_size(self.mesh, self.row_axes)
        local = self.rows.shape[0] // nshards
        axes = self.row_axes

        def body():
            start = _shard_index(axes) * local
            return ((start + jnp.arange(local)) < m).astype(self.out_dtype)

        return self._smap(body, in_specs=(), out_specs=P(self.row_axes))()

    # -- cluster matrix ops --------------------------------------------------
    def gram(self, *, chunks: int | str = "auto") -> Array:
        """AᵀA, replicated — the paper's one-all-to-one DIMSUM reduction.

        Per-shard partial Gram then an all-reduce over the row axes.  The
        shard reduction is the Pallas tsgram kernel (autotuned block sizes)
        on TPU; on CPU `ops.tsgram` dispatches to the jnp reference, which
        stays the ground truth.  Padding rows are zero so they do not
        contribute.

        `chunks` > 1 runs the comm-overlapped schedule the planner prices
        (plan("gram") with this mesh's axis sizes): C column-segment
        cross-grams Aᵀ·A[:, seg], each segment's partial psum pipelined
        behind the next segment's compute.  Every segment is the same
        columns of the same product, so the result is bit-identical to the
        eager body; "auto" defers to the planner (1 — eager — unless the
        modeled collective dominates the extra A reads).
        """
        from repro.kernels import ops as _ops
        from repro.launch import telemetry as _tel
        axes = self.row_axes
        n = self.rows.shape[1]
        plan = self._collective_plan("gram", {"m": self._local_rows(),
                                              "n": n})
        c = self._resolve_chunks(chunks, plan)

        if c <= 1:
            def body(a):
                g = _ops.tsgram(a, out_dtype=jnp.float32)
                return jax.lax.psum(g, axes)
        else:
            bounds = chunk_bounds(n, c)

            def body(a):
                parts = [jax.lax.psum(
                    _ops.randsketch(a, a[:, s0:s1], out_dtype=jnp.float32),
                    axes) for s0, s1 in bounds]
                return jnp.concatenate(parts, axis=1)

        with _tel.current().span("collective.gram", op="gram", n=n,
                                 chunks=c) as sp:
            out = self._smap(body, in_specs=(self._spec,),
                             out_specs=P())(self.rows)
            sp.sync_on(out)
        _record_collective(plan, sp, collective="psum", chunks=c)
        return out.astype(self.out_dtype)

    def matvec(self, v: Array) -> Array:
        """A v with v replicated (driver) → row-sharded result (cluster)."""
        def body(a, v):
            return a @ v

        return self._smap(body, in_specs=(self._spec, P()),
                          out_specs=P(self.row_axes))(self.rows, v)

    def rmatvec(self, u: Array) -> Array:
        """Aᵀ u with u row-sharded → replicated n-vector (back to driver)."""
        from repro.launch import telemetry as _tel
        axes = self.row_axes
        plan = self._collective_plan("matvec", {"m": self._local_rows(),
                                                "n": self.rows.shape[1]})

        def body(a, u):
            return jax.lax.psum(a.T @ u, axes)

        with _tel.current().span("collective.rmatvec", op="matvec",
                                 n=self.rows.shape[1]) as sp:
            out = self._smap(body, in_specs=(self._spec, P(self.row_axes)),
                             out_specs=P())(self.rows, u)
            sp.sync_on(out)
        _record_collective(plan, sp, collective="psum")
        return out

    def init_psum_residual(self) -> Array:
        """Zeroed per-shard f32 error-feedback residual for the compressed
        ("psum8") fused_grad reduction: one (n,) row per row shard, laid
        out P(row_axes, None) so each shard owns exactly its own row."""
        nshards = T.axes_size(self.mesh, self.row_axes)
        z = jnp.zeros((nshards, self.rows.shape[1]), jnp.float32)
        return T.put(z, NamedSharding(self.mesh, P(self.row_axes, None)))

    def fused_grad(self, x: Array, smooth, *, chunks: int | str = "auto",
                   residual: Array | None = None):
        """(f(Ax), Aᵀ∇f(Ax), Ax) in ONE streaming pass over the shard — the
        paper's one-pass treeAggregate gradient, fused on-chip
        (kernels/fusedgrad).  `smooth` is a row-separable smooth (or its
        RowSeparable form); its target/weights are data-space vectors and
        get padded to the sharded row count, with padding rows weighted 0.
        Returns (replicated f32 scalar, replicated (n,) gradient,
        row-sharded image).

        `chunks` > 1 runs the planner's overlapped schedule (plan("grad")
        with this mesh's axis sizes, blocks["chunks"]): one full pass
        computes the image and row residual with the exact
        ``fused_grad_jnp`` math, then the gradient is assembled per column
        segment — r·A[:, seg] — with each segment's partial psum pipelined
        behind the next segment's compute.  Segmented psums of the same
        products make it bit-identical to the eager body; the price (one
        extra read of A) is the planner's break-even, so "auto" stays
        eager until the modeled collective dominates.

        `residual` (from init_psum_residual) switches the gradient psum to
        the compressed int8 wire (train.compression.psum_int8): shards
        quantize their partials against a shared pmax'd scale, the
        all-reduce ships int8, and the quantization error is carried in
        the returned residual for re-injection next call.  Returns a
        4-tuple (f, g, z, new_residual) in that mode."""
        from repro.kernels import fusedgrad as _fg
        from repro.kernels import ops as _ops
        from repro.launch import telemetry as _tel
        from repro.train import compression as _comp
        axes = self.row_axes
        nshards = T.axes_size(self.mesh, self.row_axes)
        kind, t, w, prm = T.row_separable_inputs(smooth, self.rows.shape[0],
                                                 self._row_mask)
        x = jnp.asarray(x)
        n = self.rows.shape[1]
        plan = self._collective_plan("grad", {"m": self._local_rows(),
                                              "n": n})
        c = self._resolve_chunks(chunks, plan)

        if c <= 1:
            def body(a, x, t, w, *res):
                f, g, z = _ops.fused_grad(a, x, t, w, loss=kind, param=prm)
                if res:
                    g, nres = _comp.psum_int8(g, res[0][0], axes, nshards)
                    return (jax.lax.psum(f, axes), g, z, nres[None])
                return jax.lax.psum(f, axes), jax.lax.psum(g, axes), z
        else:
            bounds = chunk_bounds(n, c)

            def body(a, x, t, w, *res):
                # Phase 1 — image + row residual, the exact math of
                # kernels.fusedgrad.fused_grad_jnp (the eager CPU path).
                z = jnp.dot(a, x, preferred_element_type=jnp.float32)
                f, r = _fg.row_loss_grad(z, t, w, kind, prm)
                rc = r.astype(a.dtype) if a.dtype == jnp.float32 else r
                # Phase 2 — per-segment gradient; segment k's partial psum
                # overlaps segment k+1's contraction.
                if res:
                    gs, rs = [], []
                    for s0, s1 in bounds:
                        part = jnp.dot(rc, a[:, s0:s1],
                                       preferred_element_type=jnp.float32)
                        gseg, rseg = _comp.psum_int8(
                            part, res[0][0, s0:s1], axes, nshards)
                        gs.append(gseg)
                        rs.append(rseg)
                    return (jax.lax.psum(f, axes), jnp.concatenate(gs), z,
                            jnp.concatenate(rs)[None])
                gs = [jax.lax.psum(
                    jnp.dot(rc, a[:, s0:s1],
                            preferred_element_type=jnp.float32)
                    .astype(x.dtype), axes) for s0, s1 in bounds]
                return jax.lax.psum(f, axes), jnp.concatenate(gs), z

        wire = "int8" if residual is not None else "f32"
        with _tel.current().span("collective.fused_grad", op="grad", n=n,
                                 chunks=c, wire=wire) as sp:
            if residual is None:
                f, g, z = self._smap(
                    body,
                    in_specs=(self._spec, P(), P(self.row_axes),
                              P(self.row_axes)),
                    out_specs=(P(), P(), P(self.row_axes)))(self.rows, x,
                                                            t, w)
                out = (f, g, z)
            else:
                f, g, z, nres = self._smap(
                    body,
                    in_specs=(self._spec, P(), P(self.row_axes),
                              P(self.row_axes), self._spec),
                    out_specs=(P(), P(), P(self.row_axes),
                               self._spec))(self.rows, x, t, w, residual)
                out = (f, g, z, nres)
            sp.sync_on(g)
        _record_collective(plan, sp, collective="psum", chunks=c, wire=wire)
        return out

    def fused_grad_multi(self, x: Array, smooths
                         ) -> tuple[Array, Array, Array]:
        """Request-batched fused gradients: (f, g, z) for a GROUP of k
        right-hand sides in ONE streaming pass over the shard — each HBM
        read of an A block is amortized across every request.  `x` is
        (k × n); `smooths` is a sequence of k row-separable smooths sharing
        one loss kind/param (or a single smooth with stacked 2-D targets).
        Returns (replicated (k,) values, replicated (k × n) gradients,
        image sharded (k × m) over the row axes)."""
        from repro.kernels import ops as _ops
        axes = self.row_axes
        kind, t, w, prm = T.row_separable_batch_inputs(
            smooths, self.rows.shape[0], self._row_mask)
        x = jnp.atleast_2d(jnp.asarray(x))

        def body(a, x, t, w):
            f, g, z = _ops.fused_grad_multi(a, x, t, w, loss=kind, param=prm)
            return jax.lax.psum(f, axes), jax.lax.psum(g, axes), z

        f, g, z = self._smap(
            body,
            in_specs=(self._spec, P(), P(None, self.row_axes),
                      P(None, self.row_axes)),
            out_specs=(P(), P(), P(None, self.row_axes)))(self.rows, x, t, w)
        return f, g, z

    def multiply_local(self, B: Array) -> "RowMatrix":
        """A @ B for a small replicated B — the `U = A (VΣ⁻¹)` pattern:
        broadcast the small factor, then embarrassingly parallel (autotuned
        Pallas GEMM per shard on TPU, jnp reference on CPU)."""
        from repro.kernels import ops as _ops

        def body(a, b):
            return _ops.gemm(a, b, out_dtype=a.dtype)

        out = self._smap(body, in_specs=(self._spec, P()),
                         out_specs=self._spec)(self.rows, B)
        return replace(self, rows=out)

    def sketch(self, r: int, *, seed: int = 0) -> "RowMatrix":
        """Y = A Ω for an (n × r) Gaussian test matrix Ω (randomized
        range finder).  Ω is generated *inside* each shard from the shared
        seed — every chip derives the identical Ω locally, so the sketch
        matrix is never materialized on (or broadcast from) the driver;
        the only HBM traffic is one pass over A."""
        n = self.rows.shape[1]

        def body(a):
            key = jax.random.PRNGKey(seed)       # same key ⇒ same Ω per shard
            omega = jax.random.normal(key, (n, r), a.dtype)
            return a @ omega

        out = self._smap(body, in_specs=(self._spec,),
                         out_specs=self._spec)(self.rows)
        return replace(self, rows=out)

    def project(self, Q: "RowMatrix", *, out_dtype=jnp.float32) -> Array:
        """B = AᵀQ for a row-conforming Q, replicated — the randomized-SVD
        projection: per-shard streaming cross-Gram (Pallas randsketch
        kernel) then a tree all-reduce over the row axes.  Padding rows are
        zero in both operands so they do not contribute."""
        from repro.kernels import ops as _ops
        axes = self.row_axes

        def body(a, q):
            partial = _ops.randsketch(a, q, out_dtype=jnp.float32)
            return jax.lax.psum(partial, axes)

        out = self._smap(body, in_specs=(self._spec, self._spec),
                         out_specs=P())(self.rows, Q.rows)
        return out.astype(out_dtype)

    def scale_columns(self, d: Array) -> "RowMatrix":
        """A · diag(d) with replicated d (DIMSUM column scaling)."""
        def body(a, d):
            return a * d[None, :]

        out = self._smap(body, in_specs=(self._spec, P()),
                         out_specs=self._spec)(self.rows, d)
        return replace(self, rows=out)

    def column_stats(self) -> dict[str, Array]:
        """Replicated per-column statistics (MLlib colStats)."""
        axes, m = self.row_axes, self.n_rows
        mask = self._row_mask()

        def body(a, mask):
            am = a * mask[:, None]
            s = jax.lax.psum(am.sum(0), axes)
            sq = jax.lax.psum((am * am).sum(0), axes)
            nnz = jax.lax.psum((am != 0).sum(0), axes)
            big = jnp.asarray(jnp.inf, a.dtype)
            sel_lo = jnp.where(mask[:, None] > 0, a, big)
            sel_hi = jnp.where(mask[:, None] > 0, a, -big)
            mn = jax.lax.pmin(sel_lo.min(0), axes)
            mx = jax.lax.pmax(sel_hi.max(0), axes)
            return s, sq, nnz, mn, mx

        s, sq, nnz, mn, mx = self._smap(
            body, in_specs=(self._spec, P(self.row_axes)),
            out_specs=(P(), P(), P(), P(), P()))(self.rows, mask)
        mean = s / m
        var = jnp.maximum(sq / m - mean * mean, 0.0) * (m / max(m - 1, 1))
        return {"mean": mean, "variance": var, "num_nonzeros": nnz,
                "min": mn, "max": mx, "norm_l2": jnp.sqrt(sq)}

    def column_similarities(self, threshold: float = 0.0, *,
                            gamma: float | None = None,
                            seed: int = 0, return_info: bool = False):
        """DIMSUM cosine similarity of columns (paper refs [10, 11]).

        threshold=0 (the default) computes cos(i,j) = (AᵀA)ij/(‖cᵢ‖‖cⱼ‖)
        exactly via the scaled Gram — on ICI the one-all-reduce reduction is
        bandwidth-optimal.  threshold>0 runs *sampled* DIMSUM: entries of
        column i survive with probability pᵢ = min(1, √γ/‖cᵢ‖), so a pair
        (i, j) is sampled with the paper's oversampling probability
        min(1, γ/‖cᵢ‖‖cⱼ‖); kept entries are rescaled by 1/pᵢ, making the
        estimator unbiased off the diagonal (the diagonal is written exactly
        — its value is known).  γ defaults to 10·log(n)/threshold, which
        preserves all similarities ≥ threshold w.h.p.  Sampling happens
        per shard from a fold_in'd key, so no randomness crosses the
        interconnect.

        return_info=True returns (sim, info) where info carries the sampling
        diagnostics: γ, the per-column keep probabilities p, and the
        per-pair variance of the estimator,
            Var[ŝᵢⱼ] = Σ_k (a_ki a_kj)² / (‖cᵢ‖²‖cⱼ‖²) · (1/(pᵢpⱼ) − 1),
        computed exactly via one extra Gram over the squared scaled matrix
        — it shrinks to 0 as γ grows (all pᵢ → 1).
        """
        from repro.kernels import ops as _ops
        norms = self.column_stats()["norm_l2"]
        inv = jnp.where(norms > 0, 1.0 / jnp.maximum(norms, 1e-30), 0.0)
        n = self.shape[1]
        if threshold <= 0.0:
            sim = self.scale_columns(inv).gram()
            if not return_info:
                return sim
            return sim, {"gamma": None, "p": jnp.ones((n,), jnp.float32),
                         "variance": jnp.zeros((n, n), jnp.float32)}
        from .sparserow import dimsum_gamma
        g = gamma if gamma is not None else dimsum_gamma(n, threshold)
        p = jnp.minimum(1.0, float(np.sqrt(g)) * inv)
        scale = inv * jnp.where(p > 0, 1.0 / p, 0.0)
        axes = self.row_axes

        def body(a, p, scale):
            key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                     _shard_index(axes))
            keep = jax.random.uniform(key, a.shape) < p[None, :]
            b = jnp.where(keep, a, 0.0) * scale[None, :]
            return jax.lax.psum(_ops.tsgram(b, out_dtype=jnp.float32), axes)

        sim = self._smap(body, in_specs=(self._spec, P(), P()),
                         out_specs=P())(self.rows, p, scale)
        sim = sim.astype(self.out_dtype)
        diag = (norms > 0).astype(sim.dtype)
        sim = sim.at[jnp.arange(n), jnp.arange(n)].set(diag)
        if not return_info:
            return sim
        scaled = self.scale_columns(inv)
        sq = replace(scaled, rows=scaled.rows * scaled.rows)
        s2 = sq.gram().astype(jnp.float32)       # Σ_k (ãki ãkj)², ã scaled
        var = T.dimsum_variance(s2, p)
        return sim, {"gamma": g, "p": p, "variance": var}

    def remesh(self, mesh: Mesh, row_axes: Sequence[str] | None = None
               ) -> "RowMatrix":
        """Re-shard the SAME logical matrix onto a different mesh (elastic
        re-mesh, train/elastic): strip the old mesh's padding rows, re-pad
        for the new shard count and device_put with the new sharding.  Used
        mid-solve after a straggler/device loss — the solver state (driver
        vectors) is mesh-independent, so only the matrix moves."""
        return RowMatrix.create(self.rows[: self.n_rows], mesh, row_axes)

    def to_sparse_row_matrix(self, bs: int | str = "auto"):
        """Block-compress into the BSR-backed sparse type (driver-scale,
        like the other format conversions)."""
        from .sparserow import SparseRowMatrix
        return SparseRowMatrix.from_dense(self.to_local(), bs=bs,
                                          mesh=self.mesh,
                                          row_axes=self.row_axes)

    def frobenius_norm(self) -> Array:
        def body(a):
            return jax.lax.psum((a * a).sum(), self.row_axes)

        return jnp.sqrt(self._smap(body, in_specs=(self._spec,),
                                   out_specs=P())(self.rows))

    # -- materialization ----------------------------------------------------
    def to_local(self) -> Array:
        return jax.device_get(self.rows)[: self.n_rows]

    # -- linalg entry points (implemented in core.linalg) -------------------
    def compute_svd(self, k: int, **kw):
        from repro.core.linalg import svd as _svd
        return _svd.compute_svd(self, k, **kw)

    def compute_pca(self, k: int, **kw):
        from repro.core.linalg import svd as _svd
        return _svd.compute_pca(self, k, **kw)

    def tall_skinny_qr(self):
        from repro.core.linalg import tsqr as _tsqr
        return _tsqr.tsqr(self)


@dataclass(frozen=True)
class IndexedRowMatrix(T.DistMatrix):
    """RowMatrix plus meaningful long-typed row indices (paper §2.1)."""
    indices: Array                   # (m_padded,), int32/64, row-sharded
    inner: RowMatrix

    @staticmethod
    def create(indices: Array, rows: Array, mesh: Mesh | None = None,
               row_axes: Sequence[str] | None = None) -> "IndexedRowMatrix":
        rm = RowMatrix.create(rows, mesh, row_axes)
        nshards = T.axes_size(rm.mesh, rm.row_axes)
        idx, _ = T.pad_rows(jnp.asarray(indices), nshards)
        idx = T.put(idx, NamedSharding(rm.mesh, P(rm.row_axes)))
        return IndexedRowMatrix(indices=idx, inner=rm)

    @property
    def shape(self) -> tuple[int, int]:
        return self.inner.shape

    def to_row_matrix(self) -> RowMatrix:
        return self.inner

    def matvec(self, v: Array) -> Array:
        return self.inner.matvec(v)

    def rmatvec(self, u: Array) -> Array:
        return self.inner.rmatvec(u)

    def to_local(self) -> Array:
        idx = np.asarray(jax.device_get(self.indices))[: self.inner.n_rows]
        dense = np.asarray(self.inner.to_local())
        out = np.zeros((int(idx.max()) + 1 if idx.size else 0,
                        dense.shape[1]), dense.dtype)
        out[idx] = dense
        return jnp.asarray(out)
