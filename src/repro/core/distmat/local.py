"""Local vectors and matrices (paper §2.4 and §4.2).

MLlib ships dense/sparse local vectors and a CCS-format SparseMatrix with
hand-rolled SpMM/SpMV kernels.  On TPU, unstructured scalar gathers do not
pay, so the CCS layout here is the *reference* implementation (pure jnp,
used as the oracle for kernels/bsr.py) and the production path converts to
MXU-friendly block-CSR (see repro/kernels/bsr.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class SparseVector:
    size: int
    indices: Array   # (nnz,) int32, sorted
    values: Array    # (nnz,)

    @staticmethod
    def from_dense(v: Array) -> "SparseVector":
        v = np.asarray(v)
        (idx,) = np.nonzero(v)
        return SparseVector(int(v.shape[0]), jnp.asarray(idx, jnp.int32),
                            jnp.asarray(v[idx]))

    def to_dense(self) -> Array:
        return jnp.zeros((self.size,),
                         self.values.dtype).at[self.indices].set(self.values)

    def dot(self, other: Array) -> Array:
        return jnp.sum(self.values * other[self.indices])


@dataclass(frozen=True)
class SparseMatrixCSC:
    """Compressed Column Storage, exactly as described in paper §4.2:
    row indices + values per nonzero, column extents in `col_ptr`."""
    shape: tuple[int, int]
    col_ptr: Array    # (n+1,) int32
    row_idx: Array    # (nnz,) int32
    values: Array     # (nnz,)

    @staticmethod
    def from_dense(a: Array) -> "SparseMatrixCSC":
        a = np.asarray(a)
        m, n = a.shape
        cols, rows, vals = [], [], [0]
        for j in range(n):
            (nz,) = np.nonzero(a[:, j])
            rows.extend(nz.tolist())
            cols.extend(a[nz, j].tolist())
            vals.append(len(rows))
        return SparseMatrixCSC(
            (m, n), jnp.asarray(vals, jnp.int32),
            jnp.asarray(rows, jnp.int32), jnp.asarray(cols))

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    def _col_of_nnz(self) -> Array:
        """Column index of each stored nonzero (from col_ptr extents)."""
        n = self.shape[1]
        return jnp.searchsorted(self.col_ptr[1:], jnp.arange(self.nnz),
                                side="right").astype(jnp.int32)

    def matvec(self, x: Array, transpose: bool = False) -> Array:
        """SpMV (optionally Aᵀx), matching MLlib's specialized kernels."""
        col = self._col_of_nnz()
        if transpose:
            contrib = self.values * x[self.row_idx]
            return jax.ops.segment_sum(contrib, col,
                                       num_segments=self.shape[1])
        contrib = self.values * x[col]
        return jax.ops.segment_sum(contrib, self.row_idx,
                                   num_segments=self.shape[0])

    def matmat(self, B: Array, transpose: bool = False) -> Array:
        """SpMM: Sparse × Dense (optionally AᵀB)."""
        col = self._col_of_nnz()
        if transpose:
            contrib = self.values[:, None] * B[self.row_idx]
            return jax.ops.segment_sum(contrib, col,
                                       num_segments=self.shape[1])
        contrib = self.values[:, None] * B[col]
        return jax.ops.segment_sum(contrib, self.row_idx,
                                   num_segments=self.shape[0])

    def to_dense(self) -> Array:
        col = self._col_of_nnz()
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[self.row_idx, col].add(self.values)
