"""BlockMatrix — 2-D block-sharded distributed matrix (paper §2.3).

The RDD of ((bi, bj), Matrix) tiles becomes one logical 2-D array sharded
over BOTH mesh axes: P(row_axes, 'model').  Each device owns one
(m/R) × (n/C) dense tile in HBM — the direct analogue of "each block small
enough to fit in memory on a single machine".

`multiply` is SUMMA adapted to ICI: instead of the Spark shuffle-join of
block pairs, each device all-gathers its row panel of A (along 'model') and
its column panel of B (along the row axes) and performs one local MXU GEMM.
Per-device communication is k·(m/R + n/C) — the textbook SUMMA volume — and
the result is already in canonical layout, no reduction step needed.

Also here: the "vector as RDD" mode from paper §1.2 — matvec where the
parameter vector itself is sharded over the model axis (large linear model
parallelism, refs [4, 9]).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from . import types as T

Array = jax.Array


@dataclass(frozen=True)
class BlockMatrix(T.DistMatrix):
    data: Array                     # (m_pad, n_pad) sharded P(row_axes, col)
    dims: tuple[int, int]           # true (m, n)
    mesh: Mesh = field(repr=False)
    row_axes: tuple[str, ...] = T.ROW_AXES
    col_axis: str = T.COL_AXIS

    @staticmethod
    def create(x: Array, mesh: Mesh | None = None,
               row_axes: Sequence[str] | None = None,
               col_axis: str = T.COL_AXIS,
               block_rows: int | None = None,
               block_cols: int | None = None) -> "BlockMatrix":
        """`block_rows/cols` are advisory (Spark's rowsPerBlock); the actual
        tile size is the shard size — we validate compatibility instead."""
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        R = T.axes_size(mesh, row_axes)
        C = mesh.shape[col_axis]
        x = jnp.asarray(x)
        m, n = x.shape
        x, _ = T.pad_rows(x, R)
        x = jnp.swapaxes(T.pad_rows(jnp.swapaxes(x, 0, 1), C)[0], 0, 1)
        x = T.put(x, NamedSharding(mesh, P(row_axes, col_axis)))
        return BlockMatrix(data=x, dims=(m, n), mesh=mesh,
                           row_axes=row_axes, col_axis=col_axis)

    # -- bookkeeping ---------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return self.dims

    @property
    def block_shape(self) -> tuple[int, int]:
        R = T.axes_size(self.mesh, self.row_axes)
        C = self.mesh.shape[self.col_axis]
        return (self.data.shape[0] // R, self.data.shape[1] // C)

    def validate(self) -> None:
        """Paper's `validate`: block grid consistent with the declared mesh."""
        R = T.axes_size(self.mesh, self.row_axes)
        C = self.mesh.shape[self.col_axis]
        mp, np_ = self.data.shape
        if mp % R or np_ % C:
            raise ValueError(
                f"padded shape {self.data.shape} not divisible by mesh grid "
                f"({R}, {C})")
        if mp < self.dims[0] or np_ < self.dims[1]:
            raise ValueError("padded storage smaller than logical dims")
        want = NamedSharding(self.mesh, P(self.row_axes, self.col_axis))
        got = self.data.sharding
        if not got.is_equivalent_to(want, self.data.ndim):
            raise ValueError(f"bad sharding {got}, want {want}")

    def _smap(self, f, in_specs, out_specs):
        return compat.shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs)

    @property
    def _spec(self) -> P:
        return P(self.row_axes, self.col_axis)

    # -- paper API: add / multiply -------------------------------------------
    def add(self, other: "BlockMatrix") -> "BlockMatrix":
        if self.dims != other.dims:
            raise ValueError(f"dim mismatch {self.dims} vs {other.dims}")
        out = self._smap(jnp.add, in_specs=(self._spec, self._spec),
                         out_specs=self._spec)(self.data, other.data)
        return BlockMatrix(out, self.dims, self.mesh, self.row_axes,
                           self.col_axis)

    def multiply(self, other: "BlockMatrix") -> "BlockMatrix":
        """SUMMA: all-gather row/column panels, one local GEMM, no shuffle."""
        if self.dims[1] != other.dims[0]:
            raise ValueError(f"inner dim mismatch {self.dims} @ {other.dims}")
        if self.data.shape[1] != other.data.shape[0]:
            # Same logical k but different padding — re-pad other.
            other = BlockMatrix.create(other.to_local(), self.mesh,
                                       self.row_axes, self.col_axis)
        from repro.kernels import ops as _ops
        rows, col = self.row_axes, self.col_axis

        def body(a, b):
            # a: (m/R, k/C) at (r, c); b: (k/R, n/C) at (r, c).  The local
            # panel product is the autotuned Pallas GEMM on TPU (jnp
            # reference on CPU, identical f32-accumulated semantics).
            a_row = jax.lax.all_gather(a, col, axis=1, tiled=True)   # (m/R, k)
            b_col = jax.lax.all_gather(b, rows, axis=0, tiled=True)  # (k, n/C)
            return _ops.gemm(a_row, b_col,
                             out_dtype=jnp.float32).astype(a.dtype)

        out = self._smap(body, in_specs=(self._spec, self._spec),
                         out_specs=self._spec)(self.data, other.data)
        return BlockMatrix(out, (self.dims[0], other.dims[1]), self.mesh,
                           self.row_axes, self.col_axis)

    def transpose(self) -> "BlockMatrix":
        out = T.put(self.data.T, NamedSharding(
            self.mesh, P(self.row_axes, self.col_axis)))
        return BlockMatrix(out, (self.dims[1], self.dims[0]), self.mesh,
                           self.row_axes, self.col_axis)

    # -- matvec family ---------------------------------------------------------
    def matvec(self, v: Array) -> Array:
        """A v, v replicated → row-sharded (m,) vector."""
        rows, col = self.row_axes, self.col_axis

        def body(a, v):
            c = jax.lax.axis_index(col)
            vc = jax.lax.dynamic_slice_in_dim(v, c * a.shape[1], a.shape[1])
            return jax.lax.psum(a @ vc, col)

        return self._smap(body, in_specs=(self._spec, P()),
                          out_specs=P(rows))(self.data, v)

    def rmatvec(self, u: Array) -> Array:
        """Aᵀ u, u row-sharded → (n,) vector sharded over the model axis.
        (Logically a global vector; jit-level consumers reshard for free.)"""
        rows, col = self.row_axes, self.col_axis

        def body(a, u):
            part = a.T @ u                       # (n/C,) partial over rows
            return jax.lax.psum(part, rows)      # (n/C,) at every (·, c)

        return self._smap(body, in_specs=(self._spec, P(rows)),
                          out_specs=P(col))(self.data, u)

    # -- "vector as RDD": large linear model parallelism (refs [4, 9]) -------
    def matvec_model_sharded(self, w: Array) -> Array:
        """A w where w is itself distributed over the model axis
        (the paper's case of vectors too large for the driver)."""
        rows, col = self.row_axes, self.col_axis

        def body(a, w):
            return jax.lax.psum(a @ w, col)

        return self._smap(body, in_specs=(self._spec, P(col)),
                          out_specs=P(rows))(self.data, w)

    def rmatvec_model_sharded(self, u: Array) -> Array:
        """Aᵀ u → gradient vector kept sharded over the model axis."""
        rows, col = self.row_axes, self.col_axis

        def body(a, u):
            return jax.lax.psum(a.T @ u, rows)

        return self._smap(body, in_specs=(self._spec, P(rows)),
                          out_specs=P(col))(self.data, u)

    def frobenius_norm(self) -> Array:
        def body(a):
            return jax.lax.psum((a * a).sum(),
                                (*self.row_axes, self.col_axis))

        return jnp.sqrt(self._smap(body, in_specs=(self._spec,),
                                   out_specs=P())(self.data))

    def to_local(self) -> Array:
        return jax.device_get(self.data)[: self.dims[0], : self.dims[1]]
