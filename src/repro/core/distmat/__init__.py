from .types import (DistMatrix, make_mesh, single_device_mesh, row_axes_for,
                    replicated, row_sharding, block_sharding)
from .rowmatrix import RowMatrix, IndexedRowMatrix
from .coordinatematrix import CoordinateMatrix
from .blockmatrix import BlockMatrix
from .sparserow import SparseRowMatrix
from .local import SparseVector, SparseMatrixCSC

__all__ = [
    "DistMatrix", "make_mesh", "single_device_mesh", "row_axes_for",
    "replicated", "row_sharding", "block_sharding",
    "RowMatrix", "IndexedRowMatrix", "CoordinateMatrix", "BlockMatrix",
    "SparseRowMatrix", "SparseVector", "SparseMatrixCSC",
]
