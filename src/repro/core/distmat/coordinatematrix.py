"""CoordinateMatrix — entry-sharded COO distributed matrix (paper §2.2).

"Should be used only when both dimensions of the matrix are huge and the
matrix is very sparse."  The RDD[MatrixEntry] becomes three 1-D arrays
(row, col, value) sharded over the nnz dimension.  Vectors (length m or n)
are replicated — the paper's operating assumption for the square-SVD case is
precisely that the matrix does not fit on one machine but vectors do.

matvec/rmatvec are the operations ARPACK-style Lanczos needs; they are
implemented as shard_map bodies: local gather + segment_sum, then a tree
all-reduce over the entry shards.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from . import types as T

Array = jax.Array


@dataclass(frozen=True)
class CoordinateMatrix(T.DistMatrix):
    row_idx: Array                  # (nnz_padded,) int32, sharded P(row_axes)
    col_idx: Array                  # (nnz_padded,) int32, sharded P(row_axes)
    values: Array                   # (nnz_padded,) float, sharded P(row_axes)
    dims: tuple[int, int]
    nnz: int
    mesh: Mesh = field(repr=False)
    row_axes: tuple[str, ...] = T.ROW_AXES

    @staticmethod
    def create(row_idx: Array, col_idx: Array, values: Array,
               shape: tuple[int, int], mesh: Mesh | None = None,
               row_axes: Sequence[str] | None = None) -> "CoordinateMatrix":
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        nshards = T.axes_size(mesh, row_axes)
        nnz = int(values.shape[0])
        # Pad with explicit zeros at entry (0, 0) — harmless under summation.
        ri, _ = T.pad_rows(jnp.asarray(row_idx, jnp.int32), nshards)
        ci, _ = T.pad_rows(jnp.asarray(col_idx, jnp.int32), nshards)
        va, _ = T.pad_rows(jnp.asarray(values), nshards)
        sh = NamedSharding(mesh, P(row_axes))
        return CoordinateMatrix(T.put(ri, sh), T.put(ci, sh), T.put(va, sh),
                                dims=shape, nnz=nnz, mesh=mesh,
                                row_axes=row_axes)

    @property
    def shape(self) -> tuple[int, int]:
        return self.dims

    def _smap(self, f, in_specs, out_specs):
        return compat.shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs)

    def matvec(self, v: Array) -> Array:
        """A v: gather v at col indices, segment-sum into rows, all-reduce."""
        m, axes = self.dims[0], self.row_axes
        spec = P(self.row_axes)

        def body(ri, ci, va, v):
            contrib = va * v[ci]
            local = jax.ops.segment_sum(contrib, ri, num_segments=m)
            return jax.lax.psum(local, axes)

        return self._smap(body, in_specs=(spec, spec, spec, P()),
                          out_specs=P())(self.row_idx, self.col_idx,
                                         self.values, v)

    def rmatvec(self, u: Array) -> Array:
        """Aᵀ u — symmetric role swap of matvec."""
        n, axes = self.dims[1], self.row_axes
        spec = P(self.row_axes)

        def body(ri, ci, va, u):
            contrib = va * u[ri]
            local = jax.ops.segment_sum(contrib, ci, num_segments=n)
            return jax.lax.psum(local, axes)

        return self._smap(body, in_specs=(spec, spec, spec, P()),
                          out_specs=P())(self.row_idx, self.col_idx,
                                         self.values, u)

    def frobenius_norm(self) -> Array:
        spec = P(self.row_axes)

        def body(va):
            return jax.lax.psum((va * va).sum(), self.row_axes)

        return jnp.sqrt(self._smap(body, in_specs=(spec,),
                                   out_specs=P())(self.values))

    def transpose(self) -> "CoordinateMatrix":
        """Aᵀ by swapping the index arrays — entry sharding makes the
        transpose free (no shuffle, no copy); the SVD transpose dispatch
        for wide-and-short inputs rides on this."""
        return CoordinateMatrix(row_idx=self.col_idx, col_idx=self.row_idx,
                                values=self.values,
                                dims=(self.dims[1], self.dims[0]),
                                nnz=self.nnz, mesh=self.mesh,
                                row_axes=self.row_axes)

    # -- conversions (paper: toIndexedRowMatrix; global shuffle warning) ----
    def to_indexed_row_matrix(self):
        """Densify rows (test/driver scale only — the paper warns that format
        conversion is a global shuffle; here it is an all-gather + scatter)."""
        from .rowmatrix import IndexedRowMatrix
        ri = np.asarray(jax.device_get(self.row_idx))[: self.nnz]
        ci = np.asarray(jax.device_get(self.col_idx))[: self.nnz]
        va = np.asarray(jax.device_get(self.values))[: self.nnz]
        uniq, inv = np.unique(ri, return_inverse=True)
        dense = np.zeros((len(uniq), self.dims[1]), va.dtype)
        np.add.at(dense, (inv, ci), va)
        return IndexedRowMatrix.create(jnp.asarray(uniq), jnp.asarray(dense),
                                       self.mesh, self.row_axes)

    def to_sparse_row_matrix(self, bs: int | str = "auto"):
        """Block-compress into the row-sharded BSR type: entries are binned
        into (block-row, block-col) blocks in one vectorized pass and each
        contiguous block-row strip lands whole on its shard — no all-to-all
        (the paper's shuffle warning does not apply)."""
        from .sparserow import SparseRowMatrix
        ri = np.asarray(jax.device_get(self.row_idx))[: self.nnz]
        ci = np.asarray(jax.device_get(self.col_idx))[: self.nnz]
        va = np.asarray(jax.device_get(self.values))[: self.nnz]
        return SparseRowMatrix.from_entries(ri, ci, va, self.dims, bs=bs,
                                            mesh=self.mesh,
                                            row_axes=self.row_axes)

    def to_block_matrix(self, block_rows: int, block_cols: int):
        from .blockmatrix import BlockMatrix
        return BlockMatrix.create(self.to_local(), self.mesh,
                                  block_rows=block_rows, block_cols=block_cols)

    def to_local(self) -> Array:
        ri = np.asarray(jax.device_get(self.row_idx))[: self.nnz]
        ci = np.asarray(jax.device_get(self.col_idx))[: self.nnz]
        va = np.asarray(jax.device_get(self.values))[: self.nnz]
        out = np.zeros(self.dims, va.dtype)
        np.add.at(out, (ri, ci), va)
        return jnp.asarray(out)
