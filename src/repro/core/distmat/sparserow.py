"""SparseRowMatrix — row-sharded block-sparse distributed matrix.

The paper's sparse story has two halves: §2.2's entry-sharded
CoordinateMatrix ("both dimensions huge, matrix very sparse") and §4.2's
local sparse kernels (MLlib hand-rolls CCS SpMV/SpMM because JVM BLAS has no
sparse story).  This type is the production middle ground the paper's
workloads actually sit in — m huge, n moderate, rows sparse: each device
owns a contiguous strip of block-rows stored as a BlockELL (kernels/bsr.py),
so the hot paths are Pallas BSR SpMM/SpMV/transpose-multiply on the MXU
while the distributed structure (one shard per device, vectors replicated)
is identical to RowMatrix.

Density-aware dispatch: block-sparse storage stops paying once the stored
block fraction is high — the BSR kernel pays lane/sublane padding on every
block plus a per-block grid step, the dense GEMM streams at full MXU
utilization.  Every multiply therefore consults the execution planner
(``launch/planner.plan("sparse_matmul", ...)``, priced against the one
calibrated MachineModel every dispatch decision shares) and falls back to
densify-and-GEMM when the shard is too dense for BSR to win.  The decision
is pure Python over static shapes — trace-safe; ``plan(...).explain()``
shows the roofline terms behind it.

Sampled DIMSUM (paper refs [10, 11]) lives here and on RowMatrix:
column_similarities(threshold) keeps an entry of column i with probability
pᵢ = min(1, √γ/‖cᵢ‖) — so a pair (i, j) survives with the paper's
oversampling probability min(1, γ/‖cᵢ‖‖cⱼ‖) — and rescales kept entries by
1/pᵢ, which makes the estimator unbiased off the diagonal.  threshold=0
recovers the exact scaled-Gram similarity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.kernels import bsr as _bsr
from . import types as T
from .rowmatrix import RowMatrix, _shard_index

Array = jax.Array

# Column-strip width for AᵀX products with wide X (gram, sampled DIMSUM):
# the fused bsr_rmatmul kernel keeps an (n_pad × nx) f32 accumulator
# resident in VMEM (falling back to HBM partials + segment_sum when even a
# strip would overflow the budget), so wide right-hand sides are processed
# in bounded strips.
_RMATMUL_STRIP = 512


def _rmatmul_strips(ops_mod, local, X: Array) -> Array:
    """AᵀX in column strips of _RMATMUL_STRIP (static trace-time loop)."""
    nx = X.shape[1]
    outs = [ops_mod.bsr_rmatmul(local, X[:, i: i + _RMATMUL_STRIP])
            for i in range(0, nx, _RMATMUL_STRIP)]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _best_block_size(shape: tuple[int, int], dtype, ell_of_bs,
                     nx_hint: int) -> int:
    """Block-size selection via the execution planner
    (launch/planner.plan("bsr_bs")), evaluated on the *actual* ELL width
    each candidate produces for this matrix (`ell_of_bs(bs)` — the
    nnz-only estimate in ops.bsr_block_size assumes uniform scatter, which
    is pessimistic for block-structured sparsity).  Shared by the dense and
    the COO "auto" constructors so both pick the same block size for the
    same matrix."""
    from repro.kernels import autotune as at
    from repro.launch import planner as _planner
    m, n = shape
    sub = at.sublane(dtype)
    ell_by_bs = {bs: ell_of_bs(bs) for bs in _planner.BS_CANDIDATES
                 if bs % sub == 0}
    p = _planner.plan("bsr_bs", {"m": m, "n": n, "nx": nx_hint}, dtype,
                      context={"ell_by_bs": ell_by_bs})
    return int(p.blocks["bs"])


def _auto_block_size(a: np.ndarray, nx_hint: int) -> int:
    """Auto block size for dense input: actual per-candidate block stats."""
    m, n = a.shape
    nz = a != 0

    def ell_of_bs(bs):
        mp, npd = _rup(m, bs), _rup(n, bs)
        padded = np.zeros((mp, npd), bool)
        padded[:m, :n] = nz
        blocks = padded.reshape(mp // bs, bs, npd // bs, bs)
        return max(1, int(blocks.any(axis=(1, 3)).sum(axis=1).max()))

    return _best_block_size(a.shape, a.dtype, ell_of_bs, nx_hint)


@dataclass(frozen=True)
class SparseRowMatrix(T.DistMatrix):
    data: Array                 # (nbr_pad, ell, bs, bs), sharded P(row_axes)
    cols: Array                 # (nbr_pad, ell) int32,   sharded P(row_axes)
    dims: tuple[int, int]       # true (m, n) before any padding
    nnz: int
    mesh: Mesh = field(repr=False)
    row_axes: tuple[str, ...] = T.ROW_AXES
    # Per-stored-block f32 dequantization scales (nbr_pad, ell), sharded
    # like data — present iff the blocks are int8-quantized (kernels/bsr
    # quantized mode); None means exact storage.
    scales: Array | None = None

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_dense(a, bs: int | str = "auto", mesh: Mesh | None = None,
                   row_axes: Sequence[str] | None = None, *,
                   nx_hint: int = 128, quantize: str = "none",
                   tol: float = 1e-3) -> "SparseRowMatrix":
        """Driver-scale constructor: block-compress a local dense matrix and
        scatter contiguous block-row strips across the mesh.

        `quantize` follows kernels/bsr.BlockELL.from_dense: "int8" stores
        blocks as int8 with per-block f32 scales, "auto" lets the planner's
        precision sweep decide whether int8 clears the `tol` guard and
        pays for itself, "none" keeps exact storage."""
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        nshards = T.axes_size(mesh, row_axes)
        a = np.asarray(jax.device_get(a))
        m, n = a.shape
        if bs == "auto":
            bs = _auto_block_size(a, nx_hint)
        bs = int(bs)
        n_pad = _rup(n, bs)
        nbr_pad = _rup(_rup(m, bs) // bs, nshards)
        padded = np.zeros((nbr_pad * bs, n_pad), a.dtype)
        padded[:m, :n] = a
        bell = _bsr.BlockELL.from_dense(padded, bs, quantize=quantize,
                                        tol=tol)
        sh = NamedSharding(mesh, P(row_axes))
        return SparseRowMatrix(T.put(bell.data, sh), T.put(bell.cols, sh),
                               dims=(m, n), nnz=int(np.count_nonzero(a)),
                               mesh=mesh, row_axes=row_axes,
                               scales=(None if bell.scales is None
                                       else T.put(bell.scales, sh)))

    @staticmethod
    def from_entries(row_idx, col_idx, values, shape: tuple[int, int],
                     bs: int | str = "auto", mesh: Mesh | None = None,
                     row_axes: Sequence[str] | None = None
                     ) -> "SparseRowMatrix":
        """COO entries → block-ELL without materializing the dense matrix:
        entries are binned into (block-row, block-col) keys with one
        np.unique + np.add.at pass — no per-entry Python loop, no shuffle
        (each block-row strip lands whole on its shard)."""
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        nshards = T.axes_size(mesh, row_axes)
        ri = np.asarray(jax.device_get(row_idx), np.int64)
        ci = np.asarray(jax.device_get(col_idx), np.int64)
        va = np.asarray(jax.device_get(values))
        m, n = shape
        if bs == "auto":
            bs = _entries_block_size(ri, ci, shape, va.dtype)
        bs = int(bs)
        n_pad = _rup(n, bs)
        nbc = n_pad // bs
        nbr_pad = _rup(_rup(m, bs) // bs, nshards)
        key = (ri // bs) * nbc + (ci // bs)
        uniq, inv = np.unique(key, return_inverse=True)
        blocks = np.zeros((max(len(uniq), 1), bs, bs), va.dtype)
        np.add.at(blocks, (inv, ri % bs, ci % bs), va)
        ubi, ubj = uniq // nbc, uniq % nbc
        counts = np.bincount(ubi, minlength=nbr_pad)
        ell = max(1, int(counts.max(initial=0)))
        starts = np.concatenate([[0], np.cumsum(counts)])
        slot = np.arange(len(uniq)) - starts[ubi]
        data = np.zeros((nbr_pad, ell, bs, bs), va.dtype)
        cols = np.zeros((nbr_pad, ell), np.int32)
        data[ubi, slot] = blocks[: len(uniq)]
        cols[ubi, slot] = ubj
        sh = NamedSharding(mesh, P(row_axes))
        nnz = int(np.count_nonzero(blocks))
        return SparseRowMatrix(T.put(jnp.asarray(data), sh),
                               T.put(jnp.asarray(cols), sh),
                               dims=(m, n), nnz=nnz, mesh=mesh,
                               row_axes=row_axes)

    def remesh(self, mesh: Mesh, row_axes: Sequence[str] | None = None
               ) -> "SparseRowMatrix":
        """Re-shard the SAME logical matrix onto a different mesh (elastic
        re-mesh, train/elastic): the block-row strips are re-padded for the
        new shard count (padding block-rows are all-zero blocks with column
        0, which contribute nothing) and device_put with the new sharding.
        Block size, ELL width and the stored blocks are unchanged."""
        mesh = mesh or T.single_device_mesh()
        row_axes = tuple(row_axes) if row_axes else T.row_axes_for(mesh)
        nshards = T.axes_size(mesh, row_axes)
        nbr_true = _rup(self.dims[0], self.bs) // self.bs
        nbr_pad = _rup(nbr_true, nshards)
        data, cols, scales = self.data, self.cols, self.scales
        if nbr_pad <= data.shape[0]:
            data, cols = data[:nbr_pad], cols[:nbr_pad]
            if scales is not None:
                scales = scales[:nbr_pad]
        else:
            extra = nbr_pad - data.shape[0]
            data = jnp.concatenate(
                [data, jnp.zeros((extra,) + data.shape[1:], data.dtype)])
            cols = jnp.concatenate(
                [cols, jnp.zeros((extra,) + cols.shape[1:], cols.dtype)])
            if scales is not None:
                # Padding block-rows hold all-zero blocks: scale 1.0 (the
                # quantizer's zero-block convention).
                scales = jnp.concatenate(
                    [scales, jnp.ones((extra,) + scales.shape[1:],
                                      scales.dtype)])
        sh = NamedSharding(mesh, P(row_axes))
        return SparseRowMatrix(T.put(data, sh), T.put(cols, sh),
                               dims=self.dims, nnz=self.nnz, mesh=mesh,
                               row_axes=row_axes,
                               scales=(None if scales is None
                                       else T.put(scales, sh)))

    # -- bookkeeping ---------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return self.dims

    @property
    def bs(self) -> int:
        return self.data.shape[-1]

    @property
    def ell(self) -> int:
        return self.data.shape[1]

    @property
    def n_pad(self) -> int:
        return _rup(self.dims[1], self.bs)

    @property
    def m_pad(self) -> int:
        return self.data.shape[0] * self.bs

    def block_density(self) -> float:
        """Stored block fraction — the number density-aware dispatch acts on."""
        return self.ell / (self.n_pad // self.bs)

    @property
    def out_dtype(self):
        """Logical result dtype: float32 for quantized or sub-f32 storage."""
        d = self.data.dtype
        return jnp.dtype(jnp.float32) if (self.scales is not None
                                          or d.itemsize < 4) else d

    def dequantize(self) -> "SparseRowMatrix":
        """Exact-f32 copy (identity when storage is already exact) — the
        cold paths (stats, DIMSUM, materialization) route through this
        instead of threading scales everywhere."""
        if self.scales is None:
            return self
        data = self.data.astype(jnp.float32) * self.scales[..., None, None]
        return replace(self, data=data, scales=None)

    def astype_store(self, dtype) -> "SparseRowMatrix":
        """Recast the stored blocks.  int8 quantizes with per-block f32
        scales (absmax/127, zero blocks get scale 1.0); any float dtype
        dequantizes first and recasts.  Sharding is preserved."""
        if isinstance(dtype, str) and dtype == "int8":
            dtype = jnp.int8
        dtype = jnp.dtype(dtype)
        if dtype == jnp.int8:
            if self.scales is not None:
                return self
            d = self.data.astype(jnp.float32)
            absmax = jnp.max(jnp.abs(d), axis=(2, 3))
            scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
            q = jnp.round(d / scales[..., None, None]).astype(jnp.int8)
            return replace(self, data=q, scales=scales)
        out = self.dequantize()
        if dtype == out.data.dtype:
            return out
        return replace(out, data=out.data.astype(dtype))

    def _smap(self, f, in_specs, out_specs):
        return compat.shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs)

    @property
    def _dspec(self) -> P:
        return P(self.row_axes)

    def _local_rows(self) -> int:
        nshards = T.axes_size(self.mesh, self.row_axes)
        return self.m_pad // nshards

    def _use_bsr(self, nx: int, dispatch: str) -> bool:
        """Per-shard BSR-vs-dense decision (static, trace-safe) via the
        execution planner (launch/planner.plan("sparse_matmul"))."""
        if dispatch in ("bsr", "dense"):
            return dispatch == "bsr"
        if dispatch != "auto":
            raise ValueError(f"dispatch must be auto | bsr | dense, "
                             f"got {dispatch!r}")
        from repro.launch import planner as _planner
        return _planner.plan(
            "sparse_matmul",
            {"m": self._local_rows(), "n": self.n_pad,
             "nx": max(nx, 1), "ell": self.ell, "bs": self.bs},
            self.data.dtype.name).choice == "bsr"

    def _collective_plan(self, op: str, dims):
        """Comm-priced plan for a distributed op on this mesh (see
        RowMatrix._collective_plan; the dense-terms model is an upper bound
        for the sparse shard's compute, which is fine for the chunk
        decision — it only moves the overlap break-even conservatively)."""
        from repro.launch import mesh as _mesh
        from repro.launch import planner as _planner
        return _planner.plan(
            op, dims, self.data.dtype.name,
            context={"axes": _mesh.axis_sizes(self.mesh, self.row_axes)})

    def _resolve_chunks(self, chunks, plan) -> int:
        if chunks == "auto":
            return int(plan.blocks.get("chunks", 1))
        return max(int(chunks), 1)

    def _local(self, data: Array, cols: Array,
               scales: Array | None = None) -> _bsr.BlockELL:
        """The shard's BlockELL view (called inside shard_map bodies)."""
        return _bsr.BlockELL(data, cols, (data.shape[0] * self.bs,
                                          self.n_pad), scales)

    def _scale_ops(self) -> tuple:
        """Trailing shard_map operand for the quantization scales — empty
        for exact storage so existing two-operand bodies are unchanged."""
        return () if self.scales is None else (self.scales,)

    def _scale_specs(self) -> tuple:
        return () if self.scales is None else (self._dspec,)

    def _row_mask(self) -> Array:
        """Row-sharded {0,1} mask of true (non-padding) rows."""
        m = self.dims[0]
        local = self._local_rows()
        axes = self.row_axes

        def body():
            start = _shard_index(axes) * local
            return ((start + jnp.arange(local)) < m).astype(self.out_dtype)

        return self._smap(body, in_specs=(), out_specs=P(self.row_axes))()

    # -- cluster matrix ops --------------------------------------------------
    def matvec(self, v: Array, *, dispatch: str = "auto") -> Array:
        """A v with v replicated (driver) → row-sharded (m_pad,) result."""
        from repro.kernels import ops as _ops
        use_bsr = self._use_bsr(1, dispatch)
        vp = jnp.pad(jnp.asarray(v), (0, self.n_pad - self.dims[1]))

        def body(data, cols, v, *sc):
            local = self._local(data, cols, *sc)
            if use_bsr:
                return _ops.bsr_matvec(local, v)
            return local.to_dense() @ v

        return self._smap(body,
                          in_specs=(self._dspec, self._dspec, P())
                          + self._scale_specs(),
                          out_specs=P(self.row_axes))(
            self.data, self.cols, vp, *self._scale_ops())

    def rmatvec(self, u: Array, *, dispatch: str = "auto") -> Array:
        """Aᵀ u with u row-sharded → replicated (n,) vector (driver)."""
        from repro.kernels import ops as _ops
        axes = self.row_axes
        use_bsr = self._use_bsr(1, dispatch)
        u = jnp.asarray(u)
        if u.shape[0] != self.m_pad:
            u = jnp.pad(u, (0, self.m_pad - u.shape[0]))

        def body(data, cols, u, *sc):
            local = self._local(data, cols, *sc)
            if use_bsr:
                out = _ops.bsr_rmatmul(local, u[:, None])[:, 0]
            else:
                out = local.to_dense().T @ u
            return jax.lax.psum(out, axes)

        out = self._smap(body,
                         in_specs=(self._dspec, self._dspec, P(axes))
                         + self._scale_specs(),
                         out_specs=P())(self.data, self.cols, u,
                                        *self._scale_ops())
        return out[: self.dims[1]]

    def multiply_local(self, B: Array, *,
                       dispatch: str = "auto") -> RowMatrix:
        """A @ B for a small replicated B — the `U = A (VΣ⁻¹)` pattern.
        The product of a sparse matrix with a dense factor is dense, so the
        result is a RowMatrix (same row sharding, no collectives)."""
        from repro.kernels import ops as _ops
        B = jnp.asarray(B)
        use_bsr = self._use_bsr(B.shape[1], dispatch)
        Bp = jnp.pad(B, ((0, self.n_pad - self.dims[1]), (0, 0)))

        def body(data, cols, b, *sc):
            local = self._local(data, cols, *sc)
            if use_bsr:
                return _ops.bsr_matmul(local, b)
            return _ops.gemm(local.to_dense(), b, out_dtype=b.dtype)

        out = self._smap(body,
                         in_specs=(self._dspec, self._dspec, P())
                         + self._scale_specs(),
                         out_specs=P(self.row_axes, None))(
            self.data, self.cols, Bp, *self._scale_ops())
        return RowMatrix(rows=out, n_rows=self.dims[0], mesh=self.mesh,
                         row_axes=self.row_axes)

    def init_psum_residual(self) -> Array:
        """Zeroed per-shard f32 error-feedback residual for the compressed
        ("psum8") fused_grad reduction — see RowMatrix.init_psum_residual.
        Sized to the padded column count (the kernel-facing gradient)."""
        nshards = T.axes_size(self.mesh, self.row_axes)
        z = jnp.zeros((nshards, self.n_pad), jnp.float32)
        return T.put(z, NamedSharding(self.mesh, P(self.row_axes, None)))

    def fused_grad(self, x: Array, smooth, *, dispatch: str = "auto",
                   chunks: int | str = "auto",
                   residual: Array | None = None):
        """(f(Ax), Aᵀ∇f(Ax), Ax) in one pass over the stored blocks — the
        BSR form of the fused composite gradient (kernels/fusedgrad): z for
        a block-row accumulates while its blocks are staged in VMEM, the
        row-local residual is evaluated on-chip, and the transpose
        contributions scatter-add into a resident accumulator.  Dense
        fallback (densify + dense fused kernel) under the same density-aware
        dispatch as every other multiply.

        `chunks` > 1 runs the comm-overlapped schedule (planner-chosen on
        "auto", via plan("grad") with this mesh's axis sizes).  The dense
        fallback arm gets the full two-phase split RowMatrix.fused_grad
        uses (per-column-segment r·A[:, seg] contractions overlapping the
        partial psums); the BSR arm keeps its one-pass kernel — re-reading
        the stored blocks per segment would forfeit exactly the fusion the
        kernel exists for — and pipelines the gradient *reduction* in
        column segments instead, so successive partial psums overlap each
        other and the f psum.  Both arms are bit-identical to eager
        (segmented psums of the same per-shard values).

        `residual` (from init_psum_residual) switches the gradient psums
        to the compressed int8 wire with error feedback — see
        RowMatrix.fused_grad; returns (f, g, z, new_residual)."""
        from repro.kernels import fusedgrad as _fg
        from repro.kernels import ops as _ops
        from repro.launch import telemetry as _tel
        from repro.train import compression as _comp
        from .rowmatrix import _record_collective, chunk_bounds
        use_bsr = self._use_bsr(1, dispatch)
        axes = self.row_axes
        nshards = T.axes_size(self.mesh, self.row_axes)
        quant = self.scales is not None
        n = self.dims[1]
        kind, t, w, prm = T.row_separable_inputs(smooth, self.m_pad,
                                                 self._row_mask)
        x = jnp.asarray(x)
        xp = jnp.pad(x, (0, self.n_pad - x.shape[0])) \
            if x.shape[0] < self.n_pad else x
        plan = self._collective_plan("grad", {"m": self._local_rows(),
                                              "n": self.n_pad})
        c = self._resolve_chunks(chunks, plan)
        bounds = chunk_bounds(self.n_pad, c)

        def _reduce(f, g, z, res):
            """Gradient reduction in column segments (c > 1 pipelines the
            partial psums); int8 wire when an EF residual came in."""
            segs = bounds if c > 1 else ((0, self.n_pad),)
            if res is not None:
                gs, rs = [], []
                for s0, s1 in segs:
                    gseg, rseg = _comp.psum_int8(g[s0:s1], res[0, s0:s1],
                                                 axes, nshards)
                    gs.append(gseg)
                    rs.append(rseg)
                return (jax.lax.psum(f, axes), jnp.concatenate(gs), z,
                        jnp.concatenate(rs)[None])
            gs = [jax.lax.psum(g[s0:s1], axes) for s0, s1 in segs]
            return jax.lax.psum(f, axes), jnp.concatenate(gs), z

        def body(data, cols, xp, t, w, *rest):
            sc, rest = (rest[:1], rest[1:]) if quant else ((), rest)
            res = rest[0] if rest else None
            local = self._local(data, cols, *sc)
            if use_bsr:
                f, g, z = _ops.fused_grad_bsr(local, xp, t, w, loss=kind,
                                              param=prm)
                return _reduce(f, g, z, res)
            if c > 1 and res is None:
                # Two-phase dense split — fused_grad_jnp's exact math with
                # the gradient built per column segment (see RowMatrix).
                dense = local.to_dense()
                z = jnp.dot(dense, xp, preferred_element_type=jnp.float32)
                f, r = _fg.row_loss_grad(z, t, w, kind, prm)
                rc = r.astype(dense.dtype) \
                    if dense.dtype == jnp.float32 else r
                gs = [jax.lax.psum(
                    jnp.dot(rc, dense[:, s0:s1],
                            preferred_element_type=jnp.float32)
                    .astype(xp.dtype), axes) for s0, s1 in bounds]
                return jax.lax.psum(f, axes), jnp.concatenate(gs), z
            f, g, z = _ops.fused_grad(local.to_dense(), xp, t, w,
                                      loss=kind, param=prm)
            return _reduce(f, g, z, res)

        wire = "int8" if residual is not None else "f32"
        base_specs = (self._dspec, self._dspec, P(), P(axes), P(axes)) \
            + self._scale_specs()
        base_ops = (self.data, self.cols, xp, t, w) + self._scale_ops()
        with _tel.current().span("collective.fused_grad", op="grad",
                                 n=self.n_pad, chunks=c, wire=wire) as sp:
            if residual is None:
                f, g, z = self._smap(
                    body, in_specs=base_specs,
                    out_specs=(P(), P(), P(axes)))(*base_ops)
                out = (f, g[:n], z)
            else:
                f, g, z, nres = self._smap(
                    body, in_specs=base_specs + (P(self.row_axes, None),),
                    out_specs=(P(), P(), P(axes),
                               P(self.row_axes, None)))(*base_ops, residual)
                out = (f, g[:n], z, nres)
            sp.sync_on(out[1])
        _record_collective(plan, sp, collective="psum", chunks=c, wire=wire)
        return out

    def fused_grad_multi(self, x: Array, smooths, *,
                         dispatch: str = "auto"
                         ) -> tuple[Array, Array, Array]:
        """Request-batched fused gradients over the stored blocks: a GROUP
        of k right-hand sides answered with ONE read of each stored block
        (the BSR multi-RHS kernel), under the same density-aware dispatch
        as fused_grad.  `x` (k × n); `smooths` a sequence of k
        row-separable smooths sharing one loss kind/param.  Returns
        (replicated (k,) values, replicated (k × n) gradients, image
        sharded (k × m_pad) over the row axes)."""
        from repro.kernels import ops as _ops
        use_bsr = self._use_bsr(1, dispatch)
        axes = self.row_axes
        n = self.dims[1]
        kind, t, w, prm = T.row_separable_batch_inputs(smooths, self.m_pad,
                                                       self._row_mask)
        x = jnp.atleast_2d(jnp.asarray(x))
        xp = jnp.pad(x, ((0, 0), (0, self.n_pad - x.shape[1]))) \
            if x.shape[1] < self.n_pad else x

        def body(data, cols, xp, t, w, *sc):
            local = self._local(data, cols, *sc)
            if use_bsr:
                f, g, z = _ops.fused_grad_bsr_multi(local, xp, t, w,
                                                    loss=kind, param=prm)
            else:
                f, g, z = _ops.fused_grad_multi(local.to_dense(), xp, t, w,
                                                loss=kind, param=prm)
            return jax.lax.psum(f, axes), jax.lax.psum(g, axes), z

        f, g, z = self._smap(
            body,
            in_specs=(self._dspec, self._dspec, P(), P(None, axes),
                      P(None, axes)) + self._scale_specs(),
            out_specs=(P(), P(), P(None, axes)))(
            self.data, self.cols, xp, t, w, *self._scale_ops())
        return f, g[:, :n], z

    def gram(self, *, dispatch: str = "auto") -> Array:
        """AᵀA, replicated — per-shard AᵀA with the sparse operand on the
        transpose side (flops ∝ stored blocks · n), then a tree all-reduce.
        Falls back to the dense tsgram kernel when the shard is dense."""
        from repro.kernels import ops as _ops
        axes = self.row_axes
        use_bsr = self._use_bsr(self.n_pad, dispatch)

        def body(data, cols, *sc):
            local = self._local(data, cols, *sc)
            dense = local.to_dense()
            if use_bsr:
                g = _rmatmul_strips(_ops, local, dense.astype(jnp.float32))
            else:
                g = _ops.tsgram(dense, out_dtype=jnp.float32)
            return jax.lax.psum(g, axes)

        out = self._smap(body,
                         in_specs=(self._dspec, self._dspec)
                         + self._scale_specs(),
                         out_specs=P())(self.data, self.cols,
                                        *self._scale_ops())
        n = self.dims[1]
        return out[:n, :n].astype(self.out_dtype)

    def frobenius_norm(self) -> Array:
        if self.scales is not None:
            return self.dequantize().frobenius_norm()
        axes = self.row_axes

        def body(data):
            return jax.lax.psum((data * data).sum(), axes)

        return jnp.sqrt(self._smap(body, in_specs=(self._dspec,),
                                   out_specs=P())(self.data))

    def column_norms(self) -> Array:
        """Replicated per-column L2 norms (the DIMSUM scaling vector)."""
        if self.scales is not None:
            return self.dequantize().column_norms()
        axes, bs = self.row_axes, self.bs
        nbc = self.n_pad // bs

        def body(data, cols):
            sq = (data * data).sum(axis=2)            # (nbr_l, ell, bs)
            out = jnp.zeros((nbc, bs), sq.dtype).at[cols].add(sq)
            return jax.lax.psum(out.reshape(-1), axes)

        out = self._smap(body, in_specs=(self._dspec, self._dspec),
                         out_specs=P())(self.data, self.cols)
        return jnp.sqrt(out[: self.dims[1]])

    def scale_columns(self, d: Array) -> "SparseRowMatrix":
        """A · diag(d) with replicated d — scales stored blocks in place
        (the sparsity pattern is unchanged, so cols are shared).
        Quantized storage dequantizes first: per-column scaling breaks the
        shared per-block scale."""
        if self.scales is not None:
            return self.dequantize().scale_columns(d)
        bs = self.bs
        dp = jnp.pad(jnp.asarray(d), (0, self.n_pad - self.dims[1]))
        db = dp.reshape(-1, bs)                       # (nbc, bs)

        def body(data, cols, db):
            return data * db[cols][:, :, None, :]

        out = self._smap(body, in_specs=(self._dspec, self._dspec, P()),
                         out_specs=self._dspec)(self.data, self.cols, db)
        return replace(self, data=out)

    # -- DIMSUM --------------------------------------------------------------
    def column_similarities(self, threshold: float = 0.0, *,
                            gamma: float | None = None,
                            seed: int = 0, return_info: bool = False):
        """Sampled DIMSUM cosine similarities (see module docstring).
        threshold=0 → exact scaled-Gram path.  return_info=True returns
        (sim, info) with the sampling diagnostics — γ, per-column keep
        probabilities p, and the exact per-pair estimator variance
        Σ_k (ã_ki ã_kj)²·(1/(pᵢpⱼ) − 1) (ã column-scaled), which shrinks
        to 0 as γ grows."""
        if self.scales is not None:
            return self.dequantize().column_similarities(
                threshold, gamma=gamma, seed=seed, return_info=return_info)
        from repro.kernels import ops as _ops
        norms = self.column_norms()
        inv = jnp.where(norms > 0, 1.0 / jnp.maximum(norms, 1e-30), 0.0)
        if threshold <= 0.0:
            sim = self.scale_columns(inv).gram()
            if not return_info:
                return sim
            nd = self.dims[1]
            return sim, {"gamma": None, "p": jnp.ones((nd,), jnp.float32),
                         "variance": jnp.zeros((nd, nd), jnp.float32)}
        n, bs = self.dims[1], self.bs
        g = gamma if gamma is not None else dimsum_gamma(n, threshold)
        p = jnp.minimum(1.0, math.sqrt(g) * inv)
        scale = inv * jnp.where(p > 0, 1.0 / p, 0.0)
        pad = self.n_pad - n
        pb = jnp.pad(p, (0, pad)).reshape(-1, bs)
        sb = jnp.pad(scale, (0, pad)).reshape(-1, bs)
        axes = self.row_axes
        use_bsr = self._use_bsr(self.n_pad, "auto")

        def body(data, cols, pb, sb):
            key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                     _shard_index(axes))
            keep = jax.random.uniform(key, data.shape) < pb[cols][:, :, None, :]
            d2 = jnp.where(keep, data, 0.0) * sb[cols][:, :, None, :]
            local = self._local(d2, cols)
            dense = local.to_dense()
            if use_bsr:
                g_ = _rmatmul_strips(_ops, local, dense.astype(jnp.float32))
            else:
                g_ = _ops.tsgram(dense, out_dtype=jnp.float32)
            return jax.lax.psum(g_, axes)

        sim = self._smap(body,
                         in_specs=(self._dspec, self._dspec, P(), P()),
                         out_specs=P())(self.data, self.cols, pb, sb)
        sim = sim[:n, :n].astype(self.out_dtype)
        # The diagonal estimator is biased (E[b²] = a²/p); its true value is
        # known exactly, so write it instead (MLlib does the same).
        diag = (norms > 0).astype(sim.dtype)
        sim = sim.at[jnp.arange(n), jnp.arange(n)].set(diag)
        if not return_info:
            return sim
        scaled = self.scale_columns(inv)
        sq = replace(scaled, data=scaled.data * scaled.data)
        s2 = sq.gram().astype(jnp.float32)
        var = T.dimsum_variance(s2, p)
        return sim, {"gamma": g, "p": p, "variance": var}

    # -- conversions ---------------------------------------------------------
    def to_row_matrix(self) -> RowMatrix:
        """Densify each shard in place — no collectives (shuffle-free): the
        block-row strips already live where RowMatrix wants the rows."""
        n = self.dims[1]

        def body(data, cols, *sc):
            return self._local(data, cols, *sc).to_dense()[:, :n]

        out = self._smap(body,
                         in_specs=(self._dspec, self._dspec)
                         + self._scale_specs(),
                         out_specs=P(self.row_axes, None))(
            self.data, self.cols, *self._scale_ops())
        return RowMatrix(rows=out, n_rows=self.dims[0], mesh=self.mesh,
                         row_axes=self.row_axes)

    def to_local(self) -> Array:
        if self.scales is not None:
            return self.dequantize().to_local()
        data = np.asarray(jax.device_get(self.data))
        cols = np.asarray(jax.device_get(self.cols))
        nbr, ell, bs = data.shape[0], data.shape[1], data.shape[-1]
        nbc = self.n_pad // bs
        out = np.zeros((nbr, nbc, bs, bs), data.dtype)
        np.add.at(out, (np.arange(nbr)[:, None], cols), data)
        dense = out.transpose(0, 2, 1, 3).reshape(self.m_pad, self.n_pad)
        return jnp.asarray(dense[: self.dims[0], : self.dims[1]])

    def transpose(self) -> "SparseRowMatrix":
        """Driver-scale transpose (the paper's format-conversion warning
        applies: this is a global reshuffle, done on the driver here)."""
        return SparseRowMatrix.from_dense(
            np.asarray(jax.device_get(self.to_local())).T, bs=self.bs,
            mesh=self.mesh, row_axes=self.row_axes)

    # -- linalg entry point --------------------------------------------------
    def compute_svd(self, k: int, **kw):
        from repro.core.linalg import svd as _svd
        return _svd.compute_svd(self, k, **kw)


def dimsum_gamma(n: int, threshold: float) -> float:
    """The paper's oversampling parameter: γ = 10·log(n)/threshold keeps the
    estimate of every pair with similarity ≥ threshold within ~20% relative
    error w.h.p. (DIMSUM analysis, refs [10, 11])."""
    return 10.0 * math.log(max(n, 2)) / threshold


def _entries_block_size(ri, ci, shape, dtype, *, nx_hint: int = 128) -> int:
    """Auto block size for COO input: per-candidate actual ELL widths from
    the index arrays alone (no densification)."""
    n = shape[1]

    def ell_of_bs(bs):
        nbc = _rup(n, bs) // bs
        key = np.unique((ri // bs) * nbc + (ci // bs))
        counts = np.bincount(key // nbc, minlength=1)
        return max(1, int(counts.max(initial=0)))

    return _best_block_size(shape, dtype, ell_of_bs, nx_hint)
