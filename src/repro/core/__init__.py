"""repro.core — the paper's contribution: distributed matrices, spectral and
convex solvers built on the matrix/vector separation principle."""
from . import distmat, linalg, tfocs, optim

__all__ = ["distmat", "linalg", "tfocs", "optim"]
