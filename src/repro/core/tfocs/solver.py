"""The TFOCS first-order engine (paper §3.2): Auslender–Teboulle accelerated
proximal gradient with backtracking Lipschitz estimation, gradient-test
restart, and linear-operator structure caching.

Composite problem:  minimize  f(A x) + h(x)
  * `linop`  (A)  — distributed matrix ops (cluster)
  * `smooth` (f)  — evaluated in data space
  * `prox`   (h)  — vector math on the replicated variable (driver)

The linear-operator caching is the paper's "the optimizer may evaluate the
(expensive) linear component and cache the result": the iterates x̄, z carry
their images A x̄, A z, so  A y = (1−θ)A x̄ + θA z  costs no matvec, and each
iteration performs exactly ONE apply and ONE adjoint (per backtracking
attempt) — the minimum possible *for the cached accelerated scheme*.

For non-accelerated runs over a row-separable smooth there is a faster
floor: with θ ≡ 1 the gradient point of the next attempt IS the candidate
point of this one, so the single-pass fused gradient kernel
(kernels/fusedgrad) — which computes f(Ax), Aᵀ∇f(Ax) and Ax in one
streaming read of A — covers the whole attempt: ONE A-pass instead of an
apply + an adjoint.  `fused="auto"` (TfocsOptions) takes that path when the
smooth advertises separability, the operator supports it, and the execution
planner (launch/planner.plan("grad", ...)) prices it ahead.  `fused=False`
opts out.

Accelerated runs over a *quadratic* row-separable smooth get the same
one-pass floor by a different trick (`_tfocs_fused_accel`): ∇f(z) = w∘(z−b)
is affine, so the x-space gradient decomposes as Aᵀ∇f(A y) = u_y − u_b with
u_v ≔ Aᵀ(w∘A v) — and u_y = (1−θ)u_x + θu_z combines from carried vectors
exactly like the cached images.  Each attempt then needs only ONE fused
pass (at the candidate z⁺, which refreshes u_z); the momentum point's
gradient is free.  acc/acc_b/acc_r/acc_rb drop from two A-passes per
attempt to one.  Non-quadratic accelerated variants keep the cached
two-pass scheme (their data-space gradient is not affine in the image).

One engine serves the whole Figure-1 family:
  accel=False                         → `gra`   (proximal gradient)
  accel=True                          → `acc`
  accel=True,  restart=True           → `acc_r`
  accel=True,  backtracking=True      → `acc_b`
  accel=True,  both                   → `acc_rb`
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .smooth import row_separable

Array = jax.Array


@dataclass(frozen=True)
class TfocsOptions:
    max_iters: int = 500
    tol: float = 1e-8
    L0: float = 1.0              # initial Lipschitz estimate
    Lexact: float | None = None  # if set: no backtracking, fixed step 1/L
    alpha: float = 2.0           # backtracking increase factor
    beta: float = 0.9            # per-iteration optimistic L decay
    max_backtracks: int = 30
    accel: bool = True
    backtracking: bool = True
    restart: bool = False        # O'Donoghue–Candès gradient-test restart
    fused: bool | str = "auto"   # single-pass fused gradient (False opts out)
    # Compute/wire precision: "auto" lets the execution planner's precision
    # sweep (launch/planner, plan("grad", context={"tol": ...})) pick among
    #   "f32"   — exact storage and wire (always admissible),
    #   "bf16"  — recast the operand's storage to bfloat16 (kernels upcast
    #             tiles on-chip and accumulate f32); admitted when
    #             tol ≥ 1e-5 and the modeled byte savings clear the floor,
    #   "psum8" — compressed int8 gradient all-reduce with error feedback
    #             (train/compression.psum_int8); admitted when tol ≥ 1e-6.
    # The guard is opts.tol: the planner never picks a precision whose
    # error guard exceeds the solver's own convergence tolerance.  "psum8"
    # applies only to the θ ≡ 1 fused engine (the EF residual needs the
    # candidate/gradient-point identity); other engines fall back to f32
    # wire.  Explicit values force the choice.
    precision: str = "auto"


def _fused_capable(linop) -> bool:
    """True when the operator — and, for delegating wrappers like
    CountingLinop (whose methods exist unconditionally and just forward to
    `.base`), the whole wrapped chain — implements fused_grad."""
    if not hasattr(linop, "fused_grad"):
        return False
    base = getattr(linop, "base", None)
    return True if base is None else _fused_capable(base)


def fused_gradient_enabled(smooth, linop, fused: bool | str = "auto",
                           *, needs_theta_one: bool = False,
                           accel: bool = False) -> bool:
    """Whether a (smooth, linop) composite should take the single-pass fused
    gradient path.  Structure gates first (row-separable smooth, a
    fused-capable operator, and — with needs_theta_one — no acceleration,
    since the θ ≡ 1 engine's candidate/gradient-point identity breaks under
    momentum; accelerated quadratic composites get their own affine fused
    engine, see `_tfocs_fused_accel`); `"auto"` then consults the execution
    planner (launch/planner.plan("grad", ...): one A read vs two, priced on
    the calibrated machine model)."""
    if fused is False or (needs_theta_one and accel):
        return False
    sep = row_separable(smooth)
    ok = sep is not None and _fused_capable(linop)
    if fused is True:
        if not ok:
            raise ValueError("fused=True needs a row-separable smooth and a "
                             "fused-capable linop (LinopMatrix)")
        return True
    if fused != "auto":
        raise ValueError(f"fused must be True, False or 'auto', got {fused!r}")
    if not ok:
        return False
    try:
        m, n = int(linop.out_shape[0]), int(linop.in_shape[0])
        dtype = linop.operand_dtype() if hasattr(linop, "operand_dtype") \
            else jnp.float32
        # The roofline compares per-shard streaming passes, so price the
        # shard, not the global row count (lane-padding waste is per shard).
        shards = linop.row_shards() if hasattr(linop, "row_shards") else 1
    except (AttributeError, TypeError):
        return True
    from repro.launch import planner as _planner
    return _planner.plan("grad", {"m": max(m // max(shards, 1), 1), "n": n},
                         dtype).choice == "fused"


_PRECISIONS = ("auto", "f32", "bf16", "psum8")


def resolve_precision(linop, opts: TfocsOptions) -> str:
    """The solver's precision choice: "auto" runs the planner's precision
    sweep — plan("grad", per-shard dims, context={"tol": opts.tol, axes})
    prices {f32, bf16 storage, int8-compressed psum} against the roofline
    and admits a candidate only when its error guard clears opts.tol AND
    the modeled byte savings clear the planner's floor.  Explicit "f32"/
    "bf16"/"psum8" force the choice; non-f32 operands (already recast) and
    non-matrix operators resolve to "f32"."""
    if opts.precision != "auto":
        if opts.precision not in _PRECISIONS:
            raise ValueError(f"precision must be one of {_PRECISIONS}, "
                             f"got {opts.precision!r}")
        return opts.precision
    if not (_fused_capable(linop) and hasattr(linop, "operand_dtype")):
        return "f32"
    try:
        if jnp.dtype(linop.operand_dtype()) != jnp.float32:
            return "f32"
        m, n = int(linop.out_shape[0]), int(linop.in_shape[0])
        shards = linop.row_shards() if hasattr(linop, "row_shards") else 1
    except (AttributeError, TypeError):
        return "f32"
    ctx = {"tol": float(opts.tol)}
    A = getattr(linop, "A", None)
    if hasattr(A, "mesh") and hasattr(A, "row_axes"):
        from repro.launch import mesh as _mesh
        ctx["axes"] = _mesh.axis_sizes(A.mesh, A.row_axes)
    from repro.launch import planner as _planner
    p = _planner.plan("grad", {"m": max(m // max(shards, 1), 1), "n": n},
                      "float32", context=ctx)
    return p.precision or "f32"


class TfocsState(NamedTuple):
    x: Array
    Ax: Array
    z: Array
    Az: Array
    theta: Array
    L: Array
    k: Array
    hist: Array                  # objective per outer iteration
    done: Array
    n_backtracks: Array
    n_restarts: Array


class _Attempt(NamedTuple):
    L: Array
    theta: Array
    x: Array
    Ax: Array
    z: Array
    Az: Array
    fy: Array
    gy: Array                    # data-space gradient at y
    Ay: Array
    ok: Array
    tries: Array


class _FusedState(NamedTuple):
    # No image cache: the backtracking test collapses to x-space and the
    # kernel returns A x⁺ fresh each attempt, so (unlike TfocsState) no
    # (m,)-vector rides the loop carry.
    x: Array
    f: Array                     # smooth value at x (carried, no recompute)
    g: Array                     # x-space gradient at x (carried)
    L: Array
    k: Array
    hist: Array
    done: Array
    n_backtracks: Array
    # Compressed-psum error-feedback residual (None → exact f32 wire).
    # None is an empty pytree node, so the while_loop carry stays legal
    # either way.
    res: object = None


class _FusedAttempt(NamedTuple):
    L: Array
    x: Array
    f: Array
    g: Array
    ok: Array
    tries: Array
    res: object = None


def _tfocs_fused(smooth, linop, prox, x0: Array, opts: TfocsOptions,
                 sep, residual=None) -> tuple[Array, dict]:
    """Non-accelerated engine over the fused single-pass gradient.

    With θ ≡ 1 the candidate point x⁺ = prox(x − g/L) is also the next
    gradient point, so `linop.fused_grad(x⁺)` — one streaming pass over A —
    yields everything an attempt needs: f(Ax⁺) for the backtracking test
    (⟨∇f(Ay), A x⁺ − A y⟩ collapses to the x-space ⟨g, x⁺ − x⟩), the next
    gradient, and the image A x⁺.  Exactly ONE A-pass per backtracking
    attempt, against apply + adjoint = two on the unfused path; the math is
    identical, so the iterates match the unfused engine to float tolerance.

    `residual` (the planner's "psum8" pick; see linop.init_psum_residual)
    threads the compressed-wire error-feedback state through the loop:
    every fused pass ships an int8 gradient payload and returns the
    updated residual.  A failed backtracking attempt recomputes from the
    pre-step residual, so no quantization error is double-counted.
    """
    backtracking = opts.backtracking and opts.Lexact is None
    L_init = jnp.asarray(opts.Lexact if opts.Lexact is not None else opts.L0,
                         jnp.float32)
    use8 = residual is not None

    def fg(x, res):
        """One fused A-pass; compressed wire iff an EF residual rides."""
        if use8:
            f, g, _, nres = linop.fused_grad(x, sep, residual=res)
            return f, g, nres
        f, g, _ = linop.fused_grad(x, sep)
        return f, g, res

    def attempt_once(a: _FusedAttempt, state: _FusedState) -> _FusedAttempt:
        step = 1.0 / a.L
        x_new = prox.prox(state.x - step * state.g, step)
        f_new, g_new, res_new = fg(x_new, state.res)         # ← ONE A-pass
        dx = x_new - state.x
        rhs = state.f + jnp.vdot(state.g, dx) + 0.5 * a.L * jnp.vdot(dx, dx)
        ok = f_new <= rhs + 1e-12 * jnp.abs(state.f)
        return a._replace(x=x_new, f=f_new, g=g_new, ok=ok,
                          tries=a.tries + 1, res=res_new)

    def outer(state: _FusedState) -> _FusedState:
        L0k = state.L * (opts.beta if backtracking else 1.0)
        init = _FusedAttempt(L=L0k, x=state.x, f=state.f,
                             g=state.g, ok=jnp.asarray(False),
                             tries=jnp.int32(0), res=state.res)
        first = attempt_once(init, state)

        if backtracking:
            def bt_cond(a: _FusedAttempt):
                return (~a.ok) & (a.tries < opts.max_backtracks)

            def bt_body(a: _FusedAttempt):
                return attempt_once(a._replace(L=a.L * opts.alpha), state)

            acc = jax.lax.while_loop(bt_cond, bt_body, first)
        else:
            acc = first

        obj = acc.f + prox.value(acc.x)
        hist = state.hist.at[state.k].set(obj)
        dx = acc.x - state.x
        rel = jnp.linalg.norm(dx) / jnp.maximum(1.0, jnp.linalg.norm(acc.x))
        return _FusedState(
            x=acc.x, f=acc.f, g=acc.g, L=acc.L,
            k=state.k + 1, hist=hist, done=rel < opts.tol,
            n_backtracks=state.n_backtracks + acc.tries - 1, res=acc.res)

    def cond(state: _FusedState):
        return (~state.done) & (state.k < opts.max_iters)

    f0, g0, res0 = fg(x0, residual)                  # ← ONE A-pass to seed
    init = _FusedState(
        x=x0, f=f0, g=g0, L=L_init, k=jnp.int32(0),
        hist=jnp.full((opts.max_iters,), jnp.nan, jnp.float32),
        done=jnp.asarray(False), n_backtracks=jnp.int32(0), res=res0)
    final = jax.lax.while_loop(cond, outer, init)
    # Standardized info keys (iterations / a_passes / converged / plan) plus
    # solver-specific detail; "fused" is a deprecated alias of plan=="fused"
    # kept for one release.  a_passes: seed + one per attempt (iteration +
    # extra backtracks), each exactly one streaming read of A.
    info = {"iterations": final.k,
            "a_passes": 1 + final.k + final.n_backtracks,
            "converged": final.done, "plan": "fused",
            "history": final.hist,
            "n_backtracks": final.n_backtracks,
            "n_restarts": jnp.int32(0), "fused": True,
            "objective": final.hist[jnp.maximum(final.k - 1, 0)]}
    return final.x, info


class _AccFusedState(NamedTuple):
    # The cached-image carries of TfocsState plus the x-space u-vectors
    # u_v = Aᵀ(w∘A v) that make the quadratic gradient affine.
    x: Array
    Ax: Array
    ux: Array
    z: Array
    Az: Array
    uz: Array
    theta: Array
    L: Array
    k: Array
    hist: Array
    done: Array
    n_backtracks: Array
    n_restarts: Array


class _AccFusedAttempt(NamedTuple):
    L: Array
    theta: Array
    x: Array
    Ax: Array
    ux: Array
    z: Array
    Az: Array
    uz: Array
    gy: Array                    # data-space gradient at y (restart test)
    ok: Array
    tries: Array


def _tfocs_fused_accel(smooth, linop, prox, x0: Array, opts: TfocsOptions,
                       sep) -> tuple[Array, dict]:
    """Accelerated engine over the fused single-pass gradient — quadratic
    row-separable smooths only.

    With f(z) = Σ wᵢ·½(zᵢ−bᵢ)² the x-space gradient at any point v is
    Aᵀ∇f(A v) = u_v − u_b where u_v = Aᵀ(w∘A v): *affine* in u.  The
    iterates x̄, z therefore carry u_x, u_z alongside their cached images,
    and the momentum point's gradient  u_y − u_b = (1−θ)u_x + θu_z − u_b
    costs nothing.  One `linop.fused_grad(z⁺)` per attempt refreshes
    (f(Az⁺), u_z⁺ − u_b, Az⁺) in a single streaming read of A; x̄⁺ updates
    affinely.  The math reproduces the cached engine's iterates to float
    tolerance at HALF the passes: a_passes = 2 (seed: u_b then x0) +
    iterations + extra backtracks."""
    backtracking = opts.backtracking and opts.Lexact is None
    L_init = jnp.asarray(opts.Lexact if opts.Lexact is not None else opts.L0,
                         jnp.float32)

    # Seed: u_b from a fused pass at 0 (g(0) = Aᵀ(w∘(0−b)) = −u_b), then
    # the starting iterate's image and u_x.  Two passes, done once.
    _, g_zero, _ = linop.fused_grad(jnp.zeros_like(x0), sep)
    ub = -g_zero
    _, gx0, Ax0 = linop.fused_grad(x0, sep)
    ux0 = gx0 + ub

    def theta_next(theta, L_ratio):
        return 2.0 / (1.0 + jnp.sqrt(1.0 + 4.0 * L_ratio / (theta * theta)))

    def attempt_once(a: _AccFusedAttempt,
                     state: _AccFusedState) -> _AccFusedAttempt:
        Ay = (1 - a.theta) * state.Ax + a.theta * state.Az
        fy = smooth.value(Ay)
        gy = smooth.grad(Ay)                        # data-space, no A pass
        g = (1 - a.theta) * state.ux + a.theta * state.uz - ub  # affine!
        step = 1.0 / (a.L * a.theta)
        z_new = prox.prox(state.z - step * g, step)
        _, gz, Az_new = linop.fused_grad(z_new, sep)  # ← the ONE A-pass
        uz_new = gz + ub
        x_new = (1 - a.theta) * state.x + a.theta * z_new
        Ax_new = (1 - a.theta) * state.Ax + a.theta * Az_new
        ux_new = (1 - a.theta) * state.ux + a.theta * uz_new
        f_new = smooth.value(Ax_new)
        dx = a.theta * (z_new - state.z)            # = x_new − y
        rhs = fy + jnp.vdot(gy, Ax_new - Ay) + 0.5 * a.L * jnp.vdot(dx, dx)
        ok = f_new <= rhs + 1e-12 * jnp.abs(fy)
        return a._replace(x=x_new, Ax=Ax_new, ux=ux_new, z=z_new,
                          Az=Az_new, uz=uz_new, gy=gy, ok=ok,
                          tries=a.tries + 1)

    def outer(state: _AccFusedState) -> _AccFusedState:
        L0k = state.L * (opts.beta if backtracking else 1.0)
        theta0 = theta_next(state.theta, L0k / state.L)
        init = _AccFusedAttempt(
            L=L0k, theta=theta0, x=state.x, Ax=state.Ax, ux=state.ux,
            z=state.z, Az=state.Az, uz=state.uz,
            gy=jnp.zeros_like(state.Ax), ok=jnp.asarray(False),
            tries=jnp.int32(0))
        first = attempt_once(init, state)

        if backtracking:
            def bt_cond(a: _AccFusedAttempt):
                return (~a.ok) & (a.tries < opts.max_backtracks)

            def bt_body(a: _AccFusedAttempt):
                L_new = a.L * opts.alpha
                theta_new = theta_next(state.theta, L_new / state.L)
                return attempt_once(a._replace(L=L_new, theta=theta_new),
                                    state)

            acc = jax.lax.while_loop(bt_cond, bt_body, first)
        else:
            acc = first

        # Gradient-test restart (O'Donoghue–Candès), exactly the cached
        # engine's test; resetting momentum also resets u_z to u_x.
        if opts.restart:
            uphill = jnp.vdot(acc.gy, acc.Ax - state.Ax) > 0
            theta_out = jnp.where(uphill, 1.0, acc.theta)
            z_out = jnp.where(uphill, acc.x, acc.z)
            Az_out = jnp.where(uphill, acc.Ax, acc.Az)
            uz_out = jnp.where(uphill, acc.ux, acc.uz)
            n_restarts = state.n_restarts + uphill.astype(jnp.int32)
        else:
            theta_out, z_out, Az_out, uz_out = (acc.theta, acc.z, acc.Az,
                                                acc.uz)
            n_restarts = state.n_restarts

        obj = smooth.value(acc.Ax) + prox.value(acc.x)
        hist = state.hist.at[state.k].set(obj)
        dx = acc.x - state.x
        rel = jnp.linalg.norm(dx) / jnp.maximum(1.0, jnp.linalg.norm(acc.x))
        return _AccFusedState(
            x=acc.x, Ax=acc.Ax, ux=acc.ux, z=z_out, Az=Az_out, uz=uz_out,
            theta=theta_out, L=acc.L, k=state.k + 1, hist=hist,
            done=rel < opts.tol,
            n_backtracks=state.n_backtracks + acc.tries - 1,
            n_restarts=n_restarts)

    def cond(state: _AccFusedState):
        return (~state.done) & (state.k < opts.max_iters)

    init = _AccFusedState(
        x=x0, Ax=Ax0, ux=ux0, z=x0, Az=Ax0, uz=ux0,
        theta=jnp.asarray(1.0, jnp.float32), L=L_init, k=jnp.int32(0),
        hist=jnp.full((opts.max_iters,), jnp.nan, jnp.float32),
        done=jnp.asarray(False),
        n_backtracks=jnp.int32(0), n_restarts=jnp.int32(0))
    final = jax.lax.while_loop(cond, outer, init)
    info = {"iterations": final.k,
            "a_passes": 2 + final.k + final.n_backtracks,
            "converged": final.done, "plan": "fused_affine",
            "history": final.hist,
            "n_backtracks": final.n_backtracks,
            "n_restarts": final.n_restarts, "fused": True,
            "objective": final.hist[jnp.maximum(final.k - 1, 0)]}
    return final.x, info


def tfocs(smooth, linop, prox, x0: Array,
          opts: TfocsOptions = TfocsOptions()) -> tuple[Array, dict]:
    """Run the solver; returns (x*, info dict with per-iteration history).
    info["precision"] reports the resolved compute/wire precision (see
    TfocsOptions.precision)."""
    prec = resolve_precision(linop, opts)
    if prec == "bf16":
        if hasattr(linop, "astype_store"):
            linop = linop.astype_store(jnp.bfloat16)
        else:
            prec = "f32"
    if fused_gradient_enabled(smooth, linop, opts.fused,
                              needs_theta_one=True, accel=opts.accel):
        residual = None
        if prec == "psum8":
            residual = linop.init_psum_residual() \
                if hasattr(linop, "init_psum_residual") else None
            if residual is None:
                prec = "f32"     # local operand: no wire to compress
        x, info = _tfocs_fused(smooth, linop, prox, x0, opts,
                               row_separable(smooth), residual=residual)
        info["precision"] = prec
        return x, info
    if prec == "psum8":
        prec = "f32"             # EF wire needs the θ ≡ 1 fused engine
    sep = row_separable(smooth)
    if (opts.accel and sep is not None and sep.kind == "quad"
            and _fused_capable(linop)
            and fused_gradient_enabled(smooth, linop, opts.fused)):
        x, info = _tfocs_fused_accel(smooth, linop, prox, x0, opts, sep)
        info["precision"] = prec
        return x, info
    backtracking = opts.backtracking and opts.Lexact is None
    L_init = jnp.asarray(opts.Lexact if opts.Lexact is not None else opts.L0,
                         jnp.float32)

    def theta_next(theta, L_ratio):
        """TFOCS θ update; with backtracking the ratio L⁺/L rescales the
        accumulated momentum."""
        if not opts.accel:
            return jnp.asarray(1.0, jnp.float32)
        return 2.0 / (1.0 + jnp.sqrt(1.0 + 4.0 * L_ratio / (theta * theta)))

    def attempt_once(a: _Attempt) -> _Attempt:
        """One candidate step at the current (L, θ); θ is recomputed by the
        caller whenever L changes (backtracking rescales the momentum)."""
        y = (1 - a.theta) * a.x + a.theta * a.z
        Ay = (1 - a.theta) * a.Ax + a.theta * a.Az
        fy = smooth.value(Ay)
        gy = smooth.grad(Ay)
        g = linop.adjoint(gy)                       # ← ONE adjoint
        step = 1.0 / (a.L * a.theta)
        z_new = prox.prox(a.z - step * g, step)
        Az_new = linop.apply(z_new)                 # ← ONE apply
        x_new = (1 - a.theta) * a.x + a.theta * z_new
        Ax_new = (1 - a.theta) * a.Ax + a.theta * Az_new
        f_new = smooth.value(Ax_new)
        dx = x_new - y
        rhs = fy + jnp.vdot(gy, Ax_new - Ay) + 0.5 * a.L * jnp.vdot(dx, dx)
        ok = f_new <= rhs + 1e-12 * jnp.abs(fy)
        return a._replace(x=x_new, Ax=Ax_new, z=z_new, Az=Az_new,
                          fy=fy, gy=gy, Ay=Ay, ok=ok, tries=a.tries + 1)

    def outer(state: TfocsState) -> TfocsState:
        L0k = state.L * (opts.beta if backtracking else 1.0)
        theta0 = theta_next(state.theta, L0k / state.L)

        init = _Attempt(L=L0k, theta=theta0,
                        x=state.x, Ax=state.Ax, z=state.z, Az=state.Az,
                        fy=jnp.float32(0), gy=jnp.zeros_like(state.Ax),
                        Ay=state.Ax, ok=jnp.asarray(False),
                        tries=jnp.int32(0))
        first = attempt_once(init)

        if backtracking:
            def bt_cond(a: _Attempt):
                return (~a.ok) & (a.tries < opts.max_backtracks)

            def bt_body(a: _Attempt):
                L_new = a.L * opts.alpha
                theta_new = theta_next(state.theta, L_new / state.L)
                return attempt_once(a._replace(
                    L=L_new, theta=theta_new,
                    x=state.x, Ax=state.Ax, z=state.z, Az=state.Az))

            acc = jax.lax.while_loop(bt_cond, bt_body, first)
        else:
            acc = first

        # Gradient-test restart: momentum points uphill → reset it.
        if opts.restart and opts.accel:
            uphill = jnp.vdot(acc.gy, acc.Ax - state.Ax) > 0
            theta_out = jnp.where(uphill, 1.0, acc.theta)
            z_out = jnp.where(uphill, acc.x, acc.z)
            Az_out = jnp.where(uphill, acc.Ax, acc.Az)
            n_restarts = state.n_restarts + uphill.astype(jnp.int32)
        else:
            theta_out, z_out, Az_out = acc.theta, acc.z, acc.Az
            n_restarts = state.n_restarts

        obj = smooth.value(acc.Ax) + prox.value(acc.x)
        hist = state.hist.at[state.k].set(obj)
        dx = acc.x - state.x
        rel = jnp.linalg.norm(dx) / jnp.maximum(1.0, jnp.linalg.norm(acc.x))
        return TfocsState(
            x=acc.x, Ax=acc.Ax, z=z_out, Az=Az_out,
            theta=theta_out, L=acc.L, k=state.k + 1, hist=hist,
            done=rel < opts.tol,
            n_backtracks=state.n_backtracks + acc.tries - 1,
            n_restarts=n_restarts)

    def cond(state: TfocsState):
        return (~state.done) & (state.k < opts.max_iters)

    Ax0 = linop.apply(x0)
    init = TfocsState(
        x=x0, Ax=Ax0, z=x0, Az=Ax0,
        theta=jnp.asarray(1.0, jnp.float32), L=L_init,
        k=jnp.int32(0),
        hist=jnp.full((opts.max_iters,), jnp.nan, jnp.float32),
        done=jnp.asarray(False),
        n_backtracks=jnp.int32(0), n_restarts=jnp.int32(0))
    final = jax.lax.while_loop(cond, outer, init)
    # Standardized keys as in _tfocs_fused; the cached accelerated scheme
    # pays apply + adjoint (two passes) per attempt, plus the seed apply.
    info = {"iterations": final.k,
            "a_passes": 1 + 2 * (final.k + final.n_backtracks),
            "converged": final.done, "plan": "cached",
            "history": final.hist,
            "n_backtracks": final.n_backtracks,
            "n_restarts": final.n_restarts, "fused": False,
            "objective": final.hist[jnp.maximum(final.k - 1, 0)],
            "precision": prec}
    return final.x, info
