"""Smoothed linear programming via the Smoothed Conic Dual (paper §3.2.3).

    minimize   cᵀx + μ/2 ‖x − x₀‖²
    subject to A x = b,  x ≥ 0

SCD: the smoothed dual  g(λ) = min_{x≥0} cᵀx + μ/2‖x−x₀‖² + λᵀ(b − Ax)
has the closed-form minimizer  x*(λ) = max(0, x₀ + (Aᵀλ − c)/μ)  and dual
gradient  ∇g(λ) = b − A x*(λ)  — one adjoint + one apply per evaluation,
so the dual ascent is exactly a TFOCS composite problem on λ (which lives
in the *data/constraint* space: row-sharded when A is distributed).

Continuation (paper: "SCD formulation solver, with continuation support"):
re-center x₀ ← x*(λ*) and re-solve; as the centers converge the smoothed
solution approaches the true LP solution even for fixed μ.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .solver import tfocs, TfocsOptions
from .prox import ProxZero

Array = jax.Array


@dataclass(frozen=True)
class _DualSmooth:
    """-g(λ) as a smooth function of the *linear output* u = Aᵀλ.

    With z := u = Aᵀλ:   -g = -bᵀλ - min_x ...  The solver's linop handles
    Aᵀ; the extra affine piece -bᵀλ is handled via the `affine` hook below
    (TFOCS's "linear operator structure": offsets fold into the smooth part).
    """
    c: Array
    x0: Array
    mu: float

    def xstar(self, u: Array) -> Array:
        return jnp.maximum(0.0, self.x0 + (u - self.c) / self.mu)

    def value(self, u: Array) -> Array:
        x = self.xstar(u)
        # min_x ≥ 0 part evaluated at the minimizer (λᵀb added by wrapper)
        return -(jnp.vdot(self.c, x)
                 + 0.5 * self.mu * jnp.vdot(x - self.x0, x - self.x0)
                 - jnp.vdot(u, x))

    def grad(self, u: Array) -> Array:
        return self.xstar(u)


@dataclass(frozen=True)
class _AffineWrap:
    """smooth(λ) = inner.value(Aᵀλ) − bᵀλ, gradient via chain rule —
    presented to the engine as acting on the identity linop over λ."""
    inner: _DualSmooth
    linop: object        # maps λ → Aᵀλ
    b: Array

    def value(self, lam: Array) -> Array:
        return self.inner.value(self.linop.apply(lam)) - jnp.vdot(self.b, lam)

    def grad(self, lam: Array) -> Array:
        # ∇ = A x*(Aᵀλ) − b
        u = self.linop.apply(lam)
        return self.linop.adjoint(self.inner.grad(u)) - self.b


class _IdentityLinop:
    def __init__(self, template: Array):
        self._t = template

    def apply(self, x):
        return x

    def adjoint(self, y):
        return y


def solve_smoothed_lp(c: Array, linop, b: Array, *, mu: float = 1e-2,
                      x0: Array | None = None, continuations: int = 3,
                      opts: TfocsOptions | None = None):
    """linop: maps x-space → constraint-space (apply = A x, adjoint = Aᵀλ).

    Returns (x, lam, info).  KKT residuals are reported in info.
    """
    n = linop.in_shape[0]
    m = linop.out_shape[0]
    x0 = jnp.zeros((n,)) if x0 is None else x0
    opts = opts or TfocsOptions(max_iters=400, restart=True,
                                backtracking=True, L0=1.0)
    lam = jnp.zeros((m,))
    info_all = {"continuations": []}

    class _AdjointOp:
        """λ ↦ Aᵀλ with adjoint x ↦ A x (swap of the primal operator)."""
        in_shape = (m,)
        out_shape = (n,)

        @staticmethod
        def apply(lamv):
            return linop.adjoint(lamv)

        @staticmethod
        def adjoint(xv):
            return linop.apply(xv)

    x_center = x0
    x = x0
    for _ in range(continuations):
        dual = _DualSmooth(c=c, x0=x_center, mu=mu)
        smooth = _AffineWrap(inner=dual, linop=_AdjointOp, b=b)
        # engine sees: minimize smooth(λ) (+ ProxZero), identity linop
        lam, info = tfocs(smooth, _IdentityLinop(lam), ProxZero(), lam, opts)
        x = dual.xstar(_AdjointOp.apply(lam))
        x_center = x
        info_all["continuations"].append(info)

    r_primal = linop.apply(x) - b
    info_all["kkt"] = {
        "primal_feasibility": jnp.linalg.norm(r_primal),
        "nonneg_violation": jnp.linalg.norm(jnp.minimum(x, 0.0)),
        "objective": jnp.vdot(c, x),
    }
    return x, lam, info_all
