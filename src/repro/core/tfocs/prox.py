"""Nonsmooth prox-capable components (paper §3.2.2 `ProxL1`).

prox_h(x, t) = argmin_u h(u) + 1/(2t) ‖u − x‖².  These act on the replicated
("driver") variable, so they are pure vector math — no collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol

import jax
import jax.numpy as jnp

Array = jax.Array


class ProxFunction(Protocol):
    def value(self, x: Array) -> Array: ...
    def prox(self, x: Array, t: Array) -> Array: ...


@dataclass(frozen=True)
class ProxZero:
    """h ≡ 0 (unconstrained smooth minimization)."""

    def value(self, x: Array) -> Array:
        return jnp.asarray(0.0, x.dtype)

    def prox(self, x: Array, t: Array) -> Array:
        return x


@dataclass(frozen=True)
class ProxL1:
    """h(x) = λ‖x‖₁ → soft thresholding."""
    lam: float

    def value(self, x: Array) -> Array:
        return self.lam * jnp.sum(jnp.abs(x))

    def prox(self, x: Array, t: Array) -> Array:
        return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t * self.lam, 0.0)


@dataclass(frozen=True)
class ProxL2Sq:
    """h(x) = (λ/2)‖x‖₂² → shrinkage."""
    lam: float

    def value(self, x: Array) -> Array:
        return 0.5 * self.lam * jnp.vdot(x, x)

    def prox(self, x: Array, t: Array) -> Array:
        return x / (1.0 + t * self.lam)


@dataclass(frozen=True)
class ProxNonneg:
    """Indicator of {x ≥ 0} → projection (the LP cone)."""

    def value(self, x: Array) -> Array:
        return jnp.asarray(0.0, x.dtype)   # +inf outside; solvers stay inside

    def prox(self, x: Array, t: Array) -> Array:
        return jnp.maximum(x, 0.0)


@dataclass(frozen=True)
class ProxBox:
    lo: float
    hi: float

    def value(self, x: Array) -> Array:
        return jnp.asarray(0.0, x.dtype)

    def prox(self, x: Array, t: Array) -> Array:
        return jnp.clip(x, self.lo, self.hi)
