"""solveLASSO helper (paper §3.2.2):  ½‖Ax − b‖² + λ‖x‖₁.

The three composite parts, exactly as the paper lists them:
  linear component    — LinopMatrix (distributed matmul)
  smooth component    — SmoothQuad (quadratic loss)
  nonsmooth component — ProxL1 (soft threshold)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .linop import LinopMatrix
from .smooth import SmoothQuad
from .prox import ProxL1
from .solver import tfocs, TfocsOptions

Array = jax.Array


def solve_lasso(A, b: Array, lam: float, *, x0: Array | None = None,
                opts: TfocsOptions | None = None):
    linop = LinopMatrix(A)
    smooth = SmoothQuad(b=linop.pad_data(b), weights=linop.row_weights())
    prox = ProxL1(lam)
    x0 = jnp.zeros(linop.in_shape) if x0 is None else x0
    opts = opts or TfocsOptions(max_iters=500, backtracking=True,
                                restart=True)
    return tfocs(smooth, linop, prox, x0, opts)
