from .linop import LinopMatrix, LinopIdentity, LinopAdjoint, CountingLinop
from .smooth import (SmoothQuad, SmoothLogLoss, SmoothLinear, SmoothHuber,
                     SmoothHuberL1, SmoothPoisson, SmoothSum, RowSeparable,
                     row_separable)
from .prox import ProxZero, ProxL1, ProxL2Sq, ProxNonneg, ProxBox
from .solver import tfocs, TfocsOptions, fused_gradient_enabled
from .lp import solve_smoothed_lp
from .lasso import solve_lasso

__all__ = [
    "LinopMatrix", "LinopIdentity", "LinopAdjoint", "CountingLinop",
    "SmoothQuad", "SmoothLogLoss", "SmoothLinear", "SmoothHuber",
    "SmoothHuberL1", "SmoothPoisson", "SmoothSum", "RowSeparable",
    "row_separable",
    "ProxZero", "ProxL1", "ProxL2Sq", "ProxNonneg", "ProxBox",
    "tfocs", "TfocsOptions", "fused_gradient_enabled",
    "solve_smoothed_lp", "solve_lasso",
]
