from .linop import LinopMatrix, LinopIdentity, LinopAdjoint
from .smooth import (SmoothQuad, SmoothLogLoss, SmoothLinear, SmoothHuberL1,
                     SmoothSum)
from .prox import ProxZero, ProxL1, ProxL2Sq, ProxNonneg, ProxBox
from .solver import tfocs, TfocsOptions
from .lp import solve_smoothed_lp
from .lasso import solve_lasso

__all__ = [
    "LinopMatrix", "LinopIdentity", "LinopAdjoint",
    "SmoothQuad", "SmoothLogLoss", "SmoothLinear", "SmoothHuberL1",
    "SmoothSum",
    "ProxZero", "ProxL1", "ProxL2Sq", "ProxNonneg", "ProxBox",
    "tfocs", "TfocsOptions", "solve_smoothed_lp", "solve_lasso",
]
