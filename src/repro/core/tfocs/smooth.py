"""Smooth components of composite objectives (paper §3.2.2 `SmoothQuad`).

A smooth function is evaluated at the *output* of the linear operator (the
row-sharded data-space vector); its gradient is mapped back through the
adjoint by the solver.  Reductions here run at jit level on global arrays —
the partitioner turns them into the tree all-reduces of the paper's
"collect on the driver" step.

`weights` lets the distributed layout mask its padding rows (and doubles as
per-example weighting).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol

import jax
import jax.numpy as jnp

Array = jax.Array


class SmoothFunction(Protocol):
    """value/grad are required; `as_row_separable` is optional — a smooth
    that implements it advertises f(z) = Σᵢ wᵢ ℓ(zᵢ, tᵢ) structure, which
    lets the distributed layer run the single-pass fused gradient kernel
    (kernels/fusedgrad) instead of a separate apply + adjoint."""

    def value(self, z: Array) -> Array: ...
    def grad(self, z: Array) -> Array: ...


@dataclass(frozen=True)
class RowSeparable:
    """Static description of a row-separable smooth: f(z) = Σᵢ wᵢ ℓ(zᵢ, tᵢ).

    `kind` is the fused-kernel loss id ("quad" | "logistic" | "huber" |
    "poisson"), `target` the per-row data (b for quad/huber, ±1 labels for
    logistic, counts for poisson), `weights` the per-row weights (None ⇒
    all-ones; distributed layouts substitute their padding-row mask), and
    `param` the loss's static scalar (the huber δ; ignored elsewhere —
    it reaches the Pallas kernels as a compile-time constant)."""
    kind: str
    target: Array
    weights: Array | None
    param: float = 1.0


def row_separable(smooth) -> RowSeparable | None:
    """The smooth's row-separable form, or None when it has none (the fused
    gradient path then falls back to apply + adjoint)."""
    fn = getattr(smooth, "as_row_separable", None)
    return fn() if fn is not None else None


def _w(weights, z):
    return jnp.ones_like(z) if weights is None else weights


@dataclass(frozen=True)
class SmoothQuad:
    """f(z) = ½ Σ wᵢ (zᵢ − bᵢ)² — quadratic loss."""
    b: Array
    weights: Array | None = None

    def value(self, z: Array) -> Array:
        w = _w(self.weights, z)
        r = z - self.b
        return 0.5 * jnp.sum(w * r * r)

    def grad(self, z: Array) -> Array:
        return _w(self.weights, z) * (z - self.b)

    def as_row_separable(self) -> RowSeparable:
        return RowSeparable("quad", self.b, self.weights)


@dataclass(frozen=True)
class SmoothLogLoss:
    """f(z) = Σ wᵢ log(1 + exp(−yᵢ zᵢ)), labels y ∈ {−1, +1}."""
    y: Array
    weights: Array | None = None

    def value(self, z: Array) -> Array:
        w = _w(self.weights, z)
        m = -self.y * z
        # log(1+e^m), stable
        return jnp.sum(w * jnp.logaddexp(0.0, m))

    def grad(self, z: Array) -> Array:
        w = _w(self.weights, z)
        return w * (-self.y) * jax.nn.sigmoid(-self.y * z)

    def as_row_separable(self) -> RowSeparable:
        return RowSeparable("logistic", self.y, self.weights)


@dataclass(frozen=True)
class SmoothHuber:
    """f(z) = Σ wᵢ huber_δ(zᵢ − bᵢ) — robust regression loss:
    ½d² inside |d| ≤ δ, linear δ(|d| − ½δ) outside.  Row-separable, so the
    distributed layer can run the single-pass fused gradient kernel."""
    b: Array
    delta: float = 1.0
    weights: Array | None = None

    def value(self, z: Array) -> Array:
        w = _w(self.weights, z)
        d = z - self.b
        a = jnp.abs(d)
        return jnp.sum(w * jnp.where(a <= self.delta, 0.5 * d * d,
                                     self.delta * (a - 0.5 * self.delta)))

    def grad(self, z: Array) -> Array:
        return _w(self.weights, z) * jnp.clip(z - self.b, -self.delta,
                                              self.delta)

    def as_row_separable(self) -> RowSeparable:
        return RowSeparable("huber", self.b, self.weights,
                            param=float(self.delta))


@dataclass(frozen=True)
class SmoothPoisson:
    """f(z) = Σ wᵢ (e^{zᵢ} − yᵢ zᵢ) — Poisson NLL with log link (up to the
    Σ log yᵢ! constant), counts y ≥ 0.  Row-separable."""
    y: Array
    weights: Array | None = None

    def value(self, z: Array) -> Array:
        w = _w(self.weights, z)
        return jnp.sum(w * (jnp.exp(z) - self.y * z))

    def grad(self, z: Array) -> Array:
        return _w(self.weights, z) * (jnp.exp(z) - self.y)

    def as_row_separable(self) -> RowSeparable:
        return RowSeparable("poisson", self.y, self.weights)


@dataclass(frozen=True)
class SmoothLinear:
    """f(z) = cᵀz (+ constant) — used by the smoothed-LP dual."""
    c: Array

    def value(self, z: Array) -> Array:
        return jnp.vdot(self.c, z)

    def grad(self, z: Array) -> Array:
        return self.c


@dataclass(frozen=True)
class SmoothHuberL1:
    """Huber-smoothed λ‖z‖₁ (for methods that need a smooth L1, e.g. the
    L-BFGS run in the Figure-1 benchmark)."""
    lam: float
    delta: float = 1e-4

    def value(self, z: Array) -> Array:
        a = jnp.abs(z)
        quad = 0.5 * z * z / self.delta
        lin = a - 0.5 * self.delta
        return self.lam * jnp.sum(jnp.where(a <= self.delta, quad, lin))

    def grad(self, z: Array) -> Array:
        return self.lam * jnp.clip(z / self.delta, -1.0, 1.0)


@dataclass(frozen=True)
class SmoothSum:
    """Pointwise sum of smooth components over the same argument."""
    parts: tuple

    def value(self, z: Array) -> Array:
        return sum(p.value(z) for p in self.parts)

    def grad(self, z: Array) -> Array:
        return sum(p.grad(z) for p in self.parts)
