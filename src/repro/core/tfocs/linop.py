"""Linear operators for composite objectives (paper §3.2.2 `LinopMatrix`).

The linear component is the *expensive, distributed* part of a TFOCS
composite objective — exactly the paper's matrix/vector split: `apply` maps
the replicated ("driver") variable into the row-sharded ("cluster") data
space; `adjoint` reduces back.  All solver math above this layer is
representation-agnostic and mesh-agnostic: it sees global arrays and lets
the operators own the collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix

Array = jax.Array


class LinearOperator(Protocol):
    in_shape: tuple[int, ...]
    out_shape: tuple[int, ...]

    def apply(self, x: Array) -> Array: ...
    def adjoint(self, y: Array) -> Array: ...


@dataclass(frozen=True)
class LinopMatrix:
    """y = A x for a distributed RowMatrix (or a plain local matrix)."""
    A: RowMatrix | Array

    @property
    def in_shape(self) -> tuple[int, ...]:
        return (self.A.shape[1],)

    @property
    def out_shape(self) -> tuple[int, ...]:
        # Padded row count — the data-space vectors (b, weights) must be
        # padded consistently; `pad_data` below does this for callers.
        if isinstance(self.A, RowMatrix):
            return (self.A.rows.shape[0],)
        return (self.A.shape[0],)

    def apply(self, x: Array) -> Array:
        if isinstance(self.A, RowMatrix):
            return self.A.matvec(x)
        return self.A @ x

    def adjoint(self, y: Array) -> Array:
        if isinstance(self.A, RowMatrix):
            return self.A.rmatvec(y)
        return self.A.T @ y

    def pad_data(self, b: Array) -> Array:
        """Pad a data-space vector to the padded row count."""
        m = self.out_shape[0]
        return jnp.pad(b, (0, m - b.shape[0])) if b.shape[0] < m else b

    def row_weights(self) -> Array:
        """{0,1} mask of true rows — weights for the smooth component so the
        padding rows of the distributed layout contribute nothing."""
        if isinstance(self.A, RowMatrix):
            return self.A._row_mask()
        return jnp.ones(self.out_shape, jnp.float32)


@dataclass(frozen=True)
class LinopIdentity:
    n: int

    @property
    def in_shape(self):
        return (self.n,)

    @property
    def out_shape(self):
        return (self.n,)

    def apply(self, x: Array) -> Array:
        return x

    def adjoint(self, y: Array) -> Array:
        return y

    def pad_data(self, b: Array) -> Array:
        return b

    def row_weights(self) -> Array:
        return jnp.ones((self.n,), jnp.float32)


@dataclass(frozen=True)
class LinopAdjoint:
    """The formal adjoint of another operator (used by the SCD dual solver,
    where the dual variable lives in data space)."""
    base: LinearOperator

    @property
    def in_shape(self):
        return self.base.out_shape

    @property
    def out_shape(self):
        return self.base.in_shape

    def apply(self, x: Array) -> Array:
        return self.base.adjoint(x)

    def adjoint(self, y: Array) -> Array:
        return self.base.apply(y)

    def pad_data(self, b: Array) -> Array:
        return b

    def row_weights(self) -> Array:
        return jnp.ones(self.out_shape, jnp.float32)
