"""Linear operators for composite objectives (paper §3.2.2 `LinopMatrix`).

The linear component is the *expensive, distributed* part of a TFOCS
composite objective — exactly the paper's matrix/vector split: `apply` maps
the replicated ("driver") variable into the row-sharded ("cluster") data
space; `adjoint` reduces back.  All solver math above this layer is
representation-agnostic and mesh-agnostic: it sees global arrays and lets
the operators own the collectives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core.distmat.rowmatrix import RowMatrix
from repro.core.distmat.sparserow import SparseRowMatrix

Array = jax.Array

_DIST = (RowMatrix, SparseRowMatrix)


class LinearOperator(Protocol):
    in_shape: tuple[int, ...]
    out_shape: tuple[int, ...]

    def apply(self, x: Array) -> Array: ...
    def adjoint(self, y: Array) -> Array: ...


@dataclass(frozen=True)
class LinopMatrix:
    """y = A x for a distributed RowMatrix / SparseRowMatrix (or a plain
    local matrix)."""
    A: RowMatrix | SparseRowMatrix | Array

    @property
    def in_shape(self) -> tuple[int, ...]:
        return (self.A.shape[1],)

    @property
    def out_shape(self) -> tuple[int, ...]:
        # Padded row count — the data-space vectors (b, weights) must be
        # padded consistently; `pad_data` below does this for callers.
        if isinstance(self.A, RowMatrix):
            return (self.A.rows.shape[0],)
        if isinstance(self.A, SparseRowMatrix):
            return (self.A.m_pad,)
        return (self.A.shape[0],)

    def apply(self, x: Array) -> Array:
        if isinstance(self.A, _DIST):
            return self.A.matvec(x)
        return self.A @ x

    def adjoint(self, y: Array) -> Array:
        if isinstance(self.A, _DIST):
            return self.A.rmatvec(y)
        return self.A.T @ y

    def fused_grad(self, x: Array, sep, residual: Array | None = None):
        """(f(Ax), Aᵀ∇f(Ax), Ax) in one streaming pass over A for a
        row-separable smooth (kernels/fusedgrad) — half the HBM traffic of
        apply + adjoint.  `sep` is the smooth's RowSeparable form.

        `residual` (distributed operands only; see
        RowMatrix.init_psum_residual) switches the gradient all-reduce to
        the compressed int8 wire and returns (f, g, z, new_residual)."""
        if isinstance(self.A, _DIST):
            if residual is not None:
                return self.A.fused_grad(x, sep, residual=residual)
            return self.A.fused_grad(x, sep)
        from repro.kernels import ops as _ops
        t = self.pad_data(jnp.asarray(sep.target))
        w = jnp.ones_like(t) if sep.weights is None \
            else self.pad_data(jnp.asarray(sep.weights))
        return _ops.fused_grad(jnp.asarray(self.A), jnp.asarray(x), t, w,
                               loss=sep.kind,
                               param=float(getattr(sep, "param", 1.0)))

    def astype_store(self, dtype) -> "LinopMatrix":
        """Recast the operand's storage (the solver's precision="auto"
        dispatch lands here) — distributed operands keep their sharding;
        compute still upcasts on-chip and accumulates f32."""
        if isinstance(self.A, _DIST):
            return LinopMatrix(self.A.astype_store(dtype))
        return LinopMatrix(jnp.asarray(self.A).astype(dtype))

    def init_psum_residual(self):
        """Zeroed error-feedback residual for the compressed gradient
        psum; None for local operands (no wire to compress)."""
        if isinstance(self.A, _DIST):
            return self.A.init_psum_residual()
        return None

    def fused_grad_multi(self, x: Array, seps) -> tuple[Array, Array, Array]:
        """Request-batched fused gradients: (f (k,), g (k × n), z (k × m))
        for a GROUP of k right-hand sides in one streaming pass over A —
        each HBM read of A is amortized across every request in the group.
        `x` is (k × n); `seps` a sequence of k RowSeparable smooths sharing
        one loss kind/param (or a single stacked-target smooth)."""
        if isinstance(self.A, _DIST):
            return self.A.fused_grad_multi(x, seps)
        from repro.core.distmat import types as _T
        from repro.kernels import ops as _ops
        kind, t, w, prm = _T.row_separable_batch_inputs(
            seps, self.out_shape[0], lambda: self.row_weights())
        return _ops.fused_grad_multi(jnp.asarray(self.A),
                                     jnp.atleast_2d(jnp.asarray(x)), t, w,
                                     loss=kind, param=prm)

    def operand_dtype(self):
        """dtype of the matrix operand (the planner dispatch input)."""
        A = self.A
        if isinstance(A, RowMatrix):
            return A.rows.dtype
        if isinstance(A, SparseRowMatrix):
            return A.data.dtype
        return jnp.asarray(A).dtype

    def row_shards(self) -> int:
        """Number of row shards the operand is split into — the fused-vs-
        unfused roofline is a per-shard decision, so the dispatch divides
        the global row count by this."""
        from repro.core.distmat import types as _T
        if isinstance(self.A, _DIST):
            return _T.axes_size(self.A.mesh, self.A.row_axes)
        return 1

    def pad_data(self, b: Array) -> Array:
        """Pad a data-space vector to the padded row count."""
        m = self.out_shape[0]
        return jnp.pad(b, (0, m - b.shape[0])) if b.shape[0] < m else b

    def row_weights(self) -> Array:
        """{0,1} mask of true rows — weights for the smooth component so the
        padding rows of the distributed layout contribute nothing."""
        if isinstance(self.A, _DIST):
            return self.A._row_mask()
        return jnp.ones(self.out_shape, jnp.float32)


@dataclass(frozen=True)
class LinopIdentity:
    n: int

    @property
    def in_shape(self):
        return (self.n,)

    @property
    def out_shape(self):
        return (self.n,)

    def apply(self, x: Array) -> Array:
        return x

    def adjoint(self, y: Array) -> Array:
        return y

    def pad_data(self, b: Array) -> Array:
        return b

    def row_weights(self) -> Array:
        return jnp.ones((self.n,), jnp.float32)


@dataclass
class CountingLinop:
    """Wraps an operator and counts its A-passes (apply / adjoint /
    fused_grad — each is exactly one streaming pass over A).

    The counters increment at *trace* time.  Solver loops are
    `lax.while_loop`s whose bodies trace exactly once, so the counts are
    the structural per-iteration pass counts — deterministic, independent
    of runtime iteration counts, and therefore non-flaky (bench_optim and
    the perf-smoke test rely on this)."""
    base: object
    counts: dict = field(default_factory=lambda: {
        "apply": 0, "adjoint": 0, "fused_grad": 0, "fused_grad_multi": 0})

    @property
    def in_shape(self):
        return self.base.in_shape

    @property
    def out_shape(self):
        return self.base.out_shape

    @property
    def A(self):
        return getattr(self.base, "A", None)

    def total(self) -> int:
        return sum(self.counts.values())

    def apply(self, x: Array) -> Array:
        self.counts["apply"] += 1
        return self.base.apply(x)

    def adjoint(self, y: Array) -> Array:
        self.counts["adjoint"] += 1
        return self.base.adjoint(y)

    def fused_grad(self, x: Array, sep, residual=None):
        self.counts["fused_grad"] += 1
        if residual is not None:
            return self.base.fused_grad(x, sep, residual=residual)
        return self.base.fused_grad(x, sep)

    def astype_store(self, dtype):
        return CountingLinop(self.base.astype_store(dtype), self.counts)

    def init_psum_residual(self):
        return self.base.init_psum_residual()

    def fused_grad_multi(self, x: Array, seps):
        # ONE pass over A regardless of group width — that equality is
        # exactly what the serving parity tests assert.
        self.counts["fused_grad_multi"] += 1
        return self.base.fused_grad_multi(x, seps)

    def operand_dtype(self):
        return self.base.operand_dtype()

    def row_shards(self) -> int:
        return self.base.row_shards()

    def pad_data(self, b: Array) -> Array:
        return self.base.pad_data(b)

    def row_weights(self) -> Array:
        return self.base.row_weights()


@dataclass(frozen=True)
class LinopAdjoint:
    """The formal adjoint of another operator (used by the SCD dual solver,
    where the dual variable lives in data space)."""
    base: LinearOperator

    @property
    def in_shape(self):
        return self.base.out_shape

    @property
    def out_shape(self):
        return self.base.in_shape

    def apply(self, x: Array) -> Array:
        return self.base.adjoint(x)

    def adjoint(self, y: Array) -> Array:
        return self.base.apply(y)

    def pad_data(self, b: Array) -> Array:
        return b

    def row_weights(self) -> Array:
        return jnp.ones(self.out_shape, jnp.float32)
