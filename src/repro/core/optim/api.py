"""minimize() — the one entry point for the paper's optimization suite."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tfocs.solver import TfocsOptions
from .first_order import minimize_first_order, METHODS
from .lbfgs import lbfgs
from .problems import Problem, lbfgs_value_and_grad


def minimize(problem: Problem, method: str, *, max_iters: int = 200,
             step_size: float | None = None, tol: float = 1e-10,
             fused: bool | str = "auto"):
    """Run one of the paper's methods on a Figure-1-style problem.

    `step_size` (initial) mirrors the paper's "all methods were given the
    same initial step size": for fixed-step variants it is used exactly; for
    backtracking variants it seeds the Lipschitz estimate (L0 = 1/step).

    `fused` controls the single-pass fused gradient fast path (one A read
    per evaluation for gra/lbfgs; see core.optim.first_order): "auto"
    consults the roofline dispatch, False opts out."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}")
    L0 = (1.0 / step_size) if step_size else problem.L
    if method == "lbfgs":
        from repro.core.tfocs.solver import fused_gradient_enabled
        ppe = 1 if fused_gradient_enabled(problem.smooth, problem.linop,
                                          fused) else 2
        x, info = lbfgs(lbfgs_value_and_grad(problem, fused=fused),
                        jnp.zeros(problem.linop.in_shape),
                        max_iters=max_iters, tol=tol, passes_per_eval=ppe)
        return x, info
    opts = TfocsOptions(max_iters=max_iters, tol=tol, L0=L0, fused=fused)
    return minimize_first_order(method, problem.smooth, problem.linop,
                                problem.prox, x0=None, opts=opts)
