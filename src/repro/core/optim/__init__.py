from .first_order import minimize_first_order, METHODS
from .lbfgs import lbfgs, lbfgs_composite
from .problems import make_problem, Problem, composite_value, \
    lbfgs_value_and_grad
from .api import minimize
from .elastic import (DeviceLostError, ElasticConfig, ElasticGroup,
                      SolveCheckpoint, TransientShardError, solve_elastic)

__all__ = ["minimize_first_order", "METHODS", "lbfgs", "lbfgs_composite",
           "make_problem", "Problem", "composite_value",
           "lbfgs_value_and_grad", "minimize",
           "ElasticGroup", "ElasticConfig", "SolveCheckpoint",
           "solve_elastic", "TransientShardError", "DeviceLostError"]
