"""L-BFGS (paper §3.3, ref [13]) — driver-side two-loop recursion over a
bounded history, cluster-side gradients.

The paper's point holds verbatim: the method only consumes (value, gradient)
pairs, so a traditional single-node implementation drives the cluster —
here the history buffers (2·mem n-vectors) are replicated "driver" state
inside one jitted `lax.while_loop`, and every gradient is a distributed
matvec pair through the composite linop.

Line search: backtracking Armijo (sufficient decrease) with a curvature
skip-guard on the history update — robust and branch-free enough for XLA.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tfocs.solver import TfocsOptions, fused_gradient_enabled
from repro.core.tfocs.smooth import row_separable
from repro.core.tfocs.prox import ProxZero

Array = jax.Array


class LbfgsState(NamedTuple):
    x: Array
    f: Array
    g: Array
    S: Array        # (mem, n) s-history
    Y: Array        # (mem, n) y-history
    rho: Array      # (mem,)
    idx: Array      # circular write pointer
    filled: Array   # number of valid history pairs
    k: Array
    hist: Array
    done: Array
    n_evals: Array


def _two_loop(g: Array, S: Array, Y: Array, rho: Array, idx: Array,
              filled: Array) -> Array:
    """H·g via the two-loop recursion over a circular, masked history."""
    mem = S.shape[0]

    def bwd(i, carry):
        q, alphas = carry
        slot = (idx - 1 - i) % mem
        valid = (i < filled).astype(g.dtype)
        a = valid * rho[slot] * jnp.vdot(S[slot], q)
        q = q - a * Y[slot]
        return q, alphas.at[slot].set(a)

    q, alphas = jax.lax.fori_loop(0, mem, bwd, (g, jnp.zeros((mem,), g.dtype)))

    newest = (idx - 1) % mem
    sy = jnp.vdot(S[newest], Y[newest])
    yy = jnp.vdot(Y[newest], Y[newest])
    gamma = jnp.where((filled > 0) & (yy > 0), sy / jnp.maximum(yy, 1e-30),
                      1.0)
    r = gamma * q

    def fwd(i, r):
        slot = (idx - filled + i) % mem
        valid = (i < filled).astype(g.dtype)
        beta = valid * rho[slot] * jnp.vdot(Y[slot], r)
        return r + (alphas[slot] - beta) * S[slot]

    return jax.lax.fori_loop(0, mem, fwd, r)


def lbfgs(value_and_grad: Callable[[Array], tuple[Array, Array]],
          x0: Array, *, mem: int = 10, max_iters: int = 500,
          tol: float = 1e-8, c1: float = 1e-4, max_ls: int = 25,
          init_step: float = 1.0,
          passes_per_eval: int = 2) -> tuple[Array, dict]:
    """`passes_per_eval` is how many streaming A-passes one
    `value_and_grad` call costs (1 for the fused single-pass gradient, 2
    for apply + adjoint) — it only feeds the info dict's `a_passes`."""
    n = x0.shape[0]

    def outer(state: LbfgsState) -> LbfgsState:
        d = -_two_loop(state.g, state.S, state.Y, state.rho, state.idx,
                       state.filled)
        gd = jnp.vdot(state.g, d)
        # Safeguard: if not a descent direction, fall back to steepest.
        bad = gd >= 0
        d = jnp.where(bad, -state.g, d)
        gd = jnp.where(bad, -jnp.vdot(state.g, state.g), gd)

        # First iteration: scale the step like gradient descent.
        t0 = jnp.where(state.filled > 0, 1.0,
                       init_step / jnp.maximum(jnp.linalg.norm(state.g),
                                               1e-12))

        def ls_cond(carry):
            t, f_new, _, tries = carry
            return (f_new > state.f + c1 * t * gd) & (tries < max_ls)

        def ls_body(carry):
            t, _, _, tries = carry
            t = 0.5 * t
            f_new, g_new = value_and_grad(state.x + t * d)
            return t, f_new, g_new, tries + 1

        f1, g1 = value_and_grad(state.x + t0 * d)
        t, f_new, g_new, tries = jax.lax.while_loop(
            ls_cond, ls_body, (t0, f1, g1, jnp.int32(1)))

        x_new = state.x + t * d
        s = x_new - state.x
        y = g_new - state.g
        sy = jnp.vdot(s, y)
        keep = sy > 1e-10 * jnp.linalg.norm(s) * jnp.linalg.norm(y)

        def store(args):
            S, Y, rho, idx, filled = args
            S = S.at[idx].set(s)
            Y = Y.at[idx].set(y)
            rho = rho.at[idx].set(1.0 / jnp.maximum(sy, 1e-30))
            return S, Y, rho, (idx + 1) % mem, jnp.minimum(filled + 1, mem)

        S, Y, rho, idx, filled = jax.lax.cond(
            keep, store, lambda a: a,
            (state.S, state.Y, state.rho, state.idx, state.filled))

        hist = state.hist.at[state.k].set(f_new)
        gnorm = jnp.linalg.norm(g_new)
        done = gnorm < tol * jnp.maximum(1.0, jnp.abs(f_new))
        return LbfgsState(x=x_new, f=f_new, g=g_new, S=S, Y=Y, rho=rho,
                          idx=idx, filled=filled, k=state.k + 1, hist=hist,
                          done=done, n_evals=state.n_evals + tries)

    f0, g0 = value_and_grad(x0)
    init = LbfgsState(
        x=x0, f=f0, g=g0,
        S=jnp.zeros((mem, n), x0.dtype), Y=jnp.zeros((mem, n), x0.dtype),
        rho=jnp.zeros((mem,), x0.dtype), idx=jnp.int32(0),
        filled=jnp.int32(0), k=jnp.int32(0),
        hist=jnp.full((max_iters,), jnp.nan, jnp.float32),
        done=jnp.asarray(False), n_evals=jnp.int32(1))
    final = jax.lax.while_loop(
        lambda s: (~s.done) & (s.k < max_iters), outer, init)
    # Standardized keys (iterations / a_passes / converged / plan) plus
    # solver-specific detail; n_evals stays as the native count (deprecated
    # as a primary key — a_passes is the cross-solver currency).
    return final.x, {"iterations": final.k,
                     "a_passes": final.n_evals * passes_per_eval,
                     "converged": final.done,
                     "plan": "fused" if passes_per_eval == 1 else "two-pass",
                     "history": final.hist,
                     "n_evals": final.n_evals,
                     "objective": final.f}


def lbfgs_composite(smooth, linop, prox=None, x0: Array | None = None,
                    opts: TfocsOptions | None = None):
    """Adapter so `minimize_first_order('lbfgs', ...)` takes the same
    composite as the TFOCS-engine methods.  Nonsmooth parts must be smooth
    for L-BFGS; ProxZero is required (use SmoothHuberL1 for smoothed L1).

    L-BFGS has no image cache to exploit — every line-search probe is a
    fresh (value, gradient) at a new point — so a row-separable smooth takes
    the single-pass fused gradient (one streaming read of A per evaluation
    instead of apply + adjoint's two); `opts.fused=False` opts out.

    `opts.precision` ("auto" by default) runs the planner's precision
    sweep like the TFOCS engines: a "bf16" pick recasts the operand's
    storage (compute upcasts on-chip).  The compressed "psum8" wire is
    NOT taken here — the EF residual's accounting assumes every pass is an
    accepted gradient point, which line-search probes violate — so a
    psum8 pick falls back to the f32 wire."""
    from repro.core.tfocs.solver import resolve_precision
    prox = prox or ProxZero()
    if not isinstance(prox, ProxZero):
        raise ValueError("lbfgs needs a smooth objective; fold the "
                         "regularizer into the smooth part (e.g. "
                         "SmoothHuberL1) or use acc_rb.")
    opts = opts or TfocsOptions()
    prec = resolve_precision(linop, opts)
    if prec == "bf16" and hasattr(linop, "astype_store"):
        linop = linop.astype_store(jnp.bfloat16)
    else:
        prec = "f32"
    x0 = jnp.zeros(linop.in_shape) if x0 is None else x0

    if fused_gradient_enabled(smooth, linop, getattr(opts, "fused", "auto")):
        sep = row_separable(smooth)

        def value_and_grad(x):
            f, g, _ = linop.fused_grad(x, sep)       # ← ONE A-pass
            return f, g

        passes_per_eval = 1
    else:
        def value_and_grad(x):
            z = linop.apply(x)
            return smooth.value(z), linop.adjoint(smooth.grad(z))

        passes_per_eval = 2

    x, info = lbfgs(value_and_grad, x0, max_iters=opts.max_iters,
                    tol=opts.tol, passes_per_eval=passes_per_eval)
    info["precision"] = prec
    return x, info
