"""Request-batched solver engines for the serving frontend (launch/serve).

The paper's driver/cluster split prices every optimizer iteration in
streaming passes over the distributed matrix.  When k requests share the
same design matrix A — the multi-user regime the serving frontend exists
for — their iterations can share those passes: the multi-RHS fused kernels
(kernels/fusedgrad) evaluate f(Ax), Aᵀ∇f(Ax) and Ax for a whole GROUP of
right-hand sides in ONE streaming read of A, so a group of k requests
consumes exactly as many A-passes per iteration as a single request.

Three engines, all operating on a fixed number of SLOTS with per-slot
convergence masks (the vLLM continuous-batching idiom transplanted to
solvers — the server admits/retires requests between iterations by editing
slot rows, and the step functions freeze inactive slots):

  * ``gra``   — proximal gradient with per-slot backtracking Lipschitz
    estimation (the θ ≡ 1 fused TFOCS engine of core/tfocs/solver, with the
    backtracking attempt loop shared across the group: every attempt is one
    group A-pass, and slots whose step already passed recompute the same
    accepted candidate deterministically while stragglers halve their step);
  * ``acc``   — the accelerated engine, quadratic smooths only, via the
    affine u-vector trick of core/tfocs/solver._tfocs_fused_accel batched
    over slots: each slot carries (u_x, u_z, u_b) alongside the cached
    images, so the momentum point's gradient is an affine combination and
    an iteration is still ONE group A-pass.  Per-slot theta/L, shared
    backtracking attempts and per-slot gradient-test restarts give the
    ``acc`` and ``acc_rb`` Figure-1 variants;
  * ``lbfgs`` — L-BFGS with the two-loop recursion vmapped over slots and a
    shared backtracking Armijo line search (each probe is one group A-pass).

Both step functions return the number of group A-passes they consumed, so
the server can meter per-request amortized cost; the structural parity —
group passes == single-request passes — is what tests/test_serve.py counts.

Only the fused (row-separable) path is provided: serving groups exist to
share A-passes, and the fused kernels are how a pass is shared.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.optim.lbfgs import _two_loop
from repro.core.tfocs.smooth import RowSeparable

Array = jax.Array

REGS = ("none", "l1", "l2")


def prox_batch(reg: str, X: Array, step: Array, lam: Array) -> Array:
    """Per-slot prox over stacked iterates: X (S × n), step/lam (S,).
    Matches ProxZero / ProxL1 / ProxL2Sq (core/tfocs/prox) row-wise."""
    if reg == "none":
        return X
    tl = (step * lam)[:, None]
    if reg == "l1":
        return jnp.sign(X) * jnp.maximum(jnp.abs(X) - tl, 0.0)
    if reg == "l2":
        return X / (1.0 + tl)
    raise ValueError(f"reg must be one of {REGS}, got {reg!r}")


def prox_value_batch(reg: str, X: Array, lam: Array) -> Array:
    """Per-slot h(x): (S,) regularizer values for the stacked iterates."""
    if reg == "none":
        return jnp.zeros(X.shape[0], jnp.float32)
    if reg == "l1":
        return lam * jnp.sum(jnp.abs(X), axis=1)
    if reg == "l2":
        return 0.5 * lam * jnp.sum(X * X, axis=1)
    raise ValueError(f"reg must be one of {REGS}, got {reg!r}")


def _group_vag(linop, kind: str, param: float, X: Array, T: Array, W: Array):
    """(F, G) for the whole group in ONE A-pass: the stacked RowSeparable
    carries per-slot targets/weights (inactive slots have zero weights, so
    their value/gradient are exactly 0)."""
    sep = RowSeparable(kind, T, W, param)
    f, g, _ = linop.fused_grad_multi(X, sep)
    return f, g


# -- batched proximal gradient (gra) ------------------------------------------

class GraGroupState(NamedTuple):
    X: Array        # (S, n) per-slot iterates
    F: Array        # (S,)  smooth value at X (carried, no recompute)
    G: Array        # (S, n) x-space gradient at X (carried)
    L: Array        # (S,)  per-slot Lipschitz estimates
    k: Array        # (S,)  per-slot completed iterations
    done: Array     # (S,)  per-slot convergence flag
    obj: Array      # (S,)  last composite objective f + h
    bt: Array       # (S,)  per-slot cumulative backtracks


def gra_group_init(slots: int, n: int, L0: float = 1.0) -> GraGroupState:
    return GraGroupState(
        X=jnp.zeros((slots, n), jnp.float32),
        F=jnp.zeros((slots,), jnp.float32),
        G=jnp.zeros((slots, n), jnp.float32),
        L=jnp.full((slots,), L0, jnp.float32),
        k=jnp.zeros((slots,), jnp.int32),
        done=jnp.zeros((slots,), bool),
        obj=jnp.full((slots,), jnp.nan, jnp.float32),
        bt=jnp.zeros((slots,), jnp.int32))


def make_gra_group(linop, kind: str, param: float = 1.0, *,
                   reg: str = "none", alpha: float = 2.0, beta: float = 0.9,
                   max_backtracks: int = 30, backtracking: bool = True,
                   tol_eps: float = 1e-12):
    """Build (seed_fn, step_fn) for a batched proximal-gradient group.

    seed_fn(state, T, W, lam)                → (state, passes)
        recompute F/G (and obj) for every slot — ONE group A-pass; called
        after the server edits slot rows (admission), and a no-op change
        for untouched slots (same inputs, same outputs).
    step_fn(state, T, W, lam, tol, active)   → (state, passes)
        one outer iteration for all active slots; `passes` is the number
        of group A-passes consumed (1 + extra backtracking attempts).
    Inactive slots are frozen bit-for-bit.
    """
    if reg not in REGS:
        raise ValueError(f"reg must be one of {REGS}, got {reg!r}")

    def seed(state: GraGroupState, T: Array, W: Array, lam: Array):
        F, G = _group_vag(linop, kind, param, state.X, T, W)
        obj = F + prox_value_batch(reg, state.X, lam)
        return state._replace(F=F, G=G, obj=obj), jnp.int32(1)

    def step(state: GraGroupState, T: Array, W: Array, lam: Array,
             tol: Array, active: Array):
        act = active & ~state.done
        L0 = jnp.where(act, state.L * (beta if backtracking else 1.0),
                       state.L)

        def attempt(L):
            stepsz = jnp.where(act, 1.0 / L, 1.0)
            Xn = prox_batch(reg, state.X - stepsz[:, None] * state.G,
                            stepsz, lam)
            Xn = jnp.where(act[:, None], Xn, state.X)
            Fn, Gn = _group_vag(linop, kind, param, Xn, T, W)   # ← ONE pass
            dX = Xn - state.X
            rhs = (state.F + jnp.sum(state.G * dX, axis=1)
                   + 0.5 * L * jnp.sum(dX * dX, axis=1))
            ok = Fn <= rhs + tol_eps * jnp.abs(state.F)
            return Xn, Fn, Gn, ok

        Xn, Fn, Gn, ok = attempt(L0)
        carry = (L0, Xn, Fn, Gn, ok, jnp.int32(1),
                 jnp.zeros_like(state.bt))

        if backtracking:
            def bt_cond(c):
                _, _, _, _, ok, tries, _ = c
                return jnp.any(act & ~ok) & (tries < max_backtracks)

            def bt_body(c):
                L, _, _, _, ok, tries, bt = c
                fail = act & ~ok
                L = jnp.where(fail, L * alpha, L)
                bt = bt + fail.astype(jnp.int32)
                # Passed slots recompute the same accepted candidate (same
                # L, same carried state → identical), so one shared attempt
                # is still ONE group A-pass for everybody.
                Xn, Fn, Gn, ok = attempt(L)
                return (L, Xn, Fn, Gn, ok, tries + 1, bt)

            carry = jax.lax.while_loop(bt_cond, bt_body, carry)

        L, Xn, Fn, Gn, _, tries, bt = carry
        dX = Xn - state.X
        rel = (jnp.linalg.norm(dX, axis=1)
               / jnp.maximum(1.0, jnp.linalg.norm(Xn, axis=1)))
        conv = act & (rel < tol)
        obj = Fn + prox_value_batch(reg, Xn, lam)
        sel = act[:, None]
        return GraGroupState(
            X=jnp.where(sel, Xn, state.X),
            F=jnp.where(act, Fn, state.F),
            G=jnp.where(sel, Gn, state.G),
            L=jnp.where(act, L, state.L),
            k=state.k + act.astype(jnp.int32),
            done=state.done | conv,
            obj=jnp.where(act, obj, state.obj),
            bt=state.bt + bt), tries

    return seed, step


# -- batched accelerated proximal gradient (acc / acc_rb) ---------------------

class AccGroupState(NamedTuple):
    X: Array        # (S, n) per-slot averaged iterates x̄
    AX: Array       # (S, m_pad) cached images A·x̄
    UX: Array       # (S, n) u_x = Aᵀ(w∘A·x̄)
    Z: Array        # (S, n) proximal-gradient iterates
    AZ: Array       # (S, m_pad)
    UZ: Array       # (S, n)
    UB: Array       # (S, n) per-slot u_b = Aᵀ(w∘t)
    F: Array        # (S,)  smooth value at X (local, from AX)
    theta: Array    # (S,)  per-slot momentum parameters
    L: Array        # (S,)  per-slot Lipschitz estimates
    k: Array        # (S,)
    done: Array     # (S,)
    obj: Array      # (S,)
    bt: Array       # (S,)  cumulative backtracks
    rs: Array       # (S,)  cumulative gradient-test restarts


def acc_group_init(slots: int, n: int, m_pad: int,
                   L0: float = 1.0) -> AccGroupState:
    return AccGroupState(
        X=jnp.zeros((slots, n), jnp.float32),
        AX=jnp.zeros((slots, m_pad), jnp.float32),
        UX=jnp.zeros((slots, n), jnp.float32),
        Z=jnp.zeros((slots, n), jnp.float32),
        AZ=jnp.zeros((slots, m_pad), jnp.float32),
        UZ=jnp.zeros((slots, n), jnp.float32),
        UB=jnp.zeros((slots, n), jnp.float32),
        F=jnp.zeros((slots,), jnp.float32),
        theta=jnp.ones((slots,), jnp.float32),
        L=jnp.full((slots,), L0, jnp.float32),
        k=jnp.zeros((slots,), jnp.int32),
        done=jnp.zeros((slots,), bool),
        obj=jnp.full((slots,), jnp.nan, jnp.float32),
        bt=jnp.zeros((slots,), jnp.int32),
        rs=jnp.zeros((slots,), jnp.int32))


def make_acc_group(linop, kind: str, param: float = 1.0, *,
                   reg: str = "none", backtracking: bool = False,
                   restart: bool = False, alpha: float = 2.0,
                   beta: float = 0.9, max_backtracks: int = 30,
                   tol_eps: float = 1e-12):
    """Build (seed_fn, step_fn) for a batched ACCELERATED group — the
    slot-parallel `_tfocs_fused_accel` (core/tfocs/solver), quadratic
    smooths only.

    With f(z) = ½ Σ wᵢ(zᵢ−tᵢ)² the x-space gradient at any point v is
    u_v − u_b with u_v = Aᵀ(w∘Av) *affine* in u, so the momentum point's
    gradient (1−θ)u_x + θu_z − u_b costs nothing and one group fused pass
    per attempt (at z⁺) is the whole iteration — the same pass-sharing
    economics as the `gra` engine despite the momentum point.

    seed_fn(state, T, W, lam) → (state, passes) refreshes per-slot
    u_b / (AX, u_x) / (AZ, u_z) in THREE group passes (at 0, X̄ and Z —
    admission re-seeds cost 3× a gra group's 1); step_fn(state, T, W,
    lam, tol, active) → (state, passes) runs one iteration for all active
    slots with shared backtracking attempts, per-slot theta/L, and (when
    `restart`) the O'Donoghue–Candès gradient test per slot.  Inactive
    slots freeze bit-for-bit."""
    if reg not in REGS:
        raise ValueError(f"reg must be one of {REGS}, got {reg!r}")
    if kind != "quad":
        raise ValueError("accelerated groups need the affine u-vector "
                         f"trick — quadratic smooths only, got {kind!r}")

    def _pass(X, T, W):
        sep = RowSeparable(kind, T, W, param)
        return linop.fused_grad_multi(X, sep)      # (F, G, AX): ONE A-pass

    def _quad_fg(AY, T, W):
        """Per-slot (value, data-space gradient) at cached images — local,
        no A-pass; matches SmoothQuad row-wise."""
        R = AY - T
        return 0.5 * jnp.sum(W * R * R, axis=1), W * R

    def seed(state: AccGroupState, T: Array, W: Array, lam: Array):
        _, G0, _ = _pass(jnp.zeros_like(state.X), T, W)   # g(0) = −u_b
        UB = -G0
        Fx, GX, AX = _pass(state.X, T, W)
        _, GZ, AZ = _pass(state.Z, T, W)
        obj = Fx + prox_value_batch(reg, state.X, lam)
        return state._replace(AX=AX, UX=GX + UB, AZ=AZ, UZ=GZ + UB,
                              UB=UB, F=Fx, obj=obj), jnp.int32(3)

    def step(state: AccGroupState, T: Array, W: Array, lam: Array,
             tol: Array, active: Array):
        act = active & ~state.done
        L0 = jnp.where(act, state.L * (beta if backtracking else 1.0),
                       state.L)

        def theta_for(L):
            # TFOCS θ update, per slot; the ratio L⁺/L rescales momentum.
            ratio = L / state.L
            return 2.0 / (1.0 + jnp.sqrt(
                1.0 + 4.0 * ratio / (state.theta * state.theta)))

        def attempt(L):
            th = theta_for(L)
            thc = th[:, None]
            AY = (1 - thc) * state.AX + thc * state.AZ
            FY, GY = _quad_fg(AY, T, W)
            G = (1 - thc) * state.UX + thc * state.UZ - state.UB  # affine!
            stepsz = jnp.where(act, 1.0 / (L * th), 1.0)
            Zn = prox_batch(reg, state.Z - stepsz[:, None] * G, stepsz, lam)
            Zn = jnp.where(act[:, None], Zn, state.Z)
            _, GZ, AZn = _pass(Zn, T, W)                 # ← the ONE pass
            UZn = GZ + state.UB
            Xn = (1 - thc) * state.X + thc * Zn
            AXn = (1 - thc) * state.AX + thc * AZn
            UXn = (1 - thc) * state.UX + thc * UZn
            Fn = 0.5 * jnp.sum(W * (AXn - T) ** 2, axis=1)
            dX = thc * (Zn - state.Z)                    # = x⁺ − y
            rhs = (FY + jnp.sum(GY * (AXn - AY), axis=1)
                   + 0.5 * L * jnp.sum(dX * dX, axis=1))
            ok = Fn <= rhs + tol_eps * jnp.abs(FY)
            return th, Xn, AXn, UXn, Zn, AZn, UZn, GY, Fn, ok

        out = attempt(L0)
        carry = (L0, *out, jnp.int32(1), jnp.zeros_like(state.bt))

        if backtracking:
            def bt_cond(c):
                ok, tries = c[10], c[11]
                return jnp.any(act & ~ok) & (tries < max_backtracks)

            def bt_body(c):
                L, ok, tries, bt = c[0], c[10], c[11], c[12]
                fail = act & ~ok
                L = jnp.where(fail, L * alpha, L)
                bt = bt + fail.astype(jnp.int32)
                # Passed slots recompute the same accepted candidate (same
                # per-slot L ⇒ same θ ⇒ identical), so one shared attempt
                # is still ONE group A-pass for everybody.
                return (L, *attempt(L), tries + 1, bt)

            carry = jax.lax.while_loop(bt_cond, bt_body, carry)

        L, th, Xn, AXn, UXn, Zn, AZn, UZn, GY, Fn, _, tries, bt = carry

        if restart:
            # Per-slot O'Donoghue–Candès gradient test; resetting momentum
            # also resets (z, Az, u_z) to the averaged iterate's.
            uphill = act & (jnp.sum(GY * (AXn - state.AX), axis=1) > 0)
            th = jnp.where(uphill, 1.0, th)
            Zn = jnp.where(uphill[:, None], Xn, Zn)
            AZn = jnp.where(uphill[:, None], AXn, AZn)
            UZn = jnp.where(uphill[:, None], UXn, UZn)
            rs = uphill.astype(jnp.int32)
        else:
            rs = jnp.zeros_like(state.rs)

        dX = Xn - state.X
        rel = (jnp.linalg.norm(dX, axis=1)
               / jnp.maximum(1.0, jnp.linalg.norm(Xn, axis=1)))
        conv = act & (rel < tol)
        obj = Fn + prox_value_batch(reg, Xn, lam)
        sel = act[:, None]
        return AccGroupState(
            X=jnp.where(sel, Xn, state.X),
            AX=jnp.where(sel, AXn, state.AX),
            UX=jnp.where(sel, UXn, state.UX),
            Z=jnp.where(sel, Zn, state.Z),
            AZ=jnp.where(sel, AZn, state.AZ),
            UZ=jnp.where(sel, UZn, state.UZ),
            UB=state.UB,
            F=jnp.where(act, Fn, state.F),
            theta=jnp.where(act, th, state.theta),
            L=jnp.where(act, L, state.L),
            k=state.k + act.astype(jnp.int32),
            done=state.done | conv,
            obj=jnp.where(act, obj, state.obj),
            bt=state.bt + bt,
            rs=state.rs + rs), tries

    return seed, step


# -- batched L-BFGS -----------------------------------------------------------

class LbfgsGroupState(NamedTuple):
    X: Array        # (S, n)
    F: Array        # (S,)
    G: Array        # (S, n)
    S_: Array       # (S, mem, n) s-history
    Y: Array        # (S, mem, n) y-history
    rho: Array      # (S, mem)
    idx: Array      # (S,) circular write pointers
    filled: Array   # (S,) valid history pairs
    k: Array        # (S,)
    done: Array     # (S,)
    obj: Array      # (S,)


def lbfgs_group_init(slots: int, n: int, mem: int = 10) -> LbfgsGroupState:
    return LbfgsGroupState(
        X=jnp.zeros((slots, n), jnp.float32),
        F=jnp.zeros((slots,), jnp.float32),
        G=jnp.zeros((slots, n), jnp.float32),
        S_=jnp.zeros((slots, mem, n), jnp.float32),
        Y=jnp.zeros((slots, mem, n), jnp.float32),
        rho=jnp.zeros((slots, mem), jnp.float32),
        idx=jnp.zeros((slots,), jnp.int32),
        filled=jnp.zeros((slots,), jnp.int32),
        k=jnp.zeros((slots,), jnp.int32),
        done=jnp.zeros((slots,), bool),
        obj=jnp.full((slots,), jnp.nan, jnp.float32))


def make_lbfgs_group(linop, kind: str, param: float = 1.0, *,
                     c1: float = 1e-4, max_ls: int = 25,
                     init_step: float = 1.0):
    """Build (seed_fn, step_fn) for a batched L-BFGS group: the two-loop
    recursion is vmapped over slots and the Armijo backtracking line search
    is shared — each probe evaluates the WHOLE group in one A-pass, with
    per-slot step halving.  Same (state, T, W, tol, active) → (state,
    passes) contract as the gra engine (no regularizer: L-BFGS needs a
    smooth objective, exactly like lbfgs_composite)."""

    def seed(state: LbfgsGroupState, T: Array, W: Array):
        F, G = _group_vag(linop, kind, param, state.X, T, W)
        return state._replace(F=F, G=G, obj=F), jnp.int32(1)

    def step(state: LbfgsGroupState, T: Array, W: Array,
             tol: Array, active: Array):
        act = active & ~state.done
        mem = state.S_.shape[1]

        d = -jax.vmap(_two_loop)(state.G, state.S_, state.Y, state.rho,
                                 state.idx, state.filled)
        gd = jnp.sum(state.G * d, axis=1)
        bad = gd >= 0
        d = jnp.where(bad[:, None], -state.G, d)
        gd = jnp.where(bad, -jnp.sum(state.G * state.G, axis=1), gd)

        gnorm = jnp.linalg.norm(state.G, axis=1)
        t0 = jnp.where(state.filled > 0, 1.0,
                       init_step / jnp.maximum(gnorm, 1e-12))

        def probe(t):
            Xp = jnp.where(act[:, None], state.X + t[:, None] * d, state.X)
            Fp, Gp = _group_vag(linop, kind, param, Xp, T, W)    # ← ONE pass
            return Fp, Gp

        F1, G1 = probe(t0)

        def ls_cond(c):
            t, Fn, _, tries = c
            fail = act & (Fn > state.F + c1 * t * gd)
            return jnp.any(fail) & (tries < max_ls)

        def ls_body(c):
            t, Fn, _, tries = c
            fail = act & (Fn > state.F + c1 * t * gd)
            t = jnp.where(fail, 0.5 * t, t)
            Fn, Gn = probe(t)
            return t, Fn, Gn, tries + 1

        t, Fn, Gn, tries = jax.lax.while_loop(
            ls_cond, ls_body, (t0, F1, G1, jnp.int32(1)))

        Xn = state.X + t[:, None] * d
        s = Xn - state.X
        y = Gn - state.G
        sy = jnp.sum(s * y, axis=1)
        keep = act & (sy > 1e-10 * jnp.linalg.norm(s, axis=1)
                      * jnp.linalg.norm(y, axis=1))

        # Per-slot circular write without dynamic indices: one-hot the write
        # slot, masked by the curvature guard.
        onehot = (jnp.arange(mem)[None, :] == state.idx[:, None]) \
            & keep[:, None]                                   # (S, mem)
        S_ = jnp.where(onehot[:, :, None], s[:, None, :], state.S_)
        Y = jnp.where(onehot[:, :, None], y[:, None, :], state.Y)
        rho = jnp.where(onehot, (1.0 / jnp.maximum(sy, 1e-30))[:, None],
                        state.rho)
        idx = jnp.where(keep, (state.idx + 1) % mem, state.idx)
        filled = jnp.where(keep, jnp.minimum(state.filled + 1, mem),
                           state.filled)

        gnorm_new = jnp.linalg.norm(Gn, axis=1)
        conv = act & (gnorm_new < tol * jnp.maximum(1.0, jnp.abs(Fn)))
        sel = act[:, None]
        return LbfgsGroupState(
            X=jnp.where(sel, Xn, state.X),
            F=jnp.where(act, Fn, state.F),
            G=jnp.where(sel, Gn, state.G),
            S_=S_, Y=Y, rho=rho, idx=idx, filled=filled,
            k=state.k + act.astype(jnp.int32),
            done=state.done | conv,
            obj=jnp.where(act, Fn, state.obj)), tries

    return seed, step
