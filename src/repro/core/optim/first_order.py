"""The Figure-1 optimizer family (paper §3.3).

`gra / acc / acc_r / acc_b / acc_rb` are all the one TFOCS engine with flags
(see core.tfocs.solver); this module binds the paper's names and presents a
uniform `minimize_first_order` that takes a *distributed* objective — a
composite (linop, smooth, prox) triple where the linop owns all cluster
communication, so the driver-side method code is oblivious to distribution,
exactly as §3.3 argues.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp

from repro.core.tfocs.solver import tfocs, TfocsOptions
from repro.core.tfocs.prox import ProxZero

METHODS = ("gra", "acc", "acc_r", "acc_b", "acc_rb", "lbfgs")

_FLAGS = {
    #            accel  backtracking restart
    "gra":      (False, False,       False),
    "acc":      (True,  False,       False),
    "acc_r":    (True,  False,       True),
    "acc_b":    (True,  True,        False),
    "acc_rb":   (True,  True,        True),
}


def minimize_first_order(method: str, smooth, linop, prox=None, x0=None,
                         opts: TfocsOptions | None = None):
    """Dispatch a paper-named method. For 'lbfgs' see core.optim.lbfgs."""
    if method == "lbfgs":
        from .lbfgs import lbfgs_composite
        return lbfgs_composite(smooth, linop, prox, x0, opts)
    accel, bt, restart = _FLAGS[method]
    opts = opts or TfocsOptions()
    opts = replace(opts, accel=accel, backtracking=bt, restart=restart)
    if not bt and opts.Lexact is None:
        # Fixed-step variants use 1/step_size as the exact Lipschitz bound.
        opts = replace(opts, Lexact=opts.L0)
    prox = prox or ProxZero()
    x0 = jnp.zeros(linop.in_shape) if x0 is None else x0
    return tfocs(smooth, linop, prox, x0, opts)
