"""The Figure-1 optimizer family (paper §3.3).

`gra / acc / acc_r / acc_b / acc_rb` are all the one TFOCS engine with flags
(see core.tfocs.solver); this module binds the paper's names and presents a
uniform `minimize_first_order` that takes a *distributed* objective — a
composite (linop, smooth, prox) triple where the linop owns all cluster
communication, so the driver-side method code is oblivious to distribution,
exactly as §3.3 argues.

Fused gradient fast path
------------------------
For row-separable smooths (SmoothQuad, SmoothLogLoss — the whole Figure-1
family — plus SmoothHuber and SmoothPoisson) the hot loop can evaluate
f(Ax), Aᵀ∇f(Ax) and Ax in ONE streaming
pass over the distributed matrix (kernels/fusedgrad) instead of the two
passes of apply + adjoint.  Dispatch, controlled by `TfocsOptions.fused`
(threaded through `minimize(..., fused=...)`):

  * `gra` and `lbfgs` take the fused path — `gra` because with θ ≡ 1 the
    next gradient point is this attempt's candidate point, `lbfgs` because
    every line-search probe is a fresh (value, gradient) pair;
  * the accelerated variants (`acc*`) keep apply + adjoint: their gradient
    point is a momentum combination whose image the TFOCS cache already
    provides for free, so two passes is their floor;
  * non-separable smooths always fall back to apply + adjoint.

`fused="auto"` (default) additionally consults the execution planner —
``launch/planner.plan("grad", {"m": rows_per_shard, "n": n})``, one A read
vs two priced on the calibrated machine model (``plan(...).explain()``
shows the roofline terms behind the decision); pass `fused=False` to opt
out, e.g. when comparing against the unfused baseline (bench_optim does
exactly that and counts one A-pass per backtracking attempt on the fused
path).

Low-precision dispatch (`TfocsOptions.precision`, default "auto")
-----------------------------------------------------------------
The same planner grows a precision axis: ``plan("grad", ...,
context={"tol": opts.tol})`` prices the roofline at candidate byte
widths and picks among

  * "f32"   — exact storage and wire (always admissible);
  * "bf16"  — the operand's storage recast to bfloat16 (2× fewer HBM and
    collective bytes; kernels upcast tiles on-chip and accumulate f32);
    admitted when tol ≥ 1e-5;
  * "psum8" — the gradient all-reduce ships int8 payloads with a shared
    scale and per-shard f32 error-feedback residuals
    (train/compression.psum_int8, ~4× fewer collective bytes); admitted
    when tol ≥ 1e-6, taken by the θ ≡ 1 fused engine (`gra`) only.

A candidate must also clear the planner's modeled-savings floor, so tiny
problems stay f32.  ``info["precision"]`` reports what ran;
``plan(...).explain()`` shows the byte savings behind the choice.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp

from repro.core.tfocs.solver import tfocs, TfocsOptions
from repro.core.tfocs.prox import ProxZero

METHODS = ("gra", "acc", "acc_r", "acc_b", "acc_rb", "lbfgs")

_FLAGS = {
    #            accel  backtracking restart
    "gra":      (False, False,       False),
    "acc":      (True,  False,       False),
    "acc_r":    (True,  False,       True),
    "acc_b":    (True,  True,        False),
    "acc_rb":   (True,  True,        True),
}


def minimize_first_order(method: str, smooth, linop, prox=None, x0=None,
                         opts: TfocsOptions | None = None):
    """Dispatch a paper-named method. For 'lbfgs' see core.optim.lbfgs."""
    if method == "lbfgs":
        from .lbfgs import lbfgs_composite
        return lbfgs_composite(smooth, linop, prox, x0, opts)
    accel, bt, restart = _FLAGS[method]
    opts = opts or TfocsOptions()
    opts = replace(opts, accel=accel, backtracking=bt, restart=restart)
    if not bt and opts.Lexact is None:
        # Fixed-step variants use 1/step_size as the exact Lipschitz bound.
        opts = replace(opts, Lexact=opts.L0)
    prox = prox or ProxZero()
    x0 = jnp.zeros(linop.in_shape) if x0 is None else x0
    return tfocs(smooth, linop, prox, x0, opts)
