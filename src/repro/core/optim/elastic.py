"""Elastic, fault-tolerant executor for the batched solver engines.

The TFOCS/L-BFGS one-shot solvers are `lax.while_loop`s — one traced
program, no host between iterations, nowhere to notice a straggler or
write a checkpoint.  The serving frontend already drives the batched
engines (core/optim/batched) one iteration at a time from the host; this
module extracts that driver into ``ElasticGroup`` and makes the
host-visible gap between iterations do the fault-tolerance work:

  * straggler mitigation — per-iteration, per-shard timing telemetry feeds
    train.straggler.ShardMonitor; when it names a slow shard, the group
    re-shards the distributed matrix onto the survivor mesh
    (train.elastic.remesh_linop / survivor_mesh) MID-SOLVE: iterate,
    gradient and history state live on the driver and never move, only the
    matrix does, so the iteration counter stays monotone and no completed
    iteration is re-run (one re-seed A-pass refreshes F/G in the new
    reduction order);
  * transient faults — a failed pass (TransientShardError) or a non-finite
    smooth value rolls back to the pre-step state and retries with bounded
    exponential backoff; DeviceLostError re-meshes like a monitor trip;
  * resumable solves — ``SolveCheckpoint`` (train.checkpoint underneath)
    snapshots the complete optimizer state (iterates, gradients, L-BFGS
    memory, iteration counters, slot masks) every N iterations and
    restores it bit-compatibly, so a killed solve resumed from its last
    checkpoint reaches the same convergence state as an undisturbed run.

``solve_elastic`` drives a 1-slot group for the direct call path
(`api.SolveRequest(checkpoint_dir=..., resume=True)` routes here);
launch/serve.GroupRunner wraps a many-slot group for the serving path.
Fault behavior is entirely opt-in: with ``elastic=None`` the group runs
the exact op sequence the serving frontend always ran — bit-for-bit.

The injection side of the contract (``fault_hook``/``on_remesh``) is
implemented by train.faults.FaultyLinop; see the "fault tolerance &
resumable solves" section of examples/quickstart.py for the wiring.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optim import batched as _batched
from repro.launch import telemetry as _tel
from repro.train import checkpoint as _ckpt
from repro.train.straggler import ShardMonitor

Array = jax.Array

GROUP_METHODS = ("gra", "acc", "acc_rb", "lbfgs")
# The accelerated members batch via the affine u-vector trick
# (batched.make_acc_group) — quadratic losses only; acc_rb adds
# backtracking + gradient-test restarts.
ACC_METHODS = ("acc", "acc_rb")


class TransientShardError(RuntimeError):
    """One pass over one shard failed but the shard is alive (dropped
    collective, preempt notice, corrupted reduction) — roll back the
    iteration and retry with backoff."""


class DeviceLostError(RuntimeError):
    """A shard's device is gone for good — re-mesh onto the survivors."""

    def __init__(self, shard: int):
        super().__init__(f"device backing shard {shard} lost")
        self.shard = shard


# -- resumable solver state ---------------------------------------------------

class SolveCheckpoint:
    """Periodic snapshots of batched solver state, restored bit-compatibly.

    The snapshot is mesh-INDEPENDENT by construction: the engines keep
    every optimizer array replicated on the driver (X/F/G, L-BFGS S/Y/rho
    memory, per-slot k/done/obj, the active mask), and the data-space
    arrays (padded targets/weights) are rebuilt from the request on
    restore — so a checkpoint written on an 8-shard mesh resumes on 1
    shard and vice versa.  Storage is train.checkpoint: atomic .tmp→rename
    commit, fsync'd LATEST pointer, and (by default) the async writer so
    the solve blocks only for the host transfer."""

    def __init__(self, ckpt_dir, *, every: int = 10, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.every = int(every)
        self.saves = 0
        self._async = _ckpt.AsyncCheckpointer(ckpt_dir) if async_save \
            else None

    def save(self, step: int, state, active, *, extra: dict | None = None):
        tree = {"state": state, "active": np.asarray(active)}
        extra = dict(extra or {})
        extra["iteration"] = int(step)
        if self._async is not None:
            self._async.save_async(step, tree, extra=extra)
        else:
            _ckpt.save(self.ckpt_dir, step, tree, extra=extra)
        self.saves += 1

    def maybe_save(self, step: int, state, active, *,
                   extra: dict | None = None) -> bool:
        if self.every <= 0 or step <= 0 or step % self.every:
            return False
        self.save(step, state, active, extra=extra)
        return True

    def latest(self) -> int | None:
        return _ckpt.latest_step(self.ckpt_dir)

    def restore(self, state_like, active_like, *, step: int | None = None):
        """(state, active, extra) from the newest committed snapshot, or
        None when the directory holds no complete checkpoint."""
        if self.latest() is None:
            return None
        tree, extra = _ckpt.restore(
            self.ckpt_dir,
            {"state": state_like, "active": np.asarray(active_like)},
            step=step)
        active = np.asarray(tree["active"]).astype(bool)
        return tree["state"], active, extra

    def wait(self) -> None:
        """Block until the in-flight async write commits (and re-raise its
        error, if any) — call before treating a checkpoint as durable."""
        if self._async is not None:
            self._async.wait()


@dataclasses.dataclass
class ElasticConfig:
    """Fault-tolerance policy for an ElasticGroup.  All parts optional:
    monitor without remesh_to only observes; checkpoint alone gives
    resumability with no straggler handling.  `sleep` is injectable so
    tests measure backoff schedules without wall time."""
    monitor: ShardMonitor | None = None
    remesh_to: Callable[[int | None], Any] | None = None   # shard -> Mesh
    checkpoint: SolveCheckpoint | None = None
    max_retries: int = 3
    backoff_s: float = 0.05
    sleep: Callable[[float], None] = time.sleep


# Module-level jitted slot writers: admission/retirement edit one row of
# the batched state between iterations, and doing the dozen scatters
# eagerly costs more host dispatch than a whole solver step — jit folds
# each into one program, cached by array shape across ALL groups.
@jax.jit
def _write_slot_gra(state, T, W, lam, tol, i, t, w, lamv, tolv, x0, L0):
    state = state._replace(
        X=state.X.at[i].set(x0), F=state.F.at[i].set(0.0),
        G=state.G.at[i].set(0.0), L=state.L.at[i].set(L0),
        k=state.k.at[i].set(0), done=state.done.at[i].set(False),
        obj=state.obj.at[i].set(jnp.nan), bt=state.bt.at[i].set(0))
    return (state, T.at[i].set(t), W.at[i].set(w), lam.at[i].set(lamv),
            tol.at[i].set(tolv))


@jax.jit
def _write_slot_lbfgs(state, T, W, lam, tol, i, t, w, lamv, tolv, x0, L0):
    state = state._replace(
        X=state.X.at[i].set(x0), F=state.F.at[i].set(0.0),
        G=state.G.at[i].set(0.0), S_=state.S_.at[i].set(0.0),
        Y=state.Y.at[i].set(0.0), rho=state.rho.at[i].set(0.0),
        idx=state.idx.at[i].set(0), filled=state.filled.at[i].set(0),
        k=state.k.at[i].set(0), done=state.done.at[i].set(False),
        obj=state.obj.at[i].set(jnp.nan))
    return (state, T.at[i].set(t), W.at[i].set(w), lam.at[i].set(lamv),
            tol.at[i].set(tolv))


@jax.jit
def _write_slot_acc(state, T, W, lam, tol, i, t, w, lamv, tolv, x0, L0):
    # Data-space caches (AX/AZ) and u-vectors are zeroed; the next seed
    # pass recomputes them for the whole group.
    state = state._replace(
        X=state.X.at[i].set(x0), AX=state.AX.at[i].set(0.0),
        UX=state.UX.at[i].set(0.0), Z=state.Z.at[i].set(x0),
        AZ=state.AZ.at[i].set(0.0), UZ=state.UZ.at[i].set(0.0),
        UB=state.UB.at[i].set(0.0), F=state.F.at[i].set(0.0),
        theta=state.theta.at[i].set(1.0), L=state.L.at[i].set(L0),
        k=state.k.at[i].set(0), done=state.done.at[i].set(False),
        obj=state.obj.at[i].set(jnp.nan), bt=state.bt.at[i].set(0),
        rs=state.rs.at[i].set(0))
    return (state, T.at[i].set(t), W.at[i].set(w), lam.at[i].set(lamv),
            tol.at[i].set(tolv))


_SLOT_WRITERS = {"gra": _write_slot_gra, "lbfgs": _write_slot_lbfgs,
                 "acc": _write_slot_acc, "acc_rb": _write_slot_acc}


@jax.jit
def _bind_slot(T, W, lam, tol, i, t, w, lamv, tolv):
    # Resume path: rebind the data-space rows around RESTORED solver state
    # (the restored X/F/G/k must survive untouched).
    return (T.at[i].set(t), W.at[i].set(w), lam.at[i].set(lamv),
            tol.at[i].set(tolv))


@jax.jit
def _clear_row(W, i):
    return W.at[i].set(0.0)


def _find_hook(linop):
    """Innermost wrapper exposing the fault_hook protocol (train.faults)."""
    obj = linop
    while obj is not None:
        if hasattr(obj, "fault_hook"):
            return obj
        obj = getattr(obj, "base", None)
    return None


class ElasticGroup:
    """Host-driven executor for one batched solver group, one iteration at
    a time — the state-holder behind launch/serve.GroupRunner and
    ``solve_elastic``.

    Owns `slots` lanes of batched engine state over a shared linop plus
    the data-space rows (targets T, weights W, per-slot lam/tol) and the
    host-side active mask.  ``admit_slot`` writes a problem into a free
    lane; ``step_iteration`` advances every active lane by one engine step
    (ONE fused group A-pass plus shared backtracking attempts) and, when
    an ElasticConfig is present, runs the recovery ladder around it:

      retry    — TransientShardError / non-finite smooth → roll back to
                 the pre-step state, exponential backoff, bounded retries;
      re-mesh  — DeviceLostError or a ShardMonitor trip → rebuild the
                 linop on config.remesh_to(shard)'s mesh, re-pad T/W for
                 the new shard count, re-seed F/G in one pass; driver-side
                 state is untouched, so `k` stays monotone;
      resume   — config.checkpoint snapshots (state, active) every N
                 iterations.

    With ``elastic=None`` every branch above is skipped and the op
    sequence is exactly the legacy serving loop's.

    Observability: every iteration phase is wrapped in a telemetry span
    (``solver.iteration`` > seed_pass / fused_pass / validate /
    checkpoint / remesh — see launch/telemetry.py), and when a recorder
    is live each engine step emits a plan-vs-actual record pricing the
    fused A-pass against the planner's model.  `telemetry=None` (the
    default) resolves the module-level recorder at call time — a no-op
    unless ``telemetry.enable()`` / ``api.*Request(telemetry=...)`` is in
    effect, so the untraced path costs nothing."""

    def __init__(self, linop, kind: str, param: float = 1.0, *,
                 reg: str = "none", method: str = "gra", slots: int = 8,
                 mem: int = 10, elastic: ElasticConfig | None = None,
                 telemetry: _tel.Recorder | None = None):
        if method not in GROUP_METHODS:
            raise ValueError(f"method must be one of {GROUP_METHODS}")
        if method == "lbfgs" and reg != "none":
            raise ValueError("lbfgs groups need reg='none'")
        if method in ACC_METHODS and kind != "quad":
            raise ValueError("accelerated groups batch via the affine "
                             "u-vector trick — loss='quad' only, got "
                             f"{kind!r}")
        self.linop, self.kind, self.param = linop, kind, param
        self.reg, self.method, self.slots = reg, method, slots
        self.mem = mem
        self.elastic = elastic
        self.n = linop.in_shape[0]
        self.m_pad = linop.out_shape[0]
        if method == "gra":
            self.state = _batched.gra_group_init(slots, self.n)
        elif method in ACC_METHODS:
            self.state = _batched.acc_group_init(slots, self.n, self.m_pad)
        else:
            self.state = _batched.lbfgs_group_init(slots, self.n, mem=mem)
        self._build_engines()
        self.T = jnp.zeros((slots, self.m_pad), jnp.float32)
        self.W = jnp.zeros((slots, self.m_pad), jnp.float32)
        self.lam = jnp.zeros((slots,), jnp.float32)
        self.tol = jnp.full((slots,), 1e-8, jnp.float32)
        self.active = np.zeros(slots, bool)          # host-side slot map
        self._slot_b: list = [None] * slots          # raw targets (remesh)
        self.a_passes = 0          # lifetime group passes (the shared cost)
        self._dirty = False        # admissions since the last seed pass
        self.iteration = 0         # global monotone iteration counter
        self.retries = 0
        self.remeshes = 0
        self.checkpoint_saves = 0
        self._telemetry = telemetry
        self._fused_plan_cache = None   # invalidated on remesh
        self.monitor = elastic.monitor if elastic is not None else None
        if self.monitor is not None \
                and self.monitor.nshards != linop.row_shards():
            self.monitor.reset(linop.row_shards())

    @property
    def tel(self) -> _tel.Recorder:
        """The group's recorder: the one passed in, else the module-level
        ``telemetry.current()`` (a no-op unless enabled)."""
        return self._telemetry if self._telemetry is not None \
            else _tel.current()

    def _fused_plan(self):
        """Lazily-priced ExecutionPlan for this group's fused A-pass (the
        per-step unit of plan-vs-actual); re-priced after a remesh."""
        if self._fused_plan_cache is None:
            from repro.launch import planner
            self._fused_plan_cache = planner.plan(
                "fusedgrad", {"m": self.m_pad, "n": self.n})
        return self._fused_plan_cache

    def _build_engines(self) -> None:
        if self.method == "gra":
            seed, step = _batched.make_gra_group(self.linop, self.kind,
                                                 self.param, reg=self.reg)
        elif self.method in ACC_METHODS:
            rb = self.method == "acc_rb"
            seed, step = _batched.make_acc_group(
                self.linop, self.kind, self.param, reg=self.reg,
                backtracking=rb, restart=rb)
        else:
            seed, step = _batched.make_lbfgs_group(self.linop, self.kind,
                                                   self.param)
        self._seed, self._step = jax.jit(seed), jax.jit(step)

    # -- slot management ------------------------------------------------------

    def free_slots(self) -> int:
        return int(self.slots - self.active.sum())

    def busy(self) -> bool:
        return bool(self.active.any())

    def admit_slot(self, b, *, lam: float = 0.0, tol: float = 1e-8,
                   x0=None, L0: float = 1.0,
                   reset_state: bool = True) -> int:
        """Write a problem into a free slot; costs no pass by itself (the
        next step's seed recomputes F/G for the whole group in one).
        `reset_state=False` binds only the data-space rows, for restoring
        checkpointed solver state into the lane afterwards."""
        i = int(np.flatnonzero(~self.active)[0])
        b = jnp.asarray(b, jnp.float32)
        x0 = jnp.zeros((self.n,), jnp.float32) if x0 is None \
            else jnp.asarray(x0, jnp.float32)
        if reset_state:
            write = _SLOT_WRITERS[self.method]
            self.state, self.T, self.W, self.lam, self.tol = write(
                self.state, self.T, self.W, self.lam, self.tol, i,
                self.linop.pad_data(b), self.linop.row_weights(),
                float(lam), float(tol), x0, float(L0))
            self._dirty = True
        else:
            self.T, self.W, self.lam, self.tol = _bind_slot(
                self.T, self.W, self.lam, self.tol, i,
                self.linop.pad_data(b), self.linop.row_weights(),
                float(lam), float(tol))
        self.active[i] = True
        self._slot_b[i] = b
        return i

    def clear_slot(self, i: int) -> None:
        """Retire lane `i`: zero its weight row so it contributes nothing
        to subsequent group passes (state rows reset on the next admit)."""
        self.W = _clear_row(self.W, i)
        self.active[i] = False
        self._slot_b[i] = None

    # -- the iteration --------------------------------------------------------

    def _seed_if_dirty(self) -> int:
        if not self._dirty:
            return 0
        with self.tel.span("solver.seed_pass",
                           active=int(self.active.sum())) as sp:
            if self.method == "lbfgs":
                self.state, p = self._seed(self.state, self.T, self.W)
            else:
                self.state, p = self._seed(self.state, self.T, self.W,
                                           self.lam)
            sp.sync_on(self.state.F)
        self._dirty = False
        self.a_passes += int(p)
        return int(p)

    def _engine_step(self, act):
        if self.method == "lbfgs":
            return self._step(self.state, self.T, self.W, self.tol, act)
        return self._step(self.state, self.T, self.W, self.lam,
                          self.tol, act)

    def step_iteration(self) -> int:
        """One solver iteration for every active slot; returns the group
        A-passes consumed (including re-seeds, retries, and re-meshes).
        Raises TransientShardError when a fault outlives max_retries, and
        DeviceLostError when a device dies with no remesh_to policy."""
        if not self.busy():
            return 0
        tel = self.tel
        passes = 0
        failures = 0
        with tel.span("solver.iteration", iteration=self.iteration,
                      active=int(self.active.sum())):
            while True:
                passes += self._seed_if_dirty()
                act = jnp.asarray(self.active)
                t0 = time.monotonic()
                with tel.span("solver.fused_pass") as psp:
                    new_state, tries = self._engine_step(act)
                    dt = time.monotonic() - t0
                    psp.sync_on(new_state.F)
                    psp.annotate(tries=int(tries))
                passes += int(tries)
                self.a_passes += int(tries)
                if tel.enabled:
                    tel.record_plan_actual(
                        self._fused_plan(), psp.dur_s / max(int(tries), 1),
                        iteration=self.iteration, tries=int(tries))
                if self.elastic is None:
                    self.state = new_state
                    return passes
                telemetry = None
                try:
                    with tel.span("solver.validate"):
                        hook = _find_hook(self.linop)
                        if hook is not None:
                            new_state, telemetry = hook.fault_hook(
                                self.iteration, new_state, dt)
                        if not bool(jnp.all(jnp.isfinite(
                                jnp.where(act, new_state.F, 0.0)))):
                            raise TransientShardError(
                                "non-finite smooth value after step")
                except DeviceLostError as e:
                    if self.elastic.remesh_to is None:
                        raise
                    # Pre-step state is intact (rollback is free: new_state
                    # was never committed) — re-mesh, re-run the iteration.
                    self.remesh(self.elastic.remesh_to(e.shard),
                                dropped=e.shard)
                    failures = 0
                    continue
                except TransientShardError:
                    failures += 1
                    self.retries += 1
                    tel.counter("solver.retries").inc()
                    if failures > self.elastic.max_retries:
                        raise
                    self.elastic.sleep(self.elastic.backoff_s
                                       * (2 ** (failures - 1)))
                    continue                   # rollback + bounded retry
                self.state = new_state
                self.iteration += 1
                if telemetry is not None and self.monitor is not None:
                    verdict = self.monitor.observe(telemetry["shard_times"])
                    if verdict["tripped"] \
                            and self.elastic.remesh_to is not None:
                        self.remesh(self.elastic.remesh_to(verdict["shard"]),
                                    dropped=verdict["shard"])
                ck = self.elastic.checkpoint
                if ck is not None and ck.every > 0 \
                        and self.iteration % ck.every == 0:
                    with tel.span("solver.checkpoint",
                                  iteration=self.iteration):
                        if ck.maybe_save(self.iteration, self.state,
                                         self.active,
                                         extra={"a_passes": self.a_passes}):
                            self.checkpoint_saves += 1
                return passes

    # -- mid-solve re-mesh ----------------------------------------------------

    def remesh(self, new_mesh, dropped: int | None = None) -> None:
        """Move the MATRIX to `new_mesh` mid-solve; driver-side solver
        state is mesh-independent and stays put.  The data-space rows are
        re-padded for the new shard count from the stored raw targets, and
        the next step re-seeds F/G in one group pass — `k` is untouched,
        so no completed iteration is re-run."""
        from repro.train import elastic as _train_elastic
        tel = self.tel
        with tel.span("solver.remesh", dropped=dropped,
                      iteration=self.iteration):
            self._remesh_inner(_train_elastic, new_mesh, dropped, tel)
        tel.counter("solver.remeshes").inc()

    def _remesh_inner(self, _train_elastic, new_mesh, dropped, tel) -> None:
        self.linop = _train_elastic.remesh_linop(self.linop, new_mesh)
        obj = self.linop
        while obj is not None:                 # tell injection wrappers
            if hasattr(obj, "on_remesh"):
                obj.on_remesh(dropped)
            obj = getattr(obj, "base", None)
        self.m_pad = self.linop.out_shape[0]
        self._fused_plan_cache = None          # re-price plan-vs-actual
        with tel.span("solver.rejit"):
            self._build_engines()
        # Solver state is logically driver-side, but its arrays are still
        # committed to the OLD device set (they were produced by jits over
        # the old mesh).  Re-home them as uncommitted host-backed arrays so
        # the next jit can co-locate them with the re-meshed operands.
        self.state, self.lam, self.tol = jax.tree_util.tree_map(
            lambda a: jnp.asarray(np.asarray(jax.device_get(a))),
            (self.state, self.lam, self.tol))
        if self.method in ACC_METHODS:
            # The accelerated state caches data-space images at the OLD
            # padded row count; re-size them and let the dirty re-seed
            # (3 group passes) rebuild AX/AZ and the u-vectors.
            z = jnp.zeros((self.slots, self.m_pad), jnp.float32)
            self.state = self.state._replace(AX=z, AZ=z)
        T = jnp.zeros((self.slots, self.m_pad), jnp.float32)
        W = jnp.zeros_like(T)
        w = self.linop.row_weights()
        for i in range(self.slots):
            if self.active[i] and self._slot_b[i] is not None:
                T = T.at[i].set(self.linop.pad_data(self._slot_b[i]))
                W = W.at[i].set(w)
        self.T, self.W = T, W
        self._dirty = True                     # one re-seed pass next step
        if self.monitor is not None:
            self.monitor.reset(self.linop.row_shards())
        self.remeshes += 1


# -- the direct resumable path ------------------------------------------------

def solve_elastic(linop, kind: str, b, *, param: float = 1.0,
                  reg: str = "none", lam: float = 0.0, method: str = "gra",
                  tol: float = 1e-8, max_iters: int = 200, L0: float = 1.0,
                  x0=None, deadline_s: float | None = None,
                  resume: bool = False,
                  elastic: ElasticConfig | None = None):
    """Drive a 1-slot ElasticGroup to convergence: the fault-tolerant,
    resumable, deadline-aware twin of the one-shot solvers (and the path
    `api.solve` takes when a request carries checkpoint_dir/deadline_s).
    Returns (x, info) with the standardized info keys plus the recovery
    counters (degraded / retries / remeshes / checkpoint_saves /
    resumed_from)."""
    if elastic is None:
        elastic = ElasticConfig()
    grp = ElasticGroup(linop, kind, param, reg=reg, method=method, slots=1,
                       elastic=elastic)
    ck = elastic.checkpoint
    resumed_from = None
    if resume and ck is not None and ck.latest() is not None:
        grp.admit_slot(b, lam=lam, tol=tol, x0=x0, L0=L0,
                       reset_state=False)
        state, active, extra = ck.restore(grp.state, grp.active)
        grp.state = state
        grp.active = active
        grp.iteration = int(extra.get("iteration", 0))
        grp.a_passes = int(extra.get("a_passes", 0))
        grp._dirty = False          # F/G restored bit-exactly — no re-seed
        resumed_from = grp.iteration
    else:
        grp.admit_slot(b, lam=lam, tol=tol, x0=x0, L0=L0)

    deadline_at = time.monotonic() + deadline_s if deadline_s else None
    degraded = None
    while True:
        k = int(grp.state.k[0])
        if bool(grp.state.done[0]) or k >= max_iters:
            break
        if deadline_at is not None and time.monotonic() > deadline_at:
            degraded = "deadline"   # return the best iterate, don't block
            break
        grp.step_iteration()
    if ck is not None:
        ck.wait()                   # surface any lost background write
    k = int(grp.state.k[0])
    converged = bool(grp.state.done[0])
    if degraded is None and not converged and k >= max_iters:
        degraded = "max_iterations"
    info = {"iterations": k, "a_passes": grp.a_passes,
            "converged": converged, "plan": "elastic",
            "objective": float(grp.state.obj[0]),
            "degraded": degraded, "retries": grp.retries,
            "remeshes": grp.remeshes,
            "checkpoint_saves": grp.checkpoint_saves,
            "resumed_from": resumed_from}
    if deadline_s is not None:
        info["deadline_s"] = float(deadline_s)
    return jnp.asarray(grp.state.X[0]), info
