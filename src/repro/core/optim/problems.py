"""The four Figure-1 benchmark problems (paper §3.3), generated exactly as
described:

  linear      — scaled-up TFOCS `test_LASSO.m` data: 10000 × 1024, 512 of the
                features truly correlated; unregularized least squares.
  linear_l1   — same data, + λ‖x‖₁.
  logistic    — 10000 × 250; each feature = class-mean gaussian + noise
                gaussian; unregularized logistic regression.
  logistic_l2 — same, + (λ/2)‖x‖₂².

Problems are built as distributed composites over a RowMatrix so every
method sees the identical cluster-side objective.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import RowMatrix
from repro.core.tfocs import (LinopMatrix, SmoothQuad, SmoothLogLoss,
                              SmoothHuberL1, SmoothSum, ProxZero, ProxL1,
                              ProxL2Sq)

Array = jax.Array


@dataclass(frozen=True)
class Problem:
    name: str
    linop: LinopMatrix
    smooth: object
    prox: object
    smooth_for_lbfgs: object     # L1 folded in smoothly where needed
    L: float                     # exact Lipschitz bound (‖A‖² · curvature)


def _lipschitz_sq_norm(A: np.ndarray) -> float:
    """‖A‖₂² via a few power iterations (driver-side, benchmark setup)."""
    v = np.random.default_rng(0).normal(size=A.shape[1])
    for _ in range(50):
        v = A.T @ (A @ v)
        v /= np.linalg.norm(v)
    return float(np.linalg.norm(A @ v) ** 2)


def make_problem(name: str, *, m: int = 10000, n: int = 1024,
                 mesh=None, seed: int = 0, lam: float | None = None,
                 dtype=np.float32) -> Problem:
    rng = np.random.default_rng(seed)
    if name.startswith("linear"):
        n_eff = n
        k_true = n_eff // 2                    # 512 of 1024 truly correlated
        A = rng.normal(size=(m, n_eff)).astype(dtype)
        xtrue = np.zeros(n_eff, dtype)
        xtrue[:k_true] = rng.normal(size=k_true).astype(dtype)
        b = (A @ xtrue + 0.1 * rng.normal(size=m)).astype(dtype)
        lam = 1.0 if lam is None else lam
        rm = RowMatrix.create(jnp.asarray(A), mesh)
        linop = LinopMatrix(rm)
        quad = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                          weights=linop.row_weights())
        L = _lipschitz_sq_norm(A)
        if name == "linear":
            return Problem(name, linop, quad, ProxZero(), quad, L)
        if name == "linear_l1":
            return Problem(name, linop, quad, ProxL1(lam),
                           _WithSmoothReg(quad, SmoothHuberL1(lam)), L)
    if name.startswith("logistic"):
        n_eff = 250 if n == 1024 else n
        y = (rng.random(m) < 0.5).astype(dtype) * 2 - 1
        mu = rng.normal(size=n_eff).astype(dtype)
        A = (y[:, None] * mu[None, :]
             + rng.normal(size=(m, n_eff))).astype(dtype)
        lam = 1e-2 if lam is None else lam
        rm = RowMatrix.create(jnp.asarray(A), mesh)
        linop = LinopMatrix(rm)
        w = linop.row_weights()
        ll = SmoothLogLoss(y=linop.pad_data(jnp.asarray(y)), weights=w)
        L = 0.25 * _lipschitz_sq_norm(A)       # σ'' ≤ 1/4
        if name == "logistic":
            return Problem(name, linop, ll, ProxZero(), ll, L)
        if name == "logistic_l2":
            return Problem(name, linop, ll, ProxL2Sq(lam),
                           _WithL2(ll, lam), L + lam)
    raise ValueError(f"unknown problem {name!r}")


@dataclass(frozen=True)
class _WithSmoothReg:
    """smooth(Ax) + reg(x) presented as an x-space objective for L-BFGS."""
    inner: object
    reg: object

    def data_value(self, z):
        return self.inner.value(z)


@dataclass(frozen=True)
class _WithL2:
    inner: object
    lam: float

    def data_value(self, z):
        return self.inner.value(z)


def composite_value(problem: Problem, x: Array) -> Array:
    z = problem.linop.apply(x)
    return problem.smooth.value(z) + problem.prox.value(x)


def lbfgs_value_and_grad(problem: Problem, fused: bool | str = "auto"):
    """x-space (value, grad) for L-BFGS, with regularizers smoothed.

    The data-fit term goes through the single-pass fused gradient when the
    smooth is row-separable (it always is for the Figure-1 problems) — one
    streaming read of A per evaluation instead of apply + adjoint's two;
    regularizers are x-space vector math on top.  fused=False opts out."""
    from repro.core.tfocs.solver import fused_gradient_enabled
    from repro.core.tfocs.smooth import row_separable
    linop, prox = problem.linop, problem.prox
    use_fused = fused_gradient_enabled(problem.smooth, linop, fused)
    sep = row_separable(problem.smooth) if use_fused else None

    def vg(x):
        if use_fused:
            f, g, _ = linop.fused_grad(x, sep)       # ← ONE A-pass
        else:
            z = linop.apply(x)
            f = problem.smooth.value(z)
            g = linop.adjoint(problem.smooth.grad(z))
        if isinstance(prox, ProxL1):
            reg = SmoothHuberL1(prox.lam)
            f = f + reg.value(x)
            g = g + reg.grad(x)
        elif isinstance(prox, ProxL2Sq):
            f = f + 0.5 * prox.lam * jnp.vdot(x, x)
            g = g + prox.lam * x
        return f, g

    return vg
