"""Unified execution planner: golden-shape dispatch parity, explain()
smoke, and machine-model calibration.

The golden tables pin the decisions the PR-3/PR-4 dispatch code made
(density break-even for BSR-vs-dense at 1/5/10% block density, the
fused-vs-unfused boundary including the tiny-m shard case, autotune rank
winners) so the refactor onto launch/planner + launch/machine is provably
behavior-preserving: plan() must reproduce every one of them with the
uncalibrated reference model.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.launch import machine, planner


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Fresh persistent caches + memos: decisions must come from the
    builtin reference model, not a user calibration file."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.reset()
    yield
    at.reset()


# -- golden tables (decisions recorded from the pre-refactor dispatch) --------

# (m, n, nx, ell, bs) -> use_bsr.  Rows 3-5 are the bench_sparse break-even
# shard shapes (4096×2048, bs=128) at 1/5/10% block density (ell = 1/2/3 of
# nbc=16): BSR wins at 1% and 5%, and at 10%… the model still says BSR for
# matvec (nx=1) — the measured flip bench_sparse records is at nx-wide
# gram, covered by the 128-wide rows.
SPARSE_GOLD = [
    ((1024, 4096, 128, 2, 128), True),
    ((1024, 4096, 128, 32, 128), False),
    ((4096, 2048, 1, 1, 128), True),
    ((4096, 2048, 1, 2, 128), True),
    ((4096, 2048, 1, 3, 128), True),
    ((512, 1024, 1, 1, 64), True),
    ((512, 1024, 64, 8, 64), False),
    ((8192, 4096, 128, 4, 64), True),
    ((2048, 2048, 2048, 4, 128), True),
]

# (m, n) -> use_fused.  The first two rows are the tiny-m shard case: two
# sublane-padded streaming passes move fewer bytes than one lane-padded
# fused pass, so the boundary is real and below m ≈ 64.
FUSED_GOLD = [
    ((8, 512), False),
    ((16, 1024), False),
    ((64, 512), True),
    ((120, 24), True),
    ((128, 128), True),
    ((512, 512), True),
    ((1250, 64), True),
    ((10000, 1024), True),
    ((65536, 512), True),
    ((100, 4096), True),
    ((40, 256), True),
]

# Autotune rank winners on the reference machine (kernel, dims, blocks).
RANK_GOLD = [
    ("gemm", {"m": 1024, "k": 1024, "n": 1024},
     {"bm": 512, "bn": 512, "bk": 1024}),
    ("gemm", {"m": 10000, "k": 1000, "n": 1000},
     {"bm": 512, "bn": 512, "bk": 1024}),
    ("tsgram", {"m": 16384, "n": 256}, {"bm": 1024}),
    ("fusedgrad", {"m": 10000, "n": 1024}, {"bm": 1024}),
    ("randsketch", {"m": 16384, "n": 2048, "r": 72},
     {"bm": 1024, "bn": 1024}),
    ("bsr", {"m": 4096, "n": 2048, "nnz": 4096 * 2048 // 20, "nx": 128},
     {"bs": 128}),
]

# SVD auto-mode golden decisions (the svd.py threshold logic, verbatim).
SVD_GOLD = [
    ({"m": 100000, "n": 512, "k": 8}, {"kind": "row"}, "gram"),
    ({"m": 100000, "n": 8192, "k": 8}, {"kind": "row"}, "gram"),
    ({"m": 100000, "n": 8193, "k": 8}, {"kind": "row"}, "randomized"),
    ({"m": 100000, "n": 8193, "k": 128}, {"kind": "row"}, "randomized"),
    ({"m": 100000, "n": 8193, "k": 129}, {"kind": "row"}, "lanczos"),
    ({"m": 4096, "n": 512, "k": 8}, {"kind": "sparse", "nnz": 40000},
     "lanczos"),
    ({"m": 100000, "n": 512, "k": 8}, {"kind": "other"}, "lanczos"),
]


class TestDispatchParity:
    @pytest.mark.parametrize("shape,want", SPARSE_GOLD)
    def test_sparse_matmul_golden(self, shape, want):
        m, n, nx, ell, bs = shape
        p = planner.plan("sparse_matmul",
                         {"m": m, "n": n, "nx": nx, "ell": ell, "bs": bs})
        assert (p.choice == "bsr") == want, p.explain()
        # the decision is the argmin of its own alternatives
        alt = dict(p.alternatives)
        assert p.choice == min(alt, key=alt.get)
        assert p.cost_s == min(alt.values())

    @pytest.mark.parametrize("shape,want", FUSED_GOLD)
    def test_grad_golden(self, shape, want):
        m, n = shape
        p = planner.plan("grad", {"m": m, "n": n})
        assert (p.choice == "fused") == want, p.explain()

    @pytest.mark.parametrize("kernel,dims,want", RANK_GOLD)
    def test_kernel_rank_golden(self, kernel, dims, want):
        p = planner.plan(kernel, dims, jnp.float32)
        assert dict(p.blocks) == want, p.explain()
        # and the planner's choice is exactly what the ops wrappers resolve
        knobs = {k: None for k in at.KERNELS[kernel].knobs}
        assert at.resolve(kernel, dims, jnp.float32, knobs) == want

    @pytest.mark.parametrize("dims,ctx,want", SVD_GOLD)
    def test_svd_mode_golden(self, dims, ctx, want):
        assert planner.plan("svd", dims, context=ctx).choice == want

    def test_sparse_break_even_moves_with_density(self):
        """Monotone in ell: once dense wins it keeps winning."""
        flips = [planner.plan("sparse_matmul",
                              {"m": 4096, "n": 2048, "nx": 128,
                               "ell": ell, "bs": 128}).choice
                 for ell in range(1, 17)]
        assert flips[0] == "bsr" and flips[-1] == "dense"
        first_dense = flips.index("dense")
        assert all(c == "dense" for c in flips[first_dense:])

    def test_fused_boundary_is_real(self):
        """Tiny-m shards pick unfused; the boundary sits below one lane."""
        choices = {m: planner.plan("grad", {"m": m, "n": 512}).choice
                   for m in (8, 16, 32, 64, 128, 512)}
        assert choices[8] == "unfused" and choices[512] == "fused"

    def test_bs_auto_matches_direct_argmin(self):
        """plan("bsr_bs") = argmin of the same model over the candidates."""
        ell_by_bs = {8: 80, 16: 44, 32: 24, 64: 14, 128: 8}
        p = planner.plan("bsr_bs", {"m": 4096, "n": 2048, "nx": 128},
                         context={"ell_by_bs": ell_by_bs})
        direct = min(
            ell_by_bs,
            key=lambda bs: at.model_time(
                "bsr", {"bs": bs},
                {"m": 4096, "n": 2048, "nx": 128, "ell": ell_by_bs[bs]},
                jnp.float32))
        assert p.blocks["bs"] == direct
        assert len(p.alternatives) == len(ell_by_bs)

    def test_dispatch_sites_consult_planner(self):
        """The real call sites produce the planner's decision."""
        from repro.core.distmat import SparseRowMatrix
        rng = np.random.default_rng(0)
        mask = rng.random((8, 16)) < 0.1
        dense = (np.kron(mask, np.ones((64, 64)))
                 * rng.normal(size=(512, 1024))).astype(np.float32)
        srm = SparseRowMatrix.from_dense(dense, bs=64)
        want = planner.plan(
            "sparse_matmul",
            {"m": srm._local_rows(), "n": srm.n_pad, "nx": 1,
             "ell": srm.ell, "bs": srm.bs}, "float32").choice
        assert srm._use_bsr(1, "auto") == (want == "bsr")


# Precision-sweep goldens on the reference V5E model: (op, dims, context)
# -> chosen precision.  Recorded from the tentpole's decision sweep; the
# guards are PRECISION_GUARDS (bf16 needs tol ≥ 1e-5, psum8 ≥ 1e-6, int8 ≥
# 1e-3) and every pick must also clear the modeled-savings floor, which is
# why the tiny grad row stays f32 at a loose tolerance.  The psum8 row is a
# comm-dominated shape (small per-shard m, wide n, 64-way reduction) where
# bf16 is inadmissible (tol below its guard) and the int8 wire still pays.
PRECISION_GOLD = [
    ("grad", {"m": 8192, "n": 2048}, {"tol": 1e-4, "axes": (8,)}, "bf16"),
    ("grad", {"m": 8192, "n": 2048}, {"tol": 1e-9, "axes": (8,)}, "f32"),
    ("grad", {"m": 4096, "n": 128}, {"tol": 1e-4, "axes": (8,)}, "f32"),
    ("gram", {"m": 65536, "n": 4096}, {"tol": 1e-4, "axes": (16, 16)},
     "bf16"),
    ("gram", {"m": 512, "n": 8192}, {"tol": 5e-6, "axes": (64,)}, "psum8"),
    ("sparse_matmul", {"m": 4096, "n": 2048, "nx": 1, "ell": 2, "bs": 128},
     {"tol": 1e-3}, "int8"),
    ("sparse_matmul", {"m": 4096, "n": 2048, "nx": 1, "ell": 2, "bs": 128},
     {"tol": 1e-8}, "f32"),
    ("matvec", {"m": 65536, "n": 4096}, {"tol": 1e-4}, "bf16"),
]


class TestPrecisionDecisions:
    @pytest.mark.parametrize("op,dims,ctx,want", PRECISION_GOLD)
    def test_precision_golden(self, op, dims, ctx, want):
        p = planner.plan(op, dims, machine=machine.V5E, context=ctx)
        assert p.precision == want, p.explain()
        # The sweep keeps the caller's logical dtype: precision names how
        # the bytes move, not what x means.
        assert p.dtype == "float32"

    def test_no_tol_means_no_sweep(self):
        """Legacy call sites (no context["tol"]) are untouched: the plan
        is not precision-swept and prices exactly as before."""
        p = planner.plan("grad", {"m": 8192, "n": 2048},
                         machine=machine.V5E, context={"axes": (8,)})
        assert p.precision == ""
        q = planner.plan("grad", {"m": 8192, "n": 2048},
                         machine=machine.V5E,
                         context={"axes": (8,), "tol": 1e-9})
        assert q.choice == p.choice and q.blocks == p.blocks

    def test_explain_reports_precision_and_savings(self):
        """Acceptance: explain() must name the chosen precision and the
        modeled byte savings for grad/gram/sparse_matmul picks."""
        picked = [
            planner.plan("grad", {"m": 8192, "n": 2048}, machine=machine.V5E,
                         context={"tol": 1e-4, "axes": (8,)}),
            planner.plan("gram", {"m": 512, "n": 8192}, machine=machine.V5E,
                         context={"tol": 5e-6, "axes": (64,)}),
            planner.plan("sparse_matmul",
                         {"m": 4096, "n": 2048, "nx": 1, "ell": 2,
                          "bs": 128}, machine=machine.V5E,
                         context={"tol": 1e-3}),
        ]
        for p in picked:
            text = p.explain()
            assert p.precision in ("bf16", "psum8", "int8"), text
            assert f"precision: {p.precision}" in text
            assert "saved" in text and "modeled bytes" in text
            # Lower precision must actually model fewer seconds than the
            # f32 alternative it displaced.
            alt = dict(p.alternatives)
            assert p.cost_s <= alt["precision:f32"]

    def test_precision_is_argmin_of_alternatives(self):
        p = planner.plan("grad", {"m": 8192, "n": 2048},
                         machine=machine.V5E,
                         context={"tol": 1e-4, "axes": (8,)})
        prec_alts = {k: v for k, v in p.alternatives
                     if k.startswith("precision:")}
        assert f"precision:{p.precision}" == min(prec_alts,
                                                 key=prec_alts.get)

    def test_bf16_grad_models_big_savings(self):
        """Acceptance floor: on the bandwidth-bound fused-grad shape the
        bf16 pick must model ≥ 1.5× over f32."""
        p = planner.plan("grad", {"m": 8192, "n": 2048},
                         machine=machine.V5E,
                         context={"tol": 1e-4, "axes": (8,)})
        alt = dict(p.alternatives)
        assert alt["precision:f32"] / p.cost_s >= 1.5, p.explain()


class TestExplain:
    def test_explain_smoke_all_ops(self):
        plans = [
            planner.plan("gemm", {"m": 1024, "k": 1024, "n": 1024}, top=3),
            planner.plan("sparse_matmul", {"m": 4096, "n": 2048, "nx": 1,
                                           "ell": 2, "bs": 128}),
            planner.plan("grad", {"m": 10000, "n": 1024}),
            planner.plan("bsr_bs", {"m": 512, "n": 512, "nx": 128},
                         context={"ell_by_bs": {8: 20, 64: 4}}),
            planner.plan("svd", {"m": 100000, "n": 4096, "k": 32},
                         context={"kind": "row"}),
        ]
        for p in plans:
            text = p.explain()
            assert f"plan({p.op})" in text
            assert p.choice in text
            assert "roofline:" in text and "-bound" in text
            assert "us" in text

    def test_explain_shows_alternatives_and_machine(self):
        p = planner.plan("sparse_matmul", {"m": 4096, "n": 2048, "nx": 1,
                                           "ell": 2, "bs": 128})
        text = p.explain()
        assert "bsr" in text and "dense" in text
        assert machine.V5E.name in text and "builtin constants" in text

    def test_unknown_op_raises(self):
        with pytest.raises(ValueError, match="unknown op"):
            planner.plan("nonsense", {"m": 1})


class TestMachineModel:
    def test_constants_single_home(self):
        """roofline + autotune constants are the MachineModel's."""
        from repro.launch import roofline as RL
        assert RL.PEAK_FLOPS == machine.V5E.mxu_flops[2]
        assert RL.HBM_BW == machine.V5E.hbm_bw
        assert RL.LINK_BW == machine.V5E.link_bw
        assert at.VMEM_BYTES == machine.V5E.vmem_bytes

    def test_terms_pricing_matches_legacy_formula(self):
        """machine.time(terms) reproduces the old max(compute, hbm/bw) +
        steps·overhead arithmetic bit-for-bit."""
        dims = {"m": 1024, "k": 1024, "n": 1024}
        blocks = {"bm": 256, "bn": 256, "bk": 512}
        t = at.cost_terms("gemm", blocks, dims, jnp.float32)
        mm = machine.V5E
        want = max(t.flops / (mm.mxu_flops[4] * t.mxu_util),
                   t.hbm_bytes / mm.hbm_bw) + t.steps * mm.step_overhead_s
        assert at.model_time("gemm", blocks, dims, jnp.float32,
                             machine=mm) == pytest.approx(want, rel=1e-12)

    def test_calibration_tightens_error_and_flips_plans(self, tmp_path):
        """Synthetic 'measured' timings from a machine 4× slower on HBM:
        calibrate() must cut the modeled-vs-measured error and subsequent
        plan() calls must pick the calibrated model up."""
        slow = machine.MachineModel(
            name="slow", mxu_flops=machine.V5E.mxu_flops,
            hbm_bw=machine.V5E.hbm_bw / 4.0,
            step_overhead_s=machine.V5E.step_overhead_s,
            link_bw=machine.V5E.link_bw,
            vmem_bytes=machine.V5E.vmem_bytes)
        records = []
        for kernel, dims, blocks in [
            ("gemm", {"m": 2048, "k": 2048, "n": 2048},
             {"bm": 256, "bn": 256, "bk": 512}),
            ("gemm", {"m": 512, "k": 4096, "n": 512},
             {"bm": 128, "bn": 128, "bk": 512}),
            ("tsgram", {"m": 65536, "n": 512}, {"bm": 512}),
            ("fusedgrad", {"m": 65536, "n": 512}, {"bm": 512}),
        ]:
            records.append(planner.calibration_record(
                kernel, dims, blocks, jnp.float32,
                at.model_time(kernel, blocks, dims, jnp.float32,
                              machine=slow)))
        fitted = machine.V5E.calibrate(records)
        before, after = (machine.V5E.error(records), fitted.error(records))
        assert after < before
        assert after < 0.35                      # additive-relaxation slack
        assert fitted.hbm_eff["float32"] == pytest.approx(0.25, rel=0.3)

        # persistence: for_backend prefers the saved calibration
        machine.save_calibration("cpu", fitted,
                                 path=tmp_path / "machine.json")
        loaded = json.loads((tmp_path / "machine.json").read_text())
        assert "cpu" in loaded["backends"]
        got = machine.MachineModel.from_dict(loaded["backends"]["cpu"])
        assert got.source == "calibrated"
        assert got.hbm_eff == fitted.hbm_eff

class TestCommPlanning:
    """Collective-aware pricing: psum terms in the plan, topology-keyed
    ring/tree selection, overlap break-even, and the link_eff fit."""

    def test_collective_cost_formulas(self):
        assert machine.collective_cost(1, 4096.0, "ring") == (0.0, 0.0)
        b, s = machine.collective_cost(8, 1024.0, "ring")
        assert b == 2.0 * 1024.0 * 7 / 8 and s == 14.0
        b, s = machine.collective_cost(8, 1024.0, "tree")
        assert b == 2.0 * 1024.0 * 3 and s == 6.0
        with pytest.raises(ValueError):
            machine.collective_cost(4, 1.0, "butterfly")

    def test_ring_tree_selection_by_payload(self):
        """Ring past the bandwidth break-even, tree under it (latency)."""
        big = machine.V5E.collective(4 * 2**20, (8,), "float32")
        small = machine.V5E.collective(256.0, (256,), "float32")
        assert big["algorithm"] == "ring"
        assert small["algorithm"] == "tree"
        # multi-axis reduction sums per-axis costs
        two = machine.V5E.collective(4 * 2**20, (16, 16), "float32")
        one = machine.V5E.collective(4 * 2**20, (16,), "float32")
        assert two["comm_s"] > one["comm_s"]

    def test_comm_fraction_grows_with_device_count(self):
        """Fixed global shape spread over more devices: the shard shrinks,
        the psum payload does not — the comm share of the modeled serial
        time must rise monotonically (and be absent on one device)."""
        fracs = []
        for dev in (1, 4, 16, 64):
            p = planner.plan("gram", {"m": 1_000_000 // dev, "n": 1024},
                             machine=machine.V5E,
                             context={"axes": (dev,)})
            comm = p.breakdown.get("comm_s", 0.0)
            serial = (max(p.breakdown["compute_s"], p.breakdown["memory_s"])
                      + p.breakdown["step_s"] + comm)
            fracs.append(comm / serial)
        assert fracs[0] == 0.0
        assert all(b > a for a, b in zip(fracs, fracs[1:])), fracs
        assert fracs[-1] > 0.1

    def test_gram_overlap_past_break_even(self):
        """Chunked overlap engages only once the modeled psum is worth
        hiding: eager on few devices, overlapped on many."""
        few = planner.plan("gram", {"m": 1_000_000 // 4, "n": 1024},
                           machine=machine.V5E, context={"axes": (4,)})
        many = planner.plan("gram", {"m": 1_000_000 // 64, "n": 1024},
                            machine=machine.V5E, context={"axes": (64,)})
        assert few.choice == "eager" and few.blocks["chunks"] == 1
        assert many.choice == "overlap" and many.blocks["chunks"] > 1
        # the decision is the argmin of its own alternatives
        alt = dict(many.alternatives)
        assert many.cost_s == min(alt.values())

    def test_grad_plan_without_axes_is_unchanged(self):
        """No topology context → the seed's compute-only fused/unfused
        decision, bit-identical: no comm terms, no chunks knob."""
        p = planner.plan("grad", {"m": 10000, "n": 1024})
        assert p.choice == "fused"
        assert "chunks" not in p.blocks
        assert p.breakdown.get("comm_s", 0.0) == 0.0

    def test_grad_plan_with_axes_prices_psum(self):
        p = planner.plan("grad", {"m": 4096, "n": 1024},
                         machine=machine.V5E,
                         context={"axes": (16, 16)})
        assert p.choice in ("fused", "unfused")
        assert p.breakdown["comm_s"] > 0.0
        assert p.terms["comm_bytes"] > 0.0
        assert "chunks" in p.blocks
        text = p.explain()
        assert "comm:" in text and "% of modeled serial time" in text

    def test_matvec_plan_topology(self):
        p = planner.plan("matvec", {"m": 65536, "n": 1024},
                         machine=machine.V5E, context={"axes": (16, 16)})
        assert p.choice in ("ring", "tree")
        assert p.breakdown["comm_s"] > 0.0
        assert dict(p.alternatives).keys() == {"ring", "tree"}
        local = planner.plan("matvec", {"m": 65536, "n": 1024},
                             machine=machine.V5E,
                             context={"axes": (16, 16), "reduce": False})
        assert local.choice == "local"
        assert local.breakdown.get("comm_s", 0.0) == 0.0

    def test_calibrate_fits_link_eff_and_persists(self, tmp_path):
        """Synthetic timings from a machine with 4× slower links: the comm
        column joins the fit, link_eff lands near 0.25, and the value
        survives the machine.json round-trip."""
        base = machine.V5E
        records = []
        for payload, axes in [(4 * 2**20, (8,)), (2**20, (16,)),
                              (16 * 2**20, (4,)), (8 * 2**20, (32,))]:
            coll = base.collective(float(payload), axes, "float32")
            slow_s = (coll["comm_bytes"] / (base.link_bw / 4.0)
                      + coll["comm_steps"] * base.link_latency_s)
            records.append({"dtype": "float32", "flops": 0.0,
                            "hbm_bytes": 0.0, "steps": 0.0, "mxu_util": 1.0,
                            "comm_bytes": coll["comm_bytes"],
                            "comm_steps": coll["comm_steps"],
                            "measured_s": slow_s})
        fitted = base.calibrate(records)
        assert fitted.link_eff["float32"] == pytest.approx(0.25, rel=0.05)
        assert fitted.error(records) < base.error(records)

        machine.save_calibration("cpu", fitted,
                                 path=tmp_path / "machine.json")
        loaded = json.loads((tmp_path / "machine.json").read_text())
        got = machine.MachineModel.from_dict(loaded["backends"]["cpu"])
        assert got.link_eff == fitted.link_eff
        assert got.link_latency_s == fitted.link_latency_s

    def test_comm_free_records_reproduce_two_term_fit(self):
        """A compute-only sweep must fit exactly as before the comm column
        existed (the column only joins when records exercise it)."""
        records = []
        for kernel, dims, blocks in [
            ("gemm", {"m": 2048, "k": 2048, "n": 2048},
             {"bm": 256, "bn": 256, "bk": 512}),
            ("tsgram", {"m": 65536, "n": 512}, {"bm": 512}),
        ]:
            records.append(planner.calibration_record(
                kernel, dims, blocks, jnp.float32,
                at.model_time(kernel, blocks, dims, jnp.float32,
                              machine=machine.V5E) * 2.0))
        fitted = machine.V5E.calibrate(records)
        assert fitted.link_eff == {}


class TestMachineModelCalibrated:
    def test_plan_prefers_calibrated_constants(self, tmp_path, monkeypatch):
        """After a calibration is persisted next to the autotune cache,
        plan() on that backend reports calibrated=True and prices with the
        fitted efficiencies."""
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "autotune.json"))
        at.reset()
        before = planner.plan("grad", {"m": 10000, "n": 1024})
        assert not before.calibrated
        fitted = machine.builtin("cpu").calibrate([])  # no-op fit, flagged
        machine.save_calibration("cpu", fitted)
        at.reset()
        after = planner.plan("grad", {"m": 10000, "n": 1024})
        assert after.calibrated and after.machine == "cpu-host"
        # CPU instance: same ratio structure, decision unchanged here
        assert after.choice == "fused"
        at.reset()
