"""SparseRowMatrix subsystem: BSR kernels, conversions, density dispatch,
sampled DIMSUM, and the sparse end-to-end SVD path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distmat import CoordinateMatrix, RowMatrix, SparseRowMatrix
from repro.core.linalg import compute_svd
from repro.kernels import ops, ref
from repro.kernels import autotune as at
from repro.kernels.bsr import BlockELL
from repro.launch import planner


def block_sparse(m, n, bs, block_density, seed=0):
    """Dense array with genuinely block-structured sparsity."""
    rng = np.random.default_rng(seed)
    mask = rng.random((m // bs, n // bs)) < block_density
    return (np.kron(mask, np.ones((bs, bs)))
            * rng.normal(size=(m, n))).astype(np.float32)


class TestBsrKernels:
    """Interpret-mode Pallas parity vs the densifying oracles."""

    @pytest.mark.parametrize("bm,bn,density", [(4, 6, 0.2), (7, 3, 0.5),
                                               (1, 1, 1.0)])
    def test_spmv_parity(self, bm, bn, density):
        dense = block_sparse(bm * 8, bn * 8, 8, density, seed=bm * 10 + bn)
        bell = BlockELL.from_dense(dense, bs=8)
        x = np.random.default_rng(1).normal(size=(bn * 8,)).astype(np.float32)
        got = ops.bsr_matvec(bell, jnp.asarray(x), force_pallas=True)
        want = ref.bsr_matvec_ref(bell, jnp.asarray(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("bm,bn,nx", [(5, 4, 16), (3, 6, 7)])
    def test_rmatmul_parity(self, bm, bn, nx):
        dense = block_sparse(bm * 8, bn * 8, 8, 0.4, seed=bm + bn)
        bell = BlockELL.from_dense(dense, bs=8)
        x = np.random.default_rng(2).normal(
            size=(bm * 8, nx)).astype(np.float32)
        got = ops.bsr_rmatmul(bell, jnp.asarray(x), force_pallas=True)
        want = ref.bsr_rmatmul_ref(bell, jnp.asarray(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(got, dense.T @ x, rtol=1e-4, atol=1e-3)

    def test_structured_jnp_paths_match_oracles(self):
        """The off-TPU dispatch targets (gather/einsum, flops ∝ blocks)
        agree with the densifying refs."""
        dense = block_sparse(40, 64, 8, 0.3, seed=5)
        bell = BlockELL.from_dense(dense, bs=8)
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(64, 9)), jnp.float32)
        U = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
        np.testing.assert_allclose(ops.bsr_matvec(bell, x),
                                   ref.bsr_matvec_ref(bell, x),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(ops.bsr_matmul(bell, X),
                                   ref.bsr_matmul_ref(bell, X),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(ops.bsr_rmatmul(bell, U),
                                   ref.bsr_rmatmul_ref(bell, U),
                                   rtol=1e-4, atol=1e-3)

    def test_from_dense_vectorized_layout(self):
        """Nonzero blocks pack into leading slots in column order."""
        dense = np.zeros((16, 32), np.float32)
        dense[0:8, 24:32] = 1.0       # block (0, 3)
        dense[0:8, 8:16] = 2.0        # block (0, 1)
        bell = BlockELL.from_dense(dense, bs=8)
        assert bell.ell == 2
        assert bell.cols[0, 0] == 1 and bell.cols[0, 1] == 3
        np.testing.assert_allclose(bell.to_dense(), dense, atol=0)


class TestAutotunerBsr:
    def test_bsr_in_candidate_space(self):
        dims = {"m": 4096, "n": 4096, "nnz": 800_000, "nx": 128}
        cands = at.candidates("bsr", dims, jnp.float32)
        assert cands and all(b["bs"] % at.sublane(jnp.float32) == 0
                             for b in cands)
        ranked = at.rank("bsr", dims, jnp.float32)
        assert ranked[0][0] <= at.model_time(
            "bsr", dict(at.KERNELS["bsr"].legacy), dims, jnp.float32)

    def test_known_ell_overrides_estimate(self):
        """Block-structured matrices pass their actual ELL width; the cost
        must use it instead of the uniform-scatter estimate."""
        sparse = at.model_time("bsr", {"bs": 64},
                               {"m": 4096, "n": 4096, "nx": 128, "ell": 3},
                               jnp.float32)
        dense = at.model_time("bsr", {"bs": 64},
                              {"m": 4096, "n": 4096, "nx": 128, "ell": 64},
                              jnp.float32)
        assert sparse < dense

    def test_block_size_selector_is_static(self):
        bs = ops.bsr_block_size(4096, 4096, 800_000)
        assert bs in (8, 16, 32, 64, 128)
        assert bs == ops.bsr_block_size(4096, 4096, 800_000)  # memoized


class TestDensityDispatch:
    def test_break_even_moves_with_ell(self):
        d_sparse = planner.plan("sparse_matmul", {"m": 1024, "n": 4096,
                                                  "nx": 128, "ell": 2,
                                                  "bs": 128})
        d_dense = planner.plan("sparse_matmul", {"m": 1024, "n": 4096,
                                                 "nx": 128, "ell": 32,
                                                 "bs": 128})
        assert d_sparse.choice == "bsr" and d_dense.choice == "dense"
        costs = {p: dict(d.alternatives)
                 for p, d in (("s", d_sparse), ("d", d_dense))}
        assert costs["s"]["bsr"] < costs["s"]["dense"] < costs["d"]["bsr"]

    def test_both_paths_agree_numerically(self):
        dense = block_sparse(64, 64, 8, 0.9, seed=7)   # dense-ish shard
        srm = SparseRowMatrix.from_dense(dense, bs=8)
        v = np.random.default_rng(0).normal(size=64).astype(np.float32)
        via_bsr = np.asarray(srm.matvec(jnp.asarray(v), dispatch="bsr"))
        via_dense = np.asarray(srm.matvec(jnp.asarray(v), dispatch="dense"))
        np.testing.assert_allclose(via_bsr, via_dense, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(via_bsr[:64], dense @ v, rtol=1e-4,
                                   atol=1e-4)
        with pytest.raises(ValueError):
            srm.matvec(jnp.asarray(v), dispatch="bogus")


class TestSparseRowMatrix:
    def _make(self, m=96, n=128, bd=0.2, seed=0):
        dense = block_sparse(m, n, 8, bd, seed=seed)
        return SparseRowMatrix.from_dense(dense, bs=8), dense

    def test_round_trips(self):
        srm, dense = self._make()
        np.testing.assert_allclose(srm.to_local(), dense, atol=1e-6)
        np.testing.assert_allclose(srm.to_row_matrix().to_local(), dense,
                                   atol=1e-6)
        # COO → SparseRowMatrix → dense (explicit and auto block size)
        ri, ci = np.nonzero(dense)
        cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                     jnp.asarray(dense[ri, ci]), dense.shape)
        np.testing.assert_allclose(cm.to_sparse_row_matrix(bs=8).to_local(),
                                   dense, atol=1e-6)
        auto = cm.to_sparse_row_matrix()
        np.testing.assert_allclose(auto.to_local(), dense, atol=1e-5)
        # RowMatrix → SparseRowMatrix
        rt = RowMatrix.create(dense).to_sparse_row_matrix(bs=8)
        np.testing.assert_allclose(rt.to_local(), dense, atol=1e-6)

    def test_unaligned_shapes_pad(self):
        """True dims not multiples of bs: padding must stay invisible."""
        rng = np.random.default_rng(4)
        dense = np.zeros((37, 29), np.float32)
        sel = rng.random((37, 29)) < 0.2
        dense[sel] = rng.normal(size=int(sel.sum()))
        srm = SparseRowMatrix.from_dense(dense, bs=8)
        np.testing.assert_allclose(srm.to_local(), dense, atol=1e-6)
        v = rng.normal(size=29).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(srm.matvec(jnp.asarray(v)))[:37], dense @ v,
            rtol=1e-4, atol=1e-4)
        u = rng.normal(size=37).astype(np.float32)
        np.testing.assert_allclose(srm.rmatvec(jnp.asarray(u)),
                                   dense.T @ u, rtol=1e-3, atol=1e-3)

    def test_matvec_rmatvec_gram_norms(self):
        srm, dense = self._make(seed=1)
        rng = np.random.default_rng(1)
        v = rng.normal(size=128).astype(np.float32)
        u = rng.normal(size=96).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(srm.matvec(jnp.asarray(v)))[:96], dense @ v,
            rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(srm.rmatvec(jnp.asarray(u)), dense.T @ u,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(srm.gram(), dense.T @ dense, rtol=1e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(float(srm.frobenius_norm()),
                                   np.linalg.norm(dense), rtol=1e-5)
        np.testing.assert_allclose(srm.column_norms(),
                                   np.linalg.norm(dense, axis=0), rtol=1e-4,
                                   atol=1e-5)

    def test_multiply_local_returns_dense_rowmatrix(self):
        srm, dense = self._make(seed=2)
        B = np.random.default_rng(2).normal(size=(128, 5)).astype(np.float32)
        out = srm.multiply_local(jnp.asarray(B))
        assert isinstance(out, RowMatrix)
        np.testing.assert_allclose(out.to_local(), dense @ B, rtol=1e-3,
                                   atol=1e-3)

    def test_transpose(self):
        srm, dense = self._make(seed=3)
        np.testing.assert_allclose(srm.transpose().to_local(), dense.T,
                                   atol=1e-6)


def indicator_matrix(m=2000, n=16, seed=3):
    """Binary indicator data with overlapping column support — the bounded
    entry setting the DIMSUM concentration analysis assumes."""
    rng = np.random.default_rng(seed)
    base = rng.random((m, 4)) < 0.4
    cols = []
    for j in range(n):
        src = base[:, j % 4]
        flip = rng.random(m) < 0.15
        cols.append(np.where(flip, ~src, src))
    return np.stack(cols, 1).astype(np.float32)


class TestSampledDimsum:
    def _exact(self, A):
        norms = np.linalg.norm(A, axis=0)
        return (A.T @ A) / np.maximum(np.outer(norms, norms), 1e-30)

    def test_threshold_zero_equals_exact_gram(self):
        A = indicator_matrix()
        rm = RowMatrix.create(A)
        want = self._exact(A)
        np.testing.assert_allclose(rm.column_similarities(), want,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(rm.column_similarities(0.0), want,
                                   rtol=1e-3, atol=1e-3)
        srm = SparseRowMatrix.from_dense(A, bs=8)
        np.testing.assert_allclose(srm.column_similarities(), want,
                                   rtol=1e-3, atol=1e-3)

    def test_huge_gamma_recovers_exact(self):
        """√γ ≥ max‖cᵢ‖ ⇒ every pᵢ = 1 ⇒ the sampled estimator is exact."""
        A = indicator_matrix(seed=4)
        want = self._exact(A)
        off = ~np.eye(A.shape[1], dtype=bool)
        for M in (RowMatrix.create(A), SparseRowMatrix.from_dense(A, bs=8)):
            got = np.asarray(M.column_similarities(0.5, gamma=1e9))
            np.testing.assert_allclose(got[off], want[off], rtol=1e-3,
                                       atol=1e-3)
            np.testing.assert_allclose(np.diag(got), 1.0, atol=1e-6)

    @pytest.mark.parametrize("threshold", [0.3, 0.5])
    def test_error_bound_above_threshold(self, threshold):
        """DIMSUM contract at the default γ: pairs with similarity ≥ the
        threshold are estimated to bounded relative error (seeded)."""
        A = indicator_matrix()
        want = self._exact(A)
        off = ~np.eye(A.shape[1], dtype=bool)
        for M in (RowMatrix.create(A), SparseRowMatrix.from_dense(A, bs=8)):
            got = np.asarray(M.column_similarities(threshold, seed=0))
            hi = (want >= threshold) & off
            assert hi.any()
            rel = np.abs(got - want)[hi] / want[hi]
            # w.h.p. bounds, not worst-case: typical error is small, the
            # tail is bounded (seeded, so the assertion is deterministic).
            assert rel.mean() < 0.15, rel.mean()
            assert rel.max() < 0.55, rel.max()

    def test_estimator_is_unbiased(self):
        """Averaging estimates over seeds converges toward the exact value
        even under aggressive sampling."""
        A = indicator_matrix(seed=5)
        rm = RowMatrix.create(A)
        want = self._exact(A)
        off = ~np.eye(A.shape[1], dtype=bool)
        single = np.abs(np.asarray(
            rm.column_similarities(0.5, gamma=25.0, seed=0)) - want)[off]
        ests = np.stack([np.asarray(rm.column_similarities(
            0.5, gamma=25.0, seed=s)) for s in range(16)])
        averaged = np.abs(ests.mean(0) - want)[off]
        assert averaged.max() < single.max()
        assert averaged.mean() < 0.5 * single.mean()

    def test_variance_info_shrinks_with_gamma(self):
        """return_info=True records the exact per-pair estimator variance;
        it must be nonnegative, shrink monotonically as γ grows (pᵢ → 1),
        and vanish once every column is kept with probability 1."""
        A = indicator_matrix(seed=6)
        n = A.shape[1]
        for M in (RowMatrix.create(A), SparseRowMatrix.from_dense(A, bs=8)):
            sums = []
            for g in (2.0, 20.0, 1e9):
                sim, info = M.column_similarities(0.5, gamma=g,
                                                  return_info=True)
                v = np.asarray(info["variance"])
                assert v.shape == (n, n)
                assert (v >= -1e-6).all()
                assert np.allclose(np.diag(v), 0.0)   # diagonal is exact
                sums.append(float(v.sum()))
            assert sums[0] > sums[1] > sums[2], sums
            assert sums[2] == 0.0, sums               # all pᵢ = 1 at huge γ
            # info also carries the sampling parameters
            assert info["gamma"] == 1e9
            assert np.all(np.asarray(info["p"]) <= 1.0)

    def test_variance_info_exact_path_is_zero(self):
        A = indicator_matrix(seed=7)
        sim, info = RowMatrix.create(A).column_similarities(
            0.0, return_info=True)
        assert float(np.asarray(info["variance"]).sum()) == 0.0
        assert info["gamma"] is None


class TestSparseSVD:
    def test_lanczos_matches_dense_svd(self):
        """Acceptance bar: sparse end-to-end σ within 1e-4 rtol of dense."""
        dense = block_sparse(80, 64, 8, 0.3, seed=11)
        srm = SparseRowMatrix.from_dense(dense, bs=8)
        res = compute_svd(srm, 4, tol=1e-7, max_restarts=300)
        assert res.info["mode"] == "lanczos"       # auto → sparse iteration
        s_np = np.linalg.svd(dense, compute_uv=False)[:4]
        np.testing.assert_allclose(res.s, s_np, rtol=1e-4)
        # U comes back through the sparse multiply_local as a RowMatrix
        U = np.asarray(res.U.to_local())
        recon = U @ np.diag(np.asarray(res.s)) @ np.asarray(res.V).T
        u, s, vt = np.linalg.svd(dense, full_matrices=False)
        np.testing.assert_allclose(recon, u[:, :4] @ np.diag(s[:4]) @ vt[:4],
                                   atol=5e-3)

    def test_gram_mode_available_explicitly(self):
        dense = block_sparse(80, 64, 8, 0.3, seed=12)
        srm = SparseRowMatrix.from_dense(dense, bs=8)
        res = compute_svd(srm, 4, mode="gram")
        s_np = np.linalg.svd(dense, compute_uv=False)[:4]
        np.testing.assert_allclose(res.s, s_np, rtol=1e-3)


class TestTransposeDispatch:
    def test_wide_rowmatrix(self):
        rng = np.random.default_rng(6)
        W = rng.normal(size=(12, 300)).astype(np.float32)
        res = compute_svd(RowMatrix.create(W), 5)
        assert res.info.get("transposed") is True
        s_np = np.linalg.svd(W, compute_uv=False)[:5]
        np.testing.assert_allclose(res.s, s_np, rtol=1e-3)
        assert res.V.shape == (300, 5)
        recon = (np.asarray(res.U.to_local())
                 @ np.diag(np.asarray(res.s)) @ np.asarray(res.V).T)
        u, s, vt = np.linalg.svd(W, full_matrices=False)
        np.testing.assert_allclose(recon, u[:, :5] @ np.diag(s[:5]) @ vt[:5],
                                   atol=5e-3)

    def test_wide_coordinatematrix_index_swap(self):
        rng = np.random.default_rng(7)
        D = ((rng.random((30, 90)) < 0.2)
             * rng.normal(size=(30, 90))).astype(np.float32)
        ri, ci = np.nonzero(D)
        cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                     jnp.asarray(D[ri, ci]), (30, 90))
        np.testing.assert_allclose(cm.transpose().to_local(), D.T, atol=1e-6)
        res = compute_svd(cm, 3, mode="lanczos", tol=1e-6, max_restarts=300)
        assert res.info.get("transposed") is True
        s_np = np.linalg.svd(D, compute_uv=False)[:3]
        np.testing.assert_allclose(res.s, s_np, rtol=2e-3)
        V = np.asarray(res.V)
        for i in range(3):
            np.testing.assert_allclose(np.linalg.norm(D @ V[:, i]), s_np[i],
                                       rtol=5e-3)

    def test_wide_blockmatrix_keeps_direct_path(self):
        """Types without a transpose (BlockMatrix) must fall through to the
        direct matrix-free path on wide inputs, not raise."""
        from repro.core.distmat import BlockMatrix
        rng = np.random.default_rng(14)
        A = rng.normal(size=(40, 100)).astype(np.float32)
        res = compute_svd(BlockMatrix.create(A), 3, mode="lanczos",
                          tol=1e-6, max_restarts=300)
        assert "transposed" not in res.info
        np.testing.assert_allclose(
            res.s, np.linalg.svd(A, compute_uv=False)[:3], rtol=2e-3)

    def test_wide_sparserowmatrix(self):
        dense = block_sparse(32, 128, 8, 0.3, seed=13).astype(np.float32)
        srm = SparseRowMatrix.from_dense(dense, bs=8)
        res = compute_svd(srm, 3, tol=1e-7, max_restarts=300)
        assert res.info.get("transposed") is True
        s_np = np.linalg.svd(dense, compute_uv=False)[:3]
        np.testing.assert_allclose(res.s, s_np, rtol=1e-4)


class TestCoordinateConversionsVectorized:
    def test_indexed_row_matrix_with_duplicates(self):
        """Duplicate (row, col) entries must accumulate, matching to_local."""
        ri = np.array([5, 0, 5, 3, 5, 0], np.int64)
        ci = np.array([1, 2, 1, 0, 2, 2], np.int64)
        va = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0], np.float32)
        cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                     jnp.asarray(va), (7, 3))
        D = np.zeros((7, 3), np.float32)
        np.add.at(D, (ri, ci), va)
        irm = cm.to_indexed_row_matrix()
        got = np.asarray(irm.to_local())        # rows up to max index
        np.testing.assert_allclose(got, D[: got.shape[0]], atol=1e-6)
        assert np.all(D[got.shape[0]:] == 0)
        srm = cm.to_sparse_row_matrix(bs=8)
        np.testing.assert_allclose(srm.to_local(), D, atol=1e-6)
