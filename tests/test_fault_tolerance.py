"""Fault tolerance: checkpoint/restart, elastic re-mesh, straggler
monitor, deterministic data pipeline, failure-recovery integration — and
(the `fault`-marked half) the SOLVER-level story: straggler-triggered
mid-solve re-mesh, transient-fault retry, resumable solves, and graceful
degradation in the serving frontend, driven by the train.faults injection
harness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as dp
from repro.models import build, smoke_config
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.elastic import resume
from repro.train.straggler import (ShardMonitor, StepMonitor,
                                   StragglerConfig)
from repro.train.train_step import build_train_step


def _tiny_setup():
    cfg = smoke_config(configs.get("llama3.2-3b")).scaled(num_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt_mod.OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=50)
    opt_init, opt_update = opt_mod.make_optimizer(ocfg)
    step = jax.jit(build_train_step(model, opt_update))
    dc = dp.from_model(cfg, global_batch=4, seq_len=16)
    return cfg, model, params, opt_init, step, dc


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, params, opt_init, step, dc = _tiny_setup()
    opt_state = opt_init(params)
    batch = jax.jit(lambda s: dp.in_graph_batch(dc, s))(0)
    params, opt_state, _ = step(params, opt_state, batch)
    d = ckpt.save(tmp_path, 1, (params, opt_state),
                  extra={"data_step": 1})
    assert (d / "manifest.json").exists()
    assert ckpt.latest_step(tmp_path) == 1
    (p2, o2), extra = ckpt.restore(tmp_path, (params, opt_state))
    assert extra["data_step"] == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    cfg, model, params, opt_init, *_ = _tiny_setup()
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.save_async(5, params, extra={"data_step": 5})
    saver.wait()
    assert ckpt.latest_step(tmp_path) == 5


def test_failure_recovery_resumes_identically(tmp_path):
    """Train 4 steps; 'crash'; restore at 2; replay → identical params."""
    cfg, model, params0, opt_init, step, dc = _tiny_setup()
    batch_fn = jax.jit(lambda s: dp.in_graph_batch(dc, s))

    params, opt = params0, opt_init(params0)
    snap = None
    for s in range(4):
        params, opt, _ = step(params, opt, batch_fn(s))
        if s == 1:
            ckpt.save(tmp_path, 2, (params, opt), extra={"data_step": 2})
    ref = jax.tree.leaves(params)

    # crash + restore
    params_r, opt_r = params0, opt_init(params0)
    (params_r, opt_r), extra = ckpt.restore(tmp_path, (params_r, opt_r))
    for s in range(extra["data_step"], 4):
        params_r, opt_r, _ = step(params_r, opt_r, batch_fn(s))
    for a, b in zip(ref, jax.tree.leaves(params_r)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_elastic_remesh_changes_sharding(tmp_path):
    """Restore a checkpoint onto a different mesh (elastic downscale)."""
    cfg, model, params, opt_init, *_ = _tiny_setup()
    _, specs = model.specs()
    ckpt.save(tmp_path, 1, params, specs, extra={})
    new_mesh = make_host_mesh(1, 1)      # the "surviving slice"
    with new_mesh, use_mesh(new_mesh):
        restored, _ = ckpt.restore(tmp_path, params, mesh=new_mesh,
                                   specs=specs)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_microbatch_rescale(tmp_path):
    cfg, model, params, opt_init, *_ = _tiny_setup()
    _, specs = model.specs()
    ckpt.save(tmp_path, 3, params, specs, extra={"data_step": 3})
    mesh = make_host_mesh(1, 1)
    with mesh, use_mesh(mesh):
        tree, extra, mb = resume(tmp_path, params, specs, mesh,
                                 global_batch=256, old_microbatches=8,
                                 old_dp=32, new_dp=16)
    assert mb == 16            # half the chips → double the microbatches
    assert extra["data_step"] == 3


def test_straggler_monitor():
    mon = StepMonitor(StragglerConfig(warmup_steps=2, threshold=2.0,
                                      trip_limit=2))
    fired = []
    mon.on_straggler = fired.append
    for _ in range(6):
        mon.observe(0.10)
    v = mon.observe(0.50)                 # 5× EMA → flagged
    assert v["flagged"] and not v["tripped"]
    v = mon.observe(0.50)                 # second consecutive → tripped
    assert v["tripped"] and fired
    # EMA not polluted by the outliers
    assert mon.ema == pytest.approx(0.10, rel=0.05)


def test_straggler_deadline():
    mon = StepMonitor(StragglerConfig(deadline_s=0.2, warmup_steps=0,
                                      trip_limit=99))
    v = mon.observe(0.5)
    assert v["deadline_exceeded"] and v["tripped"]


def test_data_pipeline_determinism():
    dc = dp.DataConfig(vocab_size=100, global_batch=4, seq_len=8)
    it1 = dp.HostIterator(dc)
    b1 = [next(it1) for _ in range(3)]
    it2 = dp.HostIterator.restore(dc, {"step": 1, "seed": dc.seed})
    b2 = next(it2)
    np.testing.assert_array_equal(b1[1]["tokens"], b2["tokens"])
    # in-graph batch is also deterministic
    g1 = dp.in_graph_batch(dc, 2)
    g2 = dp.in_graph_batch(dc, 2)
    np.testing.assert_array_equal(np.asarray(g1["tokens"]),
                                  np.asarray(g2["tokens"]))


def test_data_pipeline_host_sharding():
    dc = dp.DataConfig(vocab_size=100, global_batch=8, seq_len=4)
    full = next(dp.HostIterator(dc))
    sh0 = next(dp.HostIterator(dc).shard_for(0, 2))
    sh1 = next(dp.HostIterator(dc).shard_for(1, 2))
    np.testing.assert_array_equal(
        np.concatenate([sh0["tokens"], sh1["tokens"]]), full["tokens"])


# =========================================================================
# Solver-level fault tolerance (`fault` marker): the elastic executor of
# core/optim/elastic driven through the train.faults injection harness.
# Every test is device-count adaptive — on 1 host device the "mesh" is a
# single shard and survivor_mesh re-meshes onto the same devices; the CI
# fault leg re-runs them with 8 forced host devices for real sharding.
# =========================================================================

from repro import api                                     # noqa: E402
from repro.core.distmat import RowMatrix                  # noqa: E402
from repro.core.distmat.types import make_mesh            # noqa: E402
from repro.core.optim.elastic import (ElasticConfig,      # noqa: E402
                                      ElasticGroup, SolveCheckpoint,
                                      solve_elastic)
from repro.core.tfocs.linop import LinopMatrix            # noqa: E402
from repro.launch.serve import GroupRunner, SolverServer  # noqa: E402
from repro.train.faults import (FaultPlan, FaultyLinop,   # noqa: E402
                                FaultyMesh, TransientShardError)

def _nosleep(_dt):
    """Injected in place of time.sleep: faults without the wall time."""


def _lstsq_setup(m=120, n=10, seed=21):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    b = (A @ rng.normal(size=n) + 0.01 * rng.normal(size=m)) \
        .astype(np.float32)
    return A, b, np.linalg.lstsq(A, b, rcond=None)[0]


def _sharded(A):
    """A RowMatrix on an explicit mesh over every available device (1 or
    8), so the re-mesh path is exercised either way."""
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    return RowMatrix.create(jnp.asarray(A), mesh), mesh


@pytest.mark.fault
class TestShardMonitor:
    CFG = StragglerConfig(warmup_steps=2, threshold=2.0, trip_limit=2)

    def _warm(self, mon, nshards, steps=6, dt=0.1):
        for _ in range(steps):
            v = mon.observe([dt] * nshards)
            assert not v["tripped"]

    def test_names_the_slow_shard(self):
        mon = ShardMonitor(4, self.CFG)
        self._warm(mon, 4)
        v = mon.observe([0.1, 0.1, 0.5, 0.1])     # first flag: no trip yet
        assert not v["tripped"] and 2 in v["flagged"]
        v = mon.observe([0.1, 0.1, 0.5, 0.1])     # consecutive → tripped
        assert v["tripped"] and v["shard"] == 2

    def test_uniform_slowdown_is_not_a_straggler(self):
        """Everybody 5× slower (new kernel shape, host noise): own-EMA
        monitors all trip, but nobody beats the median test — a global
        slowdown must not cost a shard its job."""
        mon = ShardMonitor(4, self.CFG)
        self._warm(mon, 4)
        for _ in range(4):
            v = mon.observe([0.5, 0.5, 0.5, 0.5])
            assert not v["tripped"]

    def test_single_shard_falls_back_to_own_trip(self):
        mon = ShardMonitor(1, self.CFG)
        self._warm(mon, 1)
        mon.observe([0.5])
        v = mon.observe([0.5])
        assert v["tripped"] and v["shard"] == 0

    def test_reset_forgets_history(self):
        mon = ShardMonitor(4, self.CFG)
        self._warm(mon, 4)
        mon.reset(3)
        assert mon.nshards == 3
        v = mon.observe([0.5, 0.5, 0.5])          # fresh warmup — no trip
        assert not v["tripped"]


@pytest.mark.fault
class TestCheckpointHardening:
    def test_async_write_error_surfaces_on_next_save(self, tmp_path):
        """A background write failure is raised at the NEXT save_async (or
        wait) — never silently dropped, and reported exactly once."""
        blocker = tmp_path / "ckpt"
        blocker.write_text("not a directory")
        saver = ckpt.AsyncCheckpointer(blocker)
        saver.save_async(1, {"a": np.zeros(3, np.float32)})
        with pytest.raises(OSError):
            saver.save_async(2, {"a": np.zeros(3, np.float32)})
        saver.wait()                               # cleared: no re-raise

    def test_latest_step_skips_partial_checkpoint(self, tmp_path):
        """A torn checkpoint (manifest present, shard data missing) is
        never picked up, even when a stale LATEST names it."""
        ckpt.save(tmp_path, 1, {"a": np.arange(3, dtype=np.float32)})
        partial = tmp_path / "step_00000002"
        partial.mkdir()
        (partial / "manifest.json").write_text("{}")
        (tmp_path / "LATEST").write_text(partial.name)
        assert ckpt.latest_step(tmp_path) == 1
        tree, _ = ckpt.restore(tmp_path, {"a": np.zeros(3, np.float32)})
        np.testing.assert_array_equal(np.asarray(tree["a"]),
                                      np.arange(3, dtype=np.float32))


@pytest.mark.fault
class TestElasticSolve:
    def test_straggler_detected_remesh_matches_clean_solve(self):
        """THE acceptance property: a shard that starts straggling
        mid-solve is detected, the matrix is re-sharded onto the survivor
        mesh without restarting, and the interrupted solve matches the
        undisturbed one at solver tolerance."""
        A, b, ref = _lstsq_setup()
        mat, mesh = _sharded(A)
        x_clean, info_clean = solve_elastic(LinopMatrix(mat), "quad", b,
                                            tol=1e-7, max_iters=400)
        assert info_clean["converged"] and info_clean["remeshes"] == 0

        lin = FaultyLinop(LinopMatrix(mat),
                          FaultPlan(shard_delays={0: 0.2}, delay_from=6),
                          sleep=_nosleep)
        fm = FaultyMesh(mesh)
        cfg = ElasticConfig(
            monitor=ShardMonitor(lin.row_shards(),
                                 StragglerConfig(warmup_steps=2,
                                                 threshold=2.0,
                                                 trip_limit=2)),
            remesh_to=fm.drop)
        x, info = solve_elastic(lin, "quad", b, tol=1e-7, max_iters=400,
                                elastic=cfg)
        assert info["converged"] and info["degraded"] is None
        assert info["remeshes"] >= 1 and fm.casualties == [0]
        assert lin.dropped == [0] and not lin.delays
        assert float(np.max(np.abs(np.asarray(x) - np.asarray(x_clean)))) \
            < 5e-4
        assert float(np.max(np.abs(np.asarray(x) - ref))) < 1e-3

    def test_device_loss_remesh_iterations_monotone(self):
        """DeviceLostError mid-solve: re-mesh, continue.  The iteration
        counter advances by at most one per step and never rewinds — no
        completed iteration is re-run."""
        A, b, ref = _lstsq_setup(seed=22)
        mat, mesh = _sharded(A)
        lin = FaultyLinop(LinopMatrix(mat),
                          FaultPlan(lose_shard_at=3, lost_shard=0),
                          sleep=_nosleep)
        fm = FaultyMesh(mesh)
        grp = ElasticGroup(lin, "quad", slots=1,
                           elastic=ElasticConfig(remesh_to=fm.drop))
        grp.admit_slot(b, tol=1e-7)
        ks = [0]
        while not bool(grp.state.done[0]) and ks[-1] < 400:
            grp.step_iteration()
            k = int(grp.state.k[0])
            assert k - ks[-1] in (0, 1) and k >= ks[-1]
            ks.append(k)
        assert grp.remeshes == 1 and fm.casualties == [0]
        assert bool(grp.state.done[0])
        assert float(np.max(np.abs(np.asarray(grp.state.X[0]) - ref))) \
            < 1e-3

    def test_transient_fault_retry_is_bit_exact(self):
        """A transient failed pass (and a NaN-poisoned reduction) roll
        back and retry; the retried iteration recomputes the identical
        step, so the whole trajectory is bit-equal to the fault-free run."""
        A, b, _ = _lstsq_setup(seed=23)
        x_clean, _ = solve_elastic(LinopMatrix(jnp.asarray(A)), "quad", b,
                                   tol=0.0, max_iters=30)
        lin = FaultyLinop(LinopMatrix(jnp.asarray(A)),
                          FaultPlan(fail_steps=(3,), nan_steps=(7,)),
                          sleep=_nosleep)
        cfg = ElasticConfig(backoff_s=1e-4, sleep=_nosleep)
        x, info = solve_elastic(lin, "quad", b, tol=0.0, max_iters=30,
                                elastic=cfg)
        assert info["retries"] == 2                # one fail + one NaN
        assert info["iterations"] == 30
        np.testing.assert_array_equal(np.asarray(x), np.asarray(x_clean))

    def test_retries_exhausted_raises(self):
        A, b, _ = _lstsq_setup(seed=24)

        class AlwaysFailing(FaultyLinop):
            def fault_hook(self, step, state, dt):
                raise TransientShardError("permanent injected fault")

        slept = []
        lin = AlwaysFailing(LinopMatrix(jnp.asarray(A)))
        cfg = ElasticConfig(max_retries=2, backoff_s=0.01,
                            sleep=slept.append)
        with pytest.raises(TransientShardError):
            solve_elastic(lin, "quad", b, tol=0.0, max_iters=10,
                          elastic=cfg)
        assert slept == [0.01, 0.02]               # exponential backoff

    def test_checkpoint_resume_is_bit_exact(self, tmp_path):
        """Kill a checkpointed solve mid-run, resume from its snapshot:
        the resumed trajectory continues from the saved iteration (no
        re-run) and the final iterate is bit-equal to an undisturbed
        solve."""
        A, b, _ = _lstsq_setup(seed=25)
        lin = lambda: LinopMatrix(jnp.asarray(A))  # noqa: E731
        x_full, info_full = solve_elastic(
            lin(), "quad", b, tol=0.0, max_iters=40,
            elastic=ElasticConfig(checkpoint=SolveCheckpoint(
                tmp_path / "full", every=5, async_save=False)))
        assert info_full["checkpoint_saves"] == 8

        # "crash" at 20 iterations…
        cut = ElasticConfig(checkpoint=SolveCheckpoint(
            tmp_path / "cut", every=5, async_save=False))
        solve_elastic(lin(), "quad", b, tol=0.0, max_iters=20, elastic=cut)
        # …and resume from the snapshot in a fresh executor.
        x2, i2 = solve_elastic(
            lin(), "quad", b, tol=0.0, max_iters=40, resume=True,
            elastic=ElasticConfig(checkpoint=SolveCheckpoint(
                tmp_path / "cut", every=5, async_save=False)))
        assert i2["resumed_from"] == 20
        assert i2["iterations"] == 40
        np.testing.assert_array_equal(np.asarray(x2), np.asarray(x_full))

    def test_async_checkpointed_solve_resumes(self, tmp_path):
        """The default async checkpointer path: snapshots land durably
        (wait() at solve end) and the solve resumes from the latest."""
        A, b, _ = _lstsq_setup(seed=26)
        ck = SolveCheckpoint(tmp_path, every=4)
        solve_elastic(LinopMatrix(jnp.asarray(A)), "quad", b, tol=0.0,
                      max_iters=12, elastic=ElasticConfig(checkpoint=ck))
        assert ck.latest() == 12
        _, info = solve_elastic(
            LinopMatrix(jnp.asarray(A)), "quad", b, tol=0.0, max_iters=16,
            resume=True,
            elastic=ElasticConfig(checkpoint=SolveCheckpoint(tmp_path,
                                                             every=4)))
        assert info["resumed_from"] == 12 and info["iterations"] == 16

    def test_deadline_returns_best_iterate(self):
        """A solve that cannot finish inside its wall budget returns the
        best iterate with converged=False and degraded='deadline' instead
        of running to the iteration cap."""
        A, b, _ = _lstsq_setup(seed=27)
        lin = FaultyLinop(LinopMatrix(jnp.asarray(A)),
                          FaultPlan(shard_delays={0: 0.02}))
        x, info = solve_elastic(lin, "quad", b, tol=0.0, max_iters=500,
                                deadline_s=0.1, elastic=ElasticConfig())
        assert info["degraded"] == "deadline"
        assert not info["converged"]
        assert 0 < info["iterations"] < 500
        assert np.all(np.isfinite(np.asarray(x)))

    def test_api_routes_checkpointed_request(self, tmp_path):
        """SolveRequest(checkpoint_dir=..., resume=True) reaches the
        elastic path through api.solve with standardized info keys."""
        A, b, _ = _lstsq_setup(seed=28)
        first = api.solve(api.SolveRequest(
            A=A, b=b, loss="quad", tol=0.0, max_iters=10,
            checkpoint_dir=str(tmp_path), checkpoint_every=5))
        assert first.info["plan"] == "elastic"
        assert first.info["checkpoint_saves"] == 2
        res = api.solve(api.SolveRequest(
            A=A, b=b, loss="quad", tol=0.0, max_iters=20,
            checkpoint_dir=str(tmp_path), checkpoint_every=5, resume=True))
        assert res.info["resumed_from"] == 10
        assert res.info["iterations"] == 20
        for key in ("iterations", "a_passes", "converged", "plan",
                    "degraded"):
            assert key in res.info


@pytest.mark.fault
class TestServingDegradation:
    def test_request_validation(self):
        A = np.eye(4, dtype=np.float32)
        b = np.ones(4, np.float32)
        for kw in ({"deadline_s": -1.0}, {"deadline_s": float("nan")},
                   {"tol": -1e-3}, {"max_iters": 0}, {"lam": -0.5},
                   {"L0": 0.0}, {"resume": True},
                   {"checkpoint_dir": "/tmp/x", "method": "acc"}):
            with pytest.raises(ValueError):
                api.SolveRequest(A=A, b=b, loss="quad", **kw)
        with pytest.raises(ValueError):
            api.SvdRequest(A=A, k=0)
        with pytest.raises(ValueError):
            api.SimilarityRequest(A=A, threshold=float("nan"))

    def test_deadline_expiry_retires_slot_not_group(self):
        """An expired resident is retired with its best iterate and
        degraded='deadline'; its co-resident solves on unharmed."""
        A, b, ref = _lstsq_setup(seed=29)
        srv = SolverServer(slots=2)
        doomed = srv.submit(api.SolveRequest(
            A=A, b=b, loss="quad", tol=0.0, max_iters=10_000,
            deadline_s=1e-6))
        healthy = srv.submit(api.SolveRequest(
            A=A, b=b, loss="quad", tol=1e-7, max_iters=400))
        srv.run()
        r = srv.result(doomed)
        assert r.info["degraded"] == "deadline"
        assert not r.info["converged"]
        assert r.info["iterations"] < 10_000
        h = srv.result(healthy)
        assert h.info["converged"] and h.info["degraded"] is None
        assert float(np.max(np.abs(np.asarray(h.x) - ref))) < 1e-3

    def test_max_iterations_degrades_gracefully(self):
        A, b, _ = _lstsq_setup(seed=30)
        srv = SolverServer(slots=1)
        rid = srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                          tol=0.0, max_iters=5))
        srv.run()
        r = srv.result(rid)
        assert not r.info["converged"]
        assert r.info["degraded"] == "max_iterations"
        assert r.info["iterations"] == 5

    def test_load_shedding_returns_typed_overloaded(self):
        A, b, _ = _lstsq_setup(seed=31)
        srv = SolverServer(slots=1, max_pending=2)
        reqs = [api.SolveRequest(A=A, b=b, loss="quad", tol=1e-6,
                                 max_iters=200) for _ in range(4)]
        ids = [srv.submit(r) for r in reqs]
        assert srv.stats["shed"] == 2
        for rid in ids[2:]:
            res = srv.result(rid)
            assert isinstance(res, api.Overloaded)
            assert res.info["degraded"] == "overloaded"
            assert res.x is None
        srv.run()
        for rid in ids[:2]:
            assert srv.result(rid).info["converged"]

    def test_oneshot_expired_in_queue_not_run(self):
        """A one-shot whose deadline died while it waited in the queue is
        answered degraded at dequeue — no device time spent on it."""
        A, b, _ = _lstsq_setup(seed=32)
        srv = SolverServer(slots=1)
        rid = srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                          method="acc", max_iters=50,
                                          deadline_s=1e-9))
        import time as _time
        _time.sleep(0.01)
        srv.run()
        r = srv.result(rid)
        assert r.info["degraded"] == "deadline"
        assert r.info["plan"] == "expired" and r.info["a_passes"] == 0

    def test_injected_fault_beyond_retries_degrades_residents(self):
        """When recovery is exhausted the residents get their best
        iterates back (degraded='fault'), and the serving loop survives."""
        A, b, _ = _lstsq_setup(seed=33)

        class AlwaysFailing(FaultyLinop):
            def fault_hook(self, step, state, dt):
                if step >= 2:
                    raise TransientShardError("injected permanent fault")
                return state, None

        lin = AlwaysFailing(LinopMatrix(jnp.asarray(A)))
        runner = GroupRunner(
            lin, "quad", slots=2,
            elastic=ElasticConfig(max_retries=1, backoff_s=1e-4,
                                  sleep=_nosleep))
        runner.admit(api.SolveRequest(A=A, b=b, loss="quad", tol=0.0,
                                      max_iters=50))
        runner.admit(api.SolveRequest(A=A, b=b, loss="quad", tol=0.0,
                                      max_iters=50))
        out = []
        while runner.busy():
            out.extend(runner.step())
        assert len(out) == 2
        for r in out:
            assert r.info["degraded"] == "fault"
            assert not r.info["converged"]
            assert r.info["iterations"] >= 2       # kept the best iterate
            assert "error" in r.info

    def test_server_with_elastic_factory_straggler_recovers(self):
        """End-to-end serving recovery: a served group hit by a mid-solve
        straggler re-meshes and still answers correctly; the scheduler
        re-prices the group on its new shard shape."""
        A, b, ref = _lstsq_setup(seed=34)
        mat, mesh = _sharded(A)
        fm = FaultyMesh(mesh)

        def factory():
            return ElasticConfig(
                monitor=ShardMonitor(1, StragglerConfig(warmup_steps=2,
                                                        threshold=2.0,
                                                        trip_limit=2)),
                remesh_to=fm.drop)

        srv = SolverServer(slots=2, elastic_factory=factory)
        req = api.SolveRequest(A=mat, b=b, loss="quad", tol=1e-7,
                               max_iters=400)
        rid = srv.submit(req)
        srv.step()                                 # group opened
        runner = next(iter(srv._runners.values()))
        # Inject the straggler into the live linop mid-solve.
        runner._eg.linop = FaultyLinop(
            runner._eg.linop, FaultPlan(shard_delays={0: 0.2},
                                        delay_from=8),
            sleep=_nosleep)
        srv.run()
        r = srv.result(rid)
        assert r.info["converged"]
        assert srv.stats["remeshes"] >= 1
        assert runner._priced_remeshes == runner._eg.remeshes >= 1
        assert float(np.max(np.abs(np.asarray(r.x) - ref))) < 1e-3
