"""Fault tolerance: checkpoint/restart, elastic re-mesh, straggler
monitor, deterministic data pipeline, failure-recovery integration."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as dp
from repro.models import build, smoke_config
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.elastic import remesh, resume
from repro.train.straggler import StepMonitor, StragglerConfig
from repro.train.train_step import build_train_step


def _tiny_setup():
    cfg = smoke_config(configs.get("llama3.2-3b")).scaled(num_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt_mod.OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=50)
    opt_init, opt_update = opt_mod.make_optimizer(ocfg)
    step = jax.jit(build_train_step(model, opt_update))
    dc = dp.from_model(cfg, global_batch=4, seq_len=16)
    return cfg, model, params, opt_init, step, dc


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, params, opt_init, step, dc = _tiny_setup()
    opt_state = opt_init(params)
    batch = jax.jit(lambda s: dp.in_graph_batch(dc, s))(0)
    params, opt_state, _ = step(params, opt_state, batch)
    d = ckpt.save(tmp_path, 1, (params, opt_state),
                  extra={"data_step": 1})
    assert (d / "manifest.json").exists()
    assert ckpt.latest_step(tmp_path) == 1
    (p2, o2), extra = ckpt.restore(tmp_path, (params, opt_state))
    assert extra["data_step"] == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    cfg, model, params, opt_init, *_ = _tiny_setup()
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.save_async(5, params, extra={"data_step": 5})
    saver.wait()
    assert ckpt.latest_step(tmp_path) == 5


def test_failure_recovery_resumes_identically(tmp_path):
    """Train 4 steps; 'crash'; restore at 2; replay → identical params."""
    cfg, model, params0, opt_init, step, dc = _tiny_setup()
    batch_fn = jax.jit(lambda s: dp.in_graph_batch(dc, s))

    params, opt = params0, opt_init(params0)
    snap = None
    for s in range(4):
        params, opt, _ = step(params, opt, batch_fn(s))
        if s == 1:
            ckpt.save(tmp_path, 2, (params, opt), extra={"data_step": 2})
    ref = jax.tree.leaves(params)

    # crash + restore
    params_r, opt_r = params0, opt_init(params0)
    (params_r, opt_r), extra = ckpt.restore(tmp_path, (params_r, opt_r))
    for s in range(extra["data_step"], 4):
        params_r, opt_r, _ = step(params_r, opt_r, batch_fn(s))
    for a, b in zip(ref, jax.tree.leaves(params_r)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_elastic_remesh_changes_sharding(tmp_path):
    """Restore a checkpoint onto a different mesh (elastic downscale)."""
    cfg, model, params, opt_init, *_ = _tiny_setup()
    _, specs = model.specs()
    ckpt.save(tmp_path, 1, params, specs, extra={})
    new_mesh = make_host_mesh(1, 1)      # the "surviving slice"
    with new_mesh, use_mesh(new_mesh):
        restored, _ = ckpt.restore(tmp_path, params, mesh=new_mesh,
                                   specs=specs)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_microbatch_rescale(tmp_path):
    cfg, model, params, opt_init, *_ = _tiny_setup()
    _, specs = model.specs()
    ckpt.save(tmp_path, 3, params, specs, extra={"data_step": 3})
    mesh = make_host_mesh(1, 1)
    with mesh, use_mesh(mesh):
        tree, extra, mb = resume(tmp_path, params, specs, mesh,
                                 global_batch=256, old_microbatches=8,
                                 old_dp=32, new_dp=16)
    assert mb == 16            # half the chips → double the microbatches
    assert extra["data_step"] == 3


def test_straggler_monitor():
    mon = StepMonitor(StragglerConfig(warmup_steps=2, threshold=2.0,
                                      trip_limit=2))
    fired = []
    mon.on_straggler = fired.append
    for _ in range(6):
        mon.observe(0.10)
    v = mon.observe(0.50)                 # 5× EMA → flagged
    assert v["flagged"] and not v["tripped"]
    v = mon.observe(0.50)                 # second consecutive → tripped
    assert v["tripped"] and fired
    # EMA not polluted by the outliers
    assert mon.ema == pytest.approx(0.10, rel=0.05)


def test_straggler_deadline():
    mon = StepMonitor(StragglerConfig(deadline_s=0.2, warmup_steps=0,
                                      trip_limit=99))
    v = mon.observe(0.5)
    assert v["deadline_exceeded"] and v["tripped"]


def test_data_pipeline_determinism():
    dc = dp.DataConfig(vocab_size=100, global_batch=4, seq_len=8)
    it1 = dp.HostIterator(dc)
    b1 = [next(it1) for _ in range(3)]
    it2 = dp.HostIterator.restore(dc, {"step": 1, "seed": dc.seed})
    b2 = next(it2)
    np.testing.assert_array_equal(b1[1]["tokens"], b2["tokens"])
    # in-graph batch is also deterministic
    g1 = dp.in_graph_batch(dc, 2)
    g2 = dp.in_graph_batch(dc, 2)
    np.testing.assert_array_equal(np.asarray(g1["tokens"]),
                                  np.asarray(g2["tokens"]))


def test_data_pipeline_host_sharding():
    dc = dp.DataConfig(vocab_size=100, global_batch=8, seq_len=4)
    full = next(dp.HostIterator(dc))
    sh0 = next(dp.HostIterator(dc).shard_for(0, 2))
    sh1 = next(dp.HostIterator(dc).shard_for(1, 2))
    np.testing.assert_array_equal(
        np.concatenate([sh0["tokens"], sh1["tokens"]]), full["tokens"])
