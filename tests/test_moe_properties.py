"""Property tests for the MoE dispatch invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build, smoke_config
from repro.models import moe as MOE


def _setup(seed=0):
    cfg = smoke_config(configs.get("deepseek-v2-236b"))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    p = jax.tree.map(lambda a: a[0], params["moe_blocks"])["ffn"]
    return cfg, p


@given(st.integers(4, 64), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_dispatch_matches_dense_reference(T, seed):
    """Capacity-unconstrained dispatch == dense per-token expert mixture."""
    cfg, p = _setup()
    m = cfg.moe
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(T, cfg.d_model)), jnp.float32)
    out, aux = MOE._moe_local(x, p, cfg, jnp.int32(0), m.num_experts,
                              capacity=T * m.top_k)
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = np.zeros((T, cfg.d_model), np.float32)
    for t in range(T):
        for j in range(m.top_k):
            e = int(eidx[t, j])
            h = jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
            ref[t] += float(gates[t, j]) * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_capacity_bound_is_respected(capacity):
    """No expert processes more than `capacity` tokens: shrinking capacity
    can only remove contributions (monotone output energy)."""
    cfg, p = _setup()
    m = cfg.moe
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, cfg.d_model)), jnp.float32)
    full, _ = MOE._moe_local(x, p, cfg, jnp.int32(0), m.num_experts,
                             capacity=32 * m.top_k)
    capped, _ = MOE._moe_local(x, p, cfg, jnp.int32(0), m.num_experts,
                               capacity=capacity)
    assert float(jnp.linalg.norm(capped)) <= \
        float(jnp.linalg.norm(full)) * 1.5 + 1e-6
    # tokens that survived must contribute the same values
    mask = np.asarray(jnp.any(capped != 0, axis=-1))
    # (no stronger per-token check: renormalized gates mix experts)
    assert mask.sum() <= 32


def test_expert_shard_partition_is_exact():
    """Summing the per-shard partial outputs over expert ranges equals the
    single-shard full computation (the EP psum invariant)."""
    cfg, p = _setup()
    m = cfg.moe
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)), jnp.float32)
    full, _ = MOE._moe_local(x, p, cfg, jnp.int32(0), m.num_experts,
                             capacity=16 * m.top_k)
    nsh = 4
    e_local = m.num_experts // nsh
    acc = jnp.zeros_like(full)
    for r in range(nsh):
        lo = r * e_local
        p_r = dict(p, w_gate=p["w_gate"][lo:lo + e_local],
                   w_up=p["w_up"][lo:lo + e_local],
                   w_down=p["w_down"][lo:lo + e_local])
        part, _ = MOE._moe_local(x, p_r, cfg, jnp.int32(lo), e_local,
                                 capacity=16 * m.top_k)
        acc = acc + part
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_aux_loss_uniform_routing_lower_bound():
    """Switch aux loss is ≥ 1 with equality iff routing is uniform."""
    cfg, p = _setup()
    m = cfg.moe
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)
    _, aux = MOE._moe_local(x, p, cfg, jnp.int32(0), m.num_experts,
                            capacity=64 * m.top_k)
    assert float(aux) >= 0.99
