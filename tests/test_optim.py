"""The Figure-1 optimizer family on the paper's four problems."""
import numpy as np
import pytest

from repro.core.optim import (make_problem, minimize, composite_value,
                              METHODS)


@pytest.mark.parametrize("pname", ["linear", "linear_l1", "logistic",
                                   "logistic_l2"])
def test_all_methods_converge(pname):
    p = make_problem(pname, m=300, n=48)
    objs = {}
    for method in METHODS:
        x, info = minimize(p, method, max_iters=150)
        objs[method] = float(composite_value(p, x))
        assert np.isfinite(objs[method]), (pname, method)
    best = min(objs.values())
    scale = abs(best) + 1.0
    # the accelerated+backtracking methods and lbfgs must be near-optimal
    for m in ("acc_b", "acc_rb", "lbfgs"):
        assert objs[m] <= best + 0.05 * scale, (pname, m, objs)


def test_acceleration_beats_gra_on_logistic():
    """Paper's first observation: acceleration converges faster than
    gradient descent at the same initial step size."""
    p = make_problem("logistic", m=400, n=64)
    _, info_g = minimize(p, "gra", max_iters=60)
    _, info_a = minimize(p, "acc", max_iters=60)
    hg = np.asarray(info_g["history"])
    ha = np.asarray(info_a["history"])
    assert ha[59] < hg[59], (ha[59], hg[59])


def test_restart_no_worse_on_linear():
    """Paper's second observation: automatic restarts help (here: best
    objective over the run is never significantly worse, and the damping
    of momentum oscillation is visible in the best-so-far envelope)."""
    p = make_problem("linear", m=300, n=64)
    _, i_nr = minimize(p, "acc", max_iters=200)
    _, i_r = minimize(p, "acc_r", max_iters=200)
    h_nr = np.asarray(i_nr["history"])
    h_r = np.asarray(i_r["history"])
    best_nr = np.nanmin(h_nr)
    best_r = np.nanmin(h_r)
    scale = abs(best_nr) + 1e-9
    assert best_r <= best_nr + 0.05 * scale


def test_lbfgs_outperforms_acc_on_smooth():
    """Paper's fourth observation: LBFGS generally wins."""
    p = make_problem("logistic_l2", m=400, n=64)
    _, i_a = minimize(p, "acc_rb", max_iters=60)
    _, i_l = minimize(p, "lbfgs", max_iters=60)
    k_l = int(i_l["iterations"])
    f_l = float(np.asarray(i_l["history"])[max(k_l - 1, 0)])
    f_a = float(np.asarray(i_a["history"])[59])
    assert f_l <= f_a + 1e-6


def test_history_monotone_enough():
    p = make_problem("linear", m=200, n=32)
    _, info = minimize(p, "gra", max_iters=100)
    h = np.asarray(info["history"])
    h = h[np.isfinite(h)]
    # plain gradient descent with exact L is monotonically decreasing
    assert np.all(np.diff(h) <= 1e-5)
