"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
shape + finiteness asserts; decode-vs-forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build, smoke_config
from repro.models import transformer as TF
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend:
        flen = S if cfg.family == "encdec" else cfg.frontend_len
        batch["frontend_embeds"] = jnp.asarray(
            RNG.normal(size=(B, flen, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHES)
def test_smoke_train_step(arch):
    cfg = smoke_config(configs.get(arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(model.train_loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert 1.0 < float(metrics["ce"]) < 20.0, (arch, metrics)
    # one SGD step moves the loss
    g = jax.grad(lambda p: model.train_loss(p, _batch(cfg))[0])(params)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    cfg = smoke_config(configs.get(arch)).scaled(remat="none")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, S_new = 2, 16, 3
    total = S + S_new
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, total)),
                       jnp.int32)
    fe = None
    if cfg.family == "encdec":
        fe = jnp.asarray(RNG.normal(size=(B, 8, cfg.d_model)), jnp.float32)
        caches, _ = model.init_caches(B, total, 8)
        logits, caches = model.prefill(
            params, {"tokens": toks[:, :S], "frontend_embeds": fe}, caches)
    else:
        caches, _ = model.init_caches(B, total)
        logits, caches = model.prefill(params, {"tokens": toks[:, :S]},
                                       caches)
    dec = [logits]
    pos = jnp.int32(S)
    for i in range(S_new - 1):
        lg, caches = model.decode_step(params, toks[:, S + i:S + i + 1],
                                       caches, pos)
        dec.append(lg)
        pos = pos + 1
    dec = jnp.concatenate(dec, 1)
    if cfg.family == "encdec":
        from repro.models import encdec as ED
        memory = ED.encode(params, fe, cfg)
        h, _ = ED.decode_forward(params, toks[:, :total - 1], memory, cfg)
    else:
        h, _, _ = TF.forward(params, toks[:, :total - 1], cfg)
    want = L.lm_logits(params["embed"], h, cfg)[:, S - 1:]
    err = float(jnp.abs(dec - want).max() / (jnp.abs(want).max() + 1e-9))
    assert err < 2e-2, (arch, err)


def test_scan_unroll_equivalence():
    """The cost-model unrolled lowering computes the same function."""
    cfg = smoke_config(configs.get("qwen3-4b")).scaled(remat="none")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = model.train_loss(params, batch)
    cfg2 = cfg.scaled(scan_unroll=True)
    model2 = build(cfg2)
    l2, _ = model2.train_loss(params, batch)
    assert float(jnp.abs(l1 - l2)) < 1e-5


def test_moe_capacity_drops_are_bounded():
    from dataclasses import replace
    cfg = smoke_config(configs.get("deepseek-v2-236b"))
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=1.0))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, m = jax.jit(model.train_loss)(params, _batch(cfg))
    assert np.isfinite(float(loss))


def test_vocab_padding_masks_logits():
    cfg = smoke_config(configs.get("seamless-m4t-large-v2"))
    assert cfg.vocab_size == 512
    cfg = cfg.scaled(vocab_size=500)      # forces padding to 512
    x = jnp.ones((1, 2, cfg.d_model), jnp.float32)
    p, _ = L.init_embedding(jax.random.PRNGKey(0), cfg)
    logits = L.lm_logits(p, x, cfg)
    assert logits.shape[-1] == 512
    assert float(logits[..., 500:].max()) < -1e29
