"""Distributed matrix representations vs dense numpy oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.distmat import (RowMatrix, IndexedRowMatrix,
                                CoordinateMatrix, BlockMatrix,
                                SparseMatrixCSC, SparseVector)

RNG = np.random.default_rng(0)


def rand(m, n):
    return RNG.normal(size=(m, n)).astype(np.float32)


class TestRowMatrix:
    def test_gram(self):
        A = rand(33, 7)
        np.testing.assert_allclose(RowMatrix.create(A).gram(), A.T @ A,
                                   rtol=1e-4, atol=1e-4)

    def test_matvec_roundtrip(self):
        A = rand(19, 5)
        v = RNG.normal(size=5).astype(np.float32)
        rm = RowMatrix.create(A)
        u = rm.matvec(jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(u)[:19], A @ v, rtol=1e-4)
        np.testing.assert_allclose(rm.rmatvec(u), A.T @ (A @ v), rtol=1e-3,
                                   atol=1e-4)

    def test_column_stats(self):
        A = rand(40, 6)
        A[A < -1.0] = 0.0            # some sparsity for nnz
        st_ = RowMatrix.create(A).column_stats()
        np.testing.assert_allclose(st_["mean"], A.mean(0), rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(st_["variance"], A.var(0, ddof=1),
                                   rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(st_["min"], A.min(0), rtol=1e-5)
        np.testing.assert_allclose(st_["max"], A.max(0), rtol=1e-5)
        np.testing.assert_allclose(st_["num_nonzeros"], (A != 0).sum(0))

    def test_column_similarities(self):
        A = rand(50, 4)
        sim = np.asarray(RowMatrix.create(A).column_similarities())
        norms = np.linalg.norm(A, axis=0)
        want = (A.T @ A) / np.outer(norms, norms)
        np.testing.assert_allclose(sim, want, rtol=1e-3, atol=1e-4)

    def test_multiply_local(self):
        A, B = rand(21, 6), rand(6, 3)
        out = RowMatrix.create(A).multiply_local(jnp.asarray(B)).to_local()
        np.testing.assert_allclose(out, A @ B, rtol=1e-4)

    @given(st.integers(1, 40), st.integers(1, 12))
    @settings(max_examples=10, deadline=None)
    def test_frobenius_property(self, m, n):
        A = np.random.default_rng(m * 100 + n).normal(
            size=(m, n)).astype(np.float32)
        got = float(RowMatrix.create(A).frobenius_norm())
        assert got == pytest.approx(float(np.linalg.norm(A)), rel=1e-4)

    def test_indexed(self):
        idx = np.array([4, 0, 2], np.int64)
        A = rand(3, 5)
        im = IndexedRowMatrix.create(jnp.asarray(idx), jnp.asarray(A))
        out = np.asarray(im.to_local())
        assert out.shape[0] == 5
        np.testing.assert_allclose(out[idx], A, rtol=1e-6)


class TestCoordinateMatrix:
    def _make(self, m=15, n=9, nnz=40, seed=1):
        rng = np.random.default_rng(seed)
        ri = rng.integers(0, m, nnz)
        ci = rng.integers(0, n, nnz)
        va = rng.normal(size=nnz).astype(np.float32)
        D = np.zeros((m, n), np.float32)
        np.add.at(D, (ri, ci), va)
        cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                     jnp.asarray(va), (m, n))
        return cm, D

    def test_matvec(self):
        cm, D = self._make()
        x = np.random.default_rng(2).normal(size=9).astype(np.float32)
        np.testing.assert_allclose(cm.matvec(jnp.asarray(x)), D @ x,
                                   rtol=1e-4, atol=1e-5)

    def test_rmatvec(self):
        cm, D = self._make()
        y = np.random.default_rng(3).normal(size=15).astype(np.float32)
        np.testing.assert_allclose(cm.rmatvec(jnp.asarray(y)), D.T @ y,
                                   rtol=1e-4, atol=1e-5)

    def test_conversions(self):
        cm, D = self._make()
        np.testing.assert_allclose(cm.to_local(), D, rtol=1e-6)
        irm = cm.to_indexed_row_matrix()
        np.testing.assert_allclose(np.asarray(irm.to_local())[:15], D,
                                   rtol=1e-5, atol=1e-6)
        bm = cm.to_block_matrix(4, 4)
        np.testing.assert_allclose(bm.to_local(), D, rtol=1e-6)


class TestBlockMatrix:
    def test_multiply_add_validate(self):
        A, B = rand(14, 10), rand(10, 6)
        ba, bb = BlockMatrix.create(A), BlockMatrix.create(B)
        ba.validate()
        np.testing.assert_allclose(ba.multiply(bb).to_local(), A @ B,
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(ba.add(ba).to_local(), 2 * A, rtol=1e-6)

    def test_matvec_both_modes(self):
        A = rand(12, 8)
        bm = BlockMatrix.create(A)
        v = RNG.normal(size=8).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(bm.matvec(jnp.asarray(v)))[:12], A @ v, rtol=1e-4)
        w = jnp.asarray(np.pad(v, (0, bm.data.shape[1] - 8)))
        np.testing.assert_allclose(
            np.asarray(bm.matvec_model_sharded(w))[:12], A @ v, rtol=1e-4)

    def test_dim_mismatch_raises(self):
        with pytest.raises(ValueError):
            BlockMatrix.create(rand(4, 4)).multiply(
                BlockMatrix.create(rand(5, 4)))


class TestLocalSparse:
    def test_csc_roundtrip_and_ops(self):
        rng = np.random.default_rng(5)
        S = ((rng.random((9, 7)) < 0.4) * rng.normal(size=(9, 7))
             ).astype(np.float32)
        sp = SparseMatrixCSC.from_dense(S)
        np.testing.assert_allclose(sp.to_dense(), S, rtol=1e-6)
        x = rng.normal(size=7).astype(np.float32)
        np.testing.assert_allclose(sp.matvec(jnp.asarray(x)), S @ x,
                                   rtol=1e-4, atol=1e-5)
        y = rng.normal(size=9).astype(np.float32)
        np.testing.assert_allclose(sp.matvec(jnp.asarray(y), transpose=True),
                                   S.T @ y, rtol=1e-4, atol=1e-5)
        B = rng.normal(size=(7, 3)).astype(np.float32)
        np.testing.assert_allclose(sp.matmat(jnp.asarray(B)), S @ B,
                                   rtol=1e-4, atol=1e-5)

    def test_sparse_vector(self):
        v = np.array([1.0, 0.0, 3.0], np.float32)
        sv = SparseVector.from_dense(v)
        assert sv.size == 3 and list(np.asarray(sv.indices)) == [0, 2]
        np.testing.assert_allclose(sv.to_dense(), v)
        assert float(sv.dot(jnp.asarray([2.0, 5.0, 1.0]))) == \
            pytest.approx(5.0)
