"""End-to-end system behaviour: the full train driver path (data →
grad-accum step → optimizer → checkpoint → resume) and the serve path
(prefill → decode), on CPU-scale configs — exactly the code paths the
dry-run lowers at production scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as dp
from repro.models import build, smoke_config
from repro.models.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.train_step import build_train_step


@pytest.mark.parametrize("optimizer", ["adamw", "acc_rb", "lbfgs"])
def test_train_loss_decreases(optimizer):
    """Train a small model for a few dozen steps on a FIXED batch with
    each selectable optimizer (incl. the paper's) — loss must descend."""
    cfg = smoke_config(configs.get("llama3.2-3b")).scaled(num_layers=2)
    mesh = make_host_mesh()
    with mesh, use_mesh(mesh):
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ocfg = opt_mod.OptimizerConfig(name=optimizer, lr=5e-3,
                                       warmup_steps=2, total_steps=40)
        opt_init, opt_update = opt_mod.make_optimizer(ocfg)
        step = jax.jit(build_train_step(model, opt_update, microbatches=2))
        dc = dp.from_model(cfg, global_batch=4, seq_len=16)
        batch = jax.jit(lambda s: dp.in_graph_batch(dc, s))(0)
        opt_state = opt_init(params)
        losses = []
        for _ in range(25):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert np.isfinite(losses).all()
        # monotone-ish descent; adamw is much faster but all must descend
        min_drop = 0.5 if optimizer == "adamw" else 0.1
        assert losses[-1] < losses[0] - min_drop, (optimizer, losses[:3],
                                                   losses[-3:])


def test_serve_generates_tokens():
    cfg = smoke_config(configs.get("qwen3-4b"))
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with mesh, use_mesh(mesh):
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S, G = 2, 12, 5
        caches, _ = model.init_caches(B, S + G)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                           jnp.int32)
        logits, caches = jax.jit(model.prefill)(params, {"tokens": toks},
                                                caches)
        decode = jax.jit(model.decode_step)
        outs = [jnp.argmax(logits[:, -1], -1)[:, None]]
        pos = jnp.int32(S)
        for _ in range(G - 1):
            lg, caches = decode(params, outs[-1], caches, pos)
            outs.append(jnp.argmax(lg[:, -1], -1)[:, None])
            pos = pos + 1
        gen = np.asarray(jnp.concatenate(outs, 1))
        assert gen.shape == (B, G)
        assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def test_full_driver_with_checkpoint_resume(tmp_path):
    """The launch.train path: run 6 steps w/ checkpoint at 4, kill, resume,
    verify the final params match an uninterrupted 6-step run."""
    cfg = smoke_config(configs.get("qwen3-4b")).scaled(num_layers=2)
    mesh = make_host_mesh()
    with mesh, use_mesh(mesh):
        model = build(cfg)
        ocfg = opt_mod.OptimizerConfig(lr=1e-3, warmup_steps=1,
                                       total_steps=10)
        opt_init, opt_update = opt_mod.make_optimizer(ocfg)
        step = jax.jit(build_train_step(model, opt_update))
        dc = dp.from_model(cfg, global_batch=2, seq_len=16)
        batch_fn = jax.jit(lambda s: dp.in_graph_batch(dc, s))

        params = model.init(jax.random.PRNGKey(0))
        opt = opt_init(params)
        for s in range(6):
            params, opt, _ = step(params, opt, batch_fn(s))
            if s == 3:
                ckpt.save(tmp_path, 4, (params, opt),
                          extra={"data_step": 4})
        want = [np.asarray(x, np.float32) for x in jax.tree.leaves(params)]

        p2 = model.init(jax.random.PRNGKey(0))
        o2 = opt_init(p2)
        (p2, o2), extra = ckpt.restore(tmp_path, (p2, o2))
        for s in range(extra["data_step"], 6):
            p2, o2, _ = step(p2, o2, batch_fn(s))
        got = [np.asarray(x, np.float32) for x in jax.tree.leaves(p2)]
        for a, b in zip(want, got):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
