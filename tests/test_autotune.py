"""Shape-aware autotuner: candidate legality, roofline ranking, cache."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import ops, ref

# knob -> required multiple, per kernel (lane for last-dim knobs, dtype
# sublane for second-to-last-dim knobs).
KNOB_KIND = {
    "gemm": {"bm": "sublane", "bn": "lane", "bk": "lane"},
    "tsgram": {"bm": "sublane"},
    "randsketch": {"bm": "sublane", "bn": "lane"},
    # fusedgrad's bm doubles as the lane width of its t/w/z vector strips,
    # so its candidates are lane-aligned.
    "fusedgrad": {"bm": "lane"},
    "flash_attention": {"bq": "sublane", "bk": "lane"},
    "selective_scan": {"q": "sublane"},
}

DIMS = {
    "gemm": {"m": 1000, "k": 700, "n": 900},
    "tsgram": {"m": 20000, "n": 300},
    "randsketch": {"m": 20000, "n": 2000, "r": 72},
    "fusedgrad": {"m": 10000, "n": 1024},
    "flash_attention": {"sq": 2048, "sk": 2048, "d": 128, "causal": 1},
    "selective_scan": {"s": 4096, "d": 768, "n": 16},
}


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets an empty persistent cache and fresh counters."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    at.reset()
    yield
    at.reset()


@pytest.mark.parametrize("kernel", sorted(KNOB_KIND))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_candidates_respect_layout_multiples(kernel, dtype):
    sub = at.sublane(dtype)
    cands = at.candidates(kernel, DIMS[kernel], dtype)
    assert cands, (kernel, dtype)
    for blocks in cands:
        assert set(blocks) == set(KNOB_KIND[kernel])
        for knob, kind in KNOB_KIND[kernel].items():
            mult = sub if kind == "sublane" else at.LANE
            assert blocks[knob] % mult == 0, (kernel, blocks, knob)


@pytest.mark.parametrize("kernel", sorted(KNOB_KIND))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_candidates_respect_vmem_budget(kernel, dtype):
    for blocks in at.candidates(kernel, DIMS[kernel], dtype):
        used = at.estimate_vmem(kernel, blocks, DIMS[kernel], dtype)
        assert 0 < used <= at.VMEM_BUDGET, (kernel, blocks, used)


@pytest.mark.parametrize("kernel", sorted(KNOB_KIND))
def test_ranking_never_worse_than_legacy(kernel):
    """The acceptance bar: the model-selected config scores at least as
    well as the old hand-picked constants (which stay in the pool)."""
    ranked = at.rank(kernel, DIMS[kernel], jnp.float32)
    legacy = dict(at.KERNELS[kernel].legacy)
    legacy_score = at.model_time(kernel, legacy, DIMS[kernel], jnp.float32)
    assert ranked[0][0] <= legacy_score
    assert legacy in [b for _, b in ranked]
    assert ranked == at.rank(kernel, DIMS[kernel], jnp.float32)  # determinism


def test_small_shapes_prefer_less_padding():
    """For a tiny GEMM the tuner must not pick giant tiles that would be
    pure padding waste."""
    blocks = at.rank("gemm", {"m": 16, "k": 128, "n": 128}, jnp.float32)[0][1]
    assert blocks["bm"] <= 16 and blocks["bn"] == 128


def test_shape_bucketing():
    assert at.bucket(1000) == 1024 and at.bucket(1024) == 1024
    assert at.bucket(1025) == 2048 and at.bucket(1) == 1
    k1 = at.cache_key("gemm", "cpu", jnp.float32,
                      {"m": 1000, "k": 1000, "n": 1000})
    k2 = at.cache_key("gemm", "cpu", jnp.float32,
                      {"m": 1024, "k": 1024, "n": 1024})
    k3 = at.cache_key("gemm", "cpu", jnp.bfloat16,
                      {"m": 1024, "k": 1024, "n": 1024})
    assert k1 == k2 and k2 != k3


def test_cache_roundtrip(tmp_path):
    """record() → fresh process state → lookup hits the JSON file, and a
    second lookup hits the in-memory memo — no re-ranking either time."""
    dims = {"m": 3000, "k": 500, "n": 400}
    blocks = {"bm": 128, "bn": 256, "bk": 512}
    key = at.record("gemm", dims, jnp.float32, blocks, backend="cpu")
    saved = json.loads((at.user_cache_path()).read_text())
    assert saved["entries"][key]["blocks"] == blocks

    at.reset()                      # drop memo + cache handles, keep file
    got = at.get_config("gemm", dims, jnp.float32, backend="cpu")
    assert got == blocks
    assert at.stats == {"memo_hits": 0, "cache_hits": 1, "ranked": 0,
                        "swept": 0}
    # same bucket, different exact shape: memo hit, still no ranking
    got2 = at.get_config("gemm", {"m": 2900, "k": 400, "n": 300},
                         jnp.float32, backend="cpu")
    assert got2 == blocks
    assert at.stats["memo_hits"] == 1 and at.stats["ranked"] == 0


def test_shipped_v5e_defaults_resolve_on_tpu_key():
    """The pre-swept defaults shipped with the package satisfy a TPU-keyed
    lookup without any ranking."""
    got = at.get_config("gemm", {"m": 1024, "k": 1024, "n": 1024},
                        jnp.float32, backend="tpu")
    assert at.stats["cache_hits"] == 1 and at.stats["ranked"] == 0
    assert set(got) == {"bm", "bn", "bk"}


def test_resolve_explicit_overrides_win():
    full = at.resolve("gemm", DIMS["gemm"], jnp.float32,
                      {"bm": 8, "bn": 128, "bk": 128})
    assert full == {"bm": 8, "bn": 128, "bk": 128}
    assert at.stats["ranked"] == 0          # no tuner involvement
    partial = at.resolve("gemm", DIMS["gemm"], jnp.float32,
                         {"bm": 8, "bn": None, "bk": None})
    assert partial["bm"] == 8 and partial["bn"] % at.LANE == 0
    assert at.stats["ranked"] == 1


def test_resolve_tune_off_is_legacy():
    cfg = at.resolve("tsgram", DIMS["tsgram"], jnp.float32, {"bm": None},
                     tune="off")
    assert cfg == dict(at.KERNELS["tsgram"].legacy)
    assert at.stats["ranked"] == 0
    with pytest.raises(ValueError):
        at.resolve("tsgram", DIMS["tsgram"], jnp.float32, {"bm": None},
                   tune="bogus")


def test_ops_gemm_second_call_skips_ranking():
    """Dispatch-level acceptance: two ops.gemm calls in the same shape
    bucket rank once and memo-hit the second time — and the autotuned
    result matches the reference."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(72, 96)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(96, 80)), jnp.float32)
    got = ops.gemm(a, b, force_pallas=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(got, ref.gemm_ref(a, b, jnp.float32),
                               rtol=1e-4, atol=1e-2)
    assert at.stats["ranked"] == 1
    ops.gemm(a, b, force_pallas=True, out_dtype=jnp.float32)
    assert at.stats["ranked"] == 1 and at.stats["memo_hits"] >= 1


def test_sweep_selects_fastest_candidate():
    """sweep() orders candidates by measured median; a runner with a known
    per-config cost must produce that order (no device needed)."""
    import time
    calls = []

    def run_fn(blocks):
        # emulate work with a known per-config cost: small bm tiles "fast",
        # large ones "slow" — the sweep orders by wall clock alone
        calls.append(dict(blocks))
        time.sleep(0.001 if blocks["bm"] <= 128 else 0.004)

    dims = {"m": 512, "n": 128}
    timed = at.sweep("tsgram", dims, jnp.float32, run_fn, top_n=2, reps=2)
    assert len(timed) >= 2
    assert timed == sorted(timed, key=lambda t: t[0])
    # every timed config was warmed up once + timed `reps` times
    assert len(calls) == len(timed) * 3
    assert at.stats["swept"] == 1
