"""Spectral solvers vs numpy oracles (paper §3.1)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.distmat import RowMatrix, CoordinateMatrix
from repro.core.linalg import (compute_svd, compute_pca, tsqr,
                               lanczos_eigsh)


def test_tall_skinny_svd_gram_path():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(300, 16)).astype(np.float32)
    res = compute_svd(RowMatrix.create(A), 6)
    assert res.info["mode"] == "gram"
    s_np = np.linalg.svd(A, compute_uv=False)
    np.testing.assert_allclose(res.s, s_np[:6], rtol=1e-3)
    U = np.asarray(res.U.to_local())
    recon = U @ np.diag(np.asarray(res.s)) @ np.asarray(res.V).T
    u, s, vt = np.linalg.svd(A, full_matrices=False)
    best = u[:, :6] @ np.diag(s[:6]) @ vt[:6]
    np.testing.assert_allclose(recon, best, atol=5e-3)


def test_square_svd_lanczos_path():
    rng = np.random.default_rng(1)
    m = n = 80
    D = ((rng.random((m, n)) < 0.2) * rng.normal(size=(m, n))
         ).astype(np.float32)
    ri, ci = np.nonzero(D)
    cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                 jnp.asarray(D[ri, ci]), (m, n))
    res = compute_svd(cm, 5, mode="lanczos", tol=3e-6, max_restarts=300)
    s_np = np.linalg.svd(D, compute_uv=False)
    np.testing.assert_allclose(res.s, s_np[:5], rtol=2e-3)
    assert bool(res.info["converged"])


def test_lanczos_known_spectrum():
    # diagonal operator → exact eigenvalues
    d = jnp.asarray(np.linspace(1.0, 50.0, 64), jnp.float32)
    vals, vecs, info = lanczos_eigsh(lambda v: d * v, 64, 4, tol=1e-9,
                                     max_restarts=100)
    np.testing.assert_allclose(vals, [50.0, 49.2222, 48.4444, 47.6667],
                               rtol=1e-4)
    # eigenvectors of a diagonal matrix are coordinate vectors
    top = np.abs(np.asarray(vecs[:, 0]))
    assert top.argmax() == 63 and top.max() > 0.999


def test_auto_dispatch():
    rng = np.random.default_rng(3)
    A = rng.normal(size=(64, 8)).astype(np.float32)
    res = compute_svd(RowMatrix.create(A), 3, mode="auto")
    assert res.info["mode"] == "gram"


def test_wide_and_short_transpose_dispatch():
    """m < n routes through Aᵀ and swaps the factors (paper §3.1)."""
    rng = np.random.default_rng(8)
    W = rng.normal(size=(10, 200)).astype(np.float32)
    res = compute_svd(RowMatrix.create(W), 4)
    assert res.info.get("transposed") is True
    np.testing.assert_allclose(
        res.s, np.linalg.svd(W, compute_uv=False)[:4], rtol=1e-3)
    assert res.V.shape == (200, 4) and res.U.shape == (10, 4)


@given(st.integers(20, 100), st.integers(2, 10))
@settings(max_examples=8, deadline=None)
def test_tsqr_property(m, n):
    A = np.random.default_rng(m + n).normal(size=(m, n)).astype(np.float32)
    Q, R = tsqr(RowMatrix.create(A))
    Ql, Rl = np.asarray(Q.to_local()), np.asarray(R)
    np.testing.assert_allclose(Ql @ Rl, A, atol=5e-4)
    np.testing.assert_allclose(Ql.T @ Ql, np.eye(n), atol=5e-4)
    assert np.all(np.diag(Rl) >= -1e-6)
    assert np.allclose(Rl, np.triu(Rl), atol=1e-5)


def test_pca_matches_numpy():
    rng = np.random.default_rng(4)
    A = (rng.normal(size=(200, 10)) @ np.diag(np.linspace(3, 0.1, 10))
         ).astype(np.float32) + 5.0
    comps, ev = compute_pca(RowMatrix.create(A), 3)
    C = np.cov(A.T)
    w, V = np.linalg.eigh(C)
    w, V = w[::-1][:3], V[:, ::-1][:, :3]
    np.testing.assert_allclose(ev, w, rtol=1e-3)
    for i in range(3):
        assert abs(np.dot(np.asarray(comps)[:, i], V[:, i])) > 0.99
