"""Single-pass fused gradient: kernel parity, distmat wiring, solver
structure (exactly one A-pass per backtracking attempt), and the
fused-vs-unfused solution parity the acceptance bar demands."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distmat import RowMatrix, SparseRowMatrix
from repro.core.distmat import types as T
from repro.core.optim import make_problem, minimize, composite_value
from repro.core.tfocs import (CountingLinop, LinopMatrix, ProxZero,
                              SmoothHuber, SmoothHuberL1, SmoothLogLoss,
                              SmoothPoisson, SmoothQuad, TfocsOptions,
                              fused_gradient_enabled, row_separable, tfocs)
from repro.kernels import ops, ref
from repro.kernels.bsr import BlockELL


def _data(m, n, dtype, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, n)).astype(dtype)
    x = rng.normal(size=n).astype(dtype)
    t = rng.normal(size=m).astype(np.float32)
    w = (rng.random(m).astype(np.float32) if weighted
         else np.ones(m, np.float32))
    return (jnp.asarray(a), jnp.asarray(x), jnp.asarray(t), jnp.asarray(w))


# bf16 tolerance is wide: the kernel (like the unfused adjoint) feeds the
# MXU bf16 operands, so the residual is quantized before the second product
# and cancellation amplifies the quantization on small gradient entries.
TOL = {np.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=8e-2, atol=8e-2)}


class TestKernelParity:
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    @pytest.mark.parametrize("loss", ["quad", "logistic"])
    @pytest.mark.parametrize("m,n", [(96, 48), (130, 70)])  # multi-tile+pad
    def test_dense_kernel_matches_oracle(self, dtype, loss, m, n):
        a, x, t, w = _data(m, n, dtype, seed=m + n)
        if loss == "logistic":
            t = jnp.sign(t) + (t == 0)
        got = ops.fused_grad(a, x, t, w, loss=loss, force_pallas=True)
        want = ref.fused_grad_ref(a, x, t, w, loss=loss)
        tol = TOL[dtype]
        np.testing.assert_allclose(got[0], want[0], **tol)
        np.testing.assert_allclose(np.asarray(got[1], np.float32),
                                   np.asarray(want[1], np.float32), **tol)
        np.testing.assert_allclose(got[2], want[2], **tol)

    @pytest.mark.parametrize("loss", ["quad", "logistic"])
    @pytest.mark.parametrize("bs", [8, 16])
    def test_bsr_kernel_matches_oracle(self, loss, bs):
        rng = np.random.default_rng(3)
        nbr, nbc = 5, 7
        mask = rng.random((nbr, nbc)) < 0.4
        dense = (np.kron(mask, np.ones((bs, bs)))
                 * rng.normal(size=(nbr * bs, nbc * bs))).astype(np.float32)
        bell = BlockELL.from_dense(dense, bs=bs)
        m, n = dense.shape
        x = jnp.asarray(rng.normal(size=n), jnp.float32)
        t = jnp.asarray(np.sign(rng.normal(size=m)) + 0.0, jnp.float32) \
            if loss == "logistic" else jnp.asarray(
                rng.normal(size=m), jnp.float32)
        w = jnp.asarray(rng.random(m), jnp.float32)
        got = ops.fused_grad_bsr(bell, x, t, w, loss=loss, force_pallas=True)
        want = ref.fused_grad_ref(bell, x, t, w, loss=loss)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)

    def test_bsr_fused_grad_vmem_fallback_parity(self, monkeypatch):
        """When the fused BSR kernel's resident working set would overflow
        VMEM, ops.fused_grad_bsr composes the VMEM-safe two-pass BSR
        kernels instead — same results, one extra block read."""
        from repro.kernels import autotune as at
        from repro.kernels import fusedgrad as fg
        rng = np.random.default_rng(23)
        mask = rng.random((4, 11)) < 0.5
        dense = (np.kron(mask, np.ones((8, 8)))
                 * rng.normal(size=(32, 88))).astype(np.float32)
        bell = BlockELL.from_dense(dense, bs=8)
        assert fg.fused_grad_bsr_vmem(bell) > 2048
        monkeypatch.setattr(at, "VMEM_BUDGET", 2048)
        x = jnp.asarray(rng.normal(size=88), jnp.float32)
        t = jnp.asarray(rng.normal(size=32), jnp.float32)
        w = jnp.asarray(rng.random(32), jnp.float32)
        got = ops.fused_grad_bsr(bell, x, t, w, loss="quad",
                                 force_pallas=True)
        want = ref.fused_grad_ref(bell, x, t, w, loss="quad")
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)

    def test_bsr_rmatmul_wide_fallback_parity(self, monkeypatch):
        """When the fused-scatter accumulator would overflow VMEM,
        bsr_rmatmul falls back to the partials + segment_sum scheme — force
        that branch with a tiny budget and check parity."""
        from repro.kernels import autotune as at
        from repro.kernels import bsr as bsr_mod
        rng = np.random.default_rng(17)
        mask = rng.random((3, 9)) < 0.5
        dense = (np.kron(mask, np.ones((8, 8)))
                 * rng.normal(size=(24, 72))).astype(np.float32)
        bell = BlockELL.from_dense(dense, bs=8)
        x = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
        assert bsr_mod._rmm_fused_vmem(9, 8, 16, 4) > 1024
        monkeypatch.setattr(at, "VMEM_BUDGET", 1024)
        got = ops.bsr_rmatmul(bell, x, force_pallas=True)
        np.testing.assert_allclose(got, dense.T @ x, rtol=1e-4, atol=1e-4)

    def test_jnp_paths_match_oracle(self):
        """The off-TPU dispatch target (structured jnp) is itself correct."""
        a, x, t, w = _data(100, 40, np.float32, seed=9)
        got = ops.fused_grad(a, x, t, w, loss="quad")
        want = ref.fused_grad_ref(a, x, t, w, loss="quad")
        np.testing.assert_allclose(got[0], want[0], rtol=1e-6)
        np.testing.assert_allclose(got[1], want[1], rtol=1e-5, atol=1e-5)

    def test_bad_loss_rejected(self):
        a, x, t, w = _data(16, 8, np.float32)
        with pytest.raises(ValueError):
            ops.fused_grad(a, x, t, w, loss="hinge")


class TestDistmatFusedGrad:
    def _meshes(self):
        yield None                                     # single-device
        if jax.device_count() > 1:                     # CI forces 8 hosts
            yield T.make_mesh((jax.device_count(), 1), ("data", "model"))

    @pytest.mark.parametrize("loss", ["quad", "logistic"])
    def test_rowmatrix_matches_apply_adjoint(self, loss):
        rng = np.random.default_rng(11)
        m, n = 203, 24                                 # ragged: padding rows
        A = rng.normal(size=(m, n)).astype(np.float32)
        for mesh in self._meshes():
            rm = RowMatrix.create(jnp.asarray(A), mesh)
            linop = LinopMatrix(rm)
            t = np.sign(rng.normal(size=m)).astype(np.float32) \
                if loss == "logistic" else rng.normal(size=m).astype(
                    np.float32)
            smooth = (SmoothLogLoss(y=linop.pad_data(jnp.asarray(t)),
                                    weights=linop.row_weights())
                      if loss == "logistic" else
                      SmoothQuad(b=linop.pad_data(jnp.asarray(t)),
                                 weights=linop.row_weights()))
            x = jnp.asarray(rng.normal(size=n), jnp.float32)
            f, g, z = linop.fused_grad(x, row_separable(smooth))
            zu = linop.apply(x)
            fu = smooth.value(zu)
            gu = linop.adjoint(smooth.grad(zu))
            np.testing.assert_allclose(f, fu, rtol=1e-5)
            np.testing.assert_allclose(g, gu, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(z, zu, rtol=1e-5, atol=1e-5)

    def test_sparserowmatrix_matches_apply_adjoint(self):
        rng = np.random.default_rng(12)
        mask = rng.random((8, 6)) < 0.4
        A = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(64, 48))).astype(np.float32)
        for mesh in self._meshes():
            srm = SparseRowMatrix.from_dense(A, bs=8, mesh=mesh)
            linop = LinopMatrix(srm)
            b = rng.normal(size=64).astype(np.float32)
            smooth = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                                weights=linop.row_weights())
            x = jnp.asarray(rng.normal(size=48), jnp.float32)
            for dispatch in ("bsr", "dense"):
                f, g, z = srm.fused_grad(x, row_separable(smooth),
                                         dispatch=dispatch)
                zu = linop.apply(x)
                fu = smooth.value(zu)
                gu = linop.adjoint(smooth.grad(zu))
                np.testing.assert_allclose(f, fu, rtol=1e-5)
                np.testing.assert_allclose(g, gu, rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(z, zu, rtol=1e-4, atol=1e-4)

    def test_non_separable_smooth_rejected(self):
        rm = RowMatrix.create(jnp.ones((16, 4), jnp.float32))
        with pytest.raises(ValueError):
            rm.fused_grad(jnp.ones(4), row_separable(SmoothHuberL1(0.1)))


class TestSolverStructure:
    def _composite(self, m=120, n=16, seed=5):
        rng = np.random.default_rng(seed)
        A = rng.normal(size=(m, n)).astype(np.float32)
        b = rng.normal(size=m).astype(np.float32)
        rm = RowMatrix.create(jnp.asarray(A))
        linop = LinopMatrix(rm)
        smooth = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                            weights=linop.row_weights())
        return smooth, linop

    def test_fused_path_one_pass_per_backtracking_attempt(self):
        """The structural acceptance bar: with the fused path on, every
        traced A-contact is a fused_grad (one pass) — the seed evaluation
        plus one per traced attempt site (first attempt + backtracking
        body), and zero apply/adjoint calls."""
        smooth, linop = self._composite()
        counting = CountingLinop(linop)
        tfocs(smooth, counting, ProxZero(), jnp.zeros(16),
              TfocsOptions(max_iters=3, accel=False, backtracking=True,
                           fused=True))
        assert counting.counts == {"apply": 0, "adjoint": 0,
                                   "fused_grad": 3,
                                   "fused_grad_multi": 0}, counting.counts

    def test_unfused_path_two_passes_per_attempt(self):
        smooth, linop = self._composite()
        counting = CountingLinop(linop)
        tfocs(smooth, counting, ProxZero(), jnp.zeros(16),
              TfocsOptions(max_iters=3, accel=False, backtracking=True,
                           fused=False))
        # init apply + (adjoint + apply) per traced attempt site (2 sites)
        assert counting.counts == {"apply": 3, "adjoint": 2,
                                   "fused_grad": 0,
                                   "fused_grad_multi": 0}, counting.counts

    def test_accelerated_quad_takes_affine_fused_path(self):
        """acc* over a quadratic smooth rides the affine-u engine: every
        traced A-contact is a fused_grad — two seeds (u_b, x0) plus one per
        traced attempt site (first attempt + backtracking body), and zero
        apply/adjoint calls."""
        smooth, linop = self._composite()
        counting = CountingLinop(linop)
        _, info = tfocs(smooth, counting, ProxZero(), jnp.zeros(16),
                        TfocsOptions(max_iters=3, accel=True,
                                     backtracking=True, fused="auto"))
        assert counting.counts == {"apply": 0, "adjoint": 0,
                                   "fused_grad": 4,
                                   "fused_grad_multi": 0}, counting.counts
        assert info["plan"] == "fused_affine"
        assert bool(np.asarray(info["fused"]))

    def test_accelerated_non_quad_keeps_cached_path(self):
        """The affine decomposition needs ∇f linear in the image — logistic
        acc* must stay on the cached apply+adjoint engine."""
        _, linop = self._composite()
        rng = np.random.default_rng(5)
        y = (rng.random(120) > 0.5).astype(np.float32) * 2 - 1
        smooth = SmoothLogLoss(y=linop.pad_data(jnp.asarray(y)),
                               weights=linop.row_weights())
        counting = CountingLinop(linop)
        _, info = tfocs(smooth, counting, ProxZero(), jnp.zeros(16),
                        TfocsOptions(max_iters=3, accel=True,
                                     backtracking=True, fused="auto"))
        assert counting.counts["fused_grad"] == 0
        assert info["plan"] == "cached"
        assert not bool(np.asarray(info["fused"]))

    def test_fused_true_on_non_separable_raises(self):
        _, linop = self._composite()
        with pytest.raises(ValueError):
            fused_gradient_enabled(SmoothHuberL1(0.1), linop, True)

    def test_counting_wrapper_on_non_fused_base_falls_back(self):
        """CountingLinop's delegating methods exist unconditionally; the
        capability check must unwrap to the base so a non-fused-capable
        operator keeps the apply+adjoint path instead of crashing."""
        from repro.core.tfocs import LinopIdentity
        wrapped = CountingLinop(LinopIdentity(8))
        smooth = SmoothQuad(b=jnp.zeros(8))
        assert not fused_gradient_enabled(smooth, wrapped, "auto")
        x, _ = tfocs(smooth, wrapped, ProxZero(), jnp.ones(8),
                     TfocsOptions(max_iters=5, accel=False))
        assert wrapped.counts["fused_grad"] == 0
        assert wrapped.counts["apply"] > 0
        assert np.all(np.isfinite(np.asarray(x)))

    def test_opt_out_flag(self):
        smooth, linop = self._composite()
        assert fused_gradient_enabled(smooth, linop, "auto")
        assert not fused_gradient_enabled(smooth, linop, False)


class TestSolverParity:
    """Fused and unfused paths run identical math — the iterates must agree
    to float tolerance on every Figure-1 problem (acceptance: ≤1e-5 rel in
    f32), dense and sparse."""

    @pytest.mark.parametrize("pname", ["linear", "linear_l1", "logistic",
                                       "logistic_l2"])
    @pytest.mark.parametrize("method", ["gra", "lbfgs"])
    def test_figure1_parity(self, pname, method):
        p = make_problem(pname, m=300, n=48)
        xf, info_f = minimize(p, method, max_iters=60, fused=True)
        xu, _ = minimize(p, method, max_iters=60, fused=False)
        ff, fu = (float(composite_value(p, xf)),
                  float(composite_value(p, xu)))
        assert abs(ff - fu) <= 1e-5 * (abs(fu) + 1.0), (ff, fu)
        np.testing.assert_allclose(np.asarray(xf), np.asarray(xu),
                                   rtol=1e-4, atol=1e-5)

    def test_sparse_composite_parity(self):
        rng = np.random.default_rng(21)
        mask = rng.random((10, 4)) < 0.4
        A = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(80, 32))).astype(np.float32)
        srm = SparseRowMatrix.from_dense(A, bs=8)
        linop = LinopMatrix(srm)
        b = rng.normal(size=80).astype(np.float32)
        smooth = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                            weights=linop.row_weights())
        outs = {}
        for fused in (True, False):
            outs[fused] = tfocs(
                smooth, linop, ProxZero(), jnp.zeros(32),
                TfocsOptions(max_iters=80, accel=False, backtracking=True,
                             fused=fused))[0]
        np.testing.assert_allclose(np.asarray(outs[True]),
                                   np.asarray(outs[False]),
                                   rtol=1e-4, atol=1e-5)

    def test_counting_linop_is_transparent(self):
        p = make_problem("linear", m=200, n=32)
        pw = dataclasses.replace(p, linop=CountingLinop(p.linop))
        xw, _ = minimize(pw, "gra", max_iters=30)
        x, _ = minimize(p, "gra", max_iters=30)
        np.testing.assert_allclose(np.asarray(xw), np.asarray(x), rtol=1e-6)


class TestNewLossParity:
    """huber + poisson separable losses (ROADMAP fused-grad follow-on):
    kernel parity on the dense AND the BSR paths, smooth-object consistency,
    and a fused-vs-unfused solver run over SmoothHuber."""

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    @pytest.mark.parametrize("loss,param", [("huber", 0.6), ("huber", 2.0),
                                            ("poisson", 1.0)])
    @pytest.mark.parametrize("m,n", [(96, 48), (130, 70)])  # multi-tile+pad
    def test_dense_kernel_matches_oracle(self, dtype, loss, param, m, n):
        a, x, t, w = _data(m, n, dtype, seed=m + n)
        x = x * 0.1                        # keep e^z in float32 range
        if loss == "poisson":
            t = jnp.abs(t)                 # counts-like targets
        got = ops.fused_grad(a, x, t, w, loss=loss, param=param,
                             force_pallas=True)
        want = ref.fused_grad_ref(a, x, t, w, loss=loss, param=param)
        tol = TOL[dtype]
        np.testing.assert_allclose(got[0], want[0], **tol)
        np.testing.assert_allclose(np.asarray(got[1], np.float32),
                                   np.asarray(want[1], np.float32), **tol)
        np.testing.assert_allclose(got[2], want[2], **tol)

    @pytest.mark.parametrize("loss,param", [("huber", 0.5), ("poisson", 1.0)])
    @pytest.mark.parametrize("bs", [8, 16])
    def test_bsr_kernel_matches_oracle(self, loss, param, bs):
        rng = np.random.default_rng(11)
        nbr, nbc = 5, 7
        mask = rng.random((nbr, nbc)) < 0.4
        dense = (np.kron(mask, np.ones((bs, bs)))
                 * rng.normal(size=(nbr * bs, nbc * bs))).astype(np.float32)
        bell = BlockELL.from_dense(dense, bs=bs)
        m, n = dense.shape
        x = jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32)
        t = jnp.asarray(rng.poisson(2.0, m), jnp.float32) \
            if loss == "poisson" else jnp.asarray(
                rng.normal(size=m), jnp.float32)
        w = jnp.asarray(rng.random(m), jnp.float32)
        got = ops.fused_grad_bsr(bell, x, t, w, loss=loss, param=param,
                                 force_pallas=True)
        want = ref.fused_grad_ref(bell, x, t, w, loss=loss, param=param)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("cls,loss", [(SmoothHuber, "huber"),
                                          (SmoothPoisson, "poisson")])
    def test_smooth_advertises_row_separable(self, cls, loss):
        rng = np.random.default_rng(5)
        t = jnp.asarray(np.abs(rng.normal(size=64)), jnp.float32)
        sm = cls(t, weights=None) if loss == "poisson" \
            else cls(t, delta=0.7, weights=None)
        sep = row_separable(sm)
        assert sep is not None and sep.kind == loss
        # the kernel's row-local math IS the smooth's value/grad
        from repro.kernels.fusedgrad import row_loss_grad
        z = jnp.asarray(rng.normal(size=64) * 0.3, jnp.float32)
        f, r = row_loss_grad(z, sep.target, jnp.ones(64, jnp.float32),
                             loss, sep.param)
        np.testing.assert_allclose(float(f), float(sm.value(z)), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r), np.asarray(sm.grad(z)),
                                   rtol=1e-5, atol=1e-6)

    def test_distmat_parity_dense_and_bsr(self):
        """RowMatrix.fused_grad and SparseRowMatrix.fused_grad (both
        dispatch arms) agree with apply + value/grad + adjoint for the new
        losses."""
        rng = np.random.default_rng(7)
        mask = rng.random((4, 6)) < 0.3
        dense = (np.kron(mask, np.ones((16, 16)))
                 * rng.normal(size=(64, 96))).astype(np.float32)
        x = jnp.asarray(rng.normal(size=96) * 0.1, jnp.float32)
        for A in (RowMatrix.create(dense),
                  SparseRowMatrix.from_dense(dense, bs=16)):
            linop = LinopMatrix(A)
            t = linop.pad_data(jnp.asarray(
                np.abs(rng.normal(size=64)), jnp.float32))
            for sm in (SmoothHuber(t, delta=0.8,
                                   weights=linop.row_weights()),
                       SmoothPoisson(t, weights=linop.row_weights())):
                f, g, z = linop.fused_grad(x, row_separable(sm))
                z2 = linop.apply(x)
                f2, g2 = sm.value(z2), linop.adjoint(sm.grad(z2))
                np.testing.assert_allclose(float(f), float(f2), rtol=1e-5)
                np.testing.assert_allclose(np.asarray(g), np.asarray(g2),
                                           rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(np.asarray(z)[:64],
                                           np.asarray(z2)[:64],
                                           rtol=1e-5, atol=1e-5)

    def test_huber_solver_fused_matches_unfused(self):
        """gra over SmoothHuber: the fused engine (one A-pass per attempt)
        reaches the same solution as the unfused baseline."""
        rng = np.random.default_rng(9)
        A = RowMatrix.create(rng.normal(size=(160, 24)).astype(np.float32))
        linop = LinopMatrix(A)
        b = jnp.asarray(rng.normal(size=160), jnp.float32)
        sm = SmoothHuber(linop.pad_data(b), delta=0.5,
                         weights=linop.row_weights())
        outs = {}
        for fused in (True, False):
            outs[fused], info = tfocs(
                sm, linop, ProxZero(), jnp.zeros(24),
                TfocsOptions(max_iters=60, accel=False, backtracking=True,
                             fused=fused))
            assert bool(info["fused"]) == fused
        np.testing.assert_allclose(np.asarray(outs[True]),
                                   np.asarray(outs[False]),
                                   rtol=1e-4, atol=1e-5)
