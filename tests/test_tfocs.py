"""TFOCS engine: LASSO vs long-run ISTA, smoothed LP KKT, solver flags."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distmat import RowMatrix
from repro.core.tfocs import (solve_lasso, solve_smoothed_lp, TfocsOptions,
                              LinopMatrix, SmoothQuad, ProxL1, tfocs)


@pytest.fixture(scope="module")
def lasso_problem():
    rng = np.random.default_rng(2)
    m, n = 80, 24
    A = rng.normal(size=(m, n)).astype(np.float32)
    xt = np.zeros(n, np.float32)
    xt[:5] = rng.normal(size=5) * 2
    b = (A @ xt + 0.01 * rng.normal(size=m)).astype(np.float32)
    lam = 0.5
    L = np.linalg.norm(A, 2) ** 2
    x = np.zeros(n)
    for _ in range(30000):                       # ISTA reference, float64
        x -= A.T @ (A @ x - b) / L
        x = np.sign(x) * np.maximum(np.abs(x) - lam / L, 0)
    f_ref = 0.5 * np.linalg.norm(A @ x - b) ** 2 + lam * np.abs(x).sum()
    return A, b, lam, L, x, f_ref


def _obj(A, b, lam, x):
    return 0.5 * np.linalg.norm(A @ np.asarray(x) - b) ** 2 \
        + lam * np.abs(np.asarray(x)).sum()


def test_lasso_matches_reference(lasso_problem):
    A, b, lam, L, x_ref, f_ref = lasso_problem
    xs, info = solve_lasso(RowMatrix.create(A), jnp.asarray(b), lam,
                           opts=TfocsOptions(max_iters=600, tol=1e-12,
                                             backtracking=True,
                                             restart=True))
    assert _obj(A, b, lam, xs) <= f_ref * (1 + 1e-3)
    np.testing.assert_allclose(np.asarray(xs), x_ref, atol=5e-3)


def test_solver_variant_flags(lasso_problem):
    A, b, lam, L, x_ref, f_ref = lasso_problem
    rm = RowMatrix.create(A)
    linop = LinopMatrix(rm)
    smooth = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                        weights=linop.row_weights())
    objs = {}
    for name, o in {
        "gra": TfocsOptions(max_iters=150, accel=False, Lexact=float(L),
                            backtracking=False),
        "acc": TfocsOptions(max_iters=150, accel=True, Lexact=float(L),
                            backtracking=False),
        "acc_rb": TfocsOptions(max_iters=150, backtracking=True,
                               restart=True),
    }.items():
        xv, info = tfocs(smooth, linop, ProxL1(lam), jnp.zeros(A.shape[1]),
                         o)
        objs[name] = _obj(A, b, lam, xv)
        assert np.isfinite(objs[name])
    # every variant must be near the optimum on this easy problem
    for name, f in objs.items():
        assert f <= f_ref * 1.05, (name, f, f_ref)


def test_backtracking_counts(lasso_problem):
    A, b, lam, *_ = lasso_problem
    xs, info = solve_lasso(RowMatrix.create(A), jnp.asarray(b), lam,
                           opts=TfocsOptions(max_iters=50,
                                             backtracking=True, L0=1e-3))
    # L0 deliberately tiny → backtracking must have fired
    assert int(info["n_backtracks"]) > 0


def test_smoothed_lp_kkt():
    rng = np.random.default_rng(7)
    mc, nc = 6, 14
    Ac = rng.normal(size=(mc, nc)).astype(np.float32)
    xstar = np.zeros(nc, np.float32)
    xstar[:3] = rng.random(3).astype(np.float32) + 0.5
    bc = Ac @ xstar
    y = rng.normal(size=mc).astype(np.float32)
    s = np.zeros(nc, np.float32)
    s[3:] = rng.random(nc - 3).astype(np.float32) + 0.1
    c = Ac.T @ y + s                       # strict complementarity

    class Op:
        in_shape = (nc,)
        out_shape = (mc,)
        apply = staticmethod(lambda x: jnp.asarray(Ac) @ x)
        adjoint = staticmethod(lambda u: jnp.asarray(Ac).T @ u)

    x, lam, info = solve_smoothed_lp(
        jnp.asarray(c), Op, jnp.asarray(bc), mu=1e-2, continuations=6,
        opts=TfocsOptions(max_iters=500, backtracking=True, restart=True))
    kkt = info["kkt"]
    assert float(kkt["primal_feasibility"]) < 1e-2
    assert float(kkt["nonneg_violation"]) == 0.0
    np.testing.assert_allclose(np.asarray(x), xstar, atol=0.05)
