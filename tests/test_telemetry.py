"""Runtime telemetry subsystem (launch/telemetry): spans, metrics,
plan-vs-actual, exporters — and the standardized ``Result.info`` contract
every public entry point reports.

Three layers:

  * Recorder unit behavior — span nesting/attrs/errors/cap, counter and
    gauge and histogram math (fixed log-spaced buckets, interpolated
    percentiles), the null recorder's zero-allocation no-ops, exporter
    round-trips (JSONL, Chrome/Perfetto);
  * integration — a traced api.solve carries ``info["trace"]`` with the
    solver span phases and fusedgrad plan-vs-actual records that
    ``planner.calibrate`` accepts; the served path renders per-reason
    degraded counters and non-trivial latency histograms; the elastic
    executor's fault episode (straggler → checkpoint → re-mesh) yields a
    span tree covering every recovery phase (``fault`` marker);
  * the Result.info key contract — iterations / a_passes / converged /
    plan / degraded on every entry point (solve direct, elastic,
    served, svd all modes, similarities) plus the deprecated native
    aliases ("fused", "n_evals", "mode" / "restarts" / "passes_over_A")
    kept for one release.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.distmat import RowMatrix
from repro.launch import machine, telemetry


# =========================================================================
# Recorder unit behavior
# =========================================================================

class TestSpans:
    def test_nesting_records_parent_ids(self):
        rec = telemetry.Recorder()
        with rec.span("outer") as so:
            with rec.span("inner", depth=1):
                pass
        outer = next(s for s in rec.spans if s.name == "outer")
        inner = next(s for s in rec.spans if s.name == "inner")
        assert inner.parent == outer.id
        assert outer.parent is None
        assert inner.attrs["depth"] == 1
        assert inner.dur_s >= 0 and outer.dur_s >= inner.dur_s

    def test_annotate_and_duration(self):
        rec = telemetry.Recorder()
        with rec.span("work") as sp:
            sp.annotate(tries=3)
        (span,) = rec.spans
        assert span.attrs["tries"] == 3
        assert span.dur_s >= 0

    def test_exception_recorded_and_propagated(self):
        rec = telemetry.Recorder()
        with pytest.raises(ValueError, match="boom"):
            with rec.span("explodes"):
                raise ValueError("boom")
        (span,) = rec.spans
        assert "boom" in span.attrs["error"]

    def test_span_cap_drops_and_counts(self):
        rec = telemetry.Recorder(max_spans=3)
        for i in range(5):
            with rec.span(f"s{i}"):
                pass
        assert len(rec.spans) == 3
        assert rec.spans_dropped == 2

    def test_thread_safety_and_per_thread_stacks(self):
        """Concurrent spans from worker threads never cross-parent: each
        thread's stack is its own, and all spans commit."""
        rec = telemetry.Recorder()
        errs = []

        def worker(tid):
            try:
                for _ in range(50):
                    with rec.span("outer", tid=tid):
                        with rec.span("inner", tid=tid):
                            pass
            except Exception as e:  # pragma: no cover - failure detail
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        assert len(rec.spans) == 4 * 50 * 2
        by_id = {s.id: s for s in rec.spans}
        for s in rec.spans:
            if s.name == "inner":
                parent = by_id[s.parent]
                assert parent.name == "outer"
                assert parent.attrs["tid"] == s.attrs["tid"]


class TestMetrics:
    def test_counter_inc_and_labels(self):
        rec = telemetry.Recorder()
        rec.counter("reqs").inc()
        rec.counter("reqs").inc(2)
        rec.counter("deg", reason="fault").inc()
        rec.counter("deg", reason="deadline").inc(3)
        assert rec.counter("reqs").value == 3
        breakdown = rec.counters("deg")
        assert breakdown == {"reason=fault": 1, "reason=deadline": 3}

    def test_gauge_set(self):
        rec = telemetry.Recorder()
        g = rec.gauge("backlog")
        g.set(1)
        assert rec.gauge("backlog").value == 1
        g.set(0)
        assert rec.gauge("backlog").value == 0

    def test_histogram_percentiles_bracket_observations(self):
        rec = telemetry.Recorder()
        h = rec.histogram("lat")
        for v in [0.001] * 98 + [0.5, 1.0]:
            h.observe(v)
        assert h.count == 100
        # p50 lands in 0.001's bucket; interpolation stays within a
        # bucket factor (2x) of the true value, clamped to observed range.
        assert 0.0005 <= h.percentile(0.5) <= 0.002
        assert h.percentile(0.99) >= 0.25
        assert h.percentile(1.0) <= 1.0 + 1e-9
        assert h.min <= 0.001 and h.max >= 1.0

    def test_histogram_empty(self):
        h = telemetry.Recorder().histogram("lat")
        assert h.count == 0 and np.isnan(h.percentile(0.5))


class TestNullRecorder:
    def test_noops_share_singletons(self):
        """The disabled path allocates nothing per call: every span is the
        same null context, every metric the same null sink."""
        null = telemetry.NULL
        assert not null.enabled
        s1 = null.span("a", x=1)
        s2 = null.span("b")
        assert s1 is s2
        assert null.counter("c") is null.histogram("h")
        with null.span("a") as sp:
            sp.annotate(ok=True)
            sp.sync_on(jnp.zeros(()))
        null.record_plan_actual(None, 0.0)
        assert null.summary()["spans"] == 0

    def test_current_defaults_to_null(self):
        assert telemetry.current() is telemetry.NULL

    def test_recording_scopes_current(self):
        rec = telemetry.Recorder()
        with telemetry.recording(rec):
            assert telemetry.current() is rec
            with rec.span("inside"):
                pass
        assert telemetry.current() is telemetry.NULL
        assert [s.name for s in rec.spans] == ["inside"]


class TestExporters:
    def test_jsonl_round_trip(self, tmp_path):
        rec = telemetry.Recorder()
        with rec.span("phase", k=1):
            pass
        rec.counter("n").inc(2)
        rec.histogram("h").observe(0.01)
        path = tmp_path / "events.jsonl"
        rec.export_jsonl(path)
        events = [json.loads(line) for line in
                  path.read_text().splitlines()]
        kinds = {e["type"] for e in events}
        assert {"span", "counter", "histogram"} <= kinds
        span = next(e for e in events if e["type"] == "span")
        assert span["name"] == "phase" and span["attrs"]["k"] == 1

    def test_chrome_trace_structure(self, tmp_path):
        rec = telemetry.Recorder()
        with rec.span("outer"):
            with rec.span("inner"):
                pass
        path = tmp_path / "trace.json"
        rec.export_chrome_trace(path)
        doc = json.loads(path.read_text())
        events = doc["traceEvents"]
        xs = [e for e in events if e["ph"] == "X"]
        assert {e["name"] for e in xs} == {"outer", "inner"}
        for e in xs:                       # µs timebase complete events
            assert e["dur"] >= 0 and "ts" in e and "tid" in e
        assert any(e["ph"] == "M" for e in events)   # metadata names

    def test_timeit_blocks_and_feeds_histogram(self):
        rec = telemetry.Recorder()
        h = rec.histogram("bench")
        t = telemetry.timeit(lambda: jnp.ones(8) * 2, reps=3, warmup=1,
                             hist=h)
        assert len(t.times) == 3
        assert t.min_s <= t.median_s <= max(t.times)
        assert t.mean_us == pytest.approx(t.mean_s * 1e6)
        assert h.count == 3


# =========================================================================
# Integration: traced solves, serving metrics, plan-vs-actual
# =========================================================================

def _lstsq(m=120, n=12, k=1, seed=5):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    bs = [(A @ rng.normal(size=n) + 0.01 * rng.normal(size=m))
          .astype(np.float32) for _ in range(k)]
    return A, bs


class TestTracedEntryPoints:
    def test_traced_solve_has_trace_and_matches_untraced(self):
        A, (b,) = _lstsq()
        ref = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                         tol=1e-7, max_iters=300))
        res = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                         tol=1e-7, max_iters=300,
                                         telemetry=True))
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                                   rtol=1e-6, atol=1e-6)
        trace = res.info["trace"]
        assert trace["spans"] >= 1
        assert "api.solve" in trace["phases"]
        assert "trace" not in ref.info       # off by default

    def test_traced_elastic_solve_covers_solver_phases(self, tmp_path):
        """The elastic (checkpointing) path is the fully-instrumented one:
        per-iteration spans, checkpoint spans, and fusedgrad plan-vs-actual
        records that feed calibration."""
        A, (b,) = _lstsq(m=150, n=10)
        rec = telemetry.Recorder()
        res = api.solve(api.SolveRequest(
            A=A, b=b, loss="quad", tol=1e-7, max_iters=300,
            checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=10,
            telemetry=rec))
        assert res.info["converged"]
        phases = set(res.info["trace"]["phases"])
        for name in ("api.solve", "solver.iteration", "solver.fused_pass",
                     "solver.seed_pass", "solver.checkpoint"):
            assert name in phases, (name, phases)
        pva = res.info["trace"]["plan_vs_actual"]
        assert pva["fusedgrad"]["records"] >= 1
        assert pva["fusedgrad"]["ratio"] > 0

    def test_plan_vs_actual_records_calibrate(self):
        """The acceptance property: traced records round-trip into
        MachineModel.calibrate and measurably tighten the model."""
        A, (b,) = _lstsq(m=200, n=16)
        rec = telemetry.Recorder()
        api.solve(api.SolveRequest(A=A, b=b, loss="quad", tol=0.0,
                                   max_iters=40, deadline_s=1e9,
                                   telemetry=rec))
        recs = rec.calibration_records()
        assert len(recs) >= 5
        for r in recs:
            assert r["op"] == "fusedgrad"
            assert {"flops", "hbm_bytes", "measured_s", "modeled_s",
                    "blocks"} <= set(r)
        mach = machine.builtin(jax.default_backend())
        before = mach.error(recs)
        fitted = mach.calibrate(recs)
        assert fitted.error(recs) < before

    def test_recorder_accumulates_across_requests(self):
        A, bs = _lstsq(k=2)
        rec = telemetry.Recorder()
        for b in bs:
            api.solve(api.SolveRequest(A=A, b=b, loss="quad", tol=1e-6,
                                       max_iters=200, telemetry=rec))
        assert sum(1 for s in rec.spans if s.name == "api.solve") == 2

    def test_traced_svd_and_similarities(self):
        A, _ = _lstsq(m=96, n=12)
        R = RowMatrix.create(jnp.asarray(A))
        r1 = api.svd(api.SvdRequest(A=R, k=3, telemetry=True))
        assert "api.svd" in r1.info["trace"]["phases"]
        r2 = api.similarities(api.SimilarityRequest(A=R, telemetry=True))
        assert "api.similarities" in r2.info["trace"]["phases"]


class TestServerMetrics:
    def test_stats_view_and_degraded_breakdown(self):
        """`stats` renders from typed counters, and the degraded count is
        distinguishable by reason — shed (overloaded) here."""
        from repro.launch.serve import SolverServer
        A, bs = _lstsq(m=96, n=12, k=5)
        srv = SolverServer(slots=2, max_pending=2)
        ids = [srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                           tol=1e-6, max_iters=200))
               for b in bs]
        srv.run()
        s = srv.stats
        assert s["admitted"] + s["shed"] == len(bs)
        assert s["shed"] >= 1
        assert s["degraded"].get("overloaded") == s["shed"]
        shed = [i for i in ids if srv.result(i).info["degraded"]
                == "overloaded"]
        assert len(shed) == s["shed"]

    def test_latency_histograms_nontrivial(self):
        from repro.launch.serve import SolverServer
        A, bs = _lstsq(m=96, n=12, k=4)
        srv = SolverServer(slots=4)
        for b in bs:
            srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                        tol=1e-6, max_iters=200))
        srv.run()
        lat = srv.tel.histogram("serve.latency_s")
        wait = srv.tel.histogram("serve.queue_wait_s")
        assert lat.count == len(bs) and wait.count == len(bs)
        assert 0 < lat.percentile(0.5) <= lat.percentile(0.99)

    def test_server_spans_ride_ambient_recorder(self):
        """A server constructed under telemetry.recording() traces its
        scheduler actions; one constructed outside records metrics only."""
        from repro.launch.serve import SolverServer
        A, bs = _lstsq(m=96, n=12, k=2)
        rec = telemetry.Recorder()
        with telemetry.recording(rec):
            srv = SolverServer(slots=2)
            for b in bs:
                srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                            tol=1e-6, max_iters=200))
            srv.run()
        names = {s.name for s in rec.spans}
        assert {"serve.admit", "serve.retire"} <= names

        plain = SolverServer(slots=2)
        assert plain.tel.spans == []        # private spanless recorder


@pytest.mark.fault
class TestFaultEpisodeTrace:
    def test_span_tree_covers_recovery_phases(self, tmp_path):
        """THE observability acceptance property: a solve that hits an
        injected straggler produces a span tree covering iterate /
        collective / checkpoint / re-mesh, exportable to Perfetto, with
        the trip and re-mesh visible as counters."""
        from repro.core.distmat.types import make_mesh
        from repro.core.optim.elastic import (ElasticConfig, ElasticGroup,
                                              SolveCheckpoint)
        from repro.core.tfocs.linop import LinopMatrix
        from repro.train.faults import FaultPlan, FaultyLinop, FaultyMesh
        from repro.train.straggler import ShardMonitor, StragglerConfig

        A, bs = _lstsq(m=256, n=16, k=2, seed=9)
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
        fm = FaultyMesh(mesh)
        lin = FaultyLinop(
            LinopMatrix(RowMatrix.create(jnp.asarray(A), mesh)),
            FaultPlan(shard_delays={0: 0.2}, delay_from=4),
            sleep=lambda _dt: None)
        cfg = ElasticConfig(
            monitor=ShardMonitor(lin.row_shards(),
                                 StragglerConfig(warmup_steps=2,
                                                 threshold=2.0,
                                                 trip_limit=2)),
            remesh_to=fm.drop,
            checkpoint=SolveCheckpoint(tmp_path / "ck", every=5,
                                       async_save=False))
        rec = telemetry.Recorder()
        with telemetry.recording(rec):
            grp = ElasticGroup(lin, "quad", slots=2, elastic=cfg)
            for b in bs:
                grp.admit_slot(b, tol=1e-7)
            while grp.busy() and grp.iteration < 200:
                grp.step_iteration()
        assert grp.remeshes >= 1 and fm.casualties == [0]

        names = {s.name for s in rec.spans}
        for phase in ("solver.iteration", "solver.fused_pass",
                      "solver.checkpoint", "solver.remesh",
                      "solver.rejit"):
            assert phase in names, (phase, names)
        assert rec.counter("solver.remeshes").value >= 1
        assert rec.counter("straggler.trips").value >= 1

        # phase nesting: remesh and fused_pass spans parent to iterations
        by_id = {s.id: s for s in rec.spans}
        for s in rec.spans:
            if s.name in ("solver.fused_pass", "solver.remesh"):
                assert by_id[s.parent].name == "solver.iteration"

        doc = rec.chrome_trace()
        assert any(e.get("name") == "solver.remesh"
                   for e in doc["traceEvents"])


# =========================================================================
# Result.info standardized-key contract (every public entry point)
# =========================================================================

_STD_KEYS = ("iterations", "a_passes", "converged", "plan", "degraded")


def _assert_std(info, where):
    for key in _STD_KEYS:
        assert key in info, (where, key, sorted(info))


class TestResultInfoContract:
    def test_solve_direct_gra(self):
        A, (b,) = _lstsq()
        res = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                         tol=1e-7, max_iters=300))
        _assert_std(res.info, "solve/gra")
        assert res.info["degraded"] is None
        # deprecated alias of plan == "fused", one release of grace
        assert res.info["fused"] == (res.info["plan"] == "fused")

    def test_solve_direct_lbfgs_alias(self):
        A, (b,) = _lstsq()
        res = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                         method="lbfgs", tol=1e-7,
                                         max_iters=300))
        _assert_std(res.info, "solve/lbfgs")
        # n_evals stays as the native count; a_passes is the currency
        assert int(res.info["a_passes"]) >= int(res.info["n_evals"])

    def test_solve_elastic_path(self, tmp_path):
        A, (b,) = _lstsq()
        res = api.solve(api.SolveRequest(
            A=A, b=b, loss="quad", tol=1e-7, max_iters=300,
            checkpoint_dir=str(tmp_path / "ck")))
        _assert_std(res.info, "solve/elastic")
        assert res.info["converged"]

    def test_solve_served_path(self):
        from repro.launch.serve import SolverServer
        A, (b,) = _lstsq()
        srv = SolverServer(slots=2)
        rid = srv.submit(api.SolveRequest(A=A, b=b, loss="quad",
                                          tol=1e-7, max_iters=300))
        srv.run()
        _assert_std(srv.result(rid).info, "solve/served")

    @pytest.mark.parametrize("mode", ["gram", "lanczos", "randomized"])
    def test_svd_modes_and_aliases(self, mode):
        A, _ = _lstsq(m=128, n=16)
        R = RowMatrix.create(jnp.asarray(A))
        res = api.svd(api.SvdRequest(A=R, k=3, mode=mode))
        _assert_std(res.info, f"svd/{mode}")
        assert res.info["plan"] == mode
        if mode == "randomized":      # deprecated native alias
            assert res.info["a_passes"] == res.info["passes_over_A"]
        if mode == "lanczos":
            assert res.info["iterations"] == res.info["restarts"]
            assert res.info["mode"] == "lanczos"
        if mode == "gram":
            assert res.info["mode"] == "gram"

    def test_similarities(self):
        A, _ = _lstsq(m=96, n=12)
        res = api.similarities(api.SimilarityRequest(
            A=RowMatrix.create(jnp.asarray(A))))
        _assert_std(res.info, "similarities")
