"""Perf-smoke: structural guards on the optimizer hot path and the planner.

Two deterministic, non-flaky properties that fail the moment a refactor
regresses a dispatch decision:

  * the fused gradient path's *counted* A-passes never exceed the unfused
    path's (counts are trace-level — CountingLinop: while-loop bodies trace
    once);
  * planner.plan() on the golden shape table (benchmarks/bench_planner)
    reproduces the recorded decisions against the reference machine model.
"""
import pytest

bench_optim = pytest.importorskip(
    "benchmarks.bench_optim",
    reason="benchmarks package needs the repo root on sys.path "
           "(run as `python -m pytest` from the checkout)")
bench_planner = pytest.importorskip("benchmarks.bench_planner")
bench_serve = pytest.importorskip("benchmarks.bench_serve")


@pytest.mark.perf_smoke
@pytest.mark.parametrize("pname", ["linear", "logistic"])
@pytest.mark.parametrize("method", ["gra", "lbfgs"])
def test_fused_a_passes_not_worse(pname, method):
    fused = bench_optim.fused_pass_counts(pname, method, True, m=120, n=24)
    unfused = bench_optim.fused_pass_counts(pname, method, False,
                                            m=120, n=24)
    assert fused["per_attempt"] <= unfused["per_attempt"], (fused, unfused)
    assert fused["total"] <= unfused["total"], (fused, unfused)
    # the whole point: one pass per attempt, down from two
    assert fused["per_attempt"] == 1, fused
    assert unfused["per_attempt"] == 2, unfused
    assert fused["counts"]["apply"] == fused["counts"]["adjoint"] == 0, fused


@pytest.mark.perf_smoke
def test_serving_grouped_passes_below_serial():
    """Serving canary: a shared-A group answered by the batched engine
    consumes strictly fewer A-passes than the serial schedule for the same
    requests (and identical trace-level call sites — one fused pass per
    attempt regardless of group width).  Deterministic counts, no timing."""
    rec = bench_serve.group_pass_counts(m=120, n=24, k=4, iters=6)
    assert rec["grouped_a_passes"] < rec["serial_a_passes"], rec
    assert rec["grouped_trace_counts"] == rec["serial_trace_counts"], rec
    assert rec["a_pass_ratio"] >= 2, rec


@pytest.mark.perf_smoke
def test_planner_decisions_stable_on_cpu():
    """Dispatch regressions fail fast: every golden-shape plan() decision
    matches the recorded expectation on the reference machine (priced
    explicitly against machine.V5E, so a stray user calibration file on
    the runner cannot flip it)."""
    for rec in bench_planner.golden_plans():
        assert rec["stable"], (
            f"planner decision drifted for {rec['op']} {rec['dims']}: "
            f"got {rec['choice']}, expected {rec['expected']}")


@pytest.mark.perf_smoke
def test_eager_dispatch_at_tiny_shapes():
    """Overlap canary: at shapes where one kernel call is cheaper than any
    pipeline (tiny shard, small psum), the planner must keep the eager
    single-dispatch path — chunks=1 — even with topology context, and a
    force-chunked call must still be bit-identical to eager (so a wrong
    auto decision could never corrupt results, only waste dispatches)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.distmat import RowMatrix
    from repro.launch import machine, planner

    p = planner.plan("gram", {"m": 4096, "n": 128}, machine=machine.V5E,
                     context={"axes": (8,)})
    assert p.choice == "eager" and p.blocks["chunks"] == 1, p.explain()
    g = planner.plan("grad", {"m": 4096, "n": 128}, machine=machine.V5E,
                     context={"axes": (8,)})
    assert g.blocks["chunks"] == 1, g.explain()

    rng = np.random.default_rng(0)
    A = rng.normal(size=(48, 16)).astype(np.float32)
    rm = RowMatrix.create(jnp.asarray(A))
    assert np.array_equal(np.asarray(rm.gram(chunks=4)),
                          np.asarray(rm.gram(chunks=1)))


@pytest.mark.perf_smoke
def test_tiny_shapes_stay_f32():
    """Precision canary: at tiny shapes the modeled-savings floor must
    keep the precision sweep at exact f32 eager even when the solver
    tolerance would admit bf16/psum8 — flipping precision to save
    nanoseconds is all risk and no win, and a regression here silently
    degrades every small solve."""
    from repro.launch import machine, planner

    for op in ("gram", "grad"):
        p = planner.plan(op, {"m": 4096, "n": 128}, machine=machine.V5E,
                         context={"axes": (8,), "tol": 1e-3})
        assert p.precision == "f32", p.explain()
        assert p.blocks["chunks"] == 1, p.explain()


@pytest.mark.perf_smoke
def test_telemetry_off_is_free_and_result_identical():
    """Telemetry canary: with no recorder installed every span/metric call
    resolves to shared null singletons (no per-call allocation), and a
    traced solve returns bit-identical iterates to an untraced one — the
    instrumentation must observe, never perturb."""
    import numpy as np
    from repro import api
    from repro.launch import telemetry

    null = telemetry.current()
    assert null is telemetry.NULL and not null.enabled
    # no-op paths hand back the SAME objects every call
    assert null.span("solver.iteration", k=1) is null.span("serve.admit")
    assert null.counter("a") is null.counter("b", reason="x")

    rng = np.random.default_rng(3)
    A = rng.normal(size=(120, 12)).astype(np.float32)
    b = (A @ rng.normal(size=12)).astype(np.float32)
    base = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                      tol=1e-7, max_iters=200))
    traced = api.solve(api.SolveRequest(A=A, b=b, loss="quad",
                                        tol=1e-7, max_iters=200,
                                        telemetry=True))
    np.testing.assert_array_equal(np.asarray(base.x),
                                  np.asarray(traced.x))
    assert int(base.info["iterations"]) == int(traced.info["iterations"])
    assert "trace" in traced.info and "trace" not in base.info


@pytest.mark.perf_smoke
def test_null_span_overhead_bounded():
    """A disabled span costs nanoseconds, not microseconds: 10k no-op
    spans must finish in well under the time one solver iteration takes.
    The bound is generous (0.25s) — it catches an accidental allocation
    or lock on the disabled path, not scheduler noise."""
    import time
    from repro.launch import telemetry

    null = telemetry.NULL
    t0 = time.perf_counter()
    for i in range(10_000):
        with null.span("solver.iteration", iteration=i) as sp:
            sp.annotate(ok=True)
    assert time.perf_counter() - t0 < 0.25
