"""Perf-smoke: structural guard on the optimizer hot path.

Runs the tiny bench_optim fused-vs-unfused config and asserts the fused
path's *counted* A-passes never exceed the unfused path's.  The counts are
trace-level (CountingLinop: while-loop bodies trace once), so this is a
structural property — deterministic and non-flaky — that fails the moment a
refactor silently reintroduces the second streaming pass over A.
"""
import pytest

bench_optim = pytest.importorskip(
    "benchmarks.bench_optim",
    reason="benchmarks package needs the repo root on sys.path "
           "(run as `python -m pytest` from the checkout)")


@pytest.mark.perf_smoke
@pytest.mark.parametrize("pname", ["linear", "logistic"])
@pytest.mark.parametrize("method", ["gra", "lbfgs"])
def test_fused_a_passes_not_worse(pname, method):
    fused = bench_optim.fused_pass_counts(pname, method, True, m=120, n=24)
    unfused = bench_optim.fused_pass_counts(pname, method, False,
                                            m=120, n=24)
    assert fused["per_attempt"] <= unfused["per_attempt"], (fused, unfused)
    assert fused["total"] <= unfused["total"], (fused, unfused)
    # the whole point: one pass per attempt, down from two
    assert fused["per_attempt"] == 1, fused
    assert unfused["per_attempt"] == 2, unfused
    assert fused["counts"]["apply"] == fused["counts"]["adjoint"] == 0, fused
