"""Low-precision compute: solution parity and bit-compat guarantees.

The tentpole's contract, as tests:

  * store_dtype=f32 is BIT-identical to the default path (the dtype plumbing
    must be a no-op when nothing is quantized);
  * bf16 storage / int8 BlockELL / compressed int8 psums reach the f32
    solution within the solver tolerance that admitted them (the planner's
    PRECISION_GUARDS are real accuracy ceilings, not vibes) — across the
    Figure-1 solver family, dense and BSR operands, 1- and 8-device meshes;
  * every solve reports what ran in info["precision"].
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.distmat import RowMatrix, SparseRowMatrix


def _problem(m=192, n=24, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    x = rng.normal(size=(n,)).astype(np.float32)
    b = (A @ x + noise * rng.normal(size=m)).astype(np.float32)
    return A, b


def _block_sparse(m=256, n=128, bs=32, density=0.3, seed=1):
    rng = np.random.default_rng(seed)
    mask = rng.random((m // bs, n // bs)) < density
    dense = (np.kron(mask, np.ones((bs, bs)))
             * rng.normal(size=(m, n))).astype(np.float32)
    return dense


class TestF32BitCompat:
    def test_store_f32_is_identity(self):
        A, _ = _problem()
        base = RowMatrix.create(A)
        kept = RowMatrix.create(A, store_dtype=jnp.float32)
        assert kept.rows.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(base.gram()),
                                      np.asarray(kept.gram()))
        v = np.linspace(-1, 1, A.shape[1]).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(base.matvec(v)),
                                      np.asarray(kept.matvec(v)))

    def test_astype_store_round_trip_shape(self):
        A, _ = _problem()
        rm = RowMatrix.create(A)
        lo = rm.astype_store(jnp.bfloat16)
        assert lo.rows.dtype == jnp.bfloat16
        assert lo.out_dtype == jnp.float32          # compute stays f32
        back = lo.astype_store(jnp.float32)
        assert back.rows.dtype == jnp.float32

    def test_unquantized_sparse_unchanged(self):
        dense = _block_sparse()
        srm = SparseRowMatrix.from_dense(dense, bs=32)
        srm_none = SparseRowMatrix.from_dense(dense, bs=32, quantize="none")
        np.testing.assert_array_equal(np.asarray(srm.gram()),
                                      np.asarray(srm_none.gram()))


class TestStorageParity:
    def test_bf16_gram_close(self):
        A, _ = _problem(512, 32, seed=2)
        rm = RowMatrix.create(A, store_dtype=jnp.bfloat16)
        g = np.asarray(rm.gram())
        assert g.dtype == np.float32                # f32 accumulate + out
        ref = A.T @ A
        rel = np.abs(g - ref).max() / np.abs(ref).max()
        assert rel < 2e-2, rel                      # bf16 has ~8 mantissa bits

    def test_int8_sparse_matvec_bounded(self):
        dense = _block_sparse()
        srm = SparseRowMatrix.from_dense(dense, bs=32, quantize="int8")
        assert srm.scales is not None
        v = np.random.default_rng(3).normal(size=dense.shape[1]) \
            .astype(np.float32)
        got = np.asarray(srm.matvec(v))[:dense.shape[0]]
        ref = dense @ v
        # per-block absmax/127 quantization: error scales with row norms
        bound = (np.abs(dense).max() / 127.0) * np.abs(v).sum()
        assert np.abs(got - ref).max() <= bound
        rel = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-12)
        assert rel < 2e-2, rel

    def test_psum8_fused_grad_ef_identity(self):
        """One compressed fused pass: the returned residual must satisfy
        sent + residual == exact_gradient + old_residual (per shard), so
        iteration-to-iteration nothing is lost to the int8 wire."""
        A, b = _problem(256, 32, seed=4)
        rm = RowMatrix.create(A)
        from repro.core.tfocs.linop import LinopMatrix
        from repro.core.tfocs.smooth import SmoothQuad, row_separable
        lin = LinopMatrix(rm)
        sep = row_separable(SmoothQuad(lin.pad_data(jnp.asarray(b)),
                                       lin.row_weights()))
        x = jnp.zeros((32,), jnp.float32)
        f32 = rm.fused_grad(x, sep)
        res0 = rm.init_psum_residual()
        f8, g8, _, res1 = rm.fused_grad(x, sep, residual=res0)
        # value is exact (not quantized), gradient EF-identity exact
        np.testing.assert_allclose(float(f8), float(f32[0]), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(g8) + np.asarray(res1)[0],
            np.asarray(f32[1]) + np.asarray(res0)[0],
            rtol=1e-5, atol=1e-5)


# The Figure-1 family under forced low precision: each must reach the f32
# reference within ~10× the solve tolerance.  psum8 is only taken by the
# θ ≡ 1 fused engine (gra); the other methods must REPORT the f32 fallback
# and still match exactly as well as their f32 selves.
FAMILY = [
    ("gra", "bf16", "bf16"),
    ("gra", "psum8", "psum8"),
    ("acc_b", "bf16", "bf16"),
    ("acc_b", "psum8", "f32"),
    ("acc_rb", "bf16", "bf16"),
    ("acc_rb", "psum8", "f32"),
    ("lbfgs", "bf16", "bf16"),
    ("lbfgs", "psum8", "f32"),
]


class TestSolverParity:
    @pytest.mark.parametrize("method,precision,expect", FAMILY)
    def test_family_parity(self, method, precision, expect):
        A, b = _problem(seed=5)
        M = RowMatrix.create(A)
        L = float(np.linalg.norm(A, 2) ** 2)
        tol = 1e-5
        kw = dict(loss="quad", tol=tol, max_iters=600, L0=L)
        ref = api.solve(api.SolveRequest(A=M, b=b, method=method, **kw))
        assert ref.info["precision"] == "f32"
        low = api.solve(api.SolveRequest(A=M, b=b, method=method,
                                         precision=precision, **kw))
        assert low.info["precision"] == expect, low.info
        rel = float(jnp.linalg.norm(low.x - ref.x)
                    / jnp.maximum(jnp.linalg.norm(ref.x), 1e-12))
        # the guard scale: bf16 admitted at tol ≥ 1e-5, psum8 at ≥ 1e-6
        assert rel < 100 * tol, (method, precision, rel)

    def test_auto_resolves_and_reports(self):
        """precision="auto" consults the planner and always reports; at a
        tight tolerance it must stay f32."""
        A, b = _problem(seed=6)
        M = RowMatrix.create(A)
        L = float(np.linalg.norm(A, 2) ** 2)
        r = api.solve(api.SolveRequest(A=M, b=b, method="gra", tol=1e-9,
                                       max_iters=50, L0=L))
        assert r.info["precision"] == "f32"

    def test_local_psum8_falls_back(self):
        """A non-distributed operand has no wire to compress."""
        A, b = _problem(seed=7)
        r = api.solve(api.SolveRequest(A=A, b=b, method="gra", tol=1e-5,
                                       max_iters=50,
                                       L0=float(np.linalg.norm(A, 2) ** 2),
                                       precision="psum8"))
        assert r.info["precision"] == "f32"

    def test_bsr_solver_parity_int8(self):
        """Quantized BlockELL operand through the fused solver path."""
        dense = _block_sparse(m=256, n=64, bs=32, density=0.4, seed=8)
        rng = np.random.default_rng(9)
        xs = rng.normal(size=64).astype(np.float32)
        b = (dense @ xs + 0.01 * rng.normal(size=256)).astype(np.float32)
        exact = SparseRowMatrix.from_dense(dense, bs=32)
        quant = SparseRowMatrix.from_dense(dense, bs=32, quantize="int8")
        L = float(np.linalg.norm(dense, 2) ** 2)
        kw = dict(loss="quad", tol=1e-6, max_iters=600, L0=L)
        ref = api.solve(api.SolveRequest(A=exact, b=b, method="acc_b", **kw))
        got = api.solve(api.SolveRequest(A=quant, b=b, method="acc_b", **kw))
        rel = float(jnp.linalg.norm(got.x - ref.x)
                    / jnp.maximum(jnp.linalg.norm(ref.x), 1e-12))
        # int8 storage: the OPERATOR itself is perturbed (guard tol 1e-3),
        # so parity is at the quantization scale, not the solve tolerance.
        assert rel < 5e-2, rel


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8
    from repro import api
    from repro.core.distmat import RowMatrix
    from repro.core.distmat.types import make_mesh

    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    m, n = 264, 24                       # ragged: padding rows per shard
    A = rng.normal(size=(m, n)).astype(np.float32)
    xs = rng.normal(size=n).astype(np.float32)
    b = (A @ xs + 0.01 * rng.normal(size=m)).astype(np.float32)
    L = float(np.linalg.norm(A, 2) ** 2)

    # bf16 storage on a real 8-shard mesh
    lo = RowMatrix.create(A, mesh, store_dtype=jnp.bfloat16)
    ref = A.T @ A
    rel = np.abs(np.asarray(lo.gram()) - ref).max() / np.abs(ref).max()
    assert rel < 2e-2, rel

    M = RowMatrix.create(A, mesh)
    kw = dict(loss="quad", tol=1e-5, max_iters=600, L0=L)
    r0 = api.solve(api.SolveRequest(A=M, b=b, method="gra", **kw))
    assert r0.info["precision"] == "f32"
    for prec in ("bf16", "psum8"):
        r = api.solve(api.SolveRequest(A=M, b=b, method="gra",
                                       precision=prec, **kw))
        assert r.info["precision"] == prec, (prec, r.info)
        rel = float(jnp.linalg.norm(r.x - r0.x)
                    / jnp.maximum(jnp.linalg.norm(r0.x), 1e-12))
        assert rel < 1e-3, (prec, rel)
    print("PRECISION_8DEV_OK")
""")


def test_precision_parity_8dev():
    """The same low-precision paths on a real 8-device host mesh: int8
    psum payloads crossing actual shard boundaries with a pmax-shared
    scale, bf16 shards all-reduced in f32."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MULTIDEV], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PRECISION_8DEV_OK" in out.stdout
