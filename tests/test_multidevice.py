"""Multi-device correctness: the same distmat/model code on a real
8-device (host) mesh, run in a subprocess so the main test process keeps
its single-device view."""
import os
import subprocess
import sys
import textwrap


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8
    from repro.core.distmat import RowMatrix, BlockMatrix, CoordinateMatrix
    from repro.core.distmat.types import make_mesh
    from repro.core.linalg import compute_svd, tsqr

    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    A = rng.normal(size=(37, 11)).astype(np.float32)

    rm = RowMatrix.create(A, mesh)
    np.testing.assert_allclose(rm.gram(), A.T @ A, rtol=1e-3, atol=1e-3)
    v = rng.normal(size=11).astype(np.float32)
    u = rm.matvec(jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(u)[:37], A @ v, rtol=1e-4)
    np.testing.assert_allclose(rm.rmatvec(u), A.T @ (A @ v), rtol=1e-3,
                               atol=1e-3)
    st = rm.column_stats()
    np.testing.assert_allclose(st["mean"], A.mean(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(st["min"], A.min(0), rtol=1e-5)

    res = compute_svd(rm, 4)
    s_np = np.linalg.svd(A, compute_uv=False)
    np.testing.assert_allclose(res.s, s_np[:4], rtol=1e-3)

    Q, R = tsqr(rm)
    np.testing.assert_allclose(np.asarray(Q.to_local()) @ np.asarray(R), A,
                               atol=1e-3)

    B = rng.normal(size=(11, 6)).astype(np.float32)
    bm = BlockMatrix.create(A, mesh)
    bb = BlockMatrix.create(B, mesh)
    bm.validate()
    np.testing.assert_allclose(bm.multiply(bb).to_local(), A @ B,
                               rtol=1e-3, atol=1e-3)

    nnz = 60
    ri = rng.integers(0, 20, nnz); ci = rng.integers(0, 13, nnz)
    va = rng.normal(size=nnz).astype(np.float32)
    D = np.zeros((20, 13), np.float32); np.add.at(D, (ri, ci), va)
    cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                                 jnp.asarray(va), (20, 13), mesh)
    x = rng.normal(size=13).astype(np.float32)
    np.testing.assert_allclose(cm.matvec(jnp.asarray(x)), D @ x, rtol=1e-3,
                               atol=1e-4)
    print("DISTMAT_8DEV_OK")
""")

TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro import configs
    from repro.models import build, smoke_config
    from repro.models.sharding import use_mesh
    from repro.core.distmat.types import make_mesh
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import build_train_step
    from repro.data import pipeline as dp

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = smoke_config(configs.get("qwen3-4b")).scaled(num_layers=2)
    with mesh, use_mesh(mesh):
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ocfg = opt_mod.OptimizerConfig(lr=1e-2, warmup_steps=1,
                                       total_steps=10)
        opt_init, opt_update = opt_mod.make_optimizer(ocfg)
        step = jax.jit(build_train_step(model, opt_update, microbatches=2))
        dc = dp.from_model(cfg, global_batch=4, seq_len=16)
        opt_state = opt_init(params)
        losses = []
        for s in range(6):
            batch = dp.in_graph_batch(dc, 0)   # same batch → must descend
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("TRAIN_8DEV_OK", losses[0], "->", losses[-1])

        # MoE arch with expert parallelism over model axis
        cfg2 = smoke_config(configs.get("deepseek-v2-236b"))
        model2 = build(cfg2)
        params2 = model2.init(jax.random.PRNGKey(1))
        loss, _ = jax.jit(model2.train_loss)(
            params2, dp.in_graph_batch(
                dp.from_model(cfg2, global_batch=4, seq_len=16), 0))
        assert np.isfinite(float(loss))
        print("MOE_8DEV_OK", float(loss))
""")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distmat_on_8_devices():
    assert "DISTMAT_8DEV_OK" in _run(SCRIPT)


def test_training_on_8_devices():
    out = _run(TRAIN_SCRIPT)
    assert "TRAIN_8DEV_OK" in out and "MOE_8DEV_OK" in out
