"""Randomized SVD path (core/linalg/randsvd.py) vs dense oracles, plus
interpret-mode parity for the Pallas randsketch kernel.

Deliberately hypothesis-free so the whole file runs on bare containers
where only the pinned jax toolchain exists."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distmat import RowMatrix
from repro.core.linalg import compute_svd, randomized_svd
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _low_rank_plus_noise(m, n, rank, noise=0.01, decay_from=50.0, decay_to=5.0):
    U = np.linalg.qr(RNG.normal(size=(m, rank)))[0]
    V = np.linalg.qr(RNG.normal(size=(n, rank)))[0]
    s = np.linspace(decay_from, decay_to, rank)
    return ((U * s) @ V.T + noise * RNG.normal(size=(m, n))).astype(np.float32)


def test_randomized_matches_dense_on_low_rank_plus_noise():
    A = _low_rank_plus_noise(2000, 300, rank=20)
    res = compute_svd(RowMatrix.create(A), 10, mode="randomized")
    assert res.info["mode"] == "randomized"
    s_ref = np.linalg.svd(A, compute_uv=False)[:10]
    rel = np.abs(np.asarray(res.s) - s_ref) / s_ref
    assert rel.max() <= 1e-2, rel

    # Truncated reconstruction should match the optimal rank-10 approximant.
    U = np.asarray(res.U.to_local())
    recon = U @ np.diag(np.asarray(res.s)) @ np.asarray(res.V).T
    u, s, vt = np.linalg.svd(A, full_matrices=False)
    best = u[:, :10] @ np.diag(s[:10]) @ vt[:10]
    assert (np.linalg.norm(recon - best, 2) /
            np.linalg.norm(best, 2)) <= 1e-2
    # Left factor is orthonormal (range basis rotated, not A·VΣ⁻¹ recovery).
    np.testing.assert_allclose(U.T @ U, np.eye(10), atol=1e-4)


def test_randomized_agrees_with_gram_on_tall_skinny():
    n = 40
    Q = np.linalg.qr(RNG.normal(size=(500, n)))[0]
    W = np.linalg.qr(RNG.normal(size=(n, n)))[0]
    A = ((Q * np.geomspace(30.0, 0.1, n)) @ W).astype(np.float32)
    rm = RowMatrix.create(A)
    s_gram = np.asarray(compute_svd(rm, 8, mode="gram").s)
    s_rand = np.asarray(compute_svd(rm, 8, mode="randomized").s)
    np.testing.assert_allclose(s_rand, s_gram, rtol=1e-3)


def test_auto_dispatch_three_way():
    A = _low_rank_plus_noise(600, 96, rank=8)
    rm = RowMatrix.create(A)
    # n below the gram threshold → gram wins regardless of k
    assert compute_svd(rm, 4, mode="auto").info["mode"] == "gram"
    # n above the (shrunk) threshold + low k → randomized
    res = compute_svd(rm, 4, mode="auto", gram_threshold=64)
    assert res.info["mode"] == "randomized"
    # n above the threshold + k above the sketch ceiling → lanczos
    res = compute_svd(rm, 24, mode="auto", gram_threshold=64,
                      randomized_k_threshold=16, tol=1e-5, max_restarts=100)
    assert res.info["mode"] == "lanczos"


def test_info_reports_convergence_evidence():
    A = _low_rank_plus_noise(800, 200, rank=10)
    res = compute_svd(RowMatrix.create(A), 5, mode="randomized",
                      oversampling=8, power_iters=3)
    info = res.info
    assert info["rank"] == 13
    assert info["passes_over_A"] == 2 + 2 * 3
    # rank-10 signal, k=5: the oversampled tail still holds real spectrum
    assert 0.0 < float(info["tail_ratio"]) < 1.0


def test_compute_u_false_skips_u():
    A = _low_rank_plus_noise(400, 150, rank=6)
    res = compute_svd(RowMatrix.create(A), 3, mode="randomized",
                      compute_u=False)
    assert res.U is None and res.s.shape == (3,) and res.V.shape == (150, 3)


def test_rowmatrix_sketch_project_shapes_and_seed():
    A = RNG.normal(size=(123, 37)).astype(np.float32)
    rm = RowMatrix.create(A)
    Y1, Y2 = rm.sketch(9, seed=7), rm.sketch(9, seed=7)
    np.testing.assert_array_equal(Y1.to_local(), Y2.to_local())
    assert Y1.shape == (123, 9)
    assert not np.allclose(Y1.to_local(), rm.sketch(9, seed=8).to_local())
    # project(Q) == AᵀQ
    B = rm.project(Y1)
    want = A.T @ np.asarray(Y1.to_local())
    np.testing.assert_allclose(B, want, rtol=1e-4, atol=1e-3)


def test_randomized_svd_direct_api():
    A = _low_rank_plus_noise(500, 120, rank=8)
    U, s, V, info = randomized_svd(RowMatrix.create(A), 4, oversampling=6,
                                   power_iters=2, seed=3)
    s_ref = np.linalg.svd(A, compute_uv=False)[:4]
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-2)
    assert U.shape == (500, 4) and V.shape == (120, 4)
    assert info["seed"] == 3


@pytest.mark.parametrize("m,n,r", [(64, 16, 8), (100, 20, 12),
                                   (256, 130, 24), (33, 7, 3)])
def test_randsketch_kernel_parity(m, n, r):
    a = jnp.asarray(RNG.normal(size=(m, n)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(m, r)), jnp.float32)
    got = ops.randsketch(a, q, bm=16, force_pallas=True)
    want = ref.randsketch_ref(a, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_randsketch_kernel_wide_n_tiling():
    # n much wider than one VMEM strip: bn=128 forces multiple output tiles
    # (the n > GRAM_THRESHOLD regime the randomized mode dispatches to).
    a = jnp.asarray(RNG.normal(size=(64, 1000)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(64, 12)), jnp.float32)
    got = ops.randsketch(a, q, bm=16, bn=128, force_pallas=True)
    want = ref.randsketch_ref(a, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_randsketch_kernel_bf16():
    a = jnp.asarray(RNG.normal(size=(96, 24)), jnp.bfloat16)
    q = jnp.asarray(RNG.normal(size=(96, 8)), jnp.bfloat16)
    got = ops.randsketch(a, q, bm=16, out_dtype=jnp.float32,
                         force_pallas=True)
    want = ref.randsketch_ref(a, q, jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-1)
