# NOTE: deliberately NO --xla_force_host_platform_device_count here —
# unit/smoke tests must see the real (single) device; only the dry-run and
# the dedicated multi-device subprocess tests pin a device count.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
