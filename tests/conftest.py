# NOTE: deliberately NO --xla_force_host_platform_device_count here —
# unit/smoke tests must see the real (single) device; only the dry-run and
# the dedicated multi-device subprocess tests pin a device count.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property-based modules need hypothesis (a [test] extra, installed in CI).
# On bare containers without it, skip those modules at collection instead of
# erroring out the whole run.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    collect_ignore = ["test_distmat.py", "test_kernels.py",
                      "test_linalg.py", "test_moe_properties.py"]
