"""Compute/collective overlap: the chunked (double-buffered) gram and
fused_grad bodies must be BIT-identical to the eager path — the chunks
split columns, and `dot(aᵀ, a[:, seg])` concatenated over segments is the
same float sequence as `dot(aᵀ, a)` — while issuing the segmented psum
structure the planner schedules.  Parity is pinned in-process on one
device and in a subprocess on a real 8-device host mesh."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distmat import RowMatrix, SparseRowMatrix
from repro.core.distmat.rowmatrix import chunk_bounds
from repro.core.tfocs import SmoothQuad, LinopMatrix, row_separable


def _problem(m=96, n=24, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    b = rng.normal(size=m).astype(np.float32)
    rm = RowMatrix.create(jnp.asarray(A))
    linop = LinopMatrix(rm)
    sep = row_separable(SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                                   weights=linop.row_weights()))
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    return A, rm, sep, x


class TestChunkBounds:
    def test_covers_exactly_once(self):
        for n, c in [(24, 4), (24, 1), (7, 3), (5, 8), (1, 1)]:
            bounds = chunk_bounds(n, c)
            assert bounds[0][0] == 0 and bounds[-1][1] == n
            for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
                assert a1 == b0
            assert len(bounds) <= max(min(c, n), 1)


class TestBitParity:
    def test_gram_chunked_matches_eager(self):
        A, rm, _, _ = _problem()
        eager = np.asarray(rm.gram(chunks=1))
        for c in (2, 4, 8):
            assert np.array_equal(np.asarray(rm.gram(chunks=c)), eager), c
        np.testing.assert_allclose(eager, A.T @ A, rtol=1e-4, atol=1e-4)

    def test_fused_grad_chunked_matches_eager(self):
        _, rm, sep, x = _problem()
        f1, g1, z1 = rm.fused_grad(x, sep, chunks=1)
        for c in (2, 4):
            fc, gc, zc = rm.fused_grad(x, sep, chunks=c)
            assert np.array_equal(np.asarray(fc), np.asarray(f1))
            assert np.array_equal(np.asarray(gc), np.asarray(g1))
            assert np.array_equal(np.asarray(zc), np.asarray(z1))

    def test_auto_resolves_to_eager_on_one_device(self):
        """One device → no psum payload worth hiding; the planner must keep
        chunks=1 and auto must equal the explicit eager call bitwise."""
        _, rm, sep, x = _problem()
        assert np.array_equal(np.asarray(rm.gram()),
                              np.asarray(rm.gram(chunks=1)))
        fa, ga, _ = rm.fused_grad(x, sep)
        f1, g1, _ = rm.fused_grad(x, sep, chunks=1)
        assert np.array_equal(np.asarray(ga), np.asarray(g1))
        assert np.array_equal(np.asarray(fa), np.asarray(f1))

    @pytest.mark.parametrize("dispatch", ["bsr", "dense"])
    def test_sparse_fused_grad_chunked_matches_eager(self, dispatch):
        rng = np.random.default_rng(12)
        mask = rng.random((8, 6)) < 0.4
        A = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(64, 48))).astype(np.float32)
        srm = SparseRowMatrix.from_dense(A, bs=8)
        linop = LinopMatrix(srm)
        b = rng.normal(size=64).astype(np.float32)
        sep = row_separable(SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                                       weights=linop.row_weights()))
        x = jnp.asarray(rng.normal(size=48), jnp.float32)
        f1, g1, z1 = srm.fused_grad(x, sep, dispatch=dispatch, chunks=1)
        fc, gc, zc = srm.fused_grad(x, sep, dispatch=dispatch, chunks=4)
        assert np.array_equal(np.asarray(fc), np.asarray(f1))
        assert np.array_equal(np.asarray(gc), np.asarray(g1))
        assert np.array_equal(np.asarray(zc), np.asarray(z1))


class TestPsumStructure:
    """The overlap is real, not cosmetic: the traced program must contain
    one psum per scheduled segment (each a pipelineable partial reduction)
    instead of the eager path's single full-width psum."""

    def test_gram_psum_count(self):
        _, rm, _, _ = _problem()
        eager = str(jax.make_jaxpr(lambda: rm.gram(chunks=1))())
        chunked = str(jax.make_jaxpr(lambda: rm.gram(chunks=4))())
        assert eager.count("psum") == 1
        assert chunked.count("psum") == 4

    def test_fused_grad_psum_count(self):
        _, rm, sep, x = _problem()
        eager = str(jax.make_jaxpr(
            lambda v: rm.fused_grad(v, sep, chunks=1))(x))
        chunked = str(jax.make_jaxpr(
            lambda v: rm.fused_grad(v, sep, chunks=4))(x))
        # eager: one psum for f, one for g; chunked: f + one per segment
        assert eager.count("psum") == 2
        assert chunked.count("psum") == 5


EIGHT_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8
    from repro.core.distmat import RowMatrix, SparseRowMatrix
    from repro.core.distmat.types import make_mesh
    from repro.core.tfocs import SmoothQuad, LinopMatrix, row_separable

    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    A = rng.normal(size=(96, 24)).astype(np.float32)
    b = rng.normal(size=96).astype(np.float32)
    rm = RowMatrix.create(jnp.asarray(A), mesh)
    linop = LinopMatrix(rm)
    sep = row_separable(SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                                   weights=linop.row_weights()))
    x = jnp.asarray(rng.normal(size=24), jnp.float32)

    eager = np.asarray(rm.gram(chunks=1))
    for c in (2, 4):
        assert np.array_equal(np.asarray(rm.gram(chunks=c)), eager), c
    np.testing.assert_allclose(eager, A.T @ A, rtol=1e-3, atol=1e-3)

    f1, g1, z1 = rm.fused_grad(x, sep, chunks=1)
    fc, gc, zc = rm.fused_grad(x, sep, chunks=4)
    assert np.array_equal(np.asarray(fc), np.asarray(f1))
    assert np.array_equal(np.asarray(gc), np.asarray(g1))
    assert np.array_equal(np.asarray(zc), np.asarray(z1))

    mask = rng.random((8, 6)) < 0.4
    S = (np.kron(mask, np.ones((8, 8)))
         * rng.normal(size=(64, 48))).astype(np.float32)
    srm = SparseRowMatrix.from_dense(S, bs=8, mesh=mesh)
    sl = LinopMatrix(srm)
    bs = rng.normal(size=64).astype(np.float32)
    seps = row_separable(SmoothQuad(b=sl.pad_data(jnp.asarray(bs)),
                                    weights=sl.row_weights()))
    xs = jnp.asarray(rng.normal(size=48), jnp.float32)
    for dispatch in ("bsr", "dense"):
        f1, g1, z1 = srm.fused_grad(xs, seps, dispatch=dispatch, chunks=1)
        fc, gc, zc = srm.fused_grad(xs, seps, dispatch=dispatch, chunks=4)
        assert np.array_equal(np.asarray(fc), np.asarray(f1)), dispatch
        assert np.array_equal(np.asarray(gc), np.asarray(g1)), dispatch
        assert np.array_equal(np.asarray(zc), np.asarray(z1)), dispatch
    print("OVERLAP_8DEV_OK")
""")


def test_overlap_parity_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", EIGHT_DEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OVERLAP_8DEV_OK" in out.stdout
