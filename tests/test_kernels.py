"""Pallas kernels in interpret mode vs the pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bsr import BlockELL

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (40, 70, 50),
                                   (128, 256, 128), (8, 130, 129)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("bm,bn,bk", [(16, 128, 128), (32, 256, 256)])
def test_gemm_sweep(m, k, n, dtype, bm, bn, bk):
    a = jnp.asarray(RNG.normal(size=(m, k)), dtype)
    b = jnp.asarray(RNG.normal(size=(k, n)), dtype)
    got = ops.gemm(a, b, bm=bm, bn=bn, bk=bk, force_pallas=True,
                   out_dtype=jnp.float32)
    want = ref.gemm_ref(a, b, jnp.float32)
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * k)


def test_gemm_autotuned_default_parity():
    """tune="auto" (no explicit tiles) matches the oracle too."""
    a = jnp.asarray(RNG.normal(size=(72, 130)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(130, 66)), jnp.float32)
    got = ops.gemm(a, b, force_pallas=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(got, ref.gemm_ref(a, b, jnp.float32),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("m,n", [(64, 16), (100, 20), (256, 32), (33, 7)])
@pytest.mark.parametrize("bm", [16, 64])
def test_tsgram_sweep(m, n, bm):
    a = jnp.asarray(RNG.normal(size=(m, n)), jnp.float32)
    got = ops.tsgram(a, bm=bm, force_pallas=True)
    np.testing.assert_allclose(got, ref.tsgram_ref(a), rtol=1e-4,
                               atol=1e-3)


@given(st.integers(10, 200), st.integers(2, 40), st.integers(1, 24))
@settings(max_examples=8, deadline=None)
def test_randsketch_property(m, n, r):
    rng = np.random.default_rng(m * 1000 + n * 10 + r)
    a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    got = ops.randsketch(a, q, bm=16, force_pallas=True)
    np.testing.assert_allclose(got, ref.randsketch_ref(a, q), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("bm,bn", [(16, 128), (40, 256)])
def test_randsketch_tile_configs(bm, bn):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.normal(size=(120, 150)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(120, 11)), jnp.float32)
    got = ops.randsketch(a, q, bm=bm, bn=bn, force_pallas=True)
    np.testing.assert_allclose(got, ref.randsketch_ref(a, q), rtol=1e-4,
                               atol=1e-3)


@given(st.integers(1, 6), st.integers(1, 6), st.floats(0.1, 0.9))
@settings(max_examples=8, deadline=None)
def test_bsr_property(bm, bn, density):
    rng = np.random.default_rng(int(bm * 100 + bn * 10 + density * 7))
    mask = rng.random((bm, bn)) < density
    dense = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(bm * 8, bn * 8))).astype(np.float32)
    bell = BlockELL.from_dense(dense, bs=8)
    np.testing.assert_allclose(bell.to_dense(), dense, atol=1e-6)
    x = rng.normal(size=(bn * 8, 16)).astype(np.float32)
    got = ops.bsr_matmul(bell, jnp.asarray(x), force_pallas=True)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-3)


@given(st.integers(1, 6), st.integers(1, 6), st.floats(0.1, 0.9))
@settings(max_examples=8, deadline=None)
def test_bsr_matvec_property(bm, bn, density):
    rng = np.random.default_rng(int(bm * 90 + bn * 9 + density * 11))
    mask = rng.random((bm, bn)) < density
    dense = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(bm * 8, bn * 8))).astype(np.float32)
    bell = BlockELL.from_dense(dense, bs=8)
    x = rng.normal(size=(bn * 8,)).astype(np.float32)
    got = ops.bsr_matvec(bell, jnp.asarray(x), force_pallas=True)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(ops.bsr_matvec(bell, jnp.asarray(x)),
                               dense @ x, rtol=1e-4, atol=1e-3)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 20))
@settings(max_examples=8, deadline=None)
def test_bsr_rmatmul_property(bm, bn, nx):
    rng = np.random.default_rng(bm * 77 + bn * 7 + nx)
    mask = rng.random((bm, bn)) < 0.5
    dense = (np.kron(mask, np.ones((8, 8)))
             * rng.normal(size=(bm * 8, bn * 8))).astype(np.float32)
    bell = BlockELL.from_dense(dense, bs=8)
    x = rng.normal(size=(bm * 8, nx)).astype(np.float32)
    got = ops.bsr_rmatmul(bell, jnp.asarray(x), force_pallas=True)
    np.testing.assert_allclose(got, dense.T @ x, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(ops.bsr_rmatmul(bell, jnp.asarray(x)),
                               dense.T @ x, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,hq,hkv,S,D", [
    (1, 2, 2, 64, 16),        # MHA
    (2, 4, 2, 64, 16),        # GQA 2:1
    (1, 8, 2, 128, 32),       # GQA 4:1
])
@pytest.mark.parametrize("bq,bk", [(16, 128), (32, 256)])
def test_flash_attention_sweep(B, hq, hkv, S, D, bq, bk):
    q = jnp.asarray(RNG.normal(size=(B, hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, hkv, S, D)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              force_pallas=True)
    want = ref.flash_attention_ref(
        q.reshape(B * hq, S, D), k.reshape(B * hkv, S, D),
        v.reshape(B * hkv, S, D), causal=True,
        q_heads_per_kv=hq // hkv).reshape(B, hq, S, D)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=3e-4)


def test_flash_attention_uneven_seq():
    B, H, S, D = 1, 2, 50, 16
    q, k, v = (jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
               for _ in range(3))
    got = ops.flash_attention(q, k, v, causal=True, bq=16, bk=128,
                              force_pallas=True)
    want = ref.flash_attention_ref(q[0], k[0], v[0], causal=True)[None]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=3e-4)


def test_flash_attention_bf16():
    B, H, S, D = 1, 2, 64, 32
    q, k, v = (jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.bfloat16)
               for _ in range(3))
    got = ops.flash_attention(q, k, v, causal=True, bq=16, bk=128,
                              force_pallas=True)
    want = ref.flash_attention_ref(q[0], k[0], v[0], causal=True)[None]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=5e-2)


def test_cpu_dispatch_no_force():
    """Without force_pallas on CPU the wrappers route to the reference."""
    a = jnp.asarray(RNG.normal(size=(12, 9)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(9, 5)), jnp.float32)
    np.testing.assert_allclose(ops.gemm(a, b), ref.gemm_ref(a, b),
                               rtol=1e-6)


@pytest.mark.parametrize("Bt,S,d,N,q", [(1, 32, 128, 16, 16),
                                        (2, 64, 96, 16, 16),
                                        (2, 64, 96, 16, 32),
                                        (1, 50, 70, 8, 16)])
def test_selective_scan_sweep(Bt, S, d, N, q):
    """Fused Mamba1 scan kernel (the §Perf-A kernel) vs sequential oracle."""
    rng = np.random.default_rng(Bt * 100 + S)
    x = jnp.asarray(rng.normal(size=(Bt, S, d)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(Bt, S, d))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(d, N))) - 0.1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(Bt, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(Bt, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    got = ops.selective_scan(x, dt, A, B, C, D, q=q, force_pallas=True)
    want = ref.selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_selective_scan_matches_mamba1_inner():
    """The kernel computes the same recurrence as the production
    (chunked associative-scan) path in models/ssm.py."""
    from repro.models import ssm as SSM
    rng = np.random.default_rng(7)
    Bt, S, di, N, dt_rank = 2, 32, 64, 16, 8
    x = jnp.asarray(rng.normal(size=(Bt, S, di)), jnp.float32)
    p = {
        "x_proj": jnp.asarray(rng.normal(size=(di, dt_rank + 2 * N)) * 0.1,
                              jnp.float32),
        "dt_proj": jnp.asarray(rng.normal(size=(dt_rank, di)) * 0.1,
                               jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.asarray(np.log(np.tile(np.arange(1, N + 1), (di, 1))),
                             jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
    }
    h0 = jnp.zeros((Bt, di, N), jnp.float32)
    y_prod, _ = SSM._mamba1_inner(p, x, dt_rank, N, h0, chunk=8)
    # reconstruct the kernel inputs exactly as _mamba1_inner does
    dtBC = x @ p["x_proj"]
    dtr, Bm, Cm = jnp.split(dtBC, [dt_rank, dt_rank + N], -1)
    dt = jax.nn.softplus(dtr @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y_kern = ops.selective_scan(x, dt, A, Bm, Cm, p["D"], q=16,
                                force_pallas=True)
    np.testing.assert_allclose(y_kern, y_prod, rtol=1e-3, atol=1e-3)
