"""Gradient compression: exactness properties + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (init_error_feedback, lowrank_compressor,
                                     int8_compressor, compression_ratio)


def _grads(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}


def test_lowrank_exact_when_rank_sufficient():
    rng = np.random.default_rng(1)
    U = rng.normal(size=(64, 4)).astype(np.float32)
    V = rng.normal(size=(32, 4)).astype(np.float32)
    g = {"w": jnp.asarray(U @ V.T)}
    comp = lowrank_compressor(rank=8)
    ef = init_error_feedback(g)
    out, ef2 = comp(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), U @ V.T, atol=1e-3)
    assert float(jnp.abs(ef2.residual["w"]).max()) < 1e-3


def test_error_feedback_preserves_signal():
    """Σ_t compressed_t + final residual == Σ_t grads (EF identity)."""
    comp = int8_compressor(seed=0)
    g = _grads()
    ef = init_error_feedback(g)
    total_sent = jax.tree.map(jnp.zeros_like, g)
    total_true = jax.tree.map(jnp.zeros_like, g)
    for t in range(10):
        gt = jax.tree.map(lambda x: x * (0.9 ** t), g)
        sent, ef = comp(gt, ef)
        total_sent = jax.tree.map(lambda a, s: a + s.astype(jnp.float32),
                                  total_sent, sent)
        total_true = jax.tree.map(lambda a, s: a + s, total_true, gt)
    # EF: sent-so-far + residual == true-so-far exactly
    recon = jax.tree.map(lambda s, r: s + r, total_sent, ef.residual)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(total_true)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_int8_bounded_error():
    comp = int8_compressor()
    g = _grads(2)
    out, ef = comp(g, init_error_feedback(g))
    for k in g:
        scale = float(jnp.abs(g[k]).max()) / 127.0
        err = float(jnp.abs(out[k] - g[k]).max())
        assert err <= scale * 1.0 + 1e-6


def test_small_tensors_passthrough():
    comp = lowrank_compressor(rank=8)
    g = {"b": jnp.ones((5,), jnp.float32)}
    out, _ = comp(g, init_error_feedback(g))
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones(5))


def test_compression_ratio():
    g = _grads()
    r = compression_ratio(g, rank=8)
    want = (8 * (64 + 32) + 32) / (64 * 32 + 32)
    assert abs(r - want) < 1e-6
