"""Gradient compression: exactness properties + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (init_error_feedback, lowrank_compressor,
                                     int8_compressor, compression_ratio,
                                     psum_int8)


def _grads(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}


def test_lowrank_exact_when_rank_sufficient():
    rng = np.random.default_rng(1)
    U = rng.normal(size=(64, 4)).astype(np.float32)
    V = rng.normal(size=(32, 4)).astype(np.float32)
    g = {"w": jnp.asarray(U @ V.T)}
    comp = lowrank_compressor(rank=8)
    ef = init_error_feedback(g)
    out, ef2 = comp(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), U @ V.T, atol=1e-3)
    assert float(jnp.abs(ef2.residual["w"]).max()) < 1e-3


def test_error_feedback_preserves_signal():
    """Σ_t compressed_t + final residual == Σ_t grads (EF identity)."""
    comp = int8_compressor(seed=0)
    g = _grads()
    ef = init_error_feedback(g)
    total_sent = jax.tree.map(jnp.zeros_like, g)
    total_true = jax.tree.map(jnp.zeros_like, g)
    for t in range(10):
        gt = jax.tree.map(lambda x: x * (0.9 ** t), g)
        sent, ef = comp(gt, ef)
        total_sent = jax.tree.map(lambda a, s: a + s.astype(jnp.float32),
                                  total_sent, sent)
        total_true = jax.tree.map(lambda a, s: a + s, total_true, gt)
    # EF: sent-so-far + residual == true-so-far exactly
    recon = jax.tree.map(lambda s, r: s + r, total_sent, ef.residual)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(total_true)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_int8_bounded_error():
    comp = int8_compressor()
    g = _grads(2)
    out, ef = comp(g, init_error_feedback(g))
    for k in g:
        scale = float(jnp.abs(g[k]).max()) / 127.0
        err = float(jnp.abs(out[k] - g[k]).max())
        assert err <= scale * 1.0 + 1e-6


def test_small_tensors_passthrough():
    comp = lowrank_compressor(rank=8)
    g = {"b": jnp.ones((5,), jnp.float32)}
    out, _ = comp(g, init_error_feedback(g))
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones(5))


def test_compression_ratio():
    g = _grads()
    r = compression_ratio(g, rank=8)
    want = (8 * (64 + 32) + 32) / (64 * 32 + 32)
    assert abs(r - want) < 1e-6


# -- non-f32 leaves (the compressors ship in the leaf dtype, EF stays f32) ----

def _bf16_grads(seed=3):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(size=(32,)), jnp.bfloat16)}


def test_lowrank_bf16_leaves_residual_vs_sent():
    """The residual must be measured against what was actually SENT (the
    leaf-dtype cast of the approximation), so the EF identity holds in f32
    exactly even when the wire truncates to bf16."""
    comp = lowrank_compressor(rank=4)
    g = _bf16_grads()
    ef = init_error_feedback(g)
    out, ef2 = comp(g, ef)
    assert out["w"].dtype == jnp.bfloat16
    assert ef2.residual["w"].dtype == jnp.float32
    # EF identity: sent + residual == corrected gradient, exactly in f32.
    recon = (out["w"].astype(jnp.float32)
             + ef2.residual["w"].astype(jnp.float32))
    want = g["w"].astype(jnp.float32) + ef.residual["w"]
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(want))


def test_int8_bf16_leaves_ef_identity():
    comp = int8_compressor(seed=1)
    g = _bf16_grads(4)
    ef = init_error_feedback(g)
    out, ef2 = comp(g, ef)
    for k in g:
        assert ef2.residual[k].dtype == jnp.float32
        recon = (np.asarray(out[k], np.float32)
                 + np.asarray(ef2.residual[k]))
        want = (np.asarray(g[k], np.float32) + np.asarray(ef.residual[k]))
        np.testing.assert_allclose(recon, want, rtol=1e-6, atol=1e-6)


# -- compressed psum wire (psum_int8) -----------------------------------------

def test_psum_int8_local_round_trip():
    """Degenerate (no named axes) wire: out + new_res == x + res exactly —
    the EF identity that makes the quantization bias vanish over
    iterations."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(256,)) * 30.0, jnp.float32)
    res = jnp.asarray(rng.normal(size=(256,)) * 0.1, jnp.float32)
    out, new_res = psum_int8(x, res, (), 1)
    np.testing.assert_array_equal(np.asarray(out + new_res),
                                  np.asarray(x + res))
    # Deterministic rounding error bounded by half a quantization step.
    scale = float(jnp.abs(x + res).max()) / 127.0
    assert float(jnp.abs(out - (x + res)).max()) <= 0.5 * scale + 1e-7


def test_psum_int8_ef_telescopes():
    """Σ_t out_t + res_final == Σ_t x_t (+ res_0): nothing is lost to the
    wire across iterations."""
    rng = np.random.default_rng(6)
    res = jnp.zeros((128,), jnp.float32)
    total_out = jnp.zeros((128,), jnp.float32)
    total_x = jnp.zeros((128,), jnp.float32)
    for t in range(12):
        x = jnp.asarray(rng.normal(size=(128,)) * (0.8 ** t), jnp.float32)
        out, res = psum_int8(x, res, (), 1)
        total_out = total_out + out
        total_x = total_x + x
    np.testing.assert_allclose(np.asarray(total_out + res),
                               np.asarray(total_x), rtol=1e-5, atol=1e-5)


def test_psum_int8_distributed_round_trip():
    """The real wire: shard_map over a named axis — int8 payloads summed
    across shards with a shared pmax scale must reproduce the f32 psum
    within the per-shard quantization bound, and the EF identity must hold
    per shard."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("data",))
    nshards = 1
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(nshards, 64)) * 10.0, jnp.float32)
    res = jnp.zeros((nshards, 64), jnp.float32)

    @jax.jit
    def run(x, res):
        def body(xs, rs):
            out, nr = psum_int8(xs[0], rs[0], ("data",), nshards)
            return out[None], nr[None]
        return shard_map(body, mesh=mesh,
                         in_specs=(P("data", None), P("data", None)),
                         out_specs=(P("data", None), P("data", None)))(x, res)

    out, new_res = run(x, res)
    true_sum = np.asarray(x).sum(0)
    qmax = max(127 // nshards, 1)
    scale = np.abs(np.asarray(x)).max() / qmax
    err = np.abs(np.asarray(out)[0] - true_sum).max()
    assert err <= 0.5 * scale * nshards + 1e-6
    # per-shard EF identity: q·scale + new_res == x + res
    sent = np.asarray(out)[0]          # single shard: psum == own payload
    np.testing.assert_allclose(sent + np.asarray(new_res)[0],
                               np.asarray(x)[0] + np.asarray(res)[0],
                               rtol=1e-6, atol=1e-6)
