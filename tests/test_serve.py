"""Solver serving frontend: batched-group parity with sequential solves,
structural A-pass sharing (a k-request group costs the passes of one),
continuous-batching slot churn, and planner-priced admission control."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.distmat import RowMatrix
from repro.core.distmat import types as T
from repro.core.tfocs import CountingLinop, LinopMatrix
from repro.launch import planner
from repro.launch.serve import (GroupRunner, SolverServer, batchable,
                                group_key)


def _meshes():
    yield None                                     # local / single-device
    if jax.device_count() > 1:                     # CI forces 8 hosts
        yield T.make_mesh((jax.device_count(), 1), ("data", "model"))


def _trace(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n)).astype(np.float32)
    bs = [(A @ rng.normal(size=n) + 0.01 * rng.normal(size=m))
          .astype(np.float32) for _ in range(k)]
    return A, bs


def _request(A, b, method="gra", **kw):
    kw.setdefault("tol", 1e-7)
    kw.setdefault("max_iters", 400)
    return api.SolveRequest(A=A, b=b, loss="quad", method=method, **kw)


class TestGroupParity:
    @pytest.mark.parametrize("method", ["gra", "acc_rb", "lbfgs"])
    def test_group_matches_sequential(self, method):
        """A k-request group solve reaches the same solutions as k
        sequential single-request solves on the same engine — on the local
        path and on a multi-device mesh.  (Trajectories may differ in
        float summation order between slot widths, so parity is asserted
        at convergence, not per-iterate.)"""
        m, n, k = 131, 16, 4                       # ragged: padding rows
        A, bs = _trace(m, n, k)
        for mesh in _meshes():
            mat = RowMatrix.create(jnp.asarray(A), mesh) if mesh is not None \
                else A
            grouped = SolverServer(slots=k)
            ids = [grouped.submit(_request(mat, b, method)) for b in bs]
            grouped.run()
            serial = SolverServer(slots=1)
            sids = [serial.submit(_request(mat, b, method)) for b in bs]
            serial.run()
            for rid, sid in zip(ids, sids):
                g, s = grouped.result(rid), serial.result(sid)
                assert g is not None and s is not None
                assert g.info["plan"] == "fused-group"
                err = float(jnp.max(jnp.abs(g.x - s.x)))
                assert err < 1e-4, (method, mesh, err)

    def test_group_solutions_correct(self):
        """Group answers match the least-squares truth, all slots."""
        m, n, k = 120, 12, 5
        A, bs = _trace(m, n, k, seed=3)
        srv = SolverServer(slots=k)
        ids = [srv.submit(_request(A, b)) for b in bs]
        srv.run()
        for rid, b in zip(ids, bs):
            r = srv.result(rid)
            ref = np.linalg.lstsq(A, b, rcond=None)[0]
            assert r.info["converged"]
            assert float(np.max(np.abs(np.asarray(r.x) - ref))) < 1e-3
            for key in ("iterations", "a_passes", "converged", "plan"):
                assert key in r.info

    def test_residents_unaffected_by_slot_churn(self):
        """Per-slot rows are computed independently inside the fused
        multi-RHS pass, so a resident's trajectory is bit-identical whether
        its neighbours retire/admit around it or not."""
        m, n = 96, 8
        A, bs = _trace(m, n, 3, seed=5)
        quiet = SolverServer(slots=3)
        qid = quiet.submit(_request(A, bs[0], max_iters=60, tol=0.0))
        quiet.run()
        churn = SolverServer(slots=3)
        cid = churn.submit(_request(A, bs[0], max_iters=60, tol=0.0))
        # Neighbours arrive and leave while the watched request runs.
        churn.submit(_request(A, bs[1], max_iters=5, tol=0.0))
        for _ in range(10):
            churn.step()
        churn.submit(_request(A, bs[2], max_iters=5, tol=0.0))
        churn.run()
        np.testing.assert_array_equal(np.asarray(quiet.result(qid).x),
                                      np.asarray(churn.result(cid).x))


class TestAPassSharing:
    def _run_group(self, A, bs, reqs_per_group, iters):
        """Serve len(bs) requests in groups of `reqs_per_group` through one
        CountingLinop-wrapped runner; returns (trace sites, runtime passes)."""
        lin = CountingLinop(LinopMatrix(jnp.asarray(A)))
        runner = GroupRunner(lin, "quad", slots=max(reqs_per_group, 1))
        for start in range(0, len(bs), reqs_per_group):
            for b in bs[start:start + reqs_per_group]:
                runner.admit(api.SolveRequest(A=A, b=b, loss="quad",
                                              tol=0.0, max_iters=iters))
            while runner.busy():
                runner.step()
        return lin.counts["fused_grad_multi"], runner.a_passes

    def test_group_passes_equal_single_request_passes(self):
        """THE acceptance property: a shared-A group of k requests consumes
        exactly as many A-passes per iteration as a single request.

        Exactly, in two senses: the trace-level call sites are identical
        for any group width (structural — one fused pass per attempt), and
        for k requests with identical backtracking behaviour (same b) the
        runtime attempt counts are equal too.  With DISTINCT right-hand
        sides a shared attempt runs whenever ANY slot still fails, so the
        group pays the worst member's backtracks — still k× cheaper than
        the serial schedule, which is the last assertion."""
        m, n, iters = 97, 12, 8
        A, bs = _trace(m, n, 4, seed=7)
        sites_1, passes_1 = self._run_group(A, bs[:1], 1, iters)
        sites_k, passes_k = self._run_group(A, [bs[0]] * 4, 4, iters)
        assert sites_k == sites_1
        assert passes_k == passes_1
        # Distinct b's: same call sites; runtime passes bounded by the
        # worst single member, and far below the serial sum.
        singles = [self._run_group(A, [b], 1, iters)[1] for b in bs]
        sites_d, passes_d = self._run_group(A, bs, 4, iters)
        assert sites_d == sites_1
        assert passes_d <= sum(singles) - (len(bs) - 1) * iters
        assert max(singles) <= passes_d
        assert sum(singles) > 2 * passes_d

    def test_acc_group_shares_passes(self):
        """The accelerated group keeps the pass-sharing economics: for a
        k-request group every iteration is still one fused multi-RHS pass
        (plus the 3-pass admission seed), identical call sites for any
        group width."""
        m, n, iters = 97, 12, 8
        A, bs = _trace(m, n, 4, seed=21)

        def run(width, rhs):
            lin = CountingLinop(LinopMatrix(jnp.asarray(A)))
            runner = GroupRunner(lin, "quad", method="acc_rb",
                                 slots=max(width, 1))
            for b in rhs:
                runner.admit(api.SolveRequest(A=A, b=b, loss="quad",
                                              method="acc_rb", tol=0.0,
                                              max_iters=iters))
            while runner.busy():
                runner.step()
            return lin.counts["fused_grad_multi"], runner.a_passes

        sites_1, passes_1 = run(1, bs[:1])
        sites_k, passes_k = run(4, [bs[0]] * 4)
        assert sites_k == sites_1
        assert passes_k == passes_1
        singles = [run(1, [b])[1] for b in bs]
        _, passes_d = run(4, bs)
        assert sum(singles) > 2 * passes_d

    def test_counting_linop_sees_no_unfused_calls(self):
        A, bs = _trace(64, 8, 2, seed=9)
        lin = CountingLinop(LinopMatrix(jnp.asarray(A)))
        runner = GroupRunner(lin, "quad", slots=2)
        for b in bs:
            runner.admit(api.SolveRequest(A=A, b=b, loss="quad",
                                          tol=0.0, max_iters=3))
        while runner.busy():
            runner.step()
        assert lin.counts["apply"] == lin.counts["adjoint"] == 0
        assert lin.counts["fused_grad"] == 0
        assert lin.counts["fused_grad_multi"] > 0


class TestScheduler:
    def test_admission_respects_budget(self):
        """Two groups (distinct matrices) under a budget that fits one:
        the second is deferred until the first drains."""
        m, n = 96, 16
        A1, bs1 = _trace(m, n, 1, seed=11)
        A2, bs2 = _trace(m, n, 1, seed=12)
        cost = planner.plan("fusedgrad", {"m": m, "n": n}).cost_s
        srv = SolverServer(slots=4, budget_s=cost * 1.5)
        i1 = srv.submit(_request(A1, bs1[0]))
        i2 = srv.submit(_request(A2, bs2[0]))
        srv.step()
        assert srv.pending() == 1                  # group 2 deferred
        assert srv.stats["deferred_steps"] >= 1
        srv.run()
        assert srv.result(i1) is not None and srv.result(i2) is not None
        assert [e[0] for e in srv._events] == [i1, i2]

    def test_joining_active_group_is_free(self):
        """Budget fits ONE group, yet every request sharing that group's
        matrix is admitted immediately — the same fused pass serves them."""
        m, n, k = 96, 16, 4
        A, bs = _trace(m, n, k, seed=13)
        cost = planner.plan("fusedgrad", {"m": m, "n": n}).cost_s
        srv = SolverServer(slots=k, budget_s=cost * 1.1)
        for b in bs:
            srv.submit(_request(A, b))
        srv.step()
        assert srv.pending() == 0                  # all co-admitted
        srv.run()
        assert len(srv._events) == k

    def test_retirement_frees_slots_mid_solve(self):
        """With 2 slots and 3 requests, the third waits for a retirement,
        then takes the freed slot — and still gets the right answer."""
        m, n = 120, 12
        A, bs = _trace(m, n, 3, seed=15)
        srv = SolverServer(slots=2)
        ids = [srv.submit(_request(A, b)) for b in bs]
        srv.step()
        assert srv.pending() == 1                  # no slot yet for #3
        runner = next(iter(srv._runners.values()))
        assert runner.free_slots() == 0
        srv.run()
        for rid, b in zip(ids, bs):
            r = srv.result(rid)
            ref = np.linalg.lstsq(A, b, rcond=None)[0]
            assert float(np.max(np.abs(np.asarray(r.x) - ref))) < 1e-3
        # The late arrival could only start after a retirement freed its
        # slot, so it cannot finish first.
        assert [e[0] for e in srv._events][0] != ids[2]

    def test_fifo_fairness_under_overload(self):
        """More distinct-matrix groups than the budget admits at once:
        completion order equals arrival order — later arrivals cannot
        starve the deferred head."""
        m, n = 64, 8
        cost = planner.plan("fusedgrad", {"m": m, "n": n}).cost_s
        srv = SolverServer(slots=2, budget_s=cost * 1.5)
        ids = []
        for seed in range(4):
            A, bs = _trace(m, n, 1, seed=20 + seed)
            ids.append(srv.submit(_request(A, bs[0])))
        srv.run()
        assert [e[0] for e in srv._events] == ids

    def test_lbfgs_with_reg_rejected_at_submit(self):
        A, bs = _trace(32, 4, 1)
        srv = SolverServer()
        with pytest.raises(ValueError):
            srv.submit(api.SolveRequest(A=A, b=bs[0], loss="quad",
                                        method="lbfgs", reg="l1", lam=0.1))

    def test_mixed_queue_oneshots(self):
        """SVD / similarity / non-batchable solves ride the same FIFO
        queue as one-shot jobs and return standardized Results."""
        m, n = 96, 12
        A, bs = _trace(m, n, 1, seed=17)
        R = RowMatrix.create(jnp.asarray(A))
        srv = SolverServer(slots=2)
        s0 = srv.submit(_request(A, bs[0]))
        s1 = srv.submit(api.SvdRequest(A=R, k=3))
        s2 = srv.submit(api.SimilarityRequest(A=R))
        # Non-quadratic accelerated request: no affine u-vector trick, so
        # it rides the queue as a one-shot job.
        y = np.sign(bs[0]).astype(np.float32)
        s3 = srv.submit(api.SolveRequest(A=A, b=y, loss="logistic",
                                         method="acc_rb", max_iters=80))
        res = srv.run()
        assert len(res) == 4
        sv = np.linalg.svd(A, compute_uv=False)[:3]
        got = np.asarray(srv.result(s1).factors[1])
        np.testing.assert_allclose(got, sv, rtol=1e-3, atol=1e-3)
        assert srv.result(s2).factors[0].shape == (n, n)
        assert srv.result(s3).info["iterations"] > 0
        assert srv.stats["oneshot"] == 3
        for rid in (s0, s1, s2, s3):
            info = srv.result(rid).info
            for key in ("iterations", "a_passes", "converged", "plan"):
                assert key in info, (rid, key)

    def test_batchable_and_group_key(self):
        A, bs = _trace(32, 4, 2)
        r1, r2 = _request(A, bs[0]), _request(A, bs[1])
        assert batchable(r1) and group_key(r1) == group_key(r2)
        assert not batchable(api.SvdRequest(A=A, k=2))
        # Quadratic accelerated requests batch (affine u-vector engine);
        # non-quadratic ones cannot.
        assert batchable(_request(A, bs[0], method="acc"))
        assert not batchable(api.SolveRequest(
            A=A, b=np.sign(bs[0]).astype(np.float32), loss="logistic",
            method="acc_rb"))
        r3 = _request(A, bs[0])
        r3 = api.SolveRequest(A=A, b=bs[0], loss="huber", param=0.5)
        assert group_key(r3) != group_key(r1)

    def test_l1_group_lambda_per_slot(self):
        """Requests with the same reg KIND but different lam share a group
        (lam is per-slot in the batched prox) — and each gets its own
        shrinkage."""
        m, n = 120, 10
        A, bs = _trace(m, n, 1, seed=19)
        srv = SolverServer(slots=2)
        lo = srv.submit(api.SolveRequest(A=A, b=bs[0], loss="quad", reg="l1",
                                         lam=1e-4, tol=1e-7, max_iters=400))
        hi = srv.submit(api.SolveRequest(A=A, b=bs[0], loss="quad", reg="l1",
                                         lam=5.0, tol=1e-7, max_iters=400))
        srv.run()
        assert len(srv._runners) == 1              # one shared group
        x_lo = np.asarray(srv.result(lo).x)
        x_hi = np.asarray(srv.result(hi).x)
        assert np.sum(np.abs(x_hi)) < np.sum(np.abs(x_lo))


class TestApiFacade:
    def test_minimize_wrapper_matches_core(self):
        from repro.core.optim import make_problem
        from repro.core.optim import minimize as core_minimize
        p = make_problem("linear", m=80, n=16, seed=2)
        for method in ("gra", "acc_rb", "lbfgs"):
            x1, i1 = core_minimize(p, method, max_iters=25)
            x2, i2 = api.minimize(p, method, max_iters=25, tol=1e-10)
            np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
            for key in ("iterations", "a_passes", "converged", "plan"):
                assert key in i2, (method, key)

    def test_solver_info_deprecated_aliases_survive(self):
        from repro.core.optim import make_problem
        p = make_problem("linear", m=80, n=16, seed=2)
        _, info = api.minimize(p, "gra", max_iters=10)
        assert "fused" in info and "n_backtracks" in info   # old keys
        _, info = api.minimize(p, "lbfgs", max_iters=10)
        assert "n_evals" in info                            # old key

    @pytest.mark.parametrize("mode", ["gram", "lanczos", "randomized"])
    def test_svd_info_standardized(self, mode):
        rng = np.random.default_rng(4)
        A = RowMatrix.create(jnp.asarray(
            rng.normal(size=(96, 24)).astype(np.float32)))
        U, s, V, info = api.compute_svd(A, 3, mode=mode)
        for key in ("iterations", "a_passes", "converged", "plan"):
            assert key in info, (mode, key)
        assert info["plan"] == mode
        sv = np.linalg.svd(np.asarray(A.to_local())[:96],
                           compute_uv=False)[:3]
        # rtol covers the sketch error of the randomized mode on a flat
        # Gaussian spectrum; gram/lanczos are far tighter.
        np.testing.assert_allclose(np.asarray(s), sv, rtol=5e-2)

    def test_solve_request_validation(self):
        A = np.eye(4, dtype=np.float32)
        b = np.ones(4, np.float32)
        with pytest.raises(ValueError):
            api.SolveRequest(A=A, b=b, loss="hinge")
        with pytest.raises(ValueError):
            api.SolveRequest(A=A, b=b, reg="l3")
        with pytest.raises(ValueError):
            api.SolveRequest(A=A)
        with pytest.raises(ValueError):
            api.solve(api.SolveRequest(A=A, b=b, method="lbfgs",
                                       reg="l1", lam=0.1))

    def test_column_similarities_wrapper(self):
        rng = np.random.default_rng(6)
        A = rng.normal(size=(64, 8)).astype(np.float32)
        R = RowMatrix.create(jnp.asarray(A))
        sim, info = api.column_similarities(R)
        ref = (A.T @ A) / np.outer(np.linalg.norm(A, axis=0),
                                   np.linalg.norm(A, axis=0))
        np.testing.assert_allclose(np.asarray(sim), ref, atol=1e-5)
        assert info["a_passes"] == 1 and info["plan"] == "gram"
