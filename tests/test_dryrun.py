"""Dry-run plumbing: plan construction, roofline math, HLO collective
parsing; plus a reduced-size end-to-end lower+compile in a subprocess."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import roofline as RL


def test_parse_collectives():
    hlo = """
  %all-reduce.1 = f32[256,512]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  %ag-done = bf16[8,128]{1,0} all-gather-done(%ag)
  %rs = (f32[16]{0}, f32[16]{0}) reduce-scatter(%a, %b)
  %cp = u32[4]{0} collective-permute(%c)
  %dot.5 = f32[64,64]{1,0} dot(%p, %q)
"""
    out = RL.parse_collectives(hlo)
    assert out["all-reduce"]["bytes"] == 256 * 512 * 4
    assert out["all-reduce"]["count"] == 1
    assert out["all-gather"]["bytes"] == 8 * 128 * 2
    assert out["all-gather"]["count"] == 1          # -done not re-counted
    assert out["reduce-scatter"]["bytes"] == 2 * 16 * 4
    assert out["collective-permute"]["bytes"] == 4 * 4
    assert out["total_bytes"] == sum(
        out[k]["bytes"] for k in ("all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute"))


def test_roofline_terms():
    meta = {"mesh": {"data": 16, "model": 16}, "kind": "train",
            "params_active": 1e9, "tokens_per_step": 1e6,
            "argument_bytes": 1e9}
    r = RL.analyze(flops=1e13, hbm=1e11, collective_bytes=1e9, meta=meta)
    assert r.compute_s == pytest.approx(1e13 / RL.PEAK_FLOPS)
    assert r.memory_s == pytest.approx(1e11 / RL.HBM_BW)
    assert r.collective_s == pytest.approx(1e9 / RL.LINK_BW)
    assert r.bound == "memory"
    assert r.model_flops == pytest.approx(6e15)
    assert 0 < r.roofline_fraction <= 1.0


def test_costmodel_linear_solve():
    from repro.launch.costmodel import _solve
    # fixed=5, slope_a=2, slope_b=3
    counts = [{"a": 1, "b": 1}, {"a": 2, "b": 1}, {"a": 1, "b": 2}]
    values = [10.0, 12.0, 13.0]
    est = _solve(counts, values, {"a": 10, "b": 20})
    assert est == pytest.approx(5 + 2 * 10 + 3 * 20)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    from repro.core.distmat.types import make_mesh
    from repro.launch.specs import make_plan
    from repro.launch import roofline as RL
    mesh = make_mesh((4, 4), ("data", "model"))
    # reduced depth/width but the REAL plan machinery end to end
    ov = {"num_layers": 2, "d_model": 256, "num_heads": 8,
          "num_kv_heads": 4, "head_dim": 32, "d_ff": 512,
          "vocab_size": 1024, "scan_unroll": True}
    for shape in ("train_4k", "decode_32k"):
        plan = make_plan("qwen3-4b", shape, mesh, overrides=ov,
                         microbatches=1)
        compiled = plan.lower().compile()
        from repro import compat
        cost = compat.cost_analysis(compiled)
        assert cost.get("flops", 0) > 0
        mem = compiled.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        coll = RL.parse_collectives(compiled.as_text())
        assert coll["total_bytes"] > 0, shape
        print(shape, "MINI_DRYRUN_OK")
""")


def test_mini_dryrun_end_to_end():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("MINI_DRYRUN_OK") == 2
