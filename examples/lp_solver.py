"""Paper §3.2.3 — smoothed linear programming via the SCD formulation.

    PYTHONPATH=src python examples/lp_solver.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.tfocs import solve_smoothed_lp, TfocsOptions

rng = np.random.default_rng(3)
m, n = 20, 60

# LP with a known optimum (constructed via strict complementarity)
xstar = np.zeros(n, np.float32)
xstar[:m // 2] = rng.random(m // 2) + 0.5
A = rng.normal(size=(m, n)).astype(np.float32)
b = A @ xstar
y = rng.normal(size=m).astype(np.float32)
s = np.zeros(n, np.float32)
s[m // 2:] = rng.random(n - m // 2) + 0.1
c = A.T @ y + s


class Op:
    in_shape = (n,)
    out_shape = (m,)
    apply = staticmethod(lambda x: jnp.asarray(A) @ x)
    adjoint = staticmethod(lambda lam: jnp.asarray(A).T @ lam)


x, lam, info = solve_smoothed_lp(
    jnp.asarray(c), Op, jnp.asarray(b), mu=1e-2, continuations=6,
    opts=TfocsOptions(max_iters=600, backtracking=True, restart=True))

kkt = {k: float(v) for k, v in info["kkt"].items()}
print("KKT residuals:", kkt)
print("objective (solver):", kkt["objective"])
print("objective (known) :", float(c @ xstar))
print("max |x - x*|:", float(np.abs(np.asarray(x) - xstar).max()))
